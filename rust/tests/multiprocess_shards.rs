//! Multi-process sharded materialization, end to end: spawn real
//! `repro` worker processes over disjoint row ranges, merge their
//! fragments, and require the merged directory to be bitwise-identical
//! to a single-process materialization — for every proximity kind.
//!
//! The process matrix is parameterizable so CI can pin it per job:
//! `FK_TEST_PROCS` (comma list, default `2,4`) and `FK_TEST_THREADS`
//! (per-process `--threads`, default: even core share via `--procs`).

use forest_kernels::coordinator::shard::{self, ShardReader};
use forest_kernels::sparse::Csr;
use forest_kernels::swlc::ProximityKind;
use std::path::{Path, PathBuf};
use std::process::Command;

const DATASET: &str = "covertype";
const N: &str = "500";
const TREES: &str = "12";
const SEED: &str = "21";
const STRIPE_ROWS: &str = "64";

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fk-mp-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Common dataset/forest flags — every process in a comparison must
/// train the identical forest (deterministic per seed at any thread
/// count, established in `parallel_determinism.rs`).
fn base_flags(method: &str) -> Vec<String> {
    [
        "--dataset", DATASET, "--n", N, "--trees", TREES, "--seed", SEED, "--method", method,
        "--stripe-rows", STRIPE_ROWS,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawning repro");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "repro failed ({:?}):\n{stdout}\n{stderr}", out.status);
    stdout
}

fn assert_bitwise(got: &Csr, want: &Csr, what: &str) {
    assert_eq!(got.indptr, want.indptr, "{what}: row structure differs");
    assert_eq!(got.indices, want.indices, "{what}: column indices differ");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&got.data), bits(&want.data), "{what}: values differ bitwise");
}

/// Single-process spill-to-disk reference for `method`.
fn single_process_reference(method: &str, dir: &Path) -> Csr {
    let mut cmd = repro();
    cmd.arg("materialize").args(base_flags(method)).args([
        "--sink",
        "shards",
        "--out",
        dir.to_str().unwrap(),
    ]);
    run_ok(&mut cmd);
    ShardReader::open(dir).unwrap().read_csr().unwrap()
}

fn proc_counts() -> Vec<usize> {
    match std::env::var("FK_TEST_PROCS") {
        Ok(s) => s.split(',').filter_map(|v| v.trim().parse().ok()).collect(),
        Err(_) => vec![2, 4],
    }
}

fn thread_flags(cmd: &mut Command) {
    if let Ok(t) = std::env::var("FK_TEST_THREADS") {
        cmd.args(["--threads", t.trim()]).args(["--worker-threads", t.trim()]);
    }
}

#[test]
fn multiprocess_merge_is_bitwise_identical_for_every_kind() {
    for kind in ProximityKind::ALL {
        let method = kind.name();
        let ref_dir = tmp(&format!("ref-{method}"));
        let reference = single_process_reference(method, &ref_dir);
        for procs in proc_counts() {
            let dir = tmp(&format!("p{procs}-{method}"));
            let mut cmd = repro();
            cmd.args(["shards", "run"])
                .args(base_flags(method))
                .args(["--procs", &procs.to_string()])
                .args(["--shard-dir", dir.to_str().unwrap()])
                .arg("--verify-full");
            thread_flags(&mut cmd);
            let stdout = run_ok(&mut cmd);
            assert!(
                stdout.contains("bitwise-identical"),
                "P={procs} {method}: parent verify missing:\n{stdout}"
            );
            // Independent check in this process: the merged directory
            // reproduces the single-process spill bit for bit.
            shard::validate_dir(&dir).unwrap();
            let merged = ShardReader::open(&dir).unwrap().read_csr().unwrap();
            assert_bitwise(&merged, &reference, &format!("P={procs} {method}"));
            std::fs::remove_dir_all(&dir).ok();
        }
        std::fs::remove_dir_all(&ref_dir).ok();
    }
}

#[test]
fn rerunning_with_fewer_procs_reuses_the_directory() {
    // Regression: workers only clear their own part, so a rerun with
    // fewer parts must not trip over the previous generation's
    // higher-numbered fragments (`shards run` clears them up front).
    let method = "original";
    let dir = tmp("rerun");
    for procs in [4usize, 2] {
        let mut cmd = repro();
        cmd.args(["shards", "run"])
            .args(base_flags(method))
            .args(["--procs", &procs.to_string()])
            .args(["--shard-dir", dir.to_str().unwrap()])
            .arg("--verify-full");
        let stdout = run_ok(&mut cmd);
        assert!(stdout.contains("bitwise-identical"), "P={procs}:\n{stdout}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crashed_run_fails_cleanly_and_merge_repairs_it() {
    // Simulate a crash between the workers and the merge: run the two
    // worker invocations by hand (what `shards run` would spawn) and
    // stop there — fragments exist, no merged manifest.
    let method = "kerf";
    let dir = tmp("crash");
    let n: usize = N.parse().unwrap();
    let mid = n / 2;
    for (part, (a, b)) in [(0, (0, mid)), (1, (mid, n))] {
        let mut cmd = repro();
        cmd.arg("materialize")
            .args(base_flags(method))
            .args(["--row-range", &format!("{a}..{b}")])
            .args(["--part", &part.to_string()])
            .args(["--shard-dir", dir.to_str().unwrap()])
            .args(["--procs", "2"]);
        run_ok(&mut cmd);
    }
    // Readable? No — but the error names the repair path.
    let err = ShardReader::open(&dir).unwrap_err().to_string();
    assert!(err.contains("shards merge"), "unhelpful error: {err}");
    // `shards validate` (the CLI the operator would reach for) fails too.
    let out = repro()
        .args(["shards", "validate", "--dir", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    // Repair with the CLI merge, then everything reads and validates.
    let stdout = run_ok(repro().args(["shards", "merge", "--dir", dir.to_str().unwrap()]));
    assert!(stdout.contains("merged 2 fragment(s)"), "merge output: {stdout}");
    run_ok(repro().args(["shards", "validate", "--dir", dir.to_str().unwrap()]));
    let merged = ShardReader::open(&dir).unwrap().read_csr().unwrap();
    let ref_dir = tmp("crash-ref");
    let reference = single_process_reference(method, &ref_dir);
    assert_bitwise(&merged, &reference, "repaired dir");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}

#[test]
fn shards_plan_prints_ranges_and_recipe() {
    let stdout = run_ok(repro().args(["shards", "plan"]).args(base_flags("kerf")).args([
        "--procs",
        "3",
        "--shard-dir",
        "demo-shards",
    ]));
    // Three cost-balanced parts covering [0, N) plus a runnable recipe.
    for part in 0..3 {
        assert!(stdout.contains(&format!("--part {part}")), "missing part {part}:\n{stdout}");
    }
    assert!(stdout.contains("0.."), "missing first range:\n{stdout}");
    assert!(stdout.contains(&format!("..{N}")), "missing last range:\n{stdout}");
    assert!(stdout.contains("shards merge --dir demo-shards"), "missing merge step:\n{stdout}");
    assert!(stdout.contains("shards validate --dir demo-shards"), "missing validate:\n{stdout}");
}

#[test]
fn sampled_verify_cross_checks_against_reference() {
    let method = "gap";
    let dir = tmp("verify");
    let mut cmd = repro();
    cmd.args(["shards", "run"])
        .args(base_flags(method))
        .args(["--procs", "2"])
        .args(["--shard-dir", dir.to_str().unwrap()]);
    run_ok(&mut cmd);
    let stdout = run_ok(
        repro()
            .args(["shards", "validate", "--dir", dir.to_str().unwrap(), "--verify"])
            .args(base_flags(method))
            .args(["--sample", "32"]),
    );
    assert!(stdout.contains("32 sampled row(s)"), "verify output: {stdout}");
    assert!(stdout.contains("match the reference bitwise"), "verify output: {stdout}");
    std::fs::remove_dir_all(&dir).ok();
}
