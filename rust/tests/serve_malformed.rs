//! Hammer the serve plane with malformed input over real TCP and
//! assert the replica never dies: every well-framed request gets a
//! structured 4xx/5xx answer, unframeable garbage gets a 400-and-close
//! or a clean disconnect, and afterwards the same server still answers
//! a valid `/predict` and `/healthz` — zero replica deaths, which is
//! the behavioural contract the `no-panic-in-serve` lint rule exists
//! to keep true.

use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::model::{BundleMeta, ModelBundle};
use forest_kernels::serve::{http, ServeConfig, Server};
use forest_kernels::swlc::{ForestKernel, ProximityKind};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

const N: usize = 160;
const D: usize = 5;
const TREES: usize = 12;

fn fixture(seed: u64) -> ModelBundle {
    let data = synth::gaussian_blobs(N, D, 3, 2.2, seed);
    let forest =
        Forest::train(&data, &TrainConfig { n_trees: TREES, seed, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: TREES };
    ModelBundle { forest, kernel, meta, companion: None }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        linger: Duration::from_millis(1),
        embed_dims: 4,
        embed_iters: 20,
        embed_seed: 9,
        ..Default::default()
    }
}

/// Send one raw, possibly non-UTF8 request body with correct HTTP
/// framing and `Connection: close`, then read whatever comes back.
/// Returns the status line's code, or `None` when the server closed
/// the connection without a response (acceptable only for unframeable
/// garbage — the caller decides).
fn raw_request(addr: &SocketAddr, head: &str, body: &[u8]) -> Option<u16> {
    let mut stream = TcpStream::connect(addr).ok()?;
    stream.set_read_timeout(Some(Duration::from_secs(10))).ok()?;
    let mut req = head.as_bytes().to_vec();
    req.extend_from_slice(body);
    stream.write_all(&req).ok()?;
    let mut resp = Vec::new();
    stream.read_to_end(&mut resp).ok()?;
    let text = String::from_utf8_lossy(&resp);
    let status = text.strip_prefix("HTTP/1.1 ")?.get(..3)?.parse::<u16>().ok()?;
    Some(status)
}

fn framed_post(addr: &SocketAddr, path: &str, body: &[u8]) -> Option<u16> {
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: fk\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    raw_request(addr, &head, body)
}

#[test]
fn malformed_bodies_never_kill_a_replica() {
    let server = Server::bind(fixture(1), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    // Bodies that are invalid for *every* POST endpoint: no valid
    // `"x"` (so the query routes reject them) and no loadable
    // `"path"` (so `/admin/reload` does too).
    let shared: &[&str] = &[
        "",
        "not json at all",
        "{",
        "[1, 2, 3",
        "null",
        "{}",
        "{\"x\": 5}",
        "{\"x\": \"strings are not rows\"}",
        "{\"x\": []}",
        "{\"x\": [1.0]}",                   // wrong dims
        "{\"x\": [[1, 2, 3, 4, 5], [1]]}",  // ragged batch
        "{\"x\": [[\"a\", \"b\"]]}",        // non-numeric row
        "{\"x\": [[[1, 2], [3, 4]]]}",      // over-nested
        "{\"row\": 1e9, \"k\": 5}",         // row lookup out of range
        "{\"path\": 42}",
    ];
    // Valid `"x"` but broken endpoint-specific knobs: these would be
    // accepted by a laxer endpoint, so each goes only where it must
    // be rejected.
    let per_endpoint: &[(&str, &str)] = &[
        ("/predict", "{\"x\": [1, 2, 3, 4, 5], \"budget\": \"mystery\"}"),
        ("/predict", "{\"x\": [1, 2, 3, 4, 5], \"budget\": 7}"),
        ("/neighbors", "{\"x\": [1, 2, 3, 4, 5], \"k\": 0}"),
        ("/neighbors", "{\"x\": [1, 2, 3, 4, 5], \"k\": 999999}"),
        ("/neighbors", "{\"x\": [1, 2, 3, 4, 5], \"k\": -3}"),
        ("/neighbors", "{\"x\": [[1, 2, 3, 4, 5], [5, 4, 3, 2, 1]]}"),
        ("/admin/reload", "{\"path\": \"/definitely/not/a/bundle\"}"),
    ];
    let endpoints = ["/predict", "/neighbors", "/embed", "/admin/reload"];
    let mut cases: Vec<(&str, &str)> = Vec::new();
    for path in endpoints {
        for body in shared {
            cases.push((path, body));
        }
    }
    cases.extend_from_slice(per_endpoint);
    for (path, body) in &cases {
        let status = framed_post(&addr, path, body.as_bytes())
            .unwrap_or_else(|| panic!("{path} with body {body:?}: server vanished"));
        assert!(
            (400..600).contains(&status),
            "{path} with body {body:?}: expected an error status, got {status}"
        );
    }

    // Non-UTF8 bytes with honest framing: still a structured error.
    let junk: Vec<u8> = vec![0xFF, 0xFE, 0x80, 0x00, 0xC3, 0x28, 0xF0, 0x9F];
    for path in endpoints {
        let status = framed_post(&addr, path, &junk)
            .unwrap_or_else(|| panic!("{path} with non-UTF8 body: server vanished"));
        assert!((400..600).contains(&status), "{path} non-UTF8: got {status}");
    }

    // Framing-level garbage: lying Content-Length and raw non-HTTP
    // noise. A 400 or a clean close are both fine; a dead server is
    // not — which the recovery probes below establish.
    let lying = "POST /predict HTTP/1.1\r\nHost: fk\r\nContent-Length: nope\r\n\r\n";
    if let Some(status) = raw_request(&addr, lying, b"{}") {
        assert!((400..600).contains(&status), "lying Content-Length: got {status}");
    }
    raw_request(&addr, "\x01\x02\x03 total garbage\r\n\r\n", b"");
    raw_request(&addr, "GET /predict HTTP/1.1\r\n\r\n", b""); // wrong method

    // Recovery probes: the same process still answers correctly.
    let data = synth::gaussian_blobs(N, D, 3, 2.2, 1);
    let mut row = String::from("{\"x\": [");
    for f in 0..D {
        if f > 0 {
            row.push_str(", ");
        }
        row.push_str(&format!("{}", data.x(0, f)));
    }
    row.push_str("]}");
    let (status, resp) = http::http_request(&addr, "POST", "/predict", &row).unwrap();
    assert_eq!(status, 200, "post-hammer /predict failed: {resp}");
    let (status, _) = http::http_request(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(status, 200, "post-hammer /healthz failed");

    handle.stop();
}
