//! The repo's own invariant gate: `fk-lint` over the live `rust/src/`
//! tree must report zero findings. Any regression — a bare `.unwrap()`
//! in the serve plane, an uncommented `unsafe`, a HashMap in a kernel
//! module, a malformed metric registration — fails this test before it
//! ever reaches the CI lint job.

use forest_kernels::analysis::{self, Config, MAX_SUPPRESSIONS};
use std::path::Path;

fn src_root() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"))
}

#[test]
fn live_tree_is_lint_clean() {
    let report = analysis::lint_dir(src_root(), &Config::all()).expect("scan rust/src");
    assert!(
        report.files_scanned > 20,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.clean(),
        "fk-lint found {} violation(s) in the live tree:\n{}",
        report.findings.len(),
        rendered.join("\n")
    );
}

#[test]
fn suppression_budget_is_respected() {
    let report = analysis::lint_dir(src_root(), &Config::all()).expect("scan rust/src");
    assert!(
        report.suppressions_total <= MAX_SUPPRESSIONS,
        "{} suppressions exceed the repo-wide cap of {}",
        report.suppressions_total,
        MAX_SUPPRESSIONS
    );
    // Every annotation in the tree must actually cover a finding; the
    // lint itself reports unused ones, so clean() above already implies
    // this — but assert the accounting explicitly for the day the
    // unused check is relaxed.
    assert!(
        report.suppressions_used <= report.suppressions_total,
        "used {} > total {}",
        report.suppressions_used,
        report.suppressions_total
    );
}

#[test]
fn single_rule_runs_are_supported() {
    for rule in analysis::RULE_IDS {
        let cfg = Config::from_list(rule).expect("known rule id parses");
        let report = analysis::lint_dir(src_root(), &cfg).expect("scan rust/src");
        // Per-rule runs may legitimately flag the suppressions that
        // other rules consume as "unused" only when their rule is
        // enabled, so only the enabled rule (or none) may appear.
        for f in &report.findings {
            assert!(
                f.rule == *rule || f.rule == "suppression",
                "rule {rule} run produced foreign finding: {f}"
            );
        }
    }
}
