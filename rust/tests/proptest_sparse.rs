//! Property tests for the sparse linear-algebra substrate.
//!
//! The offline vendor set has no proptest crate, so these are
//! seeded-random property sweeps driven by the library's own RNG: each
//! property is checked over many randomly generated cases with
//! shrink-free but fully reproducible failures (the seed is in the
//! panic message).

use forest_kernels::rng::Rng;
use forest_kernels::sparse::{scale_cols, scale_rows, spgemm, spgemm_nnz_flops, Csr};

const CASES: u64 = 60;

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
    let mut trip = vec![];
    for r in 0..rows {
        for c in 0..cols {
            if rng.next_f64() < density {
                trip.push((r, c as u32, (rng.next_normal() as f32 * 2.0).round() / 2.0));
            }
        }
    }
    Csr::from_triplets(rows, cols, &trip)
}

fn dense_mul(a: &Csr, b: &Csr) -> Vec<f32> {
    let (m, k, n) = (a.n_rows, a.n_cols, b.n_cols);
    let (da, db) = (a.to_dense(), b.to_dense());
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let v = da[i * k + p];
            if v != 0.0 {
                for j in 0..n {
                    c[i * n + j] += v * db[p * n + j];
                }
            }
        }
    }
    c
}

fn dims(rng: &mut Rng) -> (usize, usize, usize) {
    (1 + rng.gen_range(20), 1 + rng.gen_range(15), 1 + rng.gen_range(20))
}

#[test]
fn prop_spgemm_matches_dense_reference() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let (m, k, n) = dims(&mut rng);
        let (da, db) = (0.05 + rng.next_f64() * 0.5, 0.05 + rng.next_f64() * 0.5);
        let a = random_csr(&mut rng, m, k, da);
        let b = random_csr(&mut rng, k, n, db);
        let c = spgemm(&a, &b);
        c.check().unwrap_or_else(|e| panic!("seed {seed}: invalid CSR: {e}"));
        let exp = dense_mul(&a, &b);
        let got = c.to_dense();
        for (i, (g, e)) in got.iter().zip(&exp).enumerate() {
            assert!((g - e).abs() < 1e-3, "seed {seed} entry {i}: {g} vs {e}");
        }
    }
}

#[test]
fn prop_transpose_involution_and_nnz_preserved() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xAA);
        let (m, _, n) = dims(&mut rng);
        let a = random_csr(&mut rng, m, n, 0.3);
        let t = a.transpose();
        t.check().unwrap();
        assert_eq!(t.nnz(), a.nnz(), "seed {seed}");
        assert_eq!(t.transpose(), a, "seed {seed}");
    }
}

#[test]
fn prop_spmv_linear() {
    // A(αx + y) == αAx + Ay
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xBB);
        let (m, _, n) = dims(&mut rng);
        let a = random_csr(&mut rng, m, n, 0.4);
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
        let alpha = rng.next_normal() as f32;
        let mixed: Vec<f32> = x.iter().zip(&y).map(|(a, b)| alpha * a + b).collect();
        let mut lhs = vec![0f32; m];
        a.spmv(&mixed, &mut lhs);
        let mut ax = vec![0f32; m];
        let mut ay = vec![0f32; m];
        a.spmv(&x, &mut ax);
        a.spmv(&y, &mut ay);
        for i in 0..m {
            let rhs = alpha * ax[i] + ay[i];
            let tol = 1e-2_f32.max(rhs.abs() * 1e-3);
            assert!((lhs[i] - rhs).abs() < tol, "seed {seed}");
        }
    }
}

#[test]
fn prop_spmm_consistent_with_spmv() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed ^ 0xCC);
        let (m, _, n) = dims(&mut rng);
        let k = 1 + rng.gen_range(4);
        let a = random_csr(&mut rng, m, n, 0.35);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_normal() as f32).collect();
        let mut y = vec![0f32; m * k];
        a.spmm(&x, k, &mut y);
        for j in 0..k {
            let col: Vec<f32> = (0..n).map(|c| x[c * k + j]).collect();
            let mut yj = vec![0f32; m];
            a.spmv(&col, &mut yj);
            for i in 0..m {
                assert!((y[i * k + j] - yj[i]).abs() < 1e-3, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_scalings_match_diagonal_products() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xDD);
        let (m, _, n) = dims(&mut rng);
        let a = random_csr(&mut rng, m, n, 0.4);
        let r: Vec<f32> = (0..m).map(|_| rng.next_normal() as f32).collect();
        let c: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
        let mut scaled = a.clone();
        scale_rows(&mut scaled, &r);
        scale_cols(&mut scaled, &c);
        let dense = a.to_dense();
        let got = scaled.to_dense();
        for i in 0..m {
            for j in 0..n {
                let expect = r[i] * dense[i * n + j] * c[j];
                assert!((got[i * n + j] - expect).abs() < 1e-3, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_flops_upper_bounds_output_nnz() {
    // Every output nonzero requires >= 1 accumulate, so nnz(C) <= flops,
    // and the predicted bound min(row flops, n_cols) tightens that.
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xEE);
        let (m, k, n) = dims(&mut rng);
        let a = random_csr(&mut rng, m, k, 0.3);
        let b = random_csr(&mut rng, k, n, 0.3);
        let (flops, nnz_ub) = spgemm_nnz_flops(&a, &b);
        let c = spgemm(&a, &b);
        assert!(nnz_ub <= flops, "seed {seed}: bound {nnz_ub} > flops {flops}");
        assert!(nnz_ub <= (m * n) as u64, "seed {seed}: bound exceeds dense size");
        assert!(c.nnz() as u64 <= nnz_ub, "seed {seed}: nnz {} > bound {nnz_ub}", c.nnz());
    }
}

#[test]
fn prop_gram_products_are_psd() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed ^ 0xFF);
        let (m, _, n) = dims(&mut rng);
        let q = random_csr(&mut rng, m, n, 0.3);
        let p = spgemm(&q, &q.transpose());
        let d = p.to_dense();
        // Random quadratic forms are nonnegative (Cor. 3.7 argument).
        for _ in 0..5 {
            let v: Vec<f32> = (0..m).map(|_| rng.next_normal() as f32).collect();
            let mut quad = 0f64;
            for i in 0..m {
                for j in 0..m {
                    quad += (v[i] * d[i * m + j] * v[j]) as f64;
                }
            }
            assert!(quad > -1e-2, "seed {seed}: quadratic form {quad}");
        }
    }
}

#[test]
fn prop_from_rows_equals_from_triplets() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x11);
        let (m, _, n) = dims(&mut rng);
        let mut trip: Vec<(usize, u32, f32)> = vec![];
        for r in 0..m {
            for _ in 0..rng.gen_range(6) {
                trip.push((r, rng.gen_range(n) as u32, rng.next_normal() as f32));
            }
        }
        let a = Csr::from_triplets(m, n, &trip);
        let b = Csr::from_rows(m, n, 4, |i, push| {
            for &(r, c, v) in &trip {
                if r == i {
                    push(c, v);
                }
            }
        });
        assert_eq!(a.to_dense(), b.to_dense(), "seed {seed}");
    }
}
