//! Integration: the PJRT runtime executes every AOT artifact and the
//! results match Rust-side references. Requires `make artifacts`.
//!
//! These tests are skipped (with a loud message) if artifacts/ is
//! missing, so `cargo test` stays runnable before the python step.

use forest_kernels::coordinator::gallery::GalleryService;
use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::rng::Rng;
use forest_kernels::runtime::{Runtime, Tensor};
use forest_kernels::swlc::{predict, ForestKernel, ProximityKind};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Rust reference of the SWLC tile: P[i,j] = Σ_t q w 1[leaf match].
fn prox_ref(
    leaf_q: &[i32],
    q: &[f32],
    leaf_w: &[i32],
    w: &[f32],
    nq: usize,
    nr: usize,
    t: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; nq * nr];
    for i in 0..nq {
        for j in 0..nr {
            let mut acc = 0f32;
            for tt in 0..t {
                if leaf_q[i * t + tt] == leaf_w[j * t + tt] {
                    acc += q[i * t + tt] * w[j * t + tt];
                }
            }
            out[i * nr + j] = acc;
        }
    }
    out
}

#[test]
fn manifest_lists_all_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let names = rt.names();
    assert!(names.iter().any(|n| n.starts_with("prox_128x128x64")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("power_")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("predict_")), "{names:?}");
}

#[test]
fn prox_block_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let (bq, br, t) = (128, 128, 64);
    let mut rng = Rng::new(1);
    let leaf_q: Vec<i32> = (0..bq * t).map(|_| rng.gen_range(9) as i32).collect();
    let leaf_w: Vec<i32> = (0..br * t).map(|_| rng.gen_range(9) as i32).collect();
    let q: Vec<f32> = (0..bq * t).map(|_| rng.next_f32()).collect();
    let w: Vec<f32> = (0..br * t).map(|_| rng.next_f32()).collect();
    let got = rt.prox_block(bq, br, t, &leaf_q, &q, &leaf_w, &w).expect("execute");
    let expect = prox_ref(&leaf_q, &q, &leaf_w, &w, bq, br, t);
    assert_eq!(got.len(), expect.len());
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() < 1e-4, "{g} vs {e}");
    }
}

#[test]
fn power_step_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let spec = rt.spec("power_256x1024x32").expect("power artifact").clone();
    let (n, l) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let k = spec.inputs[1].shape[1];
    let mut rng = Rng::new(2);
    let a: Vec<f32> = (0..n * l).map(|_| rng.next_normal() as f32 * 0.1).collect();
    let v: Vec<f32> = (0..l * k).map(|_| rng.next_normal() as f32 * 0.1).collect();
    let got = rt.execute("power_256x1024x32", &[Tensor::F32(&a), Tensor::F32(&v)]).unwrap();
    // Reference: A^T (A V).
    let mut av = vec![0f32; n * k];
    for i in 0..n {
        for c in 0..l {
            let x = a[i * l + c];
            if x != 0.0 {
                for j in 0..k {
                    av[i * k + j] += x * v[c * k + j];
                }
            }
        }
    }
    let mut expect = vec![0f32; l * k];
    for i in 0..n {
        for c in 0..l {
            let x = a[i * l + c];
            if x != 0.0 {
                for j in 0..k {
                    expect[c * k + j] += x * av[i * k + j];
                }
            }
        }
    }
    let scale: f32 = expect.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() / scale < 1e-3, "{g} vs {e}");
    }
}

#[test]
fn predict_tile_matches_composition() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let name = "predict_256x256x64x16";
    let spec = rt.spec(name).expect("predict artifact").clone();
    let (bq, t) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let br = spec.inputs[2].shape[0];
    let c = spec.inputs[4].shape[1];
    let mut rng = Rng::new(3);
    let leaf_q: Vec<i32> = (0..bq * t).map(|_| rng.gen_range(7) as i32).collect();
    let leaf_w: Vec<i32> = (0..br * t).map(|_| rng.gen_range(7) as i32).collect();
    let q: Vec<f32> = (0..bq * t).map(|_| rng.next_f32()).collect();
    let w: Vec<f32> = (0..br * t).map(|_| rng.next_f32()).collect();
    let y: Vec<usize> = (0..br).map(|_| rng.gen_range(c)).collect();
    let mut onehot = vec![0f32; br * c];
    for (j, &cls) in y.iter().enumerate() {
        onehot[j * c + cls] = 1.0;
    }
    let got = rt
        .execute(
            name,
            &[
                Tensor::I32(&leaf_q),
                Tensor::F32(&q),
                Tensor::I32(&leaf_w),
                Tensor::F32(&w),
                Tensor::F32(&onehot),
            ],
        )
        .unwrap();
    // Reference: (prox tile) @ onehot.
    let p = prox_ref(&leaf_q, &q, &leaf_w, &w, bq, br, t);
    for i in 0..bq {
        for cls in 0..c {
            let mut e = 0f32;
            for j in 0..br {
                if y[j] == cls {
                    e += p[i * br + j];
                }
            }
            let g = got[i * c + cls];
            assert!((g - e).abs() < 1e-2 * e.abs().max(1.0), "({i},{cls}): {g} vs {e}");
        }
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let bad = vec![0f32; 7];
    assert!(rt.execute("prox_128x128x64", &[Tensor::F32(&bad)]).is_err());
    let leaf = vec![0i32; 128 * 64];
    let wts = vec![0f32; 128 * 64];
    // dtype mismatch on input 0:
    assert!(rt
        .execute(
            "prox_128x128x64",
            &[Tensor::F32(&wts), Tensor::F32(&wts), Tensor::I32(&leaf), Tensor::F32(&wts)]
        )
        .is_err());
}

#[test]
fn gallery_service_end_to_end_matches_sparse_path() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let data = synth::gaussian_blobs(600, 5, 3, 2.5, 7);
    let (train, test) = data.train_test_split(0.1, 8);
    let forest = Forest::train(&train, &TrainConfig { n_trees: 20, seed: 9, ..Default::default() });

    // Dense XLA path.
    let gal = GalleryService::new(&rt, &forest, &train, ProximityKind::RfGap).unwrap();
    let scores = gal.score(&forest, &test).unwrap();
    let dense_preds = gal.vote(&scores, test.n);

    // Sparse Rust path.
    let kernel = ForestKernel::fit(&forest, &train, ProximityKind::RfGap);
    let qn = kernel.oos_query_map(&forest, &test);
    let cross = kernel.cross_proximity(&qn).to_dense();
    for i in 0..test.n {
        for j in 0..train.n {
            let (a, b) = (scores[i * train.n + j], cross[i * train.n + j]);
            assert!((a - b).abs() < 1e-4, "({i},{j}): xla={a} sparse={b}");
        }
    }
    let sparse_preds = predict::predict_oos(&kernel, &qn);
    let agree = dense_preds
        .iter()
        .zip(&sparse_preds)
        .filter(|(a, b)| a == b)
        .count();
    // Identical scores ⇒ identical argmax up to fp ties.
    assert!(agree as f64 / test.n as f64 > 0.98, "agree={agree}/{}", test.n);
}
