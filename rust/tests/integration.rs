//! Cross-module integration tests: the full train → kernel → embed →
//! predict pipeline, the coordinator, and the experiment harnesses.

use forest_kernels::coordinator::{self, CoordinatorConfig};
use forest_kernels::data::registry;
use forest_kernels::experiments::{fig41, measure_kernel_cost};
use forest_kernels::forest::{Forest, ForestKind, TrainConfig};
use forest_kernels::spectral::pca::leaf_pca;
use forest_kernels::swlc::{predict, ForestKernel, ProximityKind};

#[test]
fn full_pipeline_on_covertype_analog() {
    let spec = registry::by_name("covertype").unwrap();
    let data = spec.generate(3_000, 1);
    let (train, test) = data.train_test_split(0.15, 2);
    let forest = Forest::train(&train, &TrainConfig { n_trees: 30, seed: 3, ..Default::default() });
    let forest_acc = forest.accuracy(&test);
    assert!(forest_acc > 0.5, "forest acc {forest_acc}");

    // Kernel + prediction beats chance and tracks the forest.
    let kernel = ForestKernel::fit(&forest, &train, ProximityKind::RfGap);
    let qn = kernel.oos_query_map(&forest, &test);
    let preds = predict::predict_oos(&kernel, &qn);
    let acc = predict::accuracy(&preds, &test.y);
    assert!(acc > forest_acc - 0.05, "kernel acc {acc} vs forest {forest_acc}");

    // Leaf-PCA embedding separates classes better than chance (silhouette
    // proxy: 1-NN accuracy on the training embedding itself).
    let (scores, vals) = leaf_pca(&kernel.q, 8, 8, false, 4);
    assert!(vals[0] > 0.0);
    let emb2: Vec<f32> = (0..train.n).flat_map(|i| [scores[i * 8], scores[i * 8 + 1]]).collect();
    let self_acc = forest_kernels::spectral::knn_accuracy(
        &emb2, &train.y, &emb2, &train.y, 2, 5, train.n_classes,
    );
    assert!(self_acc > 1.5 / train.n_classes as f64, "self knn acc {self_acc}");
}

#[test]
fn coordinator_and_direct_product_agree_at_scale() {
    let spec = registry::by_name("pbmc").unwrap();
    let data = spec.generate(2_000, 5);
    let forest = Forest::train(&data, &TrainConfig { n_trees: 20, seed: 6, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let direct = kernel.proximity_matrix();
    let (coord, metrics) = coordinator::materialize_to_csr(
        &kernel,
        &CoordinatorConfig { stripe_rows: 256, n_workers: 3, queue_depth: 2 },
    );
    assert_eq!(direct.nnz(), coord.nnz());
    assert_eq!(direct.indices, coord.indices);
    let max_err = direct
        .data
        .iter()
        .zip(&coord.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-6);
    let (jobs, _, _) = metrics.snapshot();
    assert_eq!(jobs, 2_000usize.div_ceil(256) as u64);
}

#[test]
fn kernel_cost_accounting_is_consistent() {
    let spec = registry::by_name("airlines").unwrap();
    let data = spec.generate(4_000, 7);
    let forest = Forest::train(&data, &TrainConfig { n_trees: 24, seed: 8, ..Default::default() });
    let cost = measure_kernel_cost(&forest, &data, ProximityKind::RfGap);
    assert_eq!(cost.n, 4_000);
    assert!(cost.secs_total() > 0.0);
    assert!(cost.lambda >= 1.0);
    assert!(cost.nnz > 0);
    // flops bound: at least nnz accumulates, at most dense N²T.
    assert!(cost.flops >= cost.nnz as u64);
    assert!(cost.flops <= (4_000u64 * 4_000 * 24));
}

#[test]
fn fig41_harness_shapes() {
    let rows = fig41::run(600, &[0.5, 1.0], &[40, 80], 3);
    assert_eq!(rows.len(), 4);
    for r in &rows {
        assert!(r.mean > 0.5 && r.mean < 1.1, "{}", r.mean);
        assert!(r.std >= 0.0);
        assert!(r.limit < 1.0);
    }
}

#[test]
fn all_forest_kinds_support_their_kernels() {
    let spec = registry::by_name("tvnews").unwrap(); // binary → GBT ok
    let data = spec.generate(800, 9);
    for (fk, kinds) in [
        (
            ForestKind::RandomForest,
            vec![
                ProximityKind::Original,
                ProximityKind::Kerf,
                ProximityKind::OobSeparable,
                ProximityKind::RfGap,
                ProximityKind::InstanceHardness,
            ],
        ),
        (ForestKind::ExtraTrees, vec![ProximityKind::Original, ProximityKind::Kerf]),
        (ForestKind::GradientBoosting, vec![ProximityKind::Boosted, ProximityKind::Kerf]),
    ] {
        let cfg = TrainConfig {
            kind: fk,
            n_trees: 10,
            criterion: if fk == ForestKind::GradientBoosting {
                forest_kernels::forest::Criterion::Mse
            } else {
                forest_kernels::forest::Criterion::Gini
            },
            max_depth: if fk == ForestKind::GradientBoosting { Some(4) } else { None },
            seed: 10,
            ..Default::default()
        };
        let forest = Forest::train(&data, &cfg);
        for kind in kinds {
            let k = ForestKernel::fit(&forest, &data, kind);
            let p = k.proximity_matrix();
            assert!(p.nnz() > 0, "{fk:?}/{kind:?} produced empty kernel");
        }
    }
}
