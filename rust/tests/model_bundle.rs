//! Model-bundle persistence: save → load must round-trip the forest,
//! the context, and the SWLC factors **bitwise** for every supported
//! `ForestKind` × `ProximityKind` combination, and every downstream
//! computation (kernel product, training prediction, OOS prediction)
//! must agree exactly between the fitted and the loaded model — on
//! both load paths: the verified heap decode and the zero-copy
//! fk-bundle-v3 mmap bind.

use forest_kernels::data::synth;
use forest_kernels::forest::{Criterion, Forest, ForestKind, TrainConfig};
use forest_kernels::model::{mmap, save, save_legacy_v2, BundleMeta, MmapMode, ModelBundle};
use forest_kernels::swlc::{predict, ForestKernel, ProximityKind};
use std::path::PathBuf;

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fk-bundle-e2e-{tag}-{}.fkb", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Proximity kinds a forest of this kind supports: the OOB-querying
/// schemes need bootstrap bookkeeping, which only RandomForest has.
fn kinds_for(fk: ForestKind) -> Vec<ProximityKind> {
    match fk {
        ForestKind::RandomForest => ProximityKind::ALL.to_vec(),
        _ => vec![
            ProximityKind::Original,
            ProximityKind::Kerf,
            ProximityKind::InstanceHardness,
            ProximityKind::Boosted,
        ],
    }
}

fn train(fk: ForestKind, seed: u64) -> (Forest, forest_kernels::Dataset) {
    // GBT is binary logistic, so give it two classes.
    let n_classes = if fk == ForestKind::GradientBoosting { 2 } else { 3 };
    let data = synth::gaussian_blobs(130, 4, n_classes, 2.2, seed);
    let cfg = TrainConfig {
        kind: fk,
        n_trees: 9,
        seed,
        max_depth: if fk == ForestKind::GradientBoosting { Some(4) } else { None },
        criterion: if fk == ForestKind::GradientBoosting {
            Criterion::Mse
        } else {
            Criterion::Gini
        },
        ..Default::default()
    };
    (Forest::train(&data, &cfg), data)
}

fn assert_csr_bitwise(
    got: &forest_kernels::Csr,
    want: &forest_kernels::Csr,
    what: &str,
) {
    assert_eq!(got.n_rows, want.n_rows, "{what}: n_rows");
    assert_eq!(got.n_cols, want.n_cols, "{what}: n_cols");
    assert_eq!(got.indptr, want.indptr, "{what}: indptr");
    assert_eq!(got.indices, want.indices, "{what}: indices");
    assert_eq!(bits(&got.data), bits(&want.data), "{what}: values");
}

fn roundtrip_one(fk: ForestKind, kind: ProximityKind, seed: u64) {
    let tag = format!("{fk:?}-{}", kind.name());
    let (forest, data) = train(fk, seed);
    let kernel = ForestKernel::fit(&forest, &data, kind);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: 9 };
    let path = tmpfile(&tag);
    save(&path, &forest, &kernel, &meta).unwrap();
    let loaded = ModelBundle::load(&path).unwrap();
    // The zero-copy bind must return bitwise the same model (the
    // mapping outlives the unlink below — Unix keeps the inode alive).
    let (mapped, map_mode) = ModelBundle::load_with_mode(&path, MmapMode::Auto).unwrap();
    std::fs::remove_file(&path).ok();
    if mmap::supported() {
        assert_eq!(map_mode, "mmap", "{tag}: auto should map a v3 bundle");
    } else {
        assert_eq!(map_mode, "heap", "{tag}: auto should fall back off-unix");
    }

    // Forest round-trips exactly (Tree/Node derive PartialEq; leaf
    // statistics are f32 payloads compared as raw bits).
    assert_eq!(loaded.forest.trees.len(), forest.trees.len(), "{tag}: tree count");
    for (a, b) in loaded.forest.trees.iter().zip(&forest.trees) {
        assert_eq!(a.nodes, b.nodes, "{tag}: nodes");
        assert_eq!(a.n_leaves, b.n_leaves, "{tag}: n_leaves");
        assert_eq!(a.depth, b.depth, "{tag}: depth");
        assert_eq!(bits(&a.leaf_stats), bits(&b.leaf_stats), "{tag}: leaf_stats");
    }
    assert_eq!(loaded.forest.leaf_offsets, forest.leaf_offsets, "{tag}: leaf_offsets");
    assert_eq!(loaded.forest.inbag, forest.inbag, "{tag}: inbag");
    assert_eq!(
        bits(&loaded.forest.tree_weights),
        bits(&forest.tree_weights),
        "{tag}: tree_weights"
    );
    assert_eq!(loaded.forest.n_classes, forest.n_classes, "{tag}: n_classes");
    assert_eq!(
        loaded.forest.init_score.to_bits(),
        forest.init_score.to_bits(),
        "{tag}: init_score"
    );
    assert_eq!(loaded.forest.binner.n_bins, forest.binner.n_bins, "{tag}: n_bins");
    assert_eq!(loaded.forest.binner.edges.len(), forest.binner.edges.len(), "{tag}: edges");
    for (a, b) in loaded.forest.binner.edges.iter().zip(&forest.binner.edges) {
        assert_eq!(bits(a), bits(b), "{tag}: bin edges");
    }

    // Context round-trips exactly.
    assert_eq!(loaded.kernel.ctx.n, kernel.ctx.n, "{tag}: ctx.n");
    assert_eq!(loaded.kernel.ctx.t, kernel.ctx.t, "{tag}: ctx.t");
    assert_eq!(loaded.kernel.ctx.l, kernel.ctx.l, "{tag}: ctx.l");
    assert_eq!(loaded.kernel.ctx.leaf_of, kernel.ctx.leaf_of, "{tag}: leaf_of");
    assert_eq!(bits(&loaded.kernel.ctx.leaf_mass), bits(&kernel.ctx.leaf_mass), "{tag}");
    assert_eq!(bits(&loaded.kernel.ctx.inbag_mass), bits(&kernel.ctx.inbag_mass), "{tag}");
    assert_eq!(loaded.kernel.ctx.inbag_count, kernel.ctx.inbag_count, "{tag}");
    assert_eq!(loaded.kernel.ctx.oob_count, kernel.ctx.oob_count, "{tag}");
    assert_eq!(loaded.kernel.ctx.y, kernel.ctx.y, "{tag}: y");
    assert_eq!(loaded.kernel.ctx.n_classes, kernel.ctx.n_classes, "{tag}");

    // Factors, cached transpose, and the full kernel product are
    // bitwise-identical.
    assert_eq!(loaded.kernel.symmetric, kernel.symmetric, "{tag}: symmetric");
    assert_csr_bitwise(&loaded.kernel.q, &kernel.q, &format!("{tag}: Q"));
    assert_csr_bitwise(&loaded.kernel.w, &kernel.w, &format!("{tag}: W"));
    assert_csr_bitwise(
        loaded.kernel.w_transpose(),
        kernel.w_transpose(),
        &format!("{tag}: Wt"),
    );
    assert_csr_bitwise(
        &loaded.kernel.proximity_matrix(),
        &kernel.proximity_matrix(),
        &format!("{tag}: P"),
    );

    // Predictions agree exactly: training rows and fresh OOS queries
    // routed through the loaded forest.
    assert_eq!(predict::predict_train(&loaded.kernel), predict::predict_train(&kernel), "{tag}");
    let queries = synth::gaussian_blobs(40, 4, kernel.ctx.n_classes, 2.2, seed ^ 0xBEEF);
    let qn_orig = kernel.oos_query_map(&forest, &queries);
    let qn_load = loaded.kernel.oos_query_map(&loaded.forest, &queries);
    assert_csr_bitwise(&qn_load, &qn_orig, &format!("{tag}: Q_new"));
    assert_eq!(
        predict::predict_oos(&loaded.kernel, &qn_load),
        predict::predict_oos(&kernel, &qn_orig),
        "{tag}: OOS predictions"
    );

    // The mapped bundle agrees bitwise with the heap decode, and
    // SpGEMM/prediction run directly on the borrowed sections.
    assert_csr_bitwise(&mapped.kernel.q, &loaded.kernel.q, &format!("{tag}: mmap Q"));
    assert_csr_bitwise(&mapped.kernel.w, &loaded.kernel.w, &format!("{tag}: mmap W"));
    assert_csr_bitwise(
        mapped.kernel.w_transpose(),
        loaded.kernel.w_transpose(),
        &format!("{tag}: mmap Wt"),
    );
    assert_eq!(mapped.kernel.ctx.leaf_of, loaded.kernel.ctx.leaf_of, "{tag}: mmap leaf_of");
    assert_eq!(
        bits(&mapped.kernel.ctx.leaf_mass),
        bits(&loaded.kernel.ctx.leaf_mass),
        "{tag}: mmap leaf_mass"
    );
    assert_csr_bitwise(
        &mapped.kernel.proximity_matrix(),
        &kernel.proximity_matrix(),
        &format!("{tag}: mmap P"),
    );
    assert_eq!(
        predict::predict_train(&mapped.kernel),
        predict::predict_train(&kernel),
        "{tag}: mmap training predictions"
    );
    let qn_map = mapped.kernel.oos_query_map(&mapped.forest, &queries);
    assert_csr_bitwise(&qn_map, &qn_orig, &format!("{tag}: mmap Q_new"));
    assert_eq!(
        predict::predict_oos(&mapped.kernel, &qn_map),
        predict::predict_oos(&kernel, &qn_orig),
        "{tag}: mmap OOS predictions"
    );
}

#[test]
fn random_forest_bundles_roundtrip_bitwise_for_all_kinds() {
    for (i, kind) in kinds_for(ForestKind::RandomForest).into_iter().enumerate() {
        roundtrip_one(ForestKind::RandomForest, kind, 100 + i as u64);
    }
}

#[test]
fn extratrees_bundles_roundtrip_bitwise() {
    for (i, kind) in kinds_for(ForestKind::ExtraTrees).into_iter().enumerate() {
        roundtrip_one(ForestKind::ExtraTrees, kind, 200 + i as u64);
    }
}

#[test]
fn gbt_bundles_roundtrip_bitwise() {
    for (i, kind) in kinds_for(ForestKind::GradientBoosting).into_iter().enumerate() {
        roundtrip_one(ForestKind::GradientBoosting, kind, 300 + i as u64);
    }
}

/// Quantized bundles (v3 form 1) across a forest-kind × proximity-kind
/// × mode grid: the mode, the stored quantized `Q`, **and** the stored
/// quantized `Wᵀ` round-trip bitwise (v3 persists `Wᵀ` verbatim — no
/// re-quantization on load), the exact slots hold `Q`'s
/// dequantization, and the verified heap decode and the mmap bind
/// agree bitwise on the full product and on OOS predictions. (The
/// fitted-vs-loaded *exact* slots are not compared against the fitted
/// exact factors: a quantized bundle is lossy by design.)
#[test]
fn quantized_bundles_roundtrip_for_kind_grid() {
    use forest_kernels::sparse::qcsr::QuantMode;
    let grid = [
        (ForestKind::RandomForest, ProximityKind::Kerf, QuantMode::Int8),
        (ForestKind::RandomForest, ProximityKind::RfGap, QuantMode::Int8),
        (ForestKind::RandomForest, ProximityKind::OobSeparable, QuantMode::Int4),
        (ForestKind::ExtraTrees, ProximityKind::Original, QuantMode::Int4),
        (ForestKind::GradientBoosting, ProximityKind::Boosted, QuantMode::Int8),
    ];
    for (i, &(fk, kind, mode)) in grid.iter().enumerate() {
        let seed = 400 + i as u64;
        let tag = format!("{fk:?}-{}-{mode:?}", kind.name());
        let (forest, data) = train(fk, seed);
        let mut kernel = ForestKernel::fit(&forest, &data, kind);
        kernel.set_quantization(Some(mode));
        let qf_orig_q = kernel.quantized().expect("mode attached").q.clone();
        let qf_orig_wt = kernel.quantized().expect("mode attached").wt.clone();
        let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: 9 };
        let path = tmpfile(&format!("quant-{tag}"));
        save(&path, &forest, &kernel, &meta).unwrap();
        let a = ModelBundle::load(&path).unwrap();
        // The second load takes the zero-copy path where supported, so
        // every cross-load assertion below is also a heap-vs-mmap one.
        let (b, _) = ModelBundle::load_with_mode(&path, MmapMode::Auto).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(a.kernel.quantization(), Some(mode), "{tag}: mode lost");
        let qf_load = a.kernel.quantized().expect("loaded bundle keeps quantized Q");
        assert_eq!(qf_load.q, qf_orig_q, "{tag}: stored quantized Q differs");
        assert_eq!(qf_load.wt, qf_orig_wt, "{tag}: stored quantized Wt differs");
        assert_csr_bitwise(&a.kernel.q, &qf_orig_q.dequantize(), &format!("{tag}: Q slot"));
        if kernel.symmetric {
            assert_csr_bitwise(&a.kernel.w, &a.kernel.q, &format!("{tag}: symmetric W"));
        }
        assert_csr_bitwise(
            &a.kernel.proximity_matrix(),
            &b.kernel.proximity_matrix(),
            &format!("{tag}: P across loads"),
        );
        let queries = synth::gaussian_blobs(30, 4, kernel.ctx.n_classes, 2.2, seed ^ 0xACE);
        let qn_a = a.kernel.oos_query_map(&a.forest, &queries);
        let qn_b = b.kernel.oos_query_map(&b.forest, &queries);
        assert_csr_bitwise(&qn_b, &qn_a, &format!("{tag}: Q_new"));
        assert_eq!(
            predict::predict_oos(&a.kernel, &qn_a),
            predict::predict_oos(&b.kernel, &qn_b),
            "{tag}: OOS predictions across loads"
        );
    }
}

/// A symmetric quantized bundle re-saves **byte-identical**: the loader
/// keeps the stored quantized `Q` verbatim and symmetric kernels store
/// no `W`, so save → load → save is a fixed point of the file bytes.
#[test]
fn symmetric_quantized_bundle_resaves_byte_identical() {
    use forest_kernels::sparse::qcsr::QuantMode;
    let (forest, data) = train(ForestKind::RandomForest, 88);
    let mut kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    assert!(kernel.symmetric, "kerf kernel should be symmetric");
    kernel.set_quantization(Some(QuantMode::Int8));
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 88, trees: 9 };
    let p1 = tmpfile("qfix-1");
    let p2 = tmpfile("qfix-2");
    save(&p1, &forest, &kernel, &meta).unwrap();
    let loaded = ModelBundle::load(&p1).unwrap();
    save(&p2, &loaded.forest, &loaded.kernel, &loaded.meta).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    assert_eq!(b1, b2, "re-saved quantized bundle bytes differ");
}

/// Truncation *inside* the aligned section region must fail cleanly in
/// **both** load modes even when the header's payload length is fixed
/// up to match the shortened file — the structured-region checksum does
/// not cover section bytes, so the per-entry bounds validation is the
/// last line of defense (it is all the mmap path gets: the zero-copy
/// bind never reads the section payloads at load time).
#[test]
fn section_truncation_fails_structurally_past_the_checksum() {
    use forest_kernels::sparse::qcsr::QuantMode;
    const HEADER: usize = 28;
    let (forest, data) = train(ForestKind::RandomForest, 99);
    let mut kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    kernel.set_quantization(Some(QuantMode::Int8));
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 99, trees: 9 };
    let path = tmpfile("qtrunc");
    save(&path, &forest, &kernel, &meta).unwrap();
    let full = std::fs::read(&path).unwrap();
    for cut in [1usize, 3, 8, 64, 512, 2048] {
        if HEADER + cut >= full.len() {
            continue;
        }
        let mut bytes = full[..full.len() - cut].to_vec();
        // Fix the payload length so only the section table can object;
        // the checksum (bytes 20..28) covers the structured region,
        // which is untouched, so it still verifies.
        let plen = (bytes.len() - HEADER) as u64;
        bytes[12..20].copy_from_slice(&plen.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        for mode in [MmapMode::Off, MmapMode::On] {
            if mode == MmapMode::On && !mmap::supported() {
                continue;
            }
            let err = ModelBundle::load_with_mode(&path, mode).unwrap_err().to_string();
            assert!(
                err.contains("out of bounds"),
                "cut {cut} ({}): expected a section-bounds error, got: {err}",
                mode.name()
            );
            assert!(
                !err.contains("checksum mismatch"),
                "cut {cut} ({}): structural validation should fire first: {err}",
                mode.name()
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

/// v2 (and the v1 files it subsumes) keep loading through the verified
/// heap fallback, bitwise-identical to a v3 save of the same model —
/// and `--mmap on` refuses them instead of silently copying.
#[test]
fn legacy_v2_bundles_heap_load_bitwise_identical_to_v3() {
    let (forest, data) = train(ForestKind::RandomForest, 55);
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::RfGap);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 55, trees: 9 };
    let p2 = tmpfile("legacy-v2");
    let p3 = tmpfile("current-v3");
    save_legacy_v2(&p2, &forest, &kernel, &meta).unwrap();
    save(&p3, &forest, &kernel, &meta).unwrap();
    let (old, old_mode) = ModelBundle::load_with_mode(&p2, MmapMode::Auto).unwrap();
    assert_eq!(old_mode, "heap", "a v2 file must take the heap fallback even under auto");
    let err = ModelBundle::load_with_mode(&p2, MmapMode::On).unwrap_err().to_string();
    assert!(err.contains("v3"), "--mmap on should name the v3 requirement, got: {err}");
    let new = ModelBundle::load(&p3).unwrap();
    std::fs::remove_file(&p2).ok();
    std::fs::remove_file(&p3).ok();

    assert_csr_bitwise(&old.kernel.q, &new.kernel.q, "legacy Q");
    assert_csr_bitwise(&old.kernel.w, &new.kernel.w, "legacy W");
    assert_csr_bitwise(old.kernel.w_transpose(), new.kernel.w_transpose(), "legacy Wt");
    assert_eq!(old.kernel.ctx.leaf_of, new.kernel.ctx.leaf_of, "legacy leaf_of");
    assert_eq!(bits(&old.kernel.ctx.leaf_mass), bits(&new.kernel.ctx.leaf_mass), "legacy mass");
    assert_eq!(old.meta.dataset, new.meta.dataset, "legacy meta");
    assert_eq!(
        predict::predict_train(&old.kernel),
        predict::predict_train(&new.kernel),
        "legacy training predictions"
    );
}

#[test]
fn loaded_bundle_needs_no_dataset() {
    // The whole point of the bundle: everything (context, labels,
    // factors) comes off disk — simulate a fresh process that only has
    // the file and a query stream.
    let (forest, data) = train(ForestKind::RandomForest, 7);
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::RfGap);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 7, trees: 9 };
    let path = tmpfile("no-dataset");
    save(&path, &forest, &kernel, &meta).unwrap();
    drop((forest, kernel, data));

    let b = ModelBundle::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(b.meta.dataset, "blobs");
    let queries = synth::gaussian_blobs(25, 4, 3, 2.2, 99);
    let qn = b.kernel.oos_query_map(&b.forest, &queries);
    let preds = predict::predict_oos(&b.kernel, &qn);
    assert_eq!(preds.len(), 25);
    assert!(preds.iter().all(|&p| p < 3));
}
