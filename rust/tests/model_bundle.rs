//! Model-bundle persistence: save → load must round-trip the forest,
//! the context, and the SWLC factors **bitwise** for every supported
//! `ForestKind` × `ProximityKind` combination, and every downstream
//! computation (kernel product, training prediction, OOS prediction)
//! must agree exactly between the fitted and the loaded model.

use forest_kernels::data::synth;
use forest_kernels::forest::{Criterion, Forest, ForestKind, TrainConfig};
use forest_kernels::model::{save, BundleMeta, ModelBundle};
use forest_kernels::swlc::{predict, ForestKernel, ProximityKind};
use std::path::PathBuf;

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fk-bundle-e2e-{tag}-{}.fkb", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Proximity kinds a forest of this kind supports: the OOB-querying
/// schemes need bootstrap bookkeeping, which only RandomForest has.
fn kinds_for(fk: ForestKind) -> Vec<ProximityKind> {
    match fk {
        ForestKind::RandomForest => ProximityKind::ALL.to_vec(),
        _ => vec![
            ProximityKind::Original,
            ProximityKind::Kerf,
            ProximityKind::InstanceHardness,
            ProximityKind::Boosted,
        ],
    }
}

fn train(fk: ForestKind, seed: u64) -> (Forest, forest_kernels::Dataset) {
    // GBT is binary logistic, so give it two classes.
    let n_classes = if fk == ForestKind::GradientBoosting { 2 } else { 3 };
    let data = synth::gaussian_blobs(130, 4, n_classes, 2.2, seed);
    let cfg = TrainConfig {
        kind: fk,
        n_trees: 9,
        seed,
        max_depth: if fk == ForestKind::GradientBoosting { Some(4) } else { None },
        criterion: if fk == ForestKind::GradientBoosting {
            Criterion::Mse
        } else {
            Criterion::Gini
        },
        ..Default::default()
    };
    (Forest::train(&data, &cfg), data)
}

fn assert_csr_bitwise(
    got: &forest_kernels::Csr,
    want: &forest_kernels::Csr,
    what: &str,
) {
    assert_eq!(got.n_rows, want.n_rows, "{what}: n_rows");
    assert_eq!(got.n_cols, want.n_cols, "{what}: n_cols");
    assert_eq!(got.indptr, want.indptr, "{what}: indptr");
    assert_eq!(got.indices, want.indices, "{what}: indices");
    assert_eq!(bits(&got.data), bits(&want.data), "{what}: values");
}

fn roundtrip_one(fk: ForestKind, kind: ProximityKind, seed: u64) {
    let tag = format!("{fk:?}-{}", kind.name());
    let (forest, data) = train(fk, seed);
    let kernel = ForestKernel::fit(&forest, &data, kind);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: 9 };
    let path = tmpfile(&tag);
    save(&path, &forest, &kernel, &meta).unwrap();
    let loaded = ModelBundle::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // Forest round-trips exactly (Tree/Node derive PartialEq; leaf
    // statistics are f32 payloads compared as raw bits).
    assert_eq!(loaded.forest.trees.len(), forest.trees.len(), "{tag}: tree count");
    for (a, b) in loaded.forest.trees.iter().zip(&forest.trees) {
        assert_eq!(a.nodes, b.nodes, "{tag}: nodes");
        assert_eq!(a.n_leaves, b.n_leaves, "{tag}: n_leaves");
        assert_eq!(a.depth, b.depth, "{tag}: depth");
        assert_eq!(bits(&a.leaf_stats), bits(&b.leaf_stats), "{tag}: leaf_stats");
    }
    assert_eq!(loaded.forest.leaf_offsets, forest.leaf_offsets, "{tag}: leaf_offsets");
    assert_eq!(loaded.forest.inbag, forest.inbag, "{tag}: inbag");
    assert_eq!(
        bits(&loaded.forest.tree_weights),
        bits(&forest.tree_weights),
        "{tag}: tree_weights"
    );
    assert_eq!(loaded.forest.n_classes, forest.n_classes, "{tag}: n_classes");
    assert_eq!(
        loaded.forest.init_score.to_bits(),
        forest.init_score.to_bits(),
        "{tag}: init_score"
    );
    assert_eq!(loaded.forest.binner.n_bins, forest.binner.n_bins, "{tag}: n_bins");
    assert_eq!(loaded.forest.binner.edges.len(), forest.binner.edges.len(), "{tag}: edges");
    for (a, b) in loaded.forest.binner.edges.iter().zip(&forest.binner.edges) {
        assert_eq!(bits(a), bits(b), "{tag}: bin edges");
    }

    // Context round-trips exactly.
    assert_eq!(loaded.kernel.ctx.n, kernel.ctx.n, "{tag}: ctx.n");
    assert_eq!(loaded.kernel.ctx.t, kernel.ctx.t, "{tag}: ctx.t");
    assert_eq!(loaded.kernel.ctx.l, kernel.ctx.l, "{tag}: ctx.l");
    assert_eq!(loaded.kernel.ctx.leaf_of, kernel.ctx.leaf_of, "{tag}: leaf_of");
    assert_eq!(bits(&loaded.kernel.ctx.leaf_mass), bits(&kernel.ctx.leaf_mass), "{tag}");
    assert_eq!(bits(&loaded.kernel.ctx.inbag_mass), bits(&kernel.ctx.inbag_mass), "{tag}");
    assert_eq!(loaded.kernel.ctx.inbag_count, kernel.ctx.inbag_count, "{tag}");
    assert_eq!(loaded.kernel.ctx.oob_count, kernel.ctx.oob_count, "{tag}");
    assert_eq!(loaded.kernel.ctx.y, kernel.ctx.y, "{tag}: y");
    assert_eq!(loaded.kernel.ctx.n_classes, kernel.ctx.n_classes, "{tag}");

    // Factors, cached transpose, and the full kernel product are
    // bitwise-identical.
    assert_eq!(loaded.kernel.symmetric, kernel.symmetric, "{tag}: symmetric");
    assert_csr_bitwise(&loaded.kernel.q, &kernel.q, &format!("{tag}: Q"));
    assert_csr_bitwise(&loaded.kernel.w, &kernel.w, &format!("{tag}: W"));
    assert_csr_bitwise(
        loaded.kernel.w_transpose(),
        kernel.w_transpose(),
        &format!("{tag}: Wt"),
    );
    assert_csr_bitwise(
        &loaded.kernel.proximity_matrix(),
        &kernel.proximity_matrix(),
        &format!("{tag}: P"),
    );

    // Predictions agree exactly: training rows and fresh OOS queries
    // routed through the loaded forest.
    assert_eq!(predict::predict_train(&loaded.kernel), predict::predict_train(&kernel), "{tag}");
    let queries = synth::gaussian_blobs(40, 4, kernel.ctx.n_classes, 2.2, seed ^ 0xBEEF);
    let qn_orig = kernel.oos_query_map(&forest, &queries);
    let qn_load = loaded.kernel.oos_query_map(&loaded.forest, &queries);
    assert_csr_bitwise(&qn_load, &qn_orig, &format!("{tag}: Q_new"));
    assert_eq!(
        predict::predict_oos(&loaded.kernel, &qn_load),
        predict::predict_oos(&kernel, &qn_orig),
        "{tag}: OOS predictions"
    );
}

#[test]
fn random_forest_bundles_roundtrip_bitwise_for_all_kinds() {
    for (i, kind) in kinds_for(ForestKind::RandomForest).into_iter().enumerate() {
        roundtrip_one(ForestKind::RandomForest, kind, 100 + i as u64);
    }
}

#[test]
fn extratrees_bundles_roundtrip_bitwise() {
    for (i, kind) in kinds_for(ForestKind::ExtraTrees).into_iter().enumerate() {
        roundtrip_one(ForestKind::ExtraTrees, kind, 200 + i as u64);
    }
}

#[test]
fn gbt_bundles_roundtrip_bitwise() {
    for (i, kind) in kinds_for(ForestKind::GradientBoosting).into_iter().enumerate() {
        roundtrip_one(ForestKind::GradientBoosting, kind, 300 + i as u64);
    }
}

/// Quantized bundles (v2 form 1) across a forest-kind × proximity-kind
/// × mode grid: the mode and the stored quantized `Q` round-trip
/// bitwise, the exact slots hold its dequantization, and two
/// independent loads agree bitwise on the full product and on OOS
/// predictions. (The fitted-vs-loaded product is *not* asserted: a
/// quantized bundle is lossy by design, and the loaded kernel's `Wᵀ` is
/// re-quantized from the dequantized factors.)
#[test]
fn quantized_bundles_roundtrip_for_kind_grid() {
    use forest_kernels::sparse::qcsr::QuantMode;
    let grid = [
        (ForestKind::RandomForest, ProximityKind::Kerf, QuantMode::Int8),
        (ForestKind::RandomForest, ProximityKind::RfGap, QuantMode::Int8),
        (ForestKind::RandomForest, ProximityKind::OobSeparable, QuantMode::Int4),
        (ForestKind::ExtraTrees, ProximityKind::Original, QuantMode::Int4),
        (ForestKind::GradientBoosting, ProximityKind::Boosted, QuantMode::Int8),
    ];
    for (i, &(fk, kind, mode)) in grid.iter().enumerate() {
        let seed = 400 + i as u64;
        let tag = format!("{fk:?}-{}-{mode:?}", kind.name());
        let (forest, data) = train(fk, seed);
        let mut kernel = ForestKernel::fit(&forest, &data, kind);
        kernel.set_quantization(Some(mode));
        let qf_orig = kernel.quantized().expect("mode attached").q.clone();
        let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: 9 };
        let path = tmpfile(&format!("quant-{tag}"));
        save(&path, &forest, &kernel, &meta).unwrap();
        let a = ModelBundle::load(&path).unwrap();
        let b = ModelBundle::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(a.kernel.quantization(), Some(mode), "{tag}: mode lost");
        let qf_load = a.kernel.quantized().expect("loaded bundle keeps quantized Q");
        assert_eq!(qf_load.q, qf_orig, "{tag}: stored quantized Q differs");
        assert_csr_bitwise(&a.kernel.q, &qf_orig.dequantize(), &format!("{tag}: Q slot"));
        if kernel.symmetric {
            assert_csr_bitwise(&a.kernel.w, &a.kernel.q, &format!("{tag}: symmetric W"));
        }
        assert_csr_bitwise(
            &a.kernel.proximity_matrix(),
            &b.kernel.proximity_matrix(),
            &format!("{tag}: P across loads"),
        );
        let queries = synth::gaussian_blobs(30, 4, kernel.ctx.n_classes, 2.2, seed ^ 0xACE);
        let qn_a = a.kernel.oos_query_map(&a.forest, &queries);
        let qn_b = b.kernel.oos_query_map(&b.forest, &queries);
        assert_csr_bitwise(&qn_b, &qn_a, &format!("{tag}: Q_new"));
        assert_eq!(
            predict::predict_oos(&a.kernel, &qn_a),
            predict::predict_oos(&b.kernel, &qn_b),
            "{tag}: OOS predictions across loads"
        );
    }
}

/// A symmetric quantized bundle re-saves **byte-identical**: the loader
/// keeps the stored quantized `Q` verbatim and symmetric kernels store
/// no `W`, so save → load → save is a fixed point of the file bytes.
#[test]
fn symmetric_quantized_bundle_resaves_byte_identical() {
    use forest_kernels::sparse::qcsr::QuantMode;
    let (forest, data) = train(ForestKind::RandomForest, 88);
    let mut kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    assert!(kernel.symmetric, "kerf kernel should be symmetric");
    kernel.set_quantization(Some(QuantMode::Int8));
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 88, trees: 9 };
    let p1 = tmpfile("qfix-1");
    let p2 = tmpfile("qfix-2");
    save(&p1, &forest, &kernel, &meta).unwrap();
    let loaded = ModelBundle::load(&p1).unwrap();
    save(&p2, &loaded.forest, &loaded.kernel, &loaded.meta).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    assert_eq!(b1, b2, "re-saved quantized bundle bytes differ");
}

/// Truncation *inside* the quantized factor section must fail cleanly
/// even when the header (payload length + FNV checksum) is fixed up to
/// match the shortened payload — the structural validation in the QCsr
/// decoder is the last line of defense, not the checksum.
#[test]
fn quantized_section_truncation_fails_cleanly_past_the_checksum() {
    use forest_kernels::coordinator::shard::fnv1a64;
    use forest_kernels::sparse::qcsr::QuantMode;
    const HEADER: usize = 28;
    let (forest, data) = train(ForestKind::RandomForest, 99);
    let mut kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    kernel.set_quantization(Some(QuantMode::Int8));
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 99, trees: 9 };
    let path = tmpfile("qtrunc");
    save(&path, &forest, &kernel, &meta).unwrap();
    let full = std::fs::read(&path).unwrap();
    for cut in [1usize, 3, 8, 64, 512, 2048] {
        if HEADER + cut >= full.len() {
            continue;
        }
        let payload = &full[HEADER..full.len() - cut];
        let mut bytes = Vec::with_capacity(HEADER + payload.len());
        bytes.extend_from_slice(&full[..12]); // magic + version
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&fnv1a64(payload).to_le_bytes());
        bytes.extend_from_slice(payload);
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelBundle::load(&path).unwrap_err().to_string();
        assert!(
            !err.contains("checksum mismatch"),
            "cut {cut}: expected a structural error, got checksum: {err}"
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn loaded_bundle_needs_no_dataset() {
    // The whole point of the bundle: everything (context, labels,
    // factors) comes off disk — simulate a fresh process that only has
    // the file and a query stream.
    let (forest, data) = train(ForestKind::RandomForest, 7);
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::RfGap);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 7, trees: 9 };
    let path = tmpfile("no-dataset");
    save(&path, &forest, &kernel, &meta).unwrap();
    drop((forest, kernel, data));

    let b = ModelBundle::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(b.meta.dataset, "blobs");
    let queries = synth::gaussian_blobs(25, 4, 3, 2.2, 99);
    let qn = b.kernel.oos_query_map(&b.forest, &queries);
    let preds = predict::predict_oos(&b.kernel, &qn);
    assert_eq!(preds.len(), 25);
    assert!(preds.iter().all(|&p| p < 3));
}
