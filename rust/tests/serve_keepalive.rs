//! Keep-alive transport + replica routing, end to end over real TCP.
//!
//! The invariant under test is the serving contract extended to the
//! new transport: answers must be **byte-identical** whether they
//! travel over N one-shot connections, N sequential requests on one
//! keep-alive connection, two requests coalesced into a single TCP
//! segment (the carried-buffer regression), or through the replica
//! router — and row-mode `/neighbors` lookups must land on the
//! row-range-owning replica.

use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::model::{BundleMeta, ModelBundle};
use forest_kernels::runtime::json::Json;
use forest_kernels::serve::http::{self, ConnReader, HttpClient};
use forest_kernels::serve::router::{Router, RouterConfig};
use forest_kernels::serve::{ServeConfig, Server};
use forest_kernels::swlc::{ForestKernel, ProximityKind};
use forest_kernels::Dataset;
use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

const N: usize = 160;
const D: usize = 5;
const C: usize = 3;
const TREES: usize = 12;

/// Deterministic model fixture (same recipe as `serve_http.rs`): two
/// calls with one seed give bitwise-identical bundles, so replicas
/// built this way really are copies of one model.
fn fixture(seed: u64) -> ModelBundle {
    let data = synth::gaussian_blobs(N, D, C, 2.2, seed);
    let forest =
        Forest::train(&data, &TrainConfig { n_trees: TREES, seed, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: TREES };
    ModelBundle { forest, kernel, meta, companion: None }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        linger: Duration::from_millis(1),
        embed_dims: 4,
        embed_iters: 20,
        embed_seed: 9,
        ..Default::default()
    }
}

fn row_json(data: &Dataset, i: usize) -> String {
    let mut s = String::from("[");
    for f in 0..data.d {
        if f > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("{}", data.x(i, f)));
    }
    s.push(']');
    s
}

fn predict_bodies(seed: u64, count: usize) -> Vec<String> {
    let queries = synth::gaussian_blobs(count, D, C, 2.2, seed);
    (0..count).map(|i| format!("{{\"x\": {}}}", row_json(&queries, i))).collect()
}

#[test]
fn keepalive_sequence_matches_one_shot_connections_bitwise() {
    let server = Server::bind(fixture(11), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    let bodies = predict_bodies(4242, 10);
    // Baseline: one connection per request.
    let want: Vec<(u16, String)> = bodies
        .iter()
        .map(|b| http::http_request(&addr, "POST", "/predict", b).unwrap())
        .collect();
    // Same sequence over ONE persistent connection.
    let mut client = HttpClient::new(addr);
    for (body, want) in bodies.iter().zip(&want) {
        let got = client.request("POST", "/predict", body).unwrap();
        assert_eq!(&got, want, "keep-alive answer differs from one-shot");
    }
    // The server accepted 10 one-shot connections + 1 keep-alive one;
    // /stats over the same live connection must see exactly 11.
    let (status, stats) = client.request("GET", "/stats", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&stats).unwrap();
    assert_eq!(
        j.get("connections").and_then(Json::as_usize),
        Some(11),
        "keep-alive client must reuse its connection: {stats}"
    );
    assert_eq!(
        j.get("requests").and_then(|r| r.get("predict")).and_then(Json::as_usize),
        Some(20)
    );
    handle.stop();
}

#[test]
fn two_requests_in_one_tcp_segment_are_both_answered() {
    let server = Server::bind(fixture(12), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    let bodies = predict_bodies(777, 2);
    let want: Vec<(u16, String)> = bodies
        .iter()
        .map(|b| http::http_request(&addr, "POST", "/predict", b).unwrap())
        .collect();

    // Serialize both requests into ONE write so the server's first
    // read almost certainly carries request 2's head past request 1's
    // Content-Length — the bytes the old transport silently discarded.
    let render = |body: &str, last: bool| {
        format!(
            "POST /predict HTTP/1.1\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: {}\r\n\r\n{body}",
            body.len(),
            if last { "close" } else { "keep-alive" },
        )
    };
    let wire = format!("{}{}", render(&bodies[0], false), render(&bodies[1], true));
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    stream.write_all(wire.as_bytes()).unwrap();
    stream.flush().unwrap();

    let mut reader = ConnReader::new();
    let (s1, b1, keep1) = http::read_response(&mut stream, &mut reader).unwrap();
    assert_eq!((s1, &b1), (want[0].0, &want[0].1), "pipelined request 1");
    assert!(keep1);
    let (s2, b2, keep2) = http::read_response(&mut stream, &mut reader).unwrap();
    assert_eq!((s2, &b2), (want[1].0, &want[1].1), "pipelined request 2");
    assert!(!keep2);
    handle.stop();
}

#[test]
fn mixed_keepalive_and_close_clients_agree() {
    let server = Server::bind(fixture(13), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    let bodies = predict_bodies(31337, 8);
    let want: Vec<(u16, String)> = bodies
        .iter()
        .map(|b| http::http_request(&addr, "POST", "/predict", b).unwrap())
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..2 {
            // Keep-alive clients: one connection for the whole sweep.
            scope.spawn(|| {
                let mut client = HttpClient::new(addr);
                for (body, want) in bodies.iter().zip(&want) {
                    let got = client.request("POST", "/predict", body).unwrap();
                    assert_eq!(&got, want, "keep-alive client diverged");
                }
            });
            // Close clients: a fresh connection per request, racing the
            // keep-alive ones through the same micro-batcher.
            scope.spawn(|| {
                for (body, want) in bodies.iter().zip(&want) {
                    let got = http::http_request(&addr, "POST", "/predict", body).unwrap();
                    assert_eq!(&got, want, "close client diverged");
                }
            });
        }
    });
    handle.stop();
}

#[test]
fn method_mismatch_is_405_and_unknown_path_stays_404() {
    let server = Server::bind(fixture(14), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    for (method, path) in [
        ("GET", "/predict"),
        ("GET", "/embed"),
        ("GET", "/neighbors"),
        ("POST", "/healthz"),
        ("POST", "/stats"),
    ] {
        let (status, body) = http::http_request(&addr, method, path, "").unwrap();
        assert_eq!(status, 405, "{method} {path}: {body}");
        assert!(body.contains("\"allow\""), "{method} {path}: {body}");
    }
    let (status, _) = http::http_request(&addr, "GET", "/nope", "").unwrap();
    assert_eq!(status, 404);
    let (status, _) = http::http_request(&addr, "DELETE", "/predict", "").unwrap();
    assert_eq!(status, 405);

    // The reason phrase must match the status (the old handler wrote
    // "Not Found" for every non-200): check the raw status line.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(b"GET /predict HTTP/1.1\r\nContent-Length: 0\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut raw = Vec::new();
    std::io::Read::read_to_end(&mut stream, &mut raw).unwrap();
    let head = String::from_utf8_lossy(&raw);
    assert!(
        head.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"),
        "bad status line: {}",
        head.lines().next().unwrap_or("")
    );
    handle.stop();
}

#[test]
fn malformed_requests_reach_the_latency_reservoir() {
    let server = Server::bind(fixture(15), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    // A request line with no path fails in read_request — before this
    // fix, the 400 was sent without ever starting the latency clock.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"BADREQUEST\r\n\r\n").unwrap();
    let mut reader = ConnReader::new();
    let (status, _, keep) = http::read_response(&mut stream, &mut reader).unwrap();
    assert_eq!(status, 400);
    assert!(!keep, "a desynchronized connection must close");
    drop(stream);

    let (status, stats) = http::http_request(&addr, "GET", "/stats", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&stats).unwrap();
    assert!(j.get("errors").and_then(Json::as_usize).unwrap() >= 1, "{stats}");
    let samples = j
        .get("latency_secs")
        .and_then(|l| l.get("samples"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(samples >= 1, "the early-400 path must record latency: {stats}");
    handle.stop();
}

#[test]
fn router_is_bitwise_transparent_and_pins_row_owners() {
    // Two replicas of one model (bitwise-identical fixtures).
    let backend_a = Server::bind(fixture(16), None, serve_cfg()).unwrap();
    let backend_b = Server::bind(fixture(16), None, serve_cfg()).unwrap();
    let addr_a = backend_a.addr();
    let addr_b = backend_b.addr();
    let h_a = backend_a.spawn();
    let h_b = backend_b.spawn();
    let router = Router::bind(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![addr_a.to_string(), addr_b.to_string()],
    })
    .unwrap();
    let raddr = router.addr();
    let rh = router.spawn();

    let mut client = HttpClient::new(raddr);

    // Router identity: its own healthz names the fleet.
    let (status, health) = client.request("GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&health).unwrap();
    assert_eq!(j.get("role").and_then(Json::as_str), Some("router"));
    assert_eq!(j.get("n").and_then(Json::as_usize), Some(N));
    assert_eq!(j.get("backends").and_then(Json::as_arr).map(<[Json]>::len), Some(2));

    // OOS endpoints through the router == direct backend answers
    // (replicas are bitwise copies, so either backend is a valid
    // reference).
    for body in &predict_bodies(999, 6) {
        let direct = http::http_request(&addr_a, "POST", "/predict", body).unwrap();
        let routed = client.request("POST", "/predict", body).unwrap();
        assert_eq!(routed, direct, "routed /predict differs from direct");
    }
    let queries = synth::gaussian_blobs(3, D, C, 2.2, 555);
    for i in 0..queries.n {
        let body = format!("{{\"x\": {}}}", row_json(&queries, i));
        let direct = http::http_request(&addr_b, "POST", "/embed", &body).unwrap();
        let routed = client.request("POST", "/embed", &body).unwrap();
        assert_eq!(routed, direct, "routed /embed differs from direct");
    }

    // Row-mode /neighbors: rows [0, 80) belong to backend A, rows
    // [80, 160) to backend B. Three lookups in A's range, two in B's.
    let low_rows = [0usize, 5, 79];
    let high_rows = [80usize, 159];
    for &row in low_rows.iter().chain(&high_rows) {
        let body = format!("{{\"row\": {row}, \"k\": 5}}");
        let direct = http::http_request(&addr_a, "POST", "/neighbors", &body).unwrap();
        let routed = client.request("POST", "/neighbors", &body).unwrap();
        assert_eq!(routed, direct, "routed row {row} differs from direct");
    }
    // Ownership is observable in the backends' own counters: only the
    // row-range owner saw its lookups (OOS traffic above never touched
    // /neighbors).
    let stats_of = |addr| {
        let (s, body) = http::http_request(addr, "GET", "/stats", "").unwrap();
        assert_eq!(s, 200);
        Json::parse(&body).unwrap()
    };
    let neighbors_count = |j: &Json| {
        j.get("requests").and_then(|r| r.get("neighbors")).and_then(Json::as_usize).unwrap()
    };
    // Backend A also answered the direct reference lookups for ALL
    // five rows; the ROUTED copies split 3 / 2 by ownership.
    assert_eq!(neighbors_count(&stats_of(&addr_a)), 5 + low_rows.len());
    assert_eq!(neighbors_count(&stats_of(&addr_b)), high_rows.len());

    // Merged /stats: totals sum the fleet, per-backend docs ride along.
    let (status, merged) = client.request("GET", "/stats", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&merged).unwrap();
    assert_eq!(j.get("role").and_then(Json::as_str), Some("router"));
    let totals = j.get("totals").unwrap();
    assert_eq!(
        totals.get("requests").and_then(|r| r.get("neighbors")).and_then(Json::as_usize),
        Some(5 + low_rows.len() + high_rows.len())
    );
    assert_eq!(j.get("backends").and_then(Json::as_arr).map(<[Json]>::len), Some(2));

    // Unroutable requests answer identically through the router.
    let direct = http::http_request(&addr_a, "GET", "/predict", "").unwrap();
    let routed = client.request("GET", "/predict", "").unwrap();
    assert_eq!(routed, direct, "405 body must match the backend's");
    let direct = http::http_request(&addr_a, "GET", "/nope", "").unwrap();
    let routed = client.request("GET", "/nope", "").unwrap();
    assert_eq!(routed, direct, "404 body must match the backend's");

    rh.stop();
    h_a.stop();
    h_b.stop();
}

#[test]
fn router_bind_health_checks_every_backend() {
    let backend = Server::bind(fixture(17), None, serve_cfg()).unwrap();
    let addr = backend.addr();
    let handle = backend.spawn();
    // A port with no listener (bind-then-drop reserves a dead addr).
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let err = Router::bind(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![addr.to_string(), dead.to_string()],
    });
    assert!(err.is_err(), "a dead backend must fail the bind health check");
    handle.stop();
}
