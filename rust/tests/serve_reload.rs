//! Hot bundle swap, end to end over real TCP: `POST /admin/reload`
//! atomically swaps in a freshly loaded bundle while queries are in
//! flight — **zero requests fail**, generations increment monotonically,
//! every response names the generation that answered it, and the swap
//! changes no payload byte when the file is unchanged (leaf-PCA and the
//! factors are deterministic). The replica router's reload is rolling:
//! every backend reloads exactly once, and routed answers stay
//! byte-identical to direct ones. The mmap and heap binds must also be
//! byte-identical to each other on every endpoint.

use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::model::{mmap, BundleMeta, MmapMode, ModelBundle};
use forest_kernels::runtime::json::Json;
use forest_kernels::serve::http::{self, HttpClient};
use forest_kernels::serve::router::{Router, RouterConfig};
use forest_kernels::serve::{ServeConfig, Server};
use forest_kernels::swlc::{ForestKernel, ProximityKind};
use forest_kernels::Dataset;
use std::path::PathBuf;
use std::time::Duration;

const N: usize = 140;
const D: usize = 5;
const C: usize = 3;
const TREES: usize = 10;

fn fixture(seed: u64) -> ModelBundle {
    let data = synth::gaussian_blobs(N, D, C, 2.2, seed);
    let forest =
        Forest::train(&data, &TrainConfig { n_trees: TREES, seed, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: TREES };
    ModelBundle { forest, kernel, meta, companion: None }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        linger: Duration::from_millis(1),
        embed_dims: 4,
        embed_iters: 20,
        embed_seed: 9,
        ..Default::default()
    }
}

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fk-reload-e2e-{tag}-{}.fkb", std::process::id()))
}

fn row_json(data: &Dataset, i: usize) -> String {
    let mut s = String::from("[");
    for f in 0..data.d {
        if f > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("{}", data.x(i, f)));
    }
    s.push(']');
    s
}

fn predict_bodies(seed: u64, count: usize) -> Vec<String> {
    let queries = synth::gaussian_blobs(count, D, C, 2.2, seed);
    (0..count).map(|i| format!("{{\"x\": {}}}", row_json(&queries, i))).collect()
}

/// Split a response into (body with the generation digits removed, the
/// generation): payloads can then be compared bitwise *across*
/// generations of an unchanged model file.
fn split_gen(body: &str) -> (String, u64) {
    let key = "\"model_generation\": ";
    let i = body.rfind(key).unwrap_or_else(|| panic!("no model_generation in: {body}"));
    let start = i + key.len();
    let end = body[start..]
        .find(|c: char| !c.is_ascii_digit())
        .map_or(body.len(), |e| start + e);
    let gen = body[start..end].parse().expect("generation is a number");
    (format!("{}{}", &body[..start], &body[end..]), gen)
}

/// Bind a server that serves `path`, loaded under `mode`.
fn bind_from_file(path: &PathBuf, mode: MmapMode) -> Server {
    let (bundle, load_mode) = ModelBundle::load_with_mode(path, mode).unwrap();
    Server::bind_with_source(bundle, None, serve_cfg(), Some((path.clone(), mode)), load_mode)
        .unwrap()
}

#[test]
fn reload_increments_the_generation_and_changes_no_payload_byte() {
    let path = tmpfile("basic");
    fixture(21).save(&path).unwrap();
    let server = bind_from_file(&path, MmapMode::Auto);
    let addr = server.addr();
    let handle = server.spawn();

    // Generation 1 is visible everywhere before any reload.
    let (status, health) = http::http_request(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&health).unwrap();
    assert_eq!(j.get("model_generation").and_then(Json::as_usize), Some(1), "{health}");
    assert!(health.contains("\"reloadable\": true"), "{health}");
    let want_mode = if mmap::supported() { "mmap" } else { "heap" };
    assert_eq!(j.get("load_mode").and_then(Json::as_str), Some(want_mode), "{health}");

    let bodies = predict_bodies(333, 4);
    let before: Vec<(String, u64)> = bodies
        .iter()
        .map(|b| {
            let (s, body) = http::http_request(&addr, "POST", "/predict", b).unwrap();
            assert_eq!(s, 200, "{body}");
            split_gen(&body)
        })
        .collect();
    assert!(before.iter().all(|&(_, g)| g == 1), "pre-reload answers carry generation 1");

    // Swap. Same file bytes -> same model -> same payloads, new tag.
    let (status, out) = http::http_request(&addr, "POST", "/admin/reload", "").unwrap();
    assert_eq!(status, 200, "{out}");
    let j = Json::parse(&out).unwrap();
    assert_eq!(j.get("status").and_then(Json::as_str), Some("reloaded"), "{out}");
    assert_eq!(j.get("model_generation").and_then(Json::as_usize), Some(2), "{out}");

    for (body, (stripped, _)) in bodies.iter().zip(&before) {
        let (s, got) = http::http_request(&addr, "POST", "/predict", body).unwrap();
        assert_eq!(s, 200);
        let (got_stripped, got_gen) = split_gen(&got);
        assert_eq!(got_gen, 2, "post-reload answers carry the new generation");
        assert_eq!(&got_stripped, stripped, "an unchanged file must answer bitwise the same");
    }
    // /embed and /neighbors carry the generation too.
    let q = format!("{{\"x\": {}}}", row_json(&synth::gaussian_blobs(1, D, C, 2.2, 7), 0));
    let (_, e) = http::http_request(&addr, "POST", "/embed", &q).unwrap();
    assert_eq!(split_gen(&e).1, 2, "{e}");
    let (_, nb) = http::http_request(&addr, "POST", "/neighbors", "{\"row\": 3, \"k\": 5}").unwrap();
    assert_eq!(split_gen(&nb).1, 2, "{nb}");
    let (_, stats) = http::http_request(&addr, "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats).unwrap();
    assert_eq!(j.get("model_generation").and_then(Json::as_usize), Some(2), "{stats}");

    handle.stop();
    std::fs::remove_file(&path).ok();
}

/// The headline invariant: hammer `/predict` from several client
/// threads (keep-alive and one-shot) while the main thread re-saves the
/// bundle and reloads repeatedly — **every** request must succeed with
/// a payload bitwise equal to the reference, and the generations
/// observed must climb from 1 to 1 + reloads with nothing dropped.
#[test]
fn queries_never_fail_across_hot_swaps() {
    use std::sync::atomic::{AtomicBool, Ordering};
    let path = tmpfile("hammer");
    let model = fixture(22);
    model.save(&path).unwrap();
    let server = bind_from_file(&path, MmapMode::Auto);
    let addr = server.addr();
    let handle = server.spawn();

    let bodies = predict_bodies(909, 6);
    let reference: Vec<String> = bodies
        .iter()
        .map(|b| {
            let (s, body) = http::http_request(&addr, "POST", "/predict", b).unwrap();
            assert_eq!(s, 200, "{body}");
            split_gen(&body).0
        })
        .collect();

    const RELOADS: u64 = 5;
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for t in 0..3usize {
            let done = &done;
            let bodies = &bodies;
            let reference = &reference;
            scope.spawn(move || {
                let mut client = (t % 2 == 0).then(|| HttpClient::new(addr));
                let mut max_gen = 0u64;
                let mut i = 0usize;
                while !done.load(Ordering::Relaxed) {
                    let b = &bodies[i % bodies.len()];
                    let out = match client.as_mut() {
                        Some(cl) => cl.request("POST", "/predict", b),
                        None => http::http_request(&addr, "POST", "/predict", b),
                    };
                    let (status, body) = out.expect("a query failed during a hot swap");
                    assert_eq!(status, 200, "failed during swap: {body}");
                    let (stripped, gen) = split_gen(&body);
                    assert_eq!(&stripped, &reference[i % bodies.len()], "payload changed");
                    assert!(gen >= max_gen, "generation went backwards: {max_gen} -> {gen}");
                    assert!(gen <= 1 + RELOADS, "generation overshot: {gen}");
                    max_gen = gen;
                    i += 1;
                }
            });
        }
        // The swapper: re-save the same model (atomic rename over the
        // live mapping) and reload, RELOADS times.
        for r in 0..RELOADS {
            model.save(&path).unwrap();
            let (status, out) = http::http_request(&addr, "POST", "/admin/reload", "").unwrap();
            assert_eq!(status, 200, "reload {r}: {out}");
            std::thread::sleep(Duration::from_millis(10));
        }
        done.store(true, Ordering::Relaxed);
    });

    let (_, health) = http::http_request(&addr, "GET", "/healthz", "").unwrap();
    let j = Json::parse(&health).unwrap();
    assert_eq!(
        j.get("model_generation").and_then(Json::as_usize),
        Some(1 + RELOADS as usize),
        "{health}"
    );
    handle.stop();
    std::fs::remove_file(&path).ok();
}

#[test]
fn reload_without_a_model_source_is_400_and_shape_changes_are_rejected() {
    // No --model: the server was fitted in-process, nothing to reload.
    let server = Server::bind(fixture(23), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();
    let (status, body) = http::http_request(&addr, "POST", "/admin/reload", "").unwrap();
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("--model"), "{body}");
    handle.stop();

    // A reload that changes the model shape must be refused and the
    // old snapshot must keep serving.
    let path = tmpfile("shape");
    fixture(24).save(&path).unwrap();
    let server = bind_from_file(&path, MmapMode::Auto);
    let addr = server.addr();
    let handle = server.spawn();
    let probes = predict_bodies(11, 1);
    let probe = &probes[0];
    let (_, before) = http::http_request(&addr, "POST", "/predict", probe).unwrap();

    // Overwrite with a model of a different N (atomic, like a bad deploy).
    let data = synth::gaussian_blobs(N / 2, D, C, 2.2, 77);
    let forest =
        Forest::train(&data, &TrainConfig { n_trees: TREES, seed: 77, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed: 77, trees: TREES };
    ModelBundle { forest, kernel, meta, companion: None }.save(&path).unwrap();

    let (status, out) = http::http_request(&addr, "POST", "/admin/reload", "").unwrap();
    assert_eq!(status, 400, "{out}");
    assert!(out.contains("incompatibly"), "{out}");
    let (status, after) = http::http_request(&addr, "POST", "/predict", probe).unwrap();
    assert_eq!(status, 200);
    assert_eq!(after, before, "a refused reload must leave the old model serving");
    let (_, health) = http::http_request(&addr, "GET", "/healthz", "").unwrap();
    let j = Json::parse(&health).unwrap();
    assert_eq!(j.get("model_generation").and_then(Json::as_usize), Some(1), "{health}");
    handle.stop();
    std::fs::remove_file(&path).ok();
}

#[test]
fn router_reload_rolls_the_fleet_and_stays_bitwise_transparent() {
    let path = tmpfile("fleet");
    fixture(25).save(&path).unwrap();
    let backend_a = bind_from_file(&path, MmapMode::Auto);
    let backend_b = bind_from_file(&path, MmapMode::Auto);
    let addr_a = backend_a.addr();
    let addr_b = backend_b.addr();
    let h_a = backend_a.spawn();
    let h_b = backend_b.spawn();
    let router = Router::bind(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![addr_a.to_string(), addr_b.to_string()],
    })
    .unwrap();
    let raddr = router.addr();
    let rh = router.spawn();
    let mut client = HttpClient::new(raddr);

    let (status, out) = client.request("POST", "/admin/reload", "").unwrap();
    assert_eq!(status, 200, "{out}");
    let j = Json::parse(&out).unwrap();
    assert_eq!(j.get("role").and_then(Json::as_str), Some("router"), "{out}");
    let per_backend = j.get("reload").and_then(Json::as_arr).unwrap();
    assert_eq!(per_backend.len(), 2, "{out}");
    for (i, entry) in per_backend.iter().enumerate() {
        assert_eq!(entry.get("status").and_then(Json::as_usize), Some(200), "backend {i}: {out}");
        assert_eq!(
            entry.get("response").and_then(|r| r.get("model_generation")).and_then(Json::as_usize),
            Some(2),
            "backend {i} did not reach generation 2: {out}"
        );
    }
    // Every backend really swapped — and the fleet keeps answering
    // byte-identically to a direct backend hit.
    for addr in [&addr_a, &addr_b] {
        let (_, health) = http::http_request(addr, "GET", "/healthz", "").unwrap();
        let j = Json::parse(&health).unwrap();
        assert_eq!(j.get("model_generation").and_then(Json::as_usize), Some(2), "{health}");
    }
    for body in &predict_bodies(444, 4) {
        let direct = http::http_request(&addr_a, "POST", "/predict", body).unwrap();
        let routed = client.request("POST", "/predict", body).unwrap();
        assert_eq!(routed, direct, "routed bytes differ from direct after the rolling reload");
    }

    rh.stop();
    h_a.stop();
    h_b.stop();
    std::fs::remove_file(&path).ok();
}

/// A v3 bundle served `--mmap on` answers every endpoint byte-for-byte
/// like the fully verified heap decode of the same file.
#[test]
fn mmap_and_heap_servers_answer_bitwise_identically() {
    if !mmap::supported() {
        return;
    }
    let path = tmpfile("modes");
    fixture(26).save(&path).unwrap();
    let heap = bind_from_file(&path, MmapMode::Off);
    let mapped = bind_from_file(&path, MmapMode::On);
    let addr_h = heap.addr();
    let addr_m = mapped.addr();
    let hh = heap.spawn();
    let hm = mapped.spawn();

    for body in &predict_bodies(555, 5) {
        let h = http::http_request(&addr_h, "POST", "/predict", body).unwrap();
        let m = http::http_request(&addr_m, "POST", "/predict", body).unwrap();
        assert_eq!(m, h, "/predict differs between mmap and heap");
    }
    let q = format!("{{\"x\": {}}}", row_json(&synth::gaussian_blobs(2, D, C, 2.2, 5), 1));
    let h = http::http_request(&addr_h, "POST", "/embed", &q).unwrap();
    let m = http::http_request(&addr_m, "POST", "/embed", &q).unwrap();
    assert_eq!(m, h, "/embed differs between mmap and heap");
    for body in [q.as_str(), "{\"row\": 9, \"k\": 7}"] {
        let h = http::http_request(&addr_h, "POST", "/neighbors", body).unwrap();
        let m = http::http_request(&addr_m, "POST", "/neighbors", body).unwrap();
        assert_eq!(m, h, "/neighbors differs between mmap and heap");
    }
    // The two servers disagree only on how the model is resident.
    let (_, h) = http::http_request(&addr_h, "GET", "/healthz", "").unwrap();
    let (_, m) = http::http_request(&addr_m, "GET", "/healthz", "").unwrap();
    assert_eq!(Json::parse(&h).unwrap().get("load_mode").and_then(Json::as_str), Some("heap"));
    assert_eq!(Json::parse(&m).unwrap().get("load_mode").and_then(Json::as_str), Some("mmap"));
    assert_eq!(
        m.replace("\"load_mode\": \"mmap\"", "\"load_mode\": \"heap\""),
        h,
        "healthz differs beyond load_mode"
    );

    hh.stop();
    hm.stop();
    std::fs::remove_file(&path).ok();
}
