//! End-to-end serving: spawn the HTTP server on an ephemeral port,
//! talk to it over real TCP, and assert the served answers are
//! **bitwise-identical** to the in-process batch paths — `/predict`
//! against `predict_oos` + `cross_proximity`/`scores_from_kernel`,
//! `/neighbors` against `knn_from_kernel` (both from factors and from
//! a materialized shard directory), `/embed` against
//! `leaf_pca`/`leaf_pca_project`.

use forest_kernels::coordinator::shard::{ShardReader, ShardSink};
use forest_kernels::coordinator::{self, CoordinatorConfig};
use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::model::{BundleMeta, ModelBundle};
use forest_kernels::runtime::json::Json;
use forest_kernels::serve::{http, ServeConfig, Server};
use forest_kernels::spectral::knn::{knn_from_kernel, rank_row};
use forest_kernels::spectral::pca;
use forest_kernels::swlc::{predict, ForestKernel, ProximityKind};
use forest_kernels::Dataset;
use std::time::Duration;

const N: usize = 160;
const D: usize = 5;
const C: usize = 3;
const TREES: usize = 12;
const EMBED_DIMS: usize = 4;
const EMBED_ITERS: usize = 20;
const EMBED_SEED: u64 = 9;

/// Deterministic model fixture: calling this twice with the same seed
/// yields bitwise-identical forests and kernels, so one copy can go to
/// the server while the other stays as the in-process reference.
fn fixture(seed: u64) -> ModelBundle {
    let data = synth::gaussian_blobs(N, D, C, 2.2, seed);
    let forest =
        Forest::train(&data, &TrainConfig { n_trees: TREES, seed, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: TREES };
    ModelBundle { forest, kernel, meta, companion: None }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        linger: Duration::from_millis(1),
        embed_dims: EMBED_DIMS,
        embed_iters: EMBED_ITERS,
        embed_seed: EMBED_SEED,
        ..Default::default()
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn f32s(j: &Json) -> Vec<f32> {
    j.as_arr()
        .expect("expected a JSON array")
        .iter()
        .map(|v| match v {
            Json::Num(x) => *x as f32,
            other => panic!("expected a number, got {other:?}"),
        })
        .collect()
}

fn u32s(j: &Json) -> Vec<u32> {
    j.as_arr()
        .expect("expected a JSON array")
        .iter()
        .map(|v| v.as_usize().expect("expected an integer") as u32)
        .collect()
}

fn row_json(data: &Dataset, i: usize) -> String {
    let mut s = String::from("[");
    for f in 0..data.d {
        if f > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("{}", data.x(i, f)));
    }
    s.push(']');
    s
}

#[test]
fn predict_over_tcp_matches_in_process_bitwise() {
    let reference = fixture(1);
    let server = Server::bind(fixture(1), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    let queries = synth::gaussian_blobs(12, D, C, 2.2, 555);
    let qn = reference.kernel.oos_query_map(&reference.forest, &queries);
    let want_preds = predict::predict_oos(&reference.kernel, &qn);
    let cross = reference.kernel.cross_proximity(&qn);
    let want_scores =
        predict::scores_from_kernel(&cross, &reference.kernel.ctx.y, C).unwrap();

    // Single-query requests: each must match its row of the in-process
    // batch exactly (batch composition never changes a row's bits).
    for i in 0..queries.n {
        let body = format!("{{\"x\": {}}}", row_json(&queries, i));
        let (status, resp) = http::http_request(&addr, "POST", "/predict", &body).unwrap();
        assert_eq!(status, 200, "query {i}: {resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(u32s(j.get("predictions").unwrap()), vec![want_preds[i]], "query {i}");
        let scores = f32s(&j.get("scores").unwrap().as_arr().unwrap()[0]);
        assert_eq!(
            bits(&scores),
            bits(&want_scores[i * C..(i + 1) * C]),
            "query {i}: scores differ bitwise"
        );
    }

    // One client-side batch holding every query.
    let mut body = String::from("{\"x\": [");
    for i in 0..queries.n {
        if i > 0 {
            body.push_str(", ");
        }
        body.push_str(&row_json(&queries, i));
    }
    body.push_str("]}");
    let (status, resp) = http::http_request(&addr, "POST", "/predict", &body).unwrap();
    assert_eq!(status, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(u32s(j.get("predictions").unwrap()), want_preds);
    let score_rows = j.get("scores").unwrap().as_arr().unwrap();
    assert_eq!(score_rows.len(), queries.n);
    for (i, row) in score_rows.iter().enumerate() {
        assert_eq!(bits(&f32s(row)), bits(&want_scores[i * C..(i + 1) * C]), "batch row {i}");
    }

    handle.stop();
}

#[test]
fn neighbors_row_lookups_match_knn_from_kernel_bitwise() {
    let reference = fixture(2);
    let k = 5;
    // The materialized kernel is the ground truth for row lookups.
    let (p, _) =
        coordinator::materialize_to_csr(&reference.kernel, &CoordinatorConfig::default());
    let g = knn_from_kernel(&p, k).unwrap();

    // Mode 1: no shard directory — rows computed on the fly from the
    // factors (the stripe product is bitwise what a shard holds).
    let server = Server::bind(fixture(2), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();
    for row in [0usize, 7, 63, N - 1] {
        let body = format!("{{\"row\": {row}, \"k\": {k}}}");
        let (status, resp) = http::http_request(&addr, "POST", "/neighbors", &body).unwrap();
        assert_eq!(status, 200, "row {row}: {resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("source").and_then(Json::as_str), Some("factors"));
        assert_eq!(u32s(j.get("ids").unwrap()), g.neighbors[row * k..(row + 1) * k], "row {row}");
        assert_eq!(
            bits(&f32s(j.get("dists").unwrap())),
            bits(&g.dists[row * k..(row + 1) * k]),
            "row {row}: dists differ bitwise"
        );
    }
    // Out-of-range rows and degenerate k fail cleanly.
    let (status, _) =
        http::http_request(&addr, "POST", "/neighbors", &format!("{{\"row\": {N}}}")).unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        http::http_request(&addr, "POST", "/neighbors", "{\"row\": 0, \"k\": 0}").unwrap();
    assert_eq!(status, 400);
    handle.stop();

    // Mode 2: the same lookups served from a materialized shard
    // directory through ShardReader.
    let dir = std::env::temp_dir()
        .join(format!("fk-serve-shards-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut sink = ShardSink::create(&dir, N, "kerf").unwrap();
    let cc = CoordinatorConfig { stripe_rows: 48, ..Default::default() };
    coordinator::materialize_into(&reference.kernel, &cc, &mut sink).unwrap();
    sink.finish().unwrap();
    let reader = ShardReader::open(&dir).unwrap();
    let server = Server::bind(fixture(2), Some(reader), serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();
    for row in [0usize, 31, 100, N - 1] {
        let body = format!("{{\"row\": {row}, \"k\": {k}}}");
        let (status, resp) = http::http_request(&addr, "POST", "/neighbors", &body).unwrap();
        assert_eq!(status, 200, "row {row}: {resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("source").and_then(Json::as_str), Some("shards"));
        assert_eq!(u32s(j.get("ids").unwrap()), g.neighbors[row * k..(row + 1) * k], "row {row}");
        assert_eq!(
            bits(&f32s(j.get("dists").unwrap())),
            bits(&g.dists[row * k..(row + 1) * k]),
            "row {row}: dists differ bitwise (shard mode)"
        );
    }
    handle.stop();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn oos_neighbors_match_cross_proximity_ranking_bitwise() {
    let reference = fixture(3);
    let server = Server::bind(fixture(3), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    let queries = synth::gaussian_blobs(6, D, C, 2.2, 777);
    let qn = reference.kernel.oos_query_map(&reference.forest, &queries);
    let cross = reference.kernel.cross_proximity(&qn);
    let k = 7;
    for i in 0..queries.n {
        let body = format!("{{\"x\": {}, \"k\": {k}}}", row_json(&queries, i));
        let (status, resp) = http::http_request(&addr, "POST", "/neighbors", &body).unwrap();
        assert_eq!(status, 200, "query {i}: {resp}");
        let j = Json::parse(&resp).unwrap();
        let (cols, vals) = cross.row(i);
        let want = rank_row(cols, vals, None, k);
        let want_ids: Vec<u32> = want.iter().map(|&(c, _)| c).collect();
        let want_prox: Vec<f32> = want.iter().map(|&(_, p)| p).collect();
        assert_eq!(u32s(j.get("ids").unwrap()), want_ids, "query {i}");
        assert_eq!(
            bits(&f32s(j.get("proximities").unwrap())),
            bits(&want_prox),
            "query {i}: proximities differ bitwise"
        );
    }
    handle.stop();
}

#[test]
fn embed_matches_leaf_pca_projection_bitwise() {
    let reference = fixture(4);
    let server = Server::bind(fixture(4), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    // Recompute the server's startup basis: leaf_pca is deterministic
    // in (factors, dims, iters, seed) at any thread count.
    let (scores, vals) =
        pca::leaf_pca(&reference.kernel.q, EMBED_DIMS, EMBED_ITERS, false, EMBED_SEED);
    let queries = synth::gaussian_blobs(9, D, C, 2.2, 888);
    let qn = reference.kernel.oos_query_map(&reference.forest, &queries);
    let want = pca::leaf_pca_project(&reference.kernel.q, &scores, &vals, &qn);

    let mut body = String::from("{\"x\": [");
    for i in 0..queries.n {
        if i > 0 {
            body.push_str(", ");
        }
        body.push_str(&row_json(&queries, i));
    }
    body.push_str("]}");
    let (status, resp) = http::http_request(&addr, "POST", "/embed", &body).unwrap();
    assert_eq!(status, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("dims").and_then(Json::as_usize), Some(EMBED_DIMS));
    let rows = j.get("coords").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), queries.n);
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(
            bits(&f32s(row)),
            bits(&want[i * EMBED_DIMS..(i + 1) * EMBED_DIMS]),
            "query {i}: embedding differs bitwise"
        );
    }
    handle.stop();
}

#[test]
fn health_stats_and_error_paths() {
    let server = Server::bind(fixture(5), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    let (status, resp) = http::http_request(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
    let model = j.get("model").unwrap();
    assert_eq!(model.get("n").and_then(Json::as_usize), Some(N));
    assert_eq!(model.get("kind").and_then(Json::as_str), Some("kerf"));
    assert_eq!(model.get("features").and_then(Json::as_usize), Some(D));

    // Errors: unknown route, method mismatch, bad JSON, wrong
    // dimension, non-class model constraints are all clean HTTP
    // errors, not hangs or panics.
    let (status, _) = http::http_request(&addr, "GET", "/nope", "").unwrap();
    assert_eq!(status, 404);
    let (status, resp) = http::http_request(&addr, "GET", "/predict", "").unwrap();
    assert_eq!(status, 405, "known path + wrong method is 405, not 404: {resp}");
    let (status, _) = http::http_request(&addr, "POST", "/predict", "{not json").unwrap();
    assert_eq!(status, 400);
    let (status, resp) =
        http::http_request(&addr, "POST", "/predict", "{\"x\": [1.0, 2.0]}").unwrap();
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("features"), "unhelpful error: {resp}");

    // A valid predict so /stats has something to report.
    let q = synth::gaussian_blobs(1, D, C, 2.2, 42);
    let body = format!("{{\"x\": {}}}", row_json(&q, 0));
    let (status, _) = http::http_request(&addr, "POST", "/predict", &body).unwrap();
    assert_eq!(status, 200);

    let (status, resp) = http::http_request(&addr, "GET", "/stats", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&resp).unwrap();
    let reqs = j.get("requests").unwrap();
    assert_eq!(reqs.get("healthz").and_then(Json::as_usize), Some(1));
    assert!(reqs.get("predict").and_then(Json::as_usize).unwrap() >= 2);
    assert!(j.get("errors").and_then(Json::as_usize).unwrap() >= 2);
    assert!(j.get("batches").and_then(Json::as_usize).unwrap() >= 1);
    // Every request above used a one-shot connection.
    assert!(j.get("connections").and_then(Json::as_usize).unwrap() >= 7);
    handle.stop();
}
