//! The observability plane, end to end: `GET /metrics` serves valid
//! Prometheus text exposition on the server and the router, the
//! router-merged counters equal the sum of the backend scrapes,
//! `x-request-id` propagates client → router → replica → response
//! (and lands in the slow-query log), and tracing is **bitwise
//! invisible** — a traced materialize produces byte-identical CSRs to
//! an untraced one at 1 and 4 workers.

use forest_kernels::coordinator::{self, CoordinatorConfig};
use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::model::{BundleMeta, ModelBundle};
use forest_kernels::obs;
use forest_kernels::runtime::json::Json;
use forest_kernels::serve::http;
use forest_kernels::serve::router::{Router, RouterConfig};
use forest_kernels::serve::{ServeConfig, Server};
use forest_kernels::swlc::{ForestKernel, ProximityKind};
use forest_kernels::Dataset;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

const N: usize = 160;
const D: usize = 5;
const C: usize = 3;
const TREES: usize = 12;

/// The metrics registry is process-global and the HTTP tests in this
/// binary all drive traffic that bumps the same counters, so the tests
/// that assert on counter values serialize behind this lock.
static HTTP_TESTS: Mutex<()> = Mutex::new(());

fn fixture(seed: u64) -> ModelBundle {
    let data = synth::gaussian_blobs(N, D, C, 2.2, seed);
    let forest =
        Forest::train(&data, &TrainConfig { n_trees: TREES, seed, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: TREES };
    ModelBundle { forest, kernel, meta, companion: None }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        linger: Duration::from_millis(1),
        embed_dims: 4,
        embed_iters: 20,
        embed_seed: 9,
        ..Default::default()
    }
}

fn row_json(data: &Dataset, i: usize) -> String {
    let mut s = String::from("[");
    for f in 0..data.d {
        if f > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("{}", data.x(i, f)));
    }
    s.push(']');
    s
}

fn predict_body(seed: u64, i: usize) -> String {
    let queries = synth::gaussian_blobs(8, D, C, 2.2, seed);
    format!("{{\"x\": {}}}", row_json(&queries, i % queries.n))
}

/// One raw HTTP/1.1 request over a fresh connection, returned as the
/// full response text (headers + body). `Connection: close` in `req`
/// makes the server end the stream, which ends the read.
fn raw_request(addr: SocketAddr, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).expect("send");
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read response");
    out
}

#[test]
fn metrics_endpoint_serves_valid_prometheus_exposition() {
    let _g = HTTP_TESTS.lock().unwrap();
    let server = Server::bind(fixture(5), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    // Touch every instrumented path once so the families are live.
    for i in 0..3 {
        let (status, _) =
            http::http_request(&addr, "POST", "/predict", &predict_body(901, i)).unwrap();
        assert_eq!(status, 200);
    }
    let (status, _) =
        http::http_request(&addr, "POST", "/neighbors", "{\"row\": 3, \"k\": 5}").unwrap();
    assert_eq!(status, 200);
    let (status, _) =
        http::http_request(&addr, "POST", "/embed", &predict_body(902, 0)).unwrap();
    assert_eq!(status, 200);
    let (status, _) = http::http_request(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);

    let (status, text) = http::http_request(&addr, "GET", "/metrics", "").unwrap();
    assert_eq!(status, 200);
    let scrape = obs::parse_prometheus(&text)
        .expect("/metrics must be valid Prometheus text exposition");

    for family in [
        "fk_http_requests_total",
        "fk_http_request_seconds",
        "fk_queue_wait_seconds",
        "fk_queue_depth",
        "fk_exec_tasks_total",
        "fk_exec_busy_seconds_total",
        "fk_uptime_seconds",
        "fk_build_info",
    ] {
        assert!(
            scrape.samples.iter().any(|s| scrape.family_of(&s.name) == family),
            "missing metric family {family} in:\n{text}"
        );
    }
    assert_eq!(scrape.type_of("fk_http_requests_total"), Some("counter"));
    assert_eq!(scrape.type_of("fk_http_request_seconds"), Some("histogram"));
    assert!(
        scrape.samples.iter().any(|s| s.name == "fk_http_request_seconds_bucket"),
        "histograms must expose _bucket samples"
    );
    assert!(
        scrape.value("fk_http_requests_total", &[("endpoint", "predict")]) >= 3.0,
        "the predict counter must cover the traffic just driven"
    );

    // Scraping /metrics must not count itself: two back-to-back
    // scrapes agree on the request counters.
    let (_, again) = http::http_request(&addr, "GET", "/metrics", "").unwrap();
    let scrape2 = obs::parse_prometheus(&again).unwrap();
    assert_eq!(
        scrape.value("fk_http_requests_total", &[]),
        scrape2.value("fk_http_requests_total", &[]),
        "a /metrics scrape must not bump the request counters"
    );

    handle.stop();
}

#[test]
fn router_metrics_merge_sums_backend_scrapes() {
    let _g = HTTP_TESTS.lock().unwrap();
    let backend_a = Server::bind(fixture(6), None, serve_cfg()).unwrap();
    let backend_b = Server::bind(fixture(6), None, serve_cfg()).unwrap();
    let (addr_a, addr_b) = (backend_a.addr(), backend_b.addr());
    let h_a = backend_a.spawn();
    let h_b = backend_b.spawn();
    let router = Router::bind(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![addr_a.to_string(), addr_b.to_string()],
    })
    .unwrap();
    let raddr = router.addr();
    let rh = router.spawn();

    for i in 0..4 {
        let (status, _) =
            http::http_request(&raddr, "POST", "/predict", &predict_body(903, i)).unwrap();
        assert_eq!(status, 200);
    }

    let (status, merged_text) = http::http_request(&raddr, "GET", "/metrics", "").unwrap();
    assert_eq!(status, 200);
    let merged = obs::parse_prometheus(&merged_text)
        .expect("the router-merged exposition must re-parse");
    let (_, text_a) = http::http_request(&addr_a, "GET", "/metrics", "").unwrap();
    let (_, text_b) = http::http_request(&addr_b, "GET", "/metrics", "").unwrap();
    let scrape_a = obs::parse_prometheus(&text_a).unwrap();
    let scrape_b = obs::parse_prometheus(&text_b).unwrap();

    // Counters sum across the fleet. The traffic counters are
    // quiescent here (nothing else is running under the lock, and
    // /metrics doesn't count itself), so merged == a + b exactly.
    for labels in
        [[("endpoint", "predict")], [("endpoint", "neighbors")], [("endpoint", "embed")]]
    {
        let want = scrape_a.value("fk_http_requests_total", &labels)
            + scrape_b.value("fk_http_requests_total", &labels);
        let got = merged.value("fk_http_requests_total", &labels);
        assert_eq!(got, want, "merged fk_http_requests_total{labels:?}");
    }
    let want = scrape_a.value("fk_http_request_seconds_count", &[])
        + scrape_b.value("fk_http_request_seconds_count", &[]);
    assert_eq!(
        merged.value("fk_http_request_seconds_count", &[]),
        want,
        "histogram counts must sum across backends"
    );

    // Gauges stay per-replica, distinguished by a backend label.
    let uptime_samples: Vec<_> = merged
        .samples
        .iter()
        .filter(|s| s.name == "fk_uptime_seconds")
        .collect();
    assert_eq!(uptime_samples.len(), 2, "one uptime gauge per backend");
    for s in uptime_samples {
        assert!(
            s.labels.iter().any(|(k, _)| k == "backend"),
            "per-replica gauges need a backend label"
        );
    }

    rh.stop();
    h_a.stop();
    h_b.stop();
}

#[test]
fn request_id_round_trips_through_router_and_slow_log() {
    let _g = HTTP_TESTS.lock().unwrap();
    let mut cfg = serve_cfg();
    cfg.slow_ms = Some(0); // every request is "slow": exercises the log
    let backend = Server::bind(fixture(7), None, cfg).unwrap();
    let baddr = backend.addr();
    let bh = backend.spawn();
    let router = Router::bind(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![baddr.to_string()],
    })
    .unwrap();
    router.set_slow_ms(1_000_000); // enabled but never firing: ids flow anyway
    let raddr = router.addr();
    let rh = router.spawn();

    let body = predict_body(904, 0);
    let tagged = format!(
        "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         Connection: close\r\nx-request-id: abc-123\r\n\r\n{body}",
        body.len()
    );
    let resp = raw_request(raddr, &tagged);
    assert!(resp.starts_with("HTTP/1.1 200"), "unexpected response: {resp}");
    assert!(
        resp.to_ascii_lowercase().contains("x-request-id: abc-123"),
        "client-supplied id must be echoed in the response header: {resp}"
    );
    assert!(
        resp.contains("\"request_id\": \"abc-123\""),
        "client-supplied id must be echoed in the JSON body: {resp}"
    );
    let json_body = &resp[resp.find("\r\n\r\n").unwrap() + 4..];
    let j = Json::parse(json_body).expect("response body parses");
    assert!(j.get("model_generation").is_some(), "request_id rides next to model_generation");
    assert_eq!(j.get("request_id").and_then(Json::as_str), Some("abc-123"));

    // Untagged traffic: an id is minted and echoed in the header, but
    // the body stays byte-identical to what untagged clients always
    // got — no request_id field.
    let untagged = format!(
        "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    let resp = raw_request(raddr, &untagged);
    assert!(resp.starts_with("HTTP/1.1 200"), "unexpected response: {resp}");
    assert!(
        resp.to_ascii_lowercase().contains("x-request-id: "),
        "a generated id must still be echoed in the header: {resp}"
    );
    assert!(
        !resp.contains("request_id\": "),
        "generated ids must stay out of the body: {resp}"
    );

    // The replica's slow-query log (slow_ms = 0) saw the relayed id:
    // it lands in the trace ring, served by GET /debug/trace.
    let (status, trace) = http::http_request(&baddr, "GET", "/debug/trace", "").unwrap();
    assert_eq!(status, 200);
    assert!(trace.contains("http.slow"), "slow-query events must reach the ring: {trace}");
    assert!(
        trace.contains("abc-123"),
        "the relayed request id must appear in the slow-query log: {trace}"
    );
    assert!(trace.contains("\"tier\""), "slow predicts record their serving tier: {trace}");

    rh.stop();
    bh.stop();
}

#[test]
fn tracing_is_bitwise_invisible_to_materialize() {
    let bundle = fixture(8);
    let kernel = &bundle.kernel;
    for workers in [1usize, 4] {
        let cfg = CoordinatorConfig { stripe_rows: 48, n_workers: workers, queue_depth: 2 };
        let (plain, _) = coordinator::materialize_to_csr(kernel, &cfg);
        let trace_path = std::env::temp_dir().join(format!(
            "fk-obs-trace-{}-{workers}.jsonl",
            std::process::id()
        ));
        obs::trace_to_file(trace_path.to_str().unwrap()).unwrap();
        let traced = {
            let _sp = obs::span("test.materialize");
            coordinator::materialize_to_csr(kernel, &cfg).0
        };
        obs::flush_trace();
        let logged = std::fs::read_to_string(&trace_path).unwrap();
        assert!(
            logged.lines().any(|l| l.contains("spgemm.stripe")),
            "the traced run must have recorded stripe events"
        );
        std::fs::remove_file(&trace_path).ok();

        assert_eq!(plain.n_rows, traced.n_rows);
        assert_eq!(plain.indptr, traced.indptr, "workers={workers}: row structure differs");
        assert_eq!(plain.indices, traced.indices, "workers={workers}: indices differ");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&plain.data),
            bits(&traced.data),
            "workers={workers}: traced materialize must be bitwise-identical"
        );
    }
}
