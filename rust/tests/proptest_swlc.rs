//! Property tests for the SWLC layer — the paper's core invariants,
//! checked over randomly generated datasets and forest configurations.
//!
//! Central property (Prop. 3.6): the factored kernel `Q Wᵀ` equals the
//! naive all-pairs evaluation of Def. 3.1 for EVERY weight scheme,
//! forest kind, and hyperparameter draw.

use forest_kernels::data::{synth, Dataset};
use forest_kernels::forest::{Criterion, Forest, ForestKind, MaxFeatures, TrainConfig};
use forest_kernels::rng::Rng;
use forest_kernels::swlc::{naive, predict, EnsembleContext, ForestKernel, ProximityKind};

const CASES: u64 = 14;

/// Random dataset + forest config (classification; binary when GBT).
fn random_fixture(seed: u64, kind: ForestKind) -> (Dataset, TrainConfig) {
    let mut rng = Rng::new(seed);
    let n = 30 + rng.gen_range(80);
    let d = 2 + rng.gen_range(6);
    let c = if kind == ForestKind::GradientBoosting { 2 } else { 2 + rng.gen_range(3) };
    let sep = 1.0 + rng.next_f64() * 2.5;
    let data = synth::gaussian_blobs(n, d, c, sep, seed ^ 0x5A5A);
    let cfg = TrainConfig {
        kind,
        n_trees: 3 + rng.gen_range(12),
        max_depth: if rng.next_f64() < 0.3 { Some(2 + rng.gen_range(6)) } else { None },
        min_samples_leaf: 1 + rng.gen_range(5),
        max_features: if rng.next_f64() < 0.5 { MaxFeatures::Sqrt } else { MaxFeatures::All },
        criterion: if kind == ForestKind::GradientBoosting {
            Criterion::Mse
        } else if rng.next_f64() < 0.5 {
            Criterion::Gini
        } else {
            Criterion::Entropy
        },
        seed: seed ^ 0xF0F0,
        ..Default::default()
    };
    (data, cfg)
}

fn assert_factored_equals_naive(kernel: &ForestKernel, seed: u64) {
    let dense = kernel.proximity_matrix().to_dense();
    let naive = naive::naive_proximity(kernel.kind, &kernel.ctx);
    let n = kernel.ctx.n;
    for i in 0..n {
        for j in 0..n {
            let (a, b) = (dense[i * n + j], naive[i * n + j]);
            assert!(
                (a - b).abs() < 1e-4,
                "seed {seed} {:?} P[{i},{j}]: factored {a} vs naive {b}",
                kernel.kind
            );
        }
    }
}

#[test]
fn prop_factored_equals_naive_rf_all_schemes() {
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed, ForestKind::RandomForest);
        let forest = Forest::train(&data, &cfg);
        for kind in [
            ProximityKind::Original,
            ProximityKind::Kerf,
            ProximityKind::OobSeparable,
            ProximityKind::RfGap,
            ProximityKind::InstanceHardness,
        ] {
            let kernel = ForestKernel::fit(&forest, &data, kind);
            assert_factored_equals_naive(&kernel, seed);
        }
    }
}

#[test]
fn prop_factored_equals_naive_extratrees() {
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed ^ 0x777, ForestKind::ExtraTrees);
        let forest = Forest::train(&data, &cfg);
        for kind in [ProximityKind::Original, ProximityKind::Kerf, ProximityKind::InstanceHardness]
        {
            let kernel = ForestKernel::fit(&forest, &data, kind);
            assert_factored_equals_naive(&kernel, seed);
        }
    }
}

#[test]
fn prop_factored_equals_naive_boosted() {
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed ^ 0x999, ForestKind::GradientBoosting);
        let forest = Forest::train(&data, &cfg);
        let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Boosted);
        assert_factored_equals_naive(&kernel, seed);
        // Boosted proximity diagonal: Σ_t w_t/Σw_s = 1.
        let p = kernel.proximity_matrix().to_dense();
        for i in 0..data.n {
            assert!((p[i * data.n + i] - 1.0).abs() < 1e-4, "seed {seed} diag {}", p[i * data.n + i]);
        }
    }
}

#[test]
fn prop_row_t_sparsity() {
    // Lemma 3.4: every factor row has at most T nonzeros.
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed ^ 0x121, ForestKind::RandomForest);
        let forest = Forest::train(&data, &cfg);
        for kind in [ProximityKind::Original, ProximityKind::RfGap, ProximityKind::OobSeparable] {
            let k = ForestKernel::fit(&forest, &data, kind);
            for i in 0..data.n {
                assert!(k.q.row(i).0.len() <= cfg.n_trees, "seed {seed}");
                assert!(k.w.row(i).0.len() <= cfg.n_trees, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_symmetric_kinds_produce_symmetric_psd_kernels() {
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed ^ 0x343, ForestKind::RandomForest);
        let forest = Forest::train(&data, &cfg);
        let mut rng = Rng::new(seed);
        for kind in [ProximityKind::Original, ProximityKind::Kerf] {
            let k = ForestKernel::fit(&forest, &data, kind);
            let p = k.proximity_matrix().to_dense();
            let n = data.n;
            for i in 0..n {
                for j in 0..n {
                    assert!((p[i * n + j] - p[j * n + i]).abs() < 1e-5, "seed {seed}");
                }
            }
            // Random quadratic forms nonnegative (Cor. 3.7).
            for _ in 0..3 {
                let v: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
                let mut quad = 0f64;
                for i in 0..n {
                    for j in 0..n {
                        quad += (v[i] * p[i * n + j] * v[j]) as f64;
                    }
                }
                assert!(quad > -1e-2, "seed {seed}: {quad}");
            }
        }
    }
}

#[test]
fn prop_oos_on_training_rows_restricted_to_training_kernel() {
    // Querying training points through the OOS path with Original
    // weights reproduces the corresponding training-kernel rows.
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed ^ 0x565, ForestKind::RandomForest);
        let forest = Forest::train(&data, &cfg);
        let k = ForestKernel::fit(&forest, &data, ProximityKind::Original);
        let m = 10.min(data.n);
        let sub = data.head(m);
        let qn = k.oos_query_map(&forest, &sub);
        let cross = k.cross_proximity(&qn).to_dense();
        let full = k.proximity_matrix().to_dense();
        for i in 0..m {
            for j in 0..data.n {
                assert!(
                    (cross[i * data.n + j] - full[i * data.n + j]).abs() < 1e-5,
                    "seed {seed} ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn prop_gap_prediction_recovers_forest_oob_votes() {
    // RF-GAP's design property [38]: proximity-weighted prediction
    // equals the forest OOB-vote argmax (strict-argmax cases).
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed ^ 0x787, ForestKind::RandomForest);
        let forest = Forest::train(&data, &cfg);
        let k = ForestKernel::fit(&forest, &data, ProximityKind::RfGap);
        let preds = predict::predict_train(&k);
        let binned = forest.binner.bin(&data);
        let votes = forest.oob_votes(&binned);
        let c = data.n_classes;
        for i in 0..data.n {
            if k.ctx.oob_count[i] == 0 {
                continue;
            }
            let row = &votes[i * c..(i + 1) * c];
            let best = (0..c).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap();
            let strict = (0..c).filter(|&j| (row[j] - row[best]).abs() < 1e-12).count() == 1;
            if strict {
                assert_eq!(preds[i], best as u32, "seed {seed} sample {i}: {row:?}");
            }
        }
    }
}

#[test]
fn prop_ratio_statistic_bounded() {
    // Fig 4.1 statistic: 0 < R <= T (trivially) and mean in (0, 1.1].
    for seed in 0..CASES / 2 {
        let (data, mut cfg) = random_fixture(seed ^ 0x9A9, ForestKind::RandomForest);
        cfg.n_trees = 40;
        let forest = Forest::train(&data, &cfg);
        let ctx = EnsembleContext::build(&forest, &data);
        let stats = naive::oob_ratio_stats(&ctx, 5_000, seed);
        if stats.n_pairs > 20 {
            assert!(stats.mean > 0.0 && stats.mean < 1.3, "seed {seed}: {}", stats.mean);
        }
    }
}

#[test]
fn prop_lambda_consistent_with_flops() {
    // predicted flops == N·T·λ̄ exactly, for full-collision schemes.
    for seed in 0..CASES {
        let (data, cfg) = random_fixture(seed ^ 0xBCB, ForestKind::RandomForest);
        let forest = Forest::train(&data, &cfg);
        let k = ForestKernel::fit(&forest, &data, ProximityKind::Original);
        let lambda = k.ctx.mean_lambda();
        let expect = (data.n * cfg.n_trees) as f64 * lambda;
        let flops = k.predicted_flops() as f64;
        assert!(
            (flops - expect).abs() / expect < 1e-9,
            "seed {seed}: flops {flops} vs N·T·λ̄ {expect}"
        );
    }
}
