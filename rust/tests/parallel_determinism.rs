//! Determinism under parallelism: every parallel hot path must produce
//! **bitwise-identical** results to its serial twin at any thread count.
//!
//! Seeded-random property sweeps in the style of `proptest_sparse.rs`
//! (the offline vendor set has no proptest crate): failures reproduce
//! from the seed in the panic message.

use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, ForestKind, TrainConfig};
use forest_kernels::rng::Rng;
use forest_kernels::sparse::{spgemm_with_threads, Csr};

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
    let mut trip = vec![];
    for r in 0..rows {
        for c in 0..cols {
            if rng.next_f64() < density {
                trip.push((r, c as u32, rng.next_normal() as f32));
            }
        }
    }
    Csr::from_triplets(rows, cols, &trip)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_parallel_spgemm_bitwise_equals_serial() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed ^ 0xDE7);
        let rows = 1 + rng.gen_range(80);
        let inner = 1 + rng.gen_range(40);
        let cols = 1 + rng.gen_range(60);
        let density = 0.05 + rng.next_f64() * 0.4;
        let a = random_csr(&mut rng, rows, inner, density);
        let b = random_csr(&mut rng, inner, cols, density);
        let serial = spgemm_with_threads(&a, &b, 1);
        for th in THREAD_COUNTS {
            let par = spgemm_with_threads(&a, &b, th);
            par.check().unwrap_or_else(|e| panic!("seed {seed} th {th}: invalid CSR: {e}"));
            assert_eq!(par.indptr, serial.indptr, "seed {seed} th {th}: structure differs");
            assert_eq!(par.indices, serial.indices, "seed {seed} th {th}: columns differ");
            assert_eq!(
                bits(&par.data),
                bits(&serial.data),
                "seed {seed} th {th}: values not bitwise equal"
            );
        }
    }
}

#[test]
fn prop_parallel_transpose_bitwise_equals_serial() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed ^ 0x7A5);
        let rows = 1 + rng.gen_range(120);
        let cols = 1 + rng.gen_range(70);
        let m = random_csr(&mut rng, rows, cols, 0.05 + rng.next_f64() * 0.35);
        let serial = m.transpose_with_threads(1);
        for th in [2usize, 3, 4, 7] {
            let par = m.transpose_with_threads(th);
            par.check().unwrap_or_else(|e| panic!("seed {seed} th {th}: invalid CSR: {e}"));
            assert_eq!(par.indptr, serial.indptr, "seed {seed} th {th}");
            assert_eq!(par.indices, serial.indices, "seed {seed} th {th}");
            assert_eq!(bits(&par.data), bits(&serial.data), "seed {seed} th {th}");
        }
    }
}

#[test]
fn prop_parallel_spmm_bitwise_equals_serial() {
    // Unblocks the Leaf-PCA subspace-iteration hot path: `Y = A·X` is
    // row-blocked across the pool, so each output row is produced by
    // the same serial inner loop whatever the partition.
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x5B11);
        let rows = 1 + rng.gen_range(100);
        let cols = 1 + rng.gen_range(60);
        let k = 1 + rng.gen_range(9);
        let m = random_csr(&mut rng, rows, cols, 0.05 + rng.next_f64() * 0.4);
        let x: Vec<f32> = (0..cols * k).map(|_| rng.next_normal() as f32).collect();
        let mut serial = vec![0f32; rows * k];
        m.spmm_with_threads(&x, k, &mut serial, 1);
        for th in [2usize, 3, 4, 8] {
            let mut par = vec![f32::NAN; rows * k];
            m.spmm_with_threads(&x, k, &mut par, th);
            assert_eq!(bits(&par), bits(&serial), "seed {seed} th {th}: spmm differs");
        }
    }
}

#[test]
fn prop_parallel_spmm_t_bitwise_equals_serial() {
    // `Y = Aᵀ·X` is partitioned by output columns: every column is
    // accumulated in row order by exactly one worker, matching the
    // serial association bit for bit.
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x7B12);
        let rows = 1 + rng.gen_range(100);
        let cols = 1 + rng.gen_range(60);
        let k = 1 + rng.gen_range(9);
        let m = random_csr(&mut rng, rows, cols, 0.05 + rng.next_f64() * 0.4);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.next_normal() as f32).collect();
        let mut serial = vec![0f32; cols * k];
        m.spmm_t_with_threads(&x, k, &mut serial, 1);
        for th in [2usize, 3, 4, 8] {
            let mut par = vec![f32::NAN; cols * k];
            m.spmm_t_with_threads(&x, k, &mut par, th);
            assert_eq!(bits(&par), bits(&serial), "seed {seed} th {th}: spmm_t differs");
        }
    }
}

/// The SpMM/SpMMᵀ output-column tiling (`SPMM_K_TILE = 16`) must be
/// invisible: for widths below, at, straddling, and well above the tile
/// width, the tiled kernels stay bitwise equal to an untiled naive loop
/// (which folds each output element's terms in the same nonzero order)
/// at every thread count.
#[test]
fn prop_spmm_k_tiling_matches_untiled_reference() {
    for (seed, k) in [(1u64, 3usize), (2, 16), (3, 17), (4, 24), (5, 40)] {
        let mut rng = Rng::new(seed ^ 0xC411);
        let rows = 1 + rng.gen_range(90);
        let cols = 1 + rng.gen_range(70);
        let m = random_csr(&mut rng, rows, cols, 0.05 + rng.next_f64() * 0.4);
        let x: Vec<f32> = (0..cols * k).map(|_| rng.next_normal() as f32).collect();
        let mut naive = vec![0f32; rows * k];
        for r in 0..rows {
            let (cs, vs) = m.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                for j in 0..k {
                    naive[r * k + j] += v * x[c as usize * k + j];
                }
            }
        }
        for th in [1usize, 2, 4, 8] {
            let mut got = vec![f32::NAN; rows * k];
            m.spmm_with_threads(&x, k, &mut got, th);
            assert_eq!(bits(&got), bits(&naive), "seed {seed} k {k} th {th}: spmm");
        }
        let xt: Vec<f32> = (0..rows * k).map(|_| rng.next_normal() as f32).collect();
        let mut naive_t = vec![0f32; cols * k];
        for r in 0..rows {
            let (cs, vs) = m.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                for j in 0..k {
                    naive_t[c as usize * k + j] += v * xt[r * k + j];
                }
            }
        }
        for th in [1usize, 2, 4, 8] {
            let mut got = vec![f32::NAN; cols * k];
            m.spmm_t_with_threads(&xt, k, &mut got, th);
            assert_eq!(bits(&got), bits(&naive_t), "seed {seed} k {k} th {th}: spmm_t");
        }
    }
}

/// Quantized SpGEMM: the parallel product of int8/int4 factors must be
/// bitwise-identical to the serial one, and both must equal the exact
/// SpGEMM of the dequantized factors (same SPA, same flush order).
#[test]
fn prop_quantized_spgemm_bitwise_equals_serial_and_dequantized() {
    use forest_kernels::sparse::qcsr::{self, QuantMode};
    for seed in 0..12u64 {
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let mut rng = Rng::new(seed ^ 0x9C5);
            let rows = 1 + rng.gen_range(70);
            let inner = 1 + rng.gen_range(50);
            let cols = 1 + rng.gen_range(60);
            let density = 0.05 + rng.next_f64() * 0.4;
            let a = qcsr::quantize(&random_csr(&mut rng, rows, inner, density), mode);
            let b = qcsr::quantize(&random_csr(&mut rng, inner, cols, density), mode);
            let serial = qcsr::spgemm_q(&a, &b, 1);
            let exact = spgemm_with_threads(&a.dequantize(), &b.dequantize(), 1);
            assert_eq!(serial.indptr, exact.indptr, "seed {seed} {mode:?}: structure");
            assert_eq!(serial.indices, exact.indices, "seed {seed} {mode:?}: columns");
            assert_eq!(bits(&serial.data), bits(&exact.data), "seed {seed} {mode:?}: values");
            for th in THREAD_COUNTS {
                let par = qcsr::spgemm_q(&a, &b, th);
                par.check().unwrap_or_else(|e| panic!("seed {seed} th {th}: invalid CSR: {e}"));
                assert_eq!(par, serial, "seed {seed} {mode:?} th {th}: parallel differs");
            }
        }
    }
}

/// A forest trained with `n_threads = 4` equals one trained with
/// `n_threads = 1`: identical trees (structure + leaf stats), OOB
/// masks, and leaf tables.
#[test]
fn forest_training_identical_across_thread_counts() {
    for (kind, seed) in [
        (ForestKind::RandomForest, 11u64),
        (ForestKind::RandomForest, 12),
        (ForestKind::ExtraTrees, 13),
    ] {
        let data = synth::gaussian_blobs(300, 5, 3, 2.0, seed);
        let base = TrainConfig { kind, n_trees: 12, seed, ..Default::default() };
        let serial = Forest::train(&data, &TrainConfig { n_threads: 1, ..base.clone() });
        for th in THREAD_COUNTS {
            let par = Forest::train(&data, &TrainConfig { n_threads: th, ..base.clone() });
            assert_eq!(par.trees.len(), serial.trees.len());
            for (t, (a, b)) in par.trees.iter().zip(&serial.trees).enumerate() {
                assert_eq!(a.nodes, b.nodes, "{kind:?} seed {seed} th {th}: tree {t} structure");
                assert_eq!(
                    bits(&a.leaf_stats),
                    bits(&b.leaf_stats),
                    "{kind:?} seed {seed} th {th}: tree {t} leaf stats"
                );
                assert_eq!(a.n_leaves, b.n_leaves);
                assert_eq!(a.depth, b.depth);
            }
            assert_eq!(par.inbag, serial.inbag, "{kind:?} seed {seed} th {th}: OOB masks");
            assert_eq!(par.leaf_offsets, serial.leaf_offsets, "{kind:?} seed {seed} th {th}");
            assert_eq!(
                par.apply(&data),
                serial.apply(&data),
                "{kind:?} seed {seed} th {th}: leaf tables"
            );
        }
    }
}

/// End-to-end: the fitted kernel factors and the exact proximity matrix
/// are identical whatever the global thread knob says (the knob is
/// process-global, but since every path is bitwise-deterministic this
/// is safe to exercise even with concurrent tests).
#[test]
fn kernel_fit_identical_across_global_thread_knob() {
    use forest_kernels::swlc::{ForestKernel, ProximityKind};
    let data = synth::gaussian_blobs(250, 4, 3, 2.0, 21);
    let forest = Forest::train(&data, &TrainConfig { n_trees: 10, seed: 21, ..Default::default() });
    let reference: Vec<(Csr, Csr)> = ProximityKind::ALL
        .iter()
        .filter(|k| **k != ProximityKind::Boosted)
        .map(|&k| {
            let kern = ForestKernel::fit(&forest, &data, k);
            let p = kern.proximity_matrix();
            (kern.q.clone(), p)
        })
        .collect();
    for th in THREAD_COUNTS {
        forest_kernels::exec::set_threads(th);
        for (i, &k) in ProximityKind::ALL
            .iter()
            .filter(|k| **k != ProximityKind::Boosted)
            .enumerate()
        {
            let kern = ForestKernel::fit(&forest, &data, k);
            let p = kern.proximity_matrix();
            assert_eq!(kern.q, reference[i].0, "{k:?} th {th}: Q factor differs");
            assert_eq!(p, reference[i].1, "{k:?} th {th}: kernel differs");
        }
    }
    forest_kernels::exec::set_threads(0);
}
