//! Block-quantized factors end-to-end: quantization error bounds, the
//! compression ratio the bundle format banks on, neighbor recall of the
//! quantized kernel, and bitwise agreement between every quantized
//! compute path (full product, striped materialization, PCA projection)
//! and its reference.

use forest_kernels::coordinator::{self, CoordinatorConfig};
use forest_kernels::data::{registry, synth};
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::model::{encoded_csr_bytes, encoded_qcsr_bytes};
use forest_kernels::rng::Rng;
use forest_kernels::sparse::qcsr::{self, QuantMode, QBLOCK};
use forest_kernels::spectral::knn::rank_row;
use forest_kernels::spectral::pca::{leaf_pca, leaf_pca_project, leaf_pca_project_q};
use forest_kernels::swlc::{ForestKernel, ProximityKind};
use forest_kernels::Csr;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
    let mut trip = vec![];
    for r in 0..rows {
        for c in 0..cols {
            if rng.next_f64() < density {
                trip.push((r, c as u32, rng.next_normal() as f32));
            }
        }
    }
    Csr::from_triplets(rows, cols, &trip)
}

fn fit_kernel(n: usize, trees: usize, kind: ProximityKind, seed: u64) -> ForestKernel {
    let data = synth::gaussian_blobs(n, 5, 3, 2.0, seed);
    let forest = Forest::train(&data, &TrainConfig { n_trees: trees, seed, ..Default::default() });
    ForestKernel::fit(&forest, &data, kind)
}

/// Per-value reconstruction error is bounded by half a quantization
/// step of the value's own block: `|v̂ - v| ≤ max_abs(block)/(2·L)`
/// (L = 127 for int8, 7 for int4), and the sparsity structure survives
/// untouched.
#[test]
fn prop_quantize_dequantize_error_bounds() {
    for seed in 0..10u64 {
        for (mode, levels) in [(QuantMode::Int8, 127.0f64), (QuantMode::Int4, 7.0)] {
            let mut rng = Rng::new(seed ^ 0x51AB);
            let rows = 1 + rng.gen_range(60);
            let cols = 1 + rng.gen_range(80);
            let m = random_csr(&mut rng, rows, cols, 0.05 + rng.next_f64() * 0.5);
            let q = qcsr::quantize(&m, mode);
            let back = q.dequantize();
            assert_eq!(back.indptr, m.indptr, "seed {seed} {mode:?}: indptr");
            assert_eq!(back.indices, m.indices, "seed {seed} {mode:?}: indices");
            for r in 0..rows {
                let (_, vs) = m.row(r);
                let (_, ws) = back.row(r);
                for (b, (chunk, wchunk)) in
                    vs.chunks(QBLOCK).zip(ws.chunks(QBLOCK)).enumerate()
                {
                    let max_abs = chunk.iter().fold(0f64, |a, &v| a.max(v.abs() as f64));
                    let bound = max_abs / (2.0 * levels) * 1.001 + 1e-7;
                    for (j, (&v, &w)) in chunk.iter().zip(wchunk).enumerate() {
                        let err = (w as f64 - v as f64).abs();
                        assert!(
                            err <= bound,
                            "seed {seed} {mode:?} row {r} block {b} elem {j}: \
                             |{w} - {v}| = {err} > {bound}"
                        );
                    }
                }
            }
        }
    }
}

/// int8-quantized kernels must preserve neighbor structure: mean
/// recall@10 of the quantized product against the exact one stays at or
/// above 0.95 (KeRF weights — smooth values, no degenerate ties).
#[test]
fn int8_recall_at_10_stays_above_floor() {
    let kernel = fit_kernel(400, 30, ProximityKind::Kerf, 31);
    let p_exact = kernel.proximity_matrix();
    let qq = qcsr::quantize(&kernel.q, QuantMode::Int8);
    let qwt = qcsr::quantize(kernel.w_transpose(), QuantMode::Int8);
    let p_q = qcsr::spgemm_q(&qq, &qwt, 2);
    let n = p_exact.n_rows;
    let (mut tot, mut cnt) = (0f64, 0usize);
    for i in 0..n {
        let (ec, ev) = p_exact.row(i);
        let top: Vec<u32> = rank_row(ec, ev, Some(i), 10).into_iter().map(|(c, _)| c).collect();
        if top.is_empty() {
            continue;
        }
        let (qc, qv) = p_q.row(i);
        let got: std::collections::HashSet<u32> =
            rank_row(qc, qv, Some(i), 10).into_iter().map(|(c, _)| c).collect();
        tot += top.iter().filter(|c| got.contains(c)).count() as f64 / top.len() as f64;
        cnt += 1;
    }
    let recall = tot / cnt as f64;
    assert!(recall >= 0.95, "int8 recall@10 = {recall:.3} < 0.95 over {cnt} rows");
}

/// The artifact-size win the v2 bundle format exists for: serialized
/// quantized factors are at least ~3× (int8) / ~3.5× (int4) smaller
/// than the exact CSR encoding at a realistic forest configuration.
#[test]
fn quantized_encoding_shrinks_serialized_factors() {
    let spec = registry::by_name("covertype").expect("covertype registered");
    let data = spec.generate(2048, 7);
    let forest = Forest::train(
        &data,
        &TrainConfig { n_trees: 32, min_samples_leaf: 32, seed: 7, ..Default::default() },
    );
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let exact = encoded_csr_bytes(&kernel.q) + encoded_csr_bytes(kernel.w_transpose());
    for (mode, floor) in [(QuantMode::Int8, 2.8f64), (QuantMode::Int4, 3.5)] {
        let qbytes = encoded_qcsr_bytes(&qcsr::quantize(&kernel.q, mode))
            + encoded_qcsr_bytes(&qcsr::quantize(kernel.w_transpose(), mode));
        let ratio = exact as f64 / qbytes as f64;
        assert!(
            ratio >= floor,
            "{mode:?}: {exact} exact bytes / {qbytes} quantized = {ratio:.2}x < {floor}x"
        );
    }
}

/// A quantized kernel materialized through the striped coordinator
/// (scratch reused across stripes on each worker) is bitwise-identical
/// to its one-shot `proximity_matrix`, for both a plain-symmetric and a
/// unit-diagonal (OOB-separable) kind, at unfriendly stripe widths.
#[test]
fn quantized_materialize_matches_full_product_bitwise() {
    for (kind, seed) in [(ProximityKind::Kerf, 41u64), (ProximityKind::OobSeparable, 42)] {
        let mut kernel = fit_kernel(350, 25, kind, seed);
        kernel.set_quantization(Some(QuantMode::Int8));
        let p_full = kernel.proximity_matrix();
        for stripe_rows in [64usize, 113, 350, 512] {
            let cfg = CoordinatorConfig { stripe_rows, ..Default::default() };
            let (p_mat, _) = coordinator::materialize_to_csr(&kernel, &cfg);
            assert_eq!(p_mat.indptr, p_full.indptr, "{kind:?} stripe {stripe_rows}: indptr");
            assert_eq!(p_mat.indices, p_full.indices, "{kind:?} stripe {stripe_rows}: indices");
            assert_eq!(
                bits(&p_mat.data),
                bits(&p_full.data),
                "{kind:?} stripe {stripe_rows}: values"
            );
        }
    }
}

/// The quantized Leaf-PCA projection (`leaf_pca_project_q`, the serve
/// `/embed` path for quantized bundles) is bitwise-identical to the
/// exact projection over the dequantized factor.
#[test]
fn quantized_pca_projection_matches_dequantized_bitwise() {
    let data = synth::gaussian_blobs(300, 5, 3, 2.0, 51);
    let forest = Forest::train(&data, &TrainConfig { n_trees: 20, seed: 51, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let qq = qcsr::quantize(&kernel.q, QuantMode::Int8);
    let deq = qq.dequantize();
    let (scores, vals) = leaf_pca(&deq, 4, 12, false, 51);
    let queries = synth::gaussian_blobs(35, 5, 3, 2.0, 52);
    let qn = kernel.oos_query_map(&forest, &queries);
    let exact = leaf_pca_project(&deq, &scores, &vals, &qn);
    let quant = leaf_pca_project_q(&qq, &scores, &vals, &qn);
    assert_eq!(bits(&quant), bits(&exact), "quantized PCA projection differs");
}
