//! Out-of-core materialization: shard-sink → `ShardReader` roundtrips
//! must reproduce the in-memory CSR **bit for bit** for every proximity
//! kind, stripe size (including `stripe_rows = 1` and a single shard),
//! and under a `--mem-budget` smaller than the kernel's own footprint
//! (the ISSUE-2 acceptance shape, run at CI-friendly N).

use forest_kernels::coordinator::shard::{self, ShardReader, ShardSink};
use forest_kernels::coordinator::sink::{CsrSink, KernelSource, SparsifyConfig, SparsifySink};
use forest_kernels::coordinator::{self, CoordinatorConfig};
use forest_kernels::data::synth;
use forest_kernels::experiments::train_for;
use forest_kernels::forest::TrainConfig;
use forest_kernels::sparse::Csr;
use forest_kernels::swlc::{ForestKernel, ProximityKind};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fk-shard-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn fixture(n: usize, kind: ProximityKind, seed: u64) -> ForestKernel {
    let data = synth::gaussian_blobs(n, 4, 3, 2.0, seed);
    let cfg = TrainConfig { n_trees: 12, seed, ..Default::default() };
    let forest = train_for(&data, kind, &cfg);
    ForestKernel::fit(&forest, &data, kind)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_bitwise_eq(a: &Csr, b: &Csr, what: &str) {
    assert_eq!(a.n_rows, b.n_rows, "{what}: n_rows");
    assert_eq!(a.n_cols, b.n_cols, "{what}: n_cols");
    assert_eq!(a.indptr, b.indptr, "{what}: indptr");
    assert_eq!(a.indices, b.indices, "{what}: indices");
    assert_eq!(bits(&a.data), bits(&b.data), "{what}: values");
}

fn shard_roundtrip(kernel: &ForestKernel, cfg: &CoordinatorConfig, tag: &str) -> Csr {
    let dir = tmpdir(tag);
    let mut sink = ShardSink::create(&dir, kernel.w.n_rows, kernel.kind.name()).unwrap();
    coordinator::materialize_into(kernel, cfg, &mut sink).unwrap();
    sink.finish().unwrap();
    let reader = ShardReader::open(&dir).unwrap();
    let back = reader.read_csr().unwrap();
    back.check().unwrap();
    std::fs::remove_dir_all(&dir).ok();
    back
}

#[test]
fn prop_shard_roundtrip_bitwise_for_every_kind() {
    let n = 90;
    for (i, kind) in ProximityKind::ALL.into_iter().enumerate() {
        let kernel = fixture(n, kind, 17 + i as u64);
        let reference = coordinator::materialize_to_csr(
            &kernel,
            &CoordinatorConfig { stripe_rows: 32, n_workers: 2, queue_depth: 2 },
        )
        .0;
        // stripe_rows = 1 (one shard per row), a mid size, and a size
        // past N (single-shard edge case).
        for stripe_rows in [1usize, 17, 1000] {
            let cfg = CoordinatorConfig { stripe_rows, n_workers: 3, queue_depth: 2 };
            let tag = format!("{}-{stripe_rows}", kind.name());
            let back = shard_roundtrip(&kernel, &cfg, &tag);
            assert_bitwise_eq(&back, &reference, &tag);
        }
    }
}

#[test]
fn fragmented_range_materialization_merges_bitwise_for_every_kind() {
    // The in-library shape of the multi-process story (the real
    // process-spawning version lives in multiprocess_shards.rs): split
    // [0, N) by measured cost, materialize each range into a fragment
    // sink, merge, and require bitwise identity with the single-process
    // materialization — for every proximity kind and partition count.
    let n = 90;
    for (i, kind) in ProximityKind::ALL.into_iter().enumerate() {
        let kernel = fixture(n, kind, 29 + i as u64);
        let reference =
            coordinator::materialize_to_csr(&kernel, &CoordinatorConfig::default()).0;
        for parts in [2usize, 4] {
            let dir = tmpdir(&format!("frag-{}-{parts}", kind.name()));
            let cfg = CoordinatorConfig { stripe_rows: 13, n_workers: 2, queue_depth: 2 };
            for (k, r) in coordinator::partition_rows(&kernel, parts).iter().enumerate() {
                let mut sink = ShardSink::create_fragment(
                    &dir,
                    kernel.w.n_rows,
                    kernel.kind.name(),
                    k,
                    r.start,
                    n,
                )
                .unwrap();
                coordinator::materialize_range_into(&kernel, &cfg, r.clone(), &mut sink)
                    .unwrap();
                sink.finish().unwrap();
            }
            shard::merge_fragments(&dir).unwrap();
            shard::validate_dir(&dir).unwrap();
            let back = ShardReader::open(&dir).unwrap().read_csr().unwrap();
            assert_bitwise_eq(&back, &reference, &format!("{} P={parts}", kind.name()));
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

#[test]
fn mem_budget_smaller_than_kernel_still_roundtrips() {
    // The acceptance shape: a budget well below nnz(P)'s footprint
    // forces small stripes, the shard sink spills them, and the read
    // side reproduces the in-memory result exactly.
    let kernel = fixture(300, ProximityKind::Kerf, 23);
    let (reference, _) = coordinator::materialize_to_csr(&kernel, &CoordinatorConfig::default());
    let budget = reference.mem_bytes() / 8;
    let cfg = CoordinatorConfig::with_mem_budget(&kernel, budget);
    assert!(cfg.stripe_rows >= 1);
    assert!(cfg.stripe_rows < 300, "budget did not shrink stripes: {}", cfg.stripe_rows);
    let back = shard_roundtrip(&kernel, &cfg, "membudget");
    assert_bitwise_eq(&back, &reference, "mem-budget roundtrip");
}

#[test]
fn sparsified_shards_match_sparsified_memory() {
    // topk → shards and topk → csr must agree bit for bit.
    let kernel = fixture(120, ProximityKind::Original, 29);
    let cfg = CoordinatorConfig { stripe_rows: 13, n_workers: 2, queue_depth: 2 };
    let sp = SparsifyConfig { top_k: 5, epsilon: 0.0, keep_diagonal: true };

    let mut mem = SparsifySink::new(sp, CsrSink::new(kernel.w.n_rows));
    coordinator::materialize_into(&kernel, &cfg, &mut mem).unwrap();
    let mem = mem.into_inner().finish();
    mem.check().unwrap();

    let dir = tmpdir("topk-shards");
    let mut disk = SparsifySink::new(
        sp,
        ShardSink::create(&dir, kernel.w.n_rows, kernel.kind.name()).unwrap(),
    );
    coordinator::materialize_into(&kernel, &cfg, &mut disk).unwrap();
    disk.into_inner().finish().unwrap();
    let back = ShardReader::open(&dir).unwrap().read_csr().unwrap();
    std::fs::remove_dir_all(&dir).ok();

    assert_bitwise_eq(&back, &mem, "sparsified roundtrip");
    // Row arity is capped at top_k + diagonal.
    for r in 0..mem.n_rows {
        let (cols, _) = mem.row(r);
        assert!(cols.len() <= 6, "row {r}: {} entries", cols.len());
    }
}

#[test]
fn topk_sink_matches_bruteforce_selection() {
    let kernel = fixture(80, ProximityKind::Kerf, 31);
    let cfg = CoordinatorConfig { stripe_rows: 11, n_workers: 2, queue_depth: 2 };
    let (full, _) = coordinator::materialize_to_csr(&kernel, &cfg);
    let k = 4usize;
    let sp = SparsifyConfig { top_k: k, epsilon: 0.0, keep_diagonal: true };
    let mut sink = SparsifySink::new(sp, CsrSink::new(kernel.w.n_rows));
    coordinator::materialize_into(&kernel, &cfg, &mut sink).unwrap();
    let thin = sink.into_inner().finish();

    for r in 0..full.n_rows {
        // Brute-force reference: off-diagonal entries sorted by
        // (value desc, col asc), truncated to k, plus the diagonal.
        let (cols, vals) = full.row(r);
        let mut offdiag: Vec<(u32, f32)> = cols
            .iter()
            .zip(vals)
            .filter(|(&c, _)| c as usize != r)
            .map(|(&c, &v)| (c, v))
            .collect();
        offdiag.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        offdiag.truncate(k);
        let mut expect: Vec<u32> = offdiag.iter().map(|&(c, _)| c).collect();
        if cols.binary_search(&(r as u32)).is_ok() {
            expect.push(r as u32);
        }
        expect.sort_unstable();
        let (got, _) = thin.row(r);
        assert_eq!(got, &expect[..], "row {r}");
    }
}

#[test]
fn stream_consumers_agree_between_memory_and_shards() {
    // `KernelSource` consumers (kNN graph, streamed prediction) must
    // not care whether the kernel is in RAM or on disk.
    use forest_kernels::spectral::knn::knn_from_kernel;
    use forest_kernels::swlc::predict;

    let kernel = fixture(100, ProximityKind::Kerf, 37);
    let cfg = CoordinatorConfig { stripe_rows: 23, n_workers: 2, queue_depth: 2 };
    let (mem, _) = coordinator::materialize_to_csr(&kernel, &cfg);

    let dir = tmpdir("consumers");
    let mut sink = ShardSink::create(&dir, kernel.w.n_rows, kernel.kind.name()).unwrap();
    coordinator::materialize_into(&kernel, &cfg, &mut sink).unwrap();
    sink.finish().unwrap();
    let reader = ShardReader::open(&dir).unwrap();

    let g_mem = knn_from_kernel(&mem, 5).unwrap();
    let g_disk = knn_from_kernel(&reader, 5).unwrap();
    assert_eq!(g_mem.neighbors, g_disk.neighbors);
    assert_eq!(bits(&g_mem.dists), bits(&g_disk.dists));

    let y = &kernel.ctx.y;
    let c = kernel.ctx.n_classes;
    let s_mem = predict::scores_from_kernel(&mem, y, c).unwrap();
    let s_disk = predict::scores_from_kernel(&reader, y, c).unwrap();
    assert_eq!(bits(&s_mem), bits(&s_disk));
    // Sanity: KernelSource agrees on shape.
    assert_eq!(KernelSource::n_rows(&reader), mem.n_rows);
    assert_eq!(KernelSource::n_cols(&reader), mem.n_cols);

    std::fs::remove_dir_all(&dir).ok();
}
