//! Latency-tiered serving end-to-end: a v4 bundle carrying a shallow
//! companion forest serves `/predict` at per-request budgets —
//! `"cheap"` runs the companion, `"full"` (and the default) runs the
//! main model **bitwise-identically to a tierless server**, and
//! `"auto"` sheds to the cheap tier under queue pressure with zero
//! 5xx. The CI `serve-tier-matrix` job re-runs the bitwise test across
//! budget × mmap cells via `FK_TEST_BUDGET` / `FK_TEST_MMAP`.

use forest_kernels::data::synth;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::model::{BundleMeta, CompanionModel, MmapMode, ModelBundle};
use forest_kernels::runtime::json::Json;
use forest_kernels::serve::{http, ServeConfig, Server};
use forest_kernels::swlc::{ForestKernel, ProximityKind};
use forest_kernels::Dataset;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

const N: usize = 160;
const D: usize = 5;
const C: usize = 3;
const TREES: usize = 12;
const COMPANION_DEPTH: usize = 3;
const COMPANION_SUBSAMPLE: f32 = 0.5;

/// Deterministic two-tier fixture: the same full model as the tierless
/// fixture (same seed → bitwise-identical forest + factors) plus a
/// depth-capped, subsampled companion.
fn fixture(seed: u64, with_companion: bool) -> ModelBundle {
    let data = synth::gaussian_blobs(N, D, C, 2.2, seed);
    let forest =
        Forest::train(&data, &TrainConfig { n_trees: TREES, seed, ..Default::default() });
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let meta = BundleMeta { dataset: "blobs".into(), n: data.n, seed, trees: TREES };
    let companion = with_companion.then(|| {
        let ccfg = TrainConfig {
            n_trees: TREES,
            seed,
            max_depth: Some(COMPANION_DEPTH),
            max_samples: Some((N as f32 * COMPANION_SUBSAMPLE) as usize),
            ..Default::default()
        };
        let c_forest = Forest::train(&data, &ccfg);
        let c_kernel = ForestKernel::fit(&c_forest, &data, ProximityKind::Kerf);
        CompanionModel {
            forest: c_forest,
            kernel: c_kernel,
            depth: COMPANION_DEPTH,
            subsample: COMPANION_SUBSAMPLE,
        }
    });
    ModelBundle { forest, kernel, meta, companion }
}

/// Route the fixture through a saved file when the CI matrix asks for
/// a specific bundle bind mode (`FK_TEST_MMAP=on|off`); plain
/// in-process fixtures otherwise.
fn bind_fixture(seed: u64, with_companion: bool, tag: &str) -> ModelBundle {
    let mode = match std::env::var("FK_TEST_MMAP").ok().as_deref() {
        Some("on") => Some(MmapMode::On),
        Some("off") => Some(MmapMode::Off),
        _ => None,
    };
    let bundle = fixture(seed, with_companion);
    let Some(mode) = mode else { return bundle };
    let path = std::env::temp_dir().join(format!(
        "fk-serve-tiered-{tag}-{}-{}.fkb",
        std::process::id(),
        seed
    ));
    bundle.save(&path).unwrap();
    let (loaded, _) = ModelBundle::load_with_mode(&path, mode).unwrap();
    std::fs::remove_file(&path).ok();
    loaded
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        linger: Duration::from_millis(1),
        ..Default::default()
    }
}

fn row_json(data: &Dataset, i: usize) -> String {
    let mut s = String::from("[");
    for f in 0..data.d {
        if f > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("{}", data.x(i, f)));
    }
    s.push(']');
    s
}

fn tier_of(resp: &str) -> String {
    Json::parse(resp)
        .unwrap()
        .get("tier")
        .and_then(Json::as_str)
        .expect("predict response carries a tier")
        .to_string()
}

fn tier_counter(stats: &Json, key: &str) -> usize {
    stats.get("tiers").and_then(|t| t.get(key)).and_then(Json::as_usize).unwrap()
}

/// The acceptance-criterion test, and the body of every CI
/// `serve-tier-matrix` cell: full-tier responses from a tiered server
/// are byte-for-byte the tierless server's responses, the matrix
/// budget (`FK_TEST_BUDGET`, default `full`) is served without errors,
/// and a cheap-budget answer really comes from the companion.
#[test]
fn full_tier_matches_tierless_server_bitwise() {
    let tiered = Server::bind(bind_fixture(11, true, "tiered"), None, serve_cfg()).unwrap();
    let tierless = Server::bind(bind_fixture(11, false, "plain"), None, serve_cfg()).unwrap();
    let (t_addr, p_addr) = (tiered.addr(), tierless.addr());
    let (t_handle, p_handle) = (tiered.spawn(), tierless.spawn());

    let budget = std::env::var("FK_TEST_BUDGET").unwrap_or_else(|_| "full".into());
    let queries = synth::gaussian_blobs(10, D, C, 2.2, 999);
    for i in 0..queries.n {
        let row = row_json(&queries, i);
        // Explicit full budget and the budget-less default must both be
        // byte-identical to the tierless server's answer.
        for body in [
            format!("{{\"x\": {row}}}"),
            format!("{{\"x\": {row}, \"budget\": \"full\"}}"),
        ] {
            let (ts, tr) = http::http_request(&t_addr, "POST", "/predict", &body).unwrap();
            // The tierless server ignores any budget-independent
            // framing: compare against its plain-body answer.
            let plain = format!("{{\"x\": {row}}}");
            let (ps, pr) = http::http_request(&p_addr, "POST", "/predict", &plain).unwrap();
            assert_eq!((ts, ps), (200, 200), "query {i}: {tr} / {pr}");
            assert_eq!(tr, pr, "query {i}: full tier differs from the tierless server");
            assert_eq!(tier_of(&tr), "full", "query {i}");
        }
        // The matrix cell's budget is always serveable on this bundle.
        let body = format!("{{\"x\": {row}, \"budget\": \"{budget}\"}}");
        let (status, resp) = http::http_request(&t_addr, "POST", "/predict", &body).unwrap();
        assert_eq!(status, 200, "budget {budget}, query {i}: {resp}");
        match budget.as_str() {
            "cheap" => assert_eq!(tier_of(&resp), "cheap", "query {i}"),
            // An unpressured queue never sheds: auto serves full.
            _ => assert_eq!(tier_of(&resp), "full", "query {i}"),
        }
    }

    // Cheap answers come from the companion: same query, different
    // model, so the scores must differ from the full tier's.
    let row = row_json(&queries, 0);
    let (status, cheap) = http::http_request(
        &t_addr,
        "POST",
        "/predict",
        &format!("{{\"x\": {row}, \"budget\": \"cheap\"}}"),
    )
    .unwrap();
    assert_eq!(status, 200, "{cheap}");
    assert_eq!(tier_of(&cheap), "cheap");
    let (_, full) = http::http_request(
        &t_addr,
        "POST",
        "/predict",
        &format!("{{\"x\": {row}, \"budget\": \"full\"}}"),
    )
    .unwrap();
    let scores = |resp: &str| format!("{:?}", Json::parse(resp).unwrap().get("scores"));
    assert_ne!(
        scores(&cheap),
        scores(&full),
        "cheap tier returned the full model's scores — companion not in use"
    );

    // /healthz advertises the companion so routers/operators can see
    // which replicas are tier-capable.
    let (status, resp) = http::http_request(&t_addr, "GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&resp).unwrap();
    let companion = j.get("companion").expect("healthz carries a companion object");
    assert_eq!(companion.get("depth").and_then(Json::as_usize), Some(COMPANION_DEPTH));
    assert_eq!(companion.get("trees").and_then(Json::as_usize), Some(TREES));
    let (_, resp) = http::http_request(&p_addr, "GET", "/healthz", "").unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(
        matches!(j.get("companion"), Some(Json::Null)),
        "tierless healthz must report companion: null"
    );

    t_handle.stop();
    p_handle.stop();
}

#[test]
fn cheap_budget_without_companion_is_rejected_cleanly() {
    let server = Server::bind(fixture(12, false), None, serve_cfg()).unwrap();
    let addr = server.addr();
    let handle = server.spawn();
    let queries = synth::gaussian_blobs(1, D, C, 2.2, 333);
    let row = row_json(&queries, 0);

    let body = format!("{{\"x\": {row}, \"budget\": \"cheap\"}}");
    let (status, resp) = http::http_request(&addr, "POST", "/predict", &body).unwrap();
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("companion"), "unhelpful error: {resp}");

    // Unknown budgets are 400s; auto without a companion serves full.
    let body = format!("{{\"x\": {row}, \"budget\": \"luxurious\"}}");
    let (status, _) = http::http_request(&addr, "POST", "/predict", &body).unwrap();
    assert_eq!(status, 400);
    let body = format!("{{\"x\": {row}, \"budget\": \"auto\"}}");
    let (status, resp) = http::http_request(&addr, "POST", "/predict", &body).unwrap();
    assert_eq!(status, 200, "{resp}");
    assert_eq!(tier_of(&resp), "full");

    handle.stop();
}

/// The admission-control contract: hammered past the bounded queue's
/// capacity, `auto` requests degrade to the cheap tier — never a 5xx,
/// never a timeout — and the `/stats` tier counters stay mutually
/// consistent while strictly growing.
#[test]
fn auto_sheds_to_cheap_under_queue_pressure_with_zero_errors() {
    // queue_depth 2 with 8-row requests: every auto request sees
    // queue_len + 8 > 2 and sheds deterministically.
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        linger: Duration::from_millis(1),
        queue_depth: 2,
        ..Default::default()
    };
    let server = Server::bind(fixture(13, true), None, cfg).unwrap();
    let addr = server.addr();
    let handle = server.spawn();

    let queries = synth::gaussian_blobs(8, D, C, 2.2, 444);
    let mut batch = String::from("{\"x\": [");
    for i in 0..queries.n {
        if i > 0 {
            batch.push_str(", ");
        }
        batch.push_str(&row_json(&queries, i));
    }
    let auto_body = format!("{batch}], \"budget\": \"auto\"}}");
    let full_body = format!("{batch}], \"budget\": \"full\"}}");

    let clients = 4;
    let per_client = 8;
    let five_xx = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| {
                for _ in 0..per_client {
                    let (status, resp) =
                        http::http_request(&addr, "POST", "/predict", &auto_body).unwrap();
                    if status >= 500 {
                        five_xx.fetch_add(1, Ordering::Relaxed);
                    } else {
                        assert_eq!(status, 200, "{resp}");
                        if tier_of(&resp) == "cheap" {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    assert_eq!(five_xx.load(Ordering::Relaxed), 0, "admission control must not 5xx");
    let total_auto = clients * per_client;
    assert_eq!(
        shed.load(Ordering::Relaxed),
        total_auto,
        "every over-capacity auto request should shed to the cheap tier"
    );

    // A couple of explicit full requests so both tiers have traffic.
    for _ in 0..2 {
        let (status, resp) =
            http::http_request(&addr, "POST", "/predict", &full_body).unwrap();
        assert_eq!(status, 200, "{resp}");
        assert_eq!(tier_of(&resp), "full");
    }

    // Tier counters: mutually consistent now, and monotone between
    // scrapes.
    let scrape = || {
        let (status, resp) = http::http_request(&addr, "GET", "/stats", "").unwrap();
        assert_eq!(status, 200);
        Json::parse(&resp).unwrap()
    };
    let j = scrape();
    let (pf, pc) = (tier_counter(&j, "predict_full"), tier_counter(&j, "predict_cheap"));
    let (pa, sh) = (tier_counter(&j, "predict_auto"), tier_counter(&j, "shed_to_cheap"));
    let predict_total = j
        .get("requests")
        .and_then(|r| r.get("predict"))
        .and_then(Json::as_usize)
        .unwrap();
    assert_eq!(pa, total_auto, "every auto request is counted as requested-auto");
    assert_eq!(sh, total_auto, "every auto request shed under pressure");
    assert_eq!(pc, total_auto, "shed requests are served (and counted) cheap");
    assert_eq!(pf, 2, "explicit full requests served full");
    assert_eq!(pf + pc, predict_total, "served-by-tier counts must sum to /predict total");
    assert!(sh <= pc, "sheds are a subset of cheap-served requests");
    let samples = |key: &str| {
        j.get("tiers")
            .and_then(|t| t.get(key))
            .and_then(|l| l.get("samples"))
            .and_then(Json::as_usize)
            .unwrap()
    };
    assert_eq!(samples("cheap_latency_secs"), pc);
    assert_eq!(samples("full_latency_secs"), pf);

    let j2 = scrape();
    for key in ["predict_full", "predict_cheap", "predict_auto", "shed_to_cheap"] {
        assert!(
            tier_counter(&j2, key) >= tier_counter(&j, key),
            "{key} went backwards between scrapes"
        );
    }

    handle.stop();
}
