//! Bench: PJRT execution of the AOT Pallas proximity tile vs the same
//! tile evaluated by a plain Rust loop. (The Pallas kernel is lowered
//! with interpret=True — CPU wallclock is NOT a TPU perf proxy; this
//! bench tracks dispatch + marshalling overhead of the serving path.)

use forest_kernels::bench_support::bench;
use forest_kernels::rng::Rng;
use forest_kernels::runtime::Runtime;

fn main() {
    let Ok(rt) = Runtime::load(std::path::Path::new("artifacts")) else {
        println!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let (bq, br, t) = (128, 128, 64);
    let mut rng = Rng::new(1);
    let leaf_q: Vec<i32> = (0..bq * t).map(|_| rng.gen_range(50) as i32).collect();
    let leaf_w: Vec<i32> = (0..br * t).map(|_| rng.gen_range(50) as i32).collect();
    let q: Vec<f32> = (0..bq * t).map(|_| rng.next_f32()).collect();
    let w: Vec<f32> = (0..br * t).map(|_| rng.next_f32()).collect();
    let xla = bench("xla prox tile 128x128x64", 10, || {
        rt.prox_block(bq, br, t, &leaf_q, &q, &leaf_w, &w).unwrap()
    });
    let rust = bench("rust prox tile 128x128x64", 10, || {
        let mut out = vec![0f32; bq * br];
        for i in 0..bq {
            for j in 0..br {
                let mut acc = 0f32;
                for tt in 0..t {
                    if leaf_q[i * t + tt] == leaf_w[j * t + tt] {
                        acc += q[i * t + tt] * w[j * t + tt];
                    }
                }
                out[i * br + j] = acc;
            }
        }
        out
    });
    println!("  -> xla/rust ratio {:.2} (interpret-mode Pallas; see DESIGN.md §Hardware-Adaptation)", xla / rust);
}
