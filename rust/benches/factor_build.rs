//! Bench: context θ build + sparse factor construction (the O(NT h̄)
//! stage of §3.3), per proximity kind.

use forest_kernels::bench_support::bench;
use forest_kernels::data::registry;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::swlc::{kernel::incidence_matrix, weights, EnsembleContext, ProximityKind};

fn main() {
    let n = 16384;
    let data = registry::by_name("covertype").unwrap().generate(n, 1);
    let forest = Forest::train(&data, &TrainConfig { n_trees: 50, seed: 2, ..Default::default() });
    bench(&format!("context_build N={n} T=50"), 3, || EnsembleContext::build(&forest, &data));
    let ctx = EnsembleContext::build(&forest, &data);
    for kind in [
        ProximityKind::Original,
        ProximityKind::Kerf,
        ProximityKind::OobSeparable,
        ProximityKind::RfGap,
        ProximityKind::InstanceHardness,
    ] {
        bench(&format!("factors {}", kind.name()), 3, || {
            let spec = weights::assign(kind, &ctx);
            let q = incidence_matrix(&ctx.leaf_of, &spec.q, ctx.n, ctx.t, ctx.l);
            let w = if spec.symmetric {
                q.clone()
            } else {
                incidence_matrix(&ctx.leaf_of, &spec.w, ctx.n, ctx.t, ctx.l)
            };
            (q, w.transpose())
        });
    }
}
