//! Bench: the headline comparison — exact factored kernel vs the naive
//! O(N²T) all-pairs evaluation, with the crossover and speedup curve.

use forest_kernels::data::registry;
use forest_kernels::experiments::{fig42, measure_kernel_cost};
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::swlc::ProximityKind;

fn main() {
    let t = 32;
    println!("N\tnaive_s\tfactored_s\tspeedup");
    for n in [512usize, 1024, 2048, 4096, 8192] {
        let naive = fig42::naive_cost(n, "covertype", t, 3).expect("known dataset");
        let data = registry::by_name("covertype").unwrap().generate(n, 3);
        let forest = Forest::train(&data, &TrainConfig { n_trees: t, seed: 3, ..Default::default() });
        let c = measure_kernel_cost(&forest, &data, ProximityKind::Original);
        println!("{n}\t{naive:.4}\t{:.4}\t{:.1}x", c.secs_total(), naive / c.secs_total());
    }
}
