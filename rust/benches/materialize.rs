//! Bench: kernel materialization sinks — in-memory CSR assembly vs the
//! spill-to-disk shard sink vs streaming the shards back. The write
//! path should track the CSR path (the product dominates; serialization
//! is one sequential pass), and the scan should be I/O-bound.

use forest_kernels::bench_support::{bench, peak_rss_bytes};
use forest_kernels::coordinator::shard::{ShardReader, ShardSink};
use forest_kernels::coordinator::sink::{CsrSink, SparsifyConfig, SparsifySink};
use forest_kernels::coordinator::{self, CoordinatorConfig};
use forest_kernels::data::registry;
use forest_kernels::forest::{Forest, TrainConfig};
use forest_kernels::swlc::{ForestKernel, ProximityKind};

fn main() {
    let n = 16384usize;
    let trees = 32usize;
    let data = registry::by_name("covertype").unwrap().generate(n, 1);
    let cfg = TrainConfig { n_trees: trees, seed: 2, ..Default::default() };
    let forest = Forest::train(&data, &cfg);
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let cc = CoordinatorConfig { stripe_rows: 2048, ..Default::default() };

    bench(&format!("materialize csr N={n} T={trees}"), 3, || {
        coordinator::materialize_to_csr(&kernel, &cc)
    });

    let dir = std::env::temp_dir().join(format!("fk-bench-mat-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    bench(&format!("materialize shards N={n} T={trees}"), 3, || {
        // `create` clears the previous iteration's shards itself — the
        // stale-generation sweep is an intrinsic cost of the sink.
        let mut sink = ShardSink::create(&dir, kernel.w.n_rows, "kerf").unwrap();
        coordinator::materialize_into(&kernel, &cc, &mut sink).unwrap();
        sink.finish().unwrap()
    });

    bench(&format!("shard read-back scan N={n}"), 3, || {
        let reader = ShardReader::open(&dir).unwrap();
        let mut nnz = 0u64;
        reader
            .for_each_stripe(|s| {
                nnz += s.rows.nnz() as u64;
                Ok(())
            })
            .unwrap();
        nnz
    });

    bench(&format!("materialize top-32 sparsified N={n}"), 3, || {
        let sp = SparsifyConfig { top_k: 32, epsilon: 0.0, keep_diagonal: true };
        let mut sink = SparsifySink::new(sp, CsrSink::new(kernel.w.n_rows));
        coordinator::materialize_into(&kernel, &cc, &mut sink).unwrap();
        sink.into_inner().finish()
    });

    std::fs::remove_dir_all(&dir).ok();
    println!("peak RSS {:.1} MB", peak_rss_bytes() as f64 / 1e6);
}
