//! Bench: Gustavson SpGEMM on SWLC-shaped factors — the paper's core
//! cost center (§3.3). Reports measured time vs the predicted
//! N·T·λ̄ flop count, i.e. effective flops/s of the accumulate loop.

use forest_kernels::bench_support::bench;
use forest_kernels::data::registry;
use forest_kernels::experiments::train_for;
use forest_kernels::forest::TrainConfig;
use forest_kernels::sparse::{spgemm, spgemm_nnz_flops};
use forest_kernels::swlc::{ForestKernel, ProximityKind};

fn main() {
    for (n, t) in [(8192usize, 32usize), (16384, 32), (16384, 64)] {
        let data = registry::by_name("covertype").unwrap().generate(n, 1);
        let cfg = TrainConfig { n_trees: t, seed: 2, ..Default::default() };
        let forest = train_for(&data, ProximityKind::Kerf, &cfg);
        let k = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
        let flops = spgemm_nnz_flops(&k.q, k.w_transpose());
        let median = bench(&format!("spgemm N={n} T={t} flops={flops}"), 3, || {
            spgemm(&k.q, k.w_transpose())
        });
        println!("  -> {:.1} Mflops/s effective", flops as f64 / median / 1e6);
    }
}
