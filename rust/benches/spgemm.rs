//! Bench: Gustavson SpGEMM on SWLC-shaped factors — the paper's core
//! cost center (§3.3). Reports measured time vs the predicted
//! N·T·λ̄ flop count (effective flops/s of the accumulate loop) and the
//! serial-vs-parallel speedup on the shared exec pool.

use forest_kernels::bench_support::bench;
use forest_kernels::data::registry;
use forest_kernels::exec;
use forest_kernels::experiments::train_for;
use forest_kernels::forest::TrainConfig;
use forest_kernels::sparse::{spgemm_nnz_flops, spgemm_with_threads};
use forest_kernels::swlc::{ForestKernel, ProximityKind};

fn main() {
    let threads = exec::threads();
    for (n, t) in [(8192usize, 32usize), (16384, 32), (16384, 64)] {
        let data = registry::by_name("covertype").unwrap().generate(n, 1);
        let cfg = TrainConfig { n_trees: t, seed: 2, ..Default::default() };
        let forest = train_for(&data, ProximityKind::Kerf, &cfg);
        let k = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
        let (flops, nnz_ub) = spgemm_nnz_flops(&k.q, k.w_transpose());
        let serial = bench(&format!("spgemm serial N={n} T={t} flops={flops} nnz<={nnz_ub}"), 3, || {
            spgemm_with_threads(&k.q, k.w_transpose(), 1)
        });
        let par = bench(&format!("spgemm {threads}-thread N={n} T={t}"), 3, || {
            spgemm_with_threads(&k.q, k.w_transpose(), threads)
        });
        println!(
            "  -> {:.1} Mflops/s serial, {:.1} Mflops/s parallel, speedup {:.2}x at {threads} threads",
            flops as f64 / serial / 1e6,
            flops as f64 / par / 1e6,
            serial / par
        );
    }
}
