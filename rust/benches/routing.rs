//! Bench: forest routing (the ℓ_t maps, O(N·T·h̄)) and prediction.

use forest_kernels::bench_support::bench;
use forest_kernels::data::registry;
use forest_kernels::forest::{Forest, TrainConfig};

fn main() {
    for (name, n, t) in [("covertype", 32768usize, 50usize), ("higgs", 65536, 32)] {
        let data = registry::by_name(name).unwrap().generate(n, 1);
        let forest = Forest::train(
            &data,
            &TrainConfig { n_trees: t, seed: 2, max_samples: Some(50_000), ..Default::default() },
        );
        let binned = forest.binner.bin(&data);
        bench(&format!("bin {name} N={n}"), 3, || forest.binner.bin(&data));
        let median = bench(&format!("route {name} N={n} T={t} h̄={:.1}", forest.mean_depth()), 3, || {
            forest.apply_binned(&binned)
        });
        println!("  -> {:.1} M leaf-lookups/s", (n * t) as f64 / median / 1e6);
    }
}
