//! PJRT runtime: load and execute the AOT HLO-text artifacts.
//!
//! `python/compile/aot.py` lowers the L2 jax graphs (which call the L1
//! Pallas kernels) to HLO *text* — the interchange the bundled
//! xla_extension 0.5.1 accepts (jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids it rejects). This module compiles every
//! artifact in the manifest once on the PJRT CPU client and exposes
//! typed execution; Python never runs on the request path.
//!
//! The XLA backend is gated behind the `xla` cargo feature (the
//! offline build has no `xla` bindings crate): without it the manifest
//! layer below still parses and validates, and [`Runtime::load`]
//! returns a descriptive error instead of compiling, so every caller —
//! CLI `serve`, the gallery service, benches — degrades gracefully.

pub mod json;

use crate::error::{Context, Result};
use crate::{anyhow, bail};
use json::Json;
use std::collections::HashMap;
use std::path::Path;

/// Tensor signature of one executable input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest entry missing dtype"))?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest entry missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype, shape })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: name, HLO file, and its I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// A typed input tensor for execution.
pub enum Tensor<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl Tensor<'_> {
    fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32(_) => "float32",
            Tensor::I32(_) => "int32",
        }
    }

    fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    #[cfg(feature = "xla")]
    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(v) => xla::Literal::vec1(v),
            Tensor::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// Parse `manifest.json` from an artifacts directory.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
    if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
        bail!("unsupported manifest format (want hlo-text)");
    }
    let arts = j
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
    arts.iter()
        .map(|a| {
            Ok(ArtifactSpec {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing name"))?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing file"))?
                    .to_string(),
                inputs: a
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact missing inputs"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                output: TensorSpec::from_json(
                    a.get("output").ok_or_else(|| anyhow!("artifact missing output"))?,
                )?,
            })
        })
        .collect()
}

/// Pick the smallest spec named `prox_{BQ}x{BR}x{T}` that fits
/// `(bq, br, t)` (caller pads up). Returns `(BQ, BR, T)`.
fn best_prox_in<'a>(
    names: impl Iterator<Item = &'a str>,
    bq: usize,
    br: usize,
    t: usize,
) -> Option<(usize, usize, usize)> {
    let mut best: Option<(usize, usize, usize)> = None;
    for name in names {
        if let Some(rest) = name.strip_prefix("prox_") {
            let dims: Vec<usize> = rest.split('x').filter_map(|p| p.parse().ok()).collect();
            if let [d0, d1, d2] = dims[..] {
                if d0 >= bq && d1 >= br && d2 >= t {
                    let cand = (d0, d1, d2);
                    if best.map_or(true, |b| cand.0 * cand.1 * cand.2 < b.0 * b.1 * b.2) {
                        best = Some(cand);
                    }
                }
            }
        }
    }
    best
}

/// The PJRT runtime: one compiled executable per manifest artifact.
#[cfg(feature = "xla")]
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    execs: HashMap<String, (xla::PjRtLoadedExecutable, ArtifactSpec)>,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Compile every artifact in `dir` on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let specs = load_manifest(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut execs = HashMap::new();
        for spec in specs {
            let proto = xla::HloModuleProto::from_text_file(dir.join(&spec.file))
                .with_context(|| format!("loading HLO text {}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {}", spec.name))?;
            execs.insert(spec.name.clone(), (exe, spec));
        }
        Ok(Runtime { client, execs })
    }

    /// Names of the loaded executables.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.execs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.execs.get(name).map(|(_, s)| s)
    }

    /// Execute artifact `name` with shape/dtype-checked inputs; returns
    /// the flat f32 output (all our graphs return one f32 tensor,
    /// lowered as a 1-tuple).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<f32>> {
        let (exe, spec) = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; loaded: {:?}", self.names()))?;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.dtype() != ts.dtype {
                bail!("{name}: input {i} dtype {} != manifest {}", t.dtype(), ts.dtype);
            }
            if t.len() != ts.numel() {
                bail!("{name}: input {i} has {} elements, manifest wants {:?}", t.len(), ts.shape);
            }
            literals.push(t.to_literal(&ts.shape)?);
        }
        // fk-lint: allow(no-panic-in-serve) -- PJRT execute() yields exactly one buffer per replica/partition for these single-device AOT artifacts
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?; // aot.py lowers with return_tuple=True
        Ok(out.to_vec::<f32>()?)
    }

    /// Convenience: run a `prox_{BQ}x{BR}x{T}` proximity tile.
    pub fn prox_block(
        &self,
        bq: usize,
        br: usize,
        t: usize,
        leaf_q: &[i32],
        q: &[f32],
        leaf_w: &[i32],
        w: &[f32],
    ) -> Result<Vec<f32>> {
        let name = format!("prox_{bq}x{br}x{t}");
        self.execute(
            &name,
            &[Tensor::I32(leaf_q), Tensor::F32(q), Tensor::I32(leaf_w), Tensor::F32(w)],
        )
    }

    /// Pick the smallest available prox variant that fits `(bq, br, t)`
    /// (caller pads up). Returns `(BQ, BR, T)`.
    pub fn best_prox_variant(&self, bq: usize, br: usize, t: usize) -> Option<(usize, usize, usize)> {
        best_prox_in(self.execs.keys().map(|s| s.as_str()), bq, br, t)
    }
}

/// Stub runtime for builds without the `xla` feature: the manifest
/// layer works, loading fails with a clear message, and the execution
/// API keeps the same shape so callers compile unchanged.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    specs: HashMap<String, ArtifactSpec>,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    /// Validate the manifest, then report that execution is unavailable.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let specs = load_manifest(dir)?;
        drop(specs);
        bail!(
            "PJRT runtime disabled: this binary was built without the `xla` cargo feature \
             (enable it with `cargo build --features xla` once the vendored xla bindings \
             are available)"
        )
    }

    /// Manifest-only construction (tests of the serving plumbing).
    pub fn from_manifest(dir: &Path) -> Result<Runtime> {
        let specs = load_manifest(dir)?;
        Ok(Runtime { specs: specs.into_iter().map(|s| (s.name.clone(), s)).collect() })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<f32>> {
        // Keep the dtype/shape validation observable even without XLA.
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; loaded: {:?}", self.names()))?;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        for (i, (t, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.dtype() != ts.dtype {
                bail!("{name}: input {i} dtype {} != manifest {}", t.dtype(), ts.dtype);
            }
            if t.len() != ts.numel() {
                bail!("{name}: input {i} has {} elements, manifest wants {:?}", t.len(), ts.shape);
            }
        }
        bail!("cannot execute {name}: built without the `xla` feature")
    }

    pub fn prox_block(
        &self,
        bq: usize,
        br: usize,
        t: usize,
        leaf_q: &[i32],
        q: &[f32],
        leaf_w: &[i32],
        w: &[f32],
    ) -> Result<Vec<f32>> {
        let name = format!("prox_{bq}x{br}x{t}");
        self.execute(
            &name,
            &[Tensor::I32(leaf_q), Tensor::F32(q), Tensor::I32(leaf_w), Tensor::F32(w)],
        )
    }

    pub fn best_prox_variant(&self, bq: usize, br: usize, t: usize) -> Option<(usize, usize, usize)> {
        best_prox_in(self.specs.keys().map(|s| s.as_str()), bq, br, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need compiled artifacts live in
    // rust/tests/runtime_artifacts.rs; here we cover the manifest layer.

    #[test]
    fn tensor_spec_numel() {
        let t = TensorSpec { dtype: "float32".into(), shape: vec![4, 8] };
        assert_eq!(t.numel(), 32);
    }

    #[test]
    fn manifest_parsing_roundtrip() {
        let dir = std::env::temp_dir().join("fk_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "hlo-text", "artifacts": [
                {"name": "a", "file": "a.hlo.txt",
                 "inputs": [{"dtype": "int32", "shape": [2, 3]}],
                 "output": {"dtype": "float32", "shape": [2, 2]}}]}"#,
        )
        .unwrap();
        let specs = load_manifest(&dir).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].inputs[0].shape, vec![2, 3]);
        assert_eq!(specs[0].output.dtype, "float32");
    }

    #[test]
    fn manifest_missing_is_error() {
        let dir = std::env::temp_dir().join("fk_manifest_none");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load_manifest(&dir).is_err());
    }

    #[test]
    fn prox_variant_selection_prefers_smallest_fit() {
        let names = ["prox_128x128x64", "prox_64x64x64", "prox_256x256x128", "other"];
        let got = best_prox_in(names.iter().copied(), 32, 32, 50);
        assert_eq!(got, Some((64, 64, 64)));
        assert_eq!(best_prox_in(names.iter().copied(), 1, 1, 200), None);
    }
}
