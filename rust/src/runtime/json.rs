//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! The offline vendor set has no serde, so this ~150-line recursive-
//! descent parser covers the JSON subset the AOT manifest uses: objects,
//! arrays, strings (no surrogate escapes), numbers, booleans, null.

use std::collections::HashMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<(), String> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", ch as char, pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                let c = b[*pos];
                *pos += 1;
                out.push(match c {
                    b'n' => '\n',
                    b't' => '\t',
                    b'r' => '\r',
                    b'"' => '"',
                    b'\\' => '\\',
                    b'/' => '/',
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| "bad \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        *pos += 4;
                        char::from_u32(code).unwrap_or('\u{FFFD}')
                    }
                    other => other as char,
                });
            }
            c => {
                // Copy raw UTF-8 bytes through.
                let ch_len = utf8_len(c);
                out.push_str(
                    std::str::from_utf8(&b[*pos..*pos + ch_len]).map_err(|_| "bad utf8")?,
                );
                *pos += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'[')?;
    let mut out = vec![];
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos}")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'{')?;
    let mut out = HashMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{
          "format": "hlo-text",
          "artifacts": [
            {"name": "prox_128x128x64", "file": "prox_128x128x64.hlo.txt",
             "inputs": [{"dtype": "int32", "shape": [128, 64]}],
             "output": {"dtype": "float32", "shape": [128, 128]}}
          ]
        }"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        let shape = arts[0].get("output").unwrap().get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(128));
    }

    #[test]
    fn parses_scalars_and_escapes() {
        let j = Json::parse(r#"{"a": -1.5e3, "b": true, "c": null, "d": "x\nyA"}"#).unwrap();
        assert_eq!(j.get("a"), Some(&Json::Num(-1500.0)));
        assert_eq!(j.get("b"), Some(&Json::Bool(true)));
        assert_eq!(j.get("c"), Some(&Json::Null));
        assert_eq!(j.get("d").unwrap().as_str(), Some("x\nyA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{\"a\": 1} extra").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert!(matches!(Json::parse("{}").unwrap(), Json::Obj(m) if m.is_empty()));
    }
}
