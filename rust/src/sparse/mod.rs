//! Sparse linear algebra substrate.
//!
//! The paper reduces forest-proximity computation to sparse products over
//! leaf-incidence matrices (`P = QᵀW`, Prop. 3.6), whose cost model —
//! "the product is accumulated only through shared non-zero column
//! indices" (§3.3) — is exactly the cost of Gustavson's row-wise SpGEMM.
//! This module provides the CSR representation and the kernels the SWLC
//! layer is built on: triplet→CSR assembly, transpose, SpGEMM with both
//! dense-scratch and hash-map accumulators, SpMV/SpMM, and row/column
//! scaling.
//!
//! [`qcsr`] adds the opt-in compressed companion: block-quantized
//! int8/int4 factors ([`QCsr`]) with delta-compressed indices and
//! quantized SpGEMM/SpMM kernels that accumulate in f32.

pub mod buf;
mod csr;
mod ops;
pub mod qcsr;
mod spgemm;

pub use buf::Buf;
pub use csr::Csr;
pub use ops::{scale_cols, scale_rows};
pub use qcsr::{QCsr, QuantMode};
pub use spgemm::{spgemm, spgemm_nnz_flops, spgemm_with_scratch, spgemm_with_threads, SpaScratch};
