//! Row/column scaling and misc. sparse utilities.
//!
//! Weight assignments in the SWLC family factor into per-sample and
//! per-leaf terms (App. B): per-sample terms are row scalings of the
//! binary leaf-incidence matrix, per-leaf terms are column scalings.
//! Expressing them this way lets every scheme share one incidence build.

use super::Csr;

/// In-place `A ← diag(s)·A` (scale row `i` by `s[i]`).
pub fn scale_rows(a: &mut Csr, s: &[f32]) {
    assert_eq!(s.len(), a.n_rows);
    for r in 0..a.n_rows {
        let (lo, hi) = (a.indptr[r], a.indptr[r + 1]);
        let f = s[r];
        for v in &mut a.data[lo..hi] {
            *v *= f;
        }
    }
}

/// In-place `A ← A·diag(s)` (scale column `j` by `s[j]`).
pub fn scale_cols(a: &mut Csr, s: &[f32]) {
    assert_eq!(s.len(), a.n_cols);
    for k in 0..a.indices.len() {
        a.data[k] *= s[a.indices[k] as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)])
    }

    #[test]
    fn row_scaling() {
        let mut m = sample();
        scale_rows(&mut m, &[2.0, -1.0]);
        assert_eq!(m.to_dense(), vec![2., 0., 4., 0., -3., 0.]);
    }

    #[test]
    fn col_scaling() {
        let mut m = sample();
        scale_cols(&mut m, &[0.0, 10.0, 0.5]);
        assert_eq!(m.to_dense(), vec![0., 0., 1., 0., 30., 0.]);
    }

    #[test]
    fn scalings_commute() {
        let mut a = sample();
        scale_rows(&mut a, &[2.0, 3.0]);
        scale_cols(&mut a, &[1.0, 2.0, 3.0]);
        let mut b = sample();
        scale_cols(&mut b, &[1.0, 2.0, 3.0]);
        scale_rows(&mut b, &[2.0, 3.0]);
        assert_eq!(a.to_dense(), b.to_dense());
    }
}
