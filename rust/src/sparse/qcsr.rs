//! Block-quantized CSR factors (`QCsr`): int8/int4 values + compressed
//! column indices, with a quantized SpGEMM/SpMM fast path.
//!
//! The SWLC hot paths (stripe SpGEMM, serve tiles, subspace iteration)
//! are memory-bandwidth-bound over the factor value/index arrays, so a
//! QLORA/RFX-style block quantization of the factors trades a bounded,
//! *documented* value error for a 3–4× smaller working set and bundle
//! artifact. The exact f32 [`Csr`] path stays canonical: quantization is
//! opt-in (`--quantize {none,int8,int4}`) and validated on neighbor
//! ranking (recall@k vs exact), never on bitwise equality.
//!
//! # Storage layout
//!
//! Values are quantized in **row-local fixed-size blocks** of
//! [`QBLOCK`] entries (the last block of a row may be short) with one
//! f32 scale per block, so row slicing and per-row decode never cross a
//! scale boundary:
//!
//! * `Int8`: one byte per entry, `q ∈ [-127, 127]`.
//! * `Int4`: two entries per byte (low nibble first), nibble stores
//!   `q + 8` with `q ∈ [-7, 7]` (nibble value 0 is unused).
//!
//! Column indices are stored as per-entry **delta varints**: the first
//! entry of a row stores its absolute column, each later entry stores
//! `col - prev - 1` (columns are strictly increasing). Deltas `< 255`
//! take one byte; larger ones take an `0xFF` escape byte plus a `u32`
//! little-endian payload. For SWLC factors (leaf gaps ≈ L/T, sample
//! gaps ≈ N/leaf-size) almost every delta fits in one byte.
//!
//! # Deterministic rounding rule
//!
//! Per block, `scale = max|v| / L` with `L = 127` (int8) or `7` (int4),
//! and `q = clamp(round(v · L / max|v|), -L, L)` using f32 arithmetic
//! and `f32::round` (round-half-away-from-zero). The dequantized value
//! is `v̂ = q · scale`, so `|v̂ - v| ≤ scale/2` up to f32 rounding. An
//! all-zero block stores `scale = 0`. The rule involves no
//! platform-dependent operations, so quantizing the same factor yields
//! identical bytes everywhere.
//!
//! # Compute path
//!
//! The quantized SpGEMM/SpMM kernels decode one row at a time into a
//! reused scratch ([`QRowScratch`]): the column loop walks the varint
//! stream, then the value loop dequantizes block-by-block — a
//! contiguous, branch-free multiply per block that the autovectorizer
//! turns into SIMD-width code. Accumulation is in f32 through the same
//! SPA ([`SpaScratch`]) the exact path uses, in the same order, so the
//! quantized product is bitwise-identical to the *exact* product of the
//! dequantized factors, and parallel runs are bitwise-identical to
//! serial at any thread count.

use super::buf::Buf;
use super::csr::Csr;
use super::spgemm::{key_bytes_for, SpaScratch};
use crate::exec;

/// Entries per quantization block (per-block f32 scale).
pub const QBLOCK: usize = 32;

/// Quantization precision for [`QCsr`] values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// One byte per value, `q ∈ [-127, 127]`.
    Int8,
    /// One nibble per value (two per byte), `q ∈ [-7, 7]`.
    Int4,
}

impl QuantMode {
    /// CLI / display name (`int8` / `int4`).
    pub fn name(self) -> &'static str {
        match self {
            QuantMode::Int8 => "int8",
            QuantMode::Int4 => "int4",
        }
    }

    /// Parse a `--quantize` value; `none` maps to `Ok(None)`.
    pub fn from_name(s: &str) -> Option<Option<QuantMode>> {
        match s {
            "none" => Some(None),
            "int8" => Some(Some(QuantMode::Int8)),
            "int4" => Some(Some(QuantMode::Int4)),
            _ => None,
        }
    }

    /// Stable on-disk code (bundle format): 1 = int8, 2 = int4.
    pub fn code(self) -> u8 {
        match self {
            QuantMode::Int8 => 1,
            QuantMode::Int4 => 2,
        }
    }

    /// Inverse of [`QuantMode::code`].
    pub fn from_code(code: u8) -> Option<QuantMode> {
        match code {
            1 => Some(QuantMode::Int8),
            2 => Some(QuantMode::Int4),
            _ => None,
        }
    }

    /// Largest representable magnitude `L` of the signed grid.
    fn levels(self) -> f32 {
        match self {
            QuantMode::Int8 => 127.0,
            QuantMode::Int4 => 7.0,
        }
    }

    /// Packed bytes needed for `len` row entries.
    fn row_bytes(self, len: usize) -> usize {
        match self {
            QuantMode::Int8 => len,
            QuantMode::Int4 => len.div_ceil(2),
        }
    }
}

/// Block-quantized CSR (see the module docs for the exact layout).
///
/// The per-row pointer arrays (`col_ptr`, `qdata_ptr`, `block_ptr`) are
/// derivable from `indptr` + `mode` + the varint stream; the bundle
/// stores only `indptr`/`col_bytes`/`qdata`/`scales` and rebuilds the
/// rest on load ([`QCsr::from_parts`]).
#[derive(Clone, Debug, PartialEq)]
pub struct QCsr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub mode: QuantMode,
    /// Entry offsets per row (same meaning as [`Csr::indptr`]).
    pub indptr: Buf<usize>,
    /// Byte offset of each row's delta-varint stream in `col_bytes`.
    pub col_ptr: Buf<usize>,
    /// Delta-varint column stream.
    pub col_bytes: Buf<u8>,
    /// Byte offset of each row's packed values in `qdata`.
    pub qdata_ptr: Buf<usize>,
    /// Quantized values: int8 as raw bytes, int4 packed two per byte.
    pub qdata: Buf<u8>,
    /// First scale-block index of each row.
    pub block_ptr: Buf<usize>,
    /// Per-block f32 scales.
    pub scales: Buf<f32>,
}

/// Reused per-worker decode buffers for one quantized row.
pub struct QRowScratch {
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
    /// Second (cols, vals) pair so an A-row can stay decoded while
    /// B-rows stream through the first pair.
    pub cols2: Vec<u32>,
    pub vals2: Vec<f32>,
}

impl QRowScratch {
    pub fn new() -> QRowScratch {
        QRowScratch { cols: Vec::new(), vals: Vec::new(), cols2: Vec::new(), vals2: Vec::new() }
    }
}

impl Default for QRowScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Quantize one block: returns `(scale, inv)` with `inv = L / max|v|`
/// (0 for an all-zero block, making every `q` 0).
fn block_scale(vals: &[f32], levels: f32) -> (f32, f32) {
    let mut max_abs = 0f32;
    for &v in vals {
        max_abs = max_abs.max(v.abs());
    }
    if max_abs == 0.0 {
        (0.0, 0.0)
    } else {
        (max_abs / levels, levels / max_abs)
    }
}

#[inline]
fn quantize_one(v: f32, inv: f32, levels: f32) -> i8 {
    (v * inv).round().clamp(-levels, levels) as i8
}

/// Append the delta varint for `col` given the previous column.
fn push_delta(out: &mut Vec<u8>, col: u32, prev: &mut i64) {
    let d = col as i64 - *prev - 1;
    debug_assert!(d >= 0, "columns must be strictly increasing");
    if (d as u64) < 0xFF {
        out.push(d as u8);
    } else {
        out.push(0xFF);
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    *prev = col as i64;
}

/// Quantize an exact CSR into a [`QCsr`] with the documented
/// deterministic rounding rule.
pub fn quantize(m: &Csr, mode: QuantMode) -> QCsr {
    assert!(m.n_cols <= u32::MAX as usize);
    let levels = mode.levels();
    let n = m.n_rows;
    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut qdata_ptr = Vec::with_capacity(n + 1);
    let mut block_ptr = Vec::with_capacity(n + 1);
    let mut col_bytes = Vec::with_capacity(m.nnz());
    let mut qdata = Vec::with_capacity(mode.row_bytes(m.nnz()));
    let mut scales = Vec::with_capacity(m.nnz().div_ceil(QBLOCK.max(1)));
    col_ptr.push(0);
    qdata_ptr.push(0);
    block_ptr.push(0);
    let mut nibbles: Vec<u8> = Vec::new();
    for i in 0..n {
        let (cols, vals) = m.row(i);
        // Columns: first entry absolute, then gap-minus-one varints.
        let mut prev: i64 = -1;
        for &c in cols {
            push_delta(&mut col_bytes, c, &mut prev);
        }
        col_ptr.push(col_bytes.len());
        // Values: row-local blocks of QBLOCK with one scale each.
        match mode {
            QuantMode::Int8 => {
                for chunk in vals.chunks(QBLOCK) {
                    let (s, inv) = block_scale(chunk, levels);
                    scales.push(s);
                    for &v in chunk {
                        qdata.push(quantize_one(v, inv, levels) as u8);
                    }
                }
            }
            QuantMode::Int4 => {
                nibbles.clear();
                for chunk in vals.chunks(QBLOCK) {
                    let (s, inv) = block_scale(chunk, levels);
                    scales.push(s);
                    for &v in chunk {
                        nibbles.push((quantize_one(v, inv, levels) + 8) as u8);
                    }
                }
                // Pack per row: entry 2m in the low nibble, 2m+1 high.
                for pair in nibbles.chunks(2) {
                    let hi = if pair.len() == 2 { pair[1] } else { 0 };
                    qdata.push(pair[0] | (hi << 4));
                }
            }
        }
        qdata_ptr.push(qdata.len());
        block_ptr.push(scales.len());
    }
    QCsr {
        n_rows: n,
        n_cols: m.n_cols,
        mode,
        indptr: m.indptr.clone(),
        col_ptr: col_ptr.into(),
        col_bytes: col_bytes.into(),
        qdata_ptr: qdata_ptr.into(),
        qdata: qdata.into(),
        block_ptr: block_ptr.into(),
        scales: scales.into(),
    }
}

impl QCsr {
    pub fn nnz(&self) -> usize {
        *self.indptr.last().unwrap_or(&0)
    }

    /// Rebuild a `QCsr` from its serialized parts (bundle load path):
    /// derives the per-row pointer arrays by walking the varint stream
    /// and fully validates the structure, so a corrupt or truncated
    /// bundle section fails here instead of at compute time.
    pub fn from_parts(
        n_rows: usize,
        n_cols: usize,
        mode: QuantMode,
        indptr: impl Into<Buf<usize>>,
        col_bytes: impl Into<Buf<u8>>,
        qdata: impl Into<Buf<u8>>,
        scales: impl Into<Buf<f32>>,
    ) -> Result<QCsr, String> {
        let (indptr, col_bytes, qdata, scales) =
            (indptr.into(), col_bytes.into(), qdata.into(), scales.into());
        if indptr.len() != n_rows + 1 {
            return Err(format!("indptr has {} entries for {} rows", indptr.len(), n_rows));
        }
        if indptr[0] != 0 || indptr.windows(2).any(|w| w[0] > w[1]) {
            return Err("indptr is not monotonically non-decreasing from 0".into());
        }
        let mut col_ptr = Vec::with_capacity(n_rows + 1);
        let mut qdata_ptr = Vec::with_capacity(n_rows + 1);
        let mut block_ptr = Vec::with_capacity(n_rows + 1);
        col_ptr.push(0);
        qdata_ptr.push(0);
        block_ptr.push(0);
        let mut byte = 0usize;
        let mut data_off = 0usize;
        let mut blocks = 0usize;
        for i in 0..n_rows {
            let len = indptr[i + 1] - indptr[i];
            // Walk (and bounds-check) this row's varint stream.
            let mut prev: i64 = -1;
            for _ in 0..len {
                let Some(&b0) = col_bytes.get(byte) else {
                    return Err(format!("column stream truncated in row {i}"));
                };
                byte += 1;
                let d = if b0 == 0xFF {
                    let Some(raw) = col_bytes.get(byte..byte + 4) else {
                        return Err(format!("escaped delta truncated in row {i}"));
                    };
                    byte += 4;
                    u32::from_le_bytes(raw.try_into().unwrap()) as i64
                } else {
                    b0 as i64
                };
                let col = prev + 1 + d;
                if col >= n_cols as i64 {
                    return Err(format!("row {i} column {col} out of bounds ({n_cols} cols)"));
                }
                prev = col;
            }
            col_ptr.push(byte);
            data_off += mode.row_bytes(len);
            qdata_ptr.push(data_off);
            blocks += len.div_ceil(QBLOCK);
            block_ptr.push(blocks);
        }
        if byte != col_bytes.len() {
            return Err(format!("{} trailing column-stream bytes", col_bytes.len() - byte));
        }
        if data_off != qdata.len() {
            return Err(format!("value payload is {} bytes, expected {data_off}", qdata.len()));
        }
        if blocks != scales.len() {
            return Err(format!("{} scales for {blocks} blocks", scales.len()));
        }
        if scales.iter().any(|s| !s.is_finite() || *s < 0.0) {
            return Err("non-finite or negative block scale".into());
        }
        Ok(QCsr {
            n_rows,
            n_cols,
            mode,
            indptr,
            col_ptr: col_ptr.into(),
            col_bytes,
            qdata_ptr: qdata_ptr.into(),
            qdata,
            block_ptr: block_ptr.into(),
            scales,
        })
    }

    /// Structural validation (the [`QCsr::from_parts`] checks applied to
    /// an already-assembled matrix).
    pub fn check(&self) -> Result<(), String> {
        let rebuilt = QCsr::from_parts(
            self.n_rows,
            self.n_cols,
            self.mode,
            self.indptr.clone(),
            self.col_bytes.clone(),
            self.qdata.clone(),
            self.scales.clone(),
        )?;
        if rebuilt.col_ptr != self.col_ptr
            || rebuilt.qdata_ptr != self.qdata_ptr
            || rebuilt.block_ptr != self.block_ptr
        {
            return Err("derived pointer arrays disagree with stored ones".into());
        }
        Ok(())
    }

    /// Resident memory footprint in bytes (all arrays).
    pub fn mem_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.col_ptr.len() * std::mem::size_of::<usize>()
            + self.qdata_ptr.len() * std::mem::size_of::<usize>()
            + self.block_ptr.len() * std::mem::size_of::<usize>()
            + self.col_bytes.len()
            + self.qdata.len()
            + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// Decode row `i`'s columns into `cols` (cleared first).
    pub fn decode_cols_into(&self, i: usize, cols: &mut Vec<u32>) {
        cols.clear();
        let len = self.indptr[i + 1] - self.indptr[i];
        cols.reserve(len);
        let bytes = &self.col_bytes[self.col_ptr[i]..self.col_ptr[i + 1]];
        let mut p = 0usize;
        let mut prev: i64 = -1;
        for _ in 0..len {
            let b0 = bytes[p];
            p += 1;
            let d = if b0 == 0xFF {
                let raw: [u8; 4] = bytes[p..p + 4].try_into().unwrap();
                p += 4;
                u32::from_le_bytes(raw) as i64
            } else {
                b0 as i64
            };
            prev += 1 + d;
            cols.push(prev as u32);
        }
    }

    /// Decode row `i`'s values into `vals` (cleared first), block by
    /// block via the unrolled [`decode_vals`] kernel.
    pub fn decode_vals_into(&self, i: usize, vals: &mut Vec<f32>) {
        vals.clear();
        let len = self.indptr[i + 1] - self.indptr[i];
        let bytes = &self.qdata[self.qdata_ptr[i]..self.qdata_ptr[i + 1]];
        let scales = &self.scales[self.block_ptr[i]..self.block_ptr[i + 1]];
        decode_vals(self.mode, len, bytes, scales, vals);
    }

    /// Decode one full row into the scratch's primary (cols, vals) pair.
    pub fn decode_row_into(&self, i: usize, rs: &mut QRowScratch) {
        self.decode_cols_into(i, &mut rs.cols);
        self.decode_vals_into(i, &mut rs.vals);
    }

    /// Exact reconstruction under the documented rounding rule:
    /// `dequantize(quantize(m))` has `m`'s structure with each value
    /// replaced by `q · scale`.
    pub fn dequantize(&self) -> Csr {
        let mut rs = QRowScratch::new();
        let mut indices = Vec::with_capacity(self.nnz());
        let mut data = Vec::with_capacity(self.nnz());
        for i in 0..self.n_rows {
            self.decode_row_into(i, &mut rs);
            indices.extend_from_slice(&rs.cols);
            data.extend_from_slice(&rs.vals);
        }
        Csr {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            indptr: self.indptr.clone(),
            indices: indices.into(),
            data: data.into(),
        }
    }

    /// Quantized `Y = A·X` (X dense `n_cols × k`, row-major-k), k-tiled
    /// like [`Csr::spmm`]; serial and bitwise-identical to
    /// `self.dequantize().spmm(...)` (same per-element accumulation
    /// order).
    pub fn spmm(&self, x: &[f32], k: usize, y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_cols * k);
        debug_assert_eq!(y.len(), self.n_rows * k);
        y.fill(0.0);
        if k == 0 {
            return;
        }
        let mut rs = QRowScratch::new();
        for r in 0..self.n_rows {
            self.decode_row_into(r, &mut rs);
            let out = &mut y[r * k..(r + 1) * k];
            for (&c, &v) in rs.cols.iter().zip(&rs.vals) {
                let xr = &x[c as usize * k..c as usize * k + k];
                for j in 0..k {
                    out[j] += v * xr[j];
                }
            }
        }
    }

    /// Quantized `Y = Aᵀ·X` (X `n_rows × k`, Y `n_cols × k`); serial and
    /// bitwise-identical to `self.dequantize().spmm_t(...)`.
    pub fn spmm_t(&self, x: &[f32], k: usize, y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_rows * k);
        debug_assert_eq!(y.len(), self.n_cols * k);
        y.fill(0.0);
        if k == 0 {
            return;
        }
        let mut rs = QRowScratch::new();
        for r in 0..self.n_rows {
            self.decode_row_into(r, &mut rs);
            let xr = &x[r * k..(r + 1) * k];
            for (&c, &v) in rs.cols.iter().zip(&rs.vals) {
                let out = &mut y[c as usize * k..c as usize * k + k];
                for j in 0..k {
                    out[j] += v * xr[j];
                }
            }
        }
    }
}

/// Dequantize one row's packed value stream into `vals`, appending
/// `len` decoded f32s.
///
/// The hot loops are explicitly unrolled to the fixed [`QBLOCK`]-wide
/// block layout (the ROADMAP "SIMD-width inner loops" item): each full
/// block is decoded through a `&[u8; 32]` (int8) / `&[u8; 16]` (int4)
/// array reference, so the inner loop has a compile-time trip count
/// and no bounds checks — exactly the shape the autovectorizer turns
/// into SIMD — with only the final short block taking the scalar tail
/// path. Each element is still computed as `q as f32 * scale` in f32,
/// so the output is bitwise-identical to the scalar reference
/// (property-tested in this module).
pub fn decode_vals(mode: QuantMode, len: usize, bytes: &[u8], scales: &[f32], vals: &mut Vec<f32>) {
    let start = vals.len();
    vals.resize(start + len, 0.0);
    let out = &mut vals[start..];
    let full = len / QBLOCK;
    match mode {
        QuantMode::Int8 => {
            for b in 0..full {
                let blk: &[u8; QBLOCK] =
                    bytes[b * QBLOCK..b * QBLOCK + QBLOCK].try_into().unwrap();
                let o = &mut out[b * QBLOCK..(b + 1) * QBLOCK];
                let s = scales[b];
                for j in 0..QBLOCK {
                    o[j] = blk[j] as i8 as f32 * s;
                }
            }
            if full * QBLOCK < len {
                let s = scales[full];
                for j in full * QBLOCK..len {
                    out[j] = bytes[j] as i8 as f32 * s;
                }
            }
        }
        QuantMode::Int4 => {
            const HALF: usize = QBLOCK / 2;
            for b in 0..full {
                let blk: &[u8; HALF] = bytes[b * HALF..b * HALF + HALF].try_into().unwrap();
                let o = &mut out[b * QBLOCK..(b + 1) * QBLOCK];
                let s = scales[b];
                for j in 0..HALF {
                    let byte = blk[j];
                    o[2 * j] = ((byte & 0xF) as i32 - 8) as f32 * s;
                    o[2 * j + 1] = ((byte >> 4) as i32 - 8) as f32 * s;
                }
            }
            // Tail block: entry parity still matches byte layout because
            // full blocks always end on a byte boundary.
            for j in full * QBLOCK..len {
                let nib = (bytes[j / 2] >> ((j & 1) * 4)) & 0xF;
                let s = scales[j / QBLOCK];
                out[j] = (nib as i32 - 8) as f32 * s;
            }
        }
    }
}

/// The pre-unroll scalar decode, kept as the property-test oracle for
/// [`decode_vals`].
#[cfg(test)]
fn decode_vals_scalar(
    mode: QuantMode,
    len: usize,
    bytes: &[u8],
    scales: &[f32],
    vals: &mut Vec<f32>,
) {
    match mode {
        QuantMode::Int8 => {
            for (b, chunk) in bytes.chunks(QBLOCK).enumerate() {
                let s = scales[b];
                for &q in chunk {
                    vals.push(q as i8 as f32 * s);
                }
            }
        }
        QuantMode::Int4 => {
            for j in 0..len {
                let nib = (bytes[j / 2] >> ((j & 1) * 4)) & 0xF;
                let s = scales[j / QBLOCK];
                vals.push((nib as i32 - 8) as f32 * s);
            }
        }
    }
}

/// Gustavson product over a row range of quantized `A` against
/// quantized `B`, reusing the caller's SPA + decode scratch (the
/// coordinator's stripe path). Output rows are built by the same
/// accumulate/sort loop as [`super::spgemm`], so stripes concatenate
/// bitwise-identically to the full [`spgemm_q`] product.
pub fn spgemm_q_range(
    a: &QCsr,
    rows: std::ops::Range<usize>,
    b: &QCsr,
    spa: &mut SpaScratch,
    rs: &mut QRowScratch,
) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "spgemm_q dim mismatch");
    spa.ensure(b.n_cols);
    let base = spa.begin_rows(rows.len());
    let key_bytes = key_bytes_for(b.n_cols);
    let mut indptr = Vec::with_capacity(rows.len() + 1);
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    indptr.push(0usize);
    for i in rows.clone() {
        let row_stamp = base + (i - rows.start) as u32;
        a.decode_cols_into(i, &mut rs.cols2);
        a.decode_vals_into(i, &mut rs.vals2);
        for (&ac, &av) in rs.cols2.iter().zip(&rs.vals2) {
            b.decode_cols_into(ac as usize, &mut rs.cols);
            b.decode_vals_into(ac as usize, &mut rs.vals);
            spa.accumulate(row_stamp, &rs.cols, &rs.vals, av);
        }
        spa.flush(key_bytes, &mut indices, &mut data);
        indptr.push(indices.len());
    }
    Csr {
        n_rows: rows.len(),
        n_cols: b.n_cols,
        indptr: indptr.into(),
        indices: indices.into(),
        data: data.into(),
    }
}

/// Quantized SpGEMM `C = A·B` on the shared worker pool; `n_threads =
/// 1` is the serial reference and the output is bitwise-identical
/// across thread counts (row-partitioned, same serial inner loop).
pub fn spgemm_q(a: &QCsr, b: &QCsr, n_threads: usize) -> Csr {
    assert!(a.n_rows < u32::MAX as usize);
    let blocks = exec::parallel_ranges(a.n_rows, n_threads.max(1), |_, rows| {
        let mut spa = SpaScratch::new(b.n_cols);
        let mut rs = QRowScratch::new();
        spgemm_q_range(a, rows, b, &mut spa, &mut rs)
    });
    stitch_row_blocks(a.n_rows, b.n_cols, blocks)
}

/// Mixed SpGEMM: exact f32 `A` (e.g. a fresh OOS query map) against
/// quantized `B` (the stored `Wᵀ`). Bitwise-identical to
/// `spgemm(a, &b.dequantize())` and across thread counts.
pub fn spgemm_csr_q(a: &Csr, b: &QCsr, n_threads: usize) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "spgemm_csr_q dim mismatch");
    assert!(a.n_rows < u32::MAX as usize);
    let key_bytes = key_bytes_for(b.n_cols);
    let blocks = exec::parallel_ranges(a.n_rows, n_threads.max(1), |_, rows| {
        let mut spa = SpaScratch::new(b.n_cols);
        let mut rs = QRowScratch::new();
        let base = spa.begin_rows(rows.len());
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices: Vec<u32> = Vec::new();
        let mut data: Vec<f32> = Vec::new();
        indptr.push(0usize);
        for i in rows.clone() {
            let row_stamp = base + (i - rows.start) as u32;
            let (acols, avals) = a.row(i);
            for (&ac, &av) in acols.iter().zip(avals) {
                b.decode_cols_into(ac as usize, &mut rs.cols);
                b.decode_vals_into(ac as usize, &mut rs.vals);
                spa.accumulate(row_stamp, &rs.cols, &rs.vals, av);
            }
            spa.flush(key_bytes, &mut indices, &mut data);
            indptr.push(indices.len());
        }
        Csr {
            n_rows: rows.len(),
            n_cols: b.n_cols,
            indptr: indptr.into(),
            indices: indices.into(),
            data: data.into(),
        }
    });
    stitch_row_blocks(a.n_rows, b.n_cols, blocks)
}

/// Stitch per-range partial products (local CSRs) in row order.
fn stitch_row_blocks(n_rows: usize, n_cols: usize, blocks: Vec<Csr>) -> Csr {
    let nnz: usize = blocks.iter().map(|blk| blk.indices.len()).sum();
    let mut indptr = Vec::with_capacity(n_rows + 1);
    let mut indices: Vec<u32> = Vec::with_capacity(nnz);
    let mut data: Vec<f32> = Vec::with_capacity(nnz);
    indptr.push(0usize);
    for blk in blocks {
        let b = indices.len();
        indptr.extend(blk.indptr[1..].iter().map(|&p| b + p));
        indices.extend_from_slice(&blk.indices);
        data.extend_from_slice(&blk.data);
    }
    if indptr.len() == 1 {
        indptr.resize(n_rows + 1, 0);
    }
    Csr { n_rows, n_cols, indptr: indptr.into(), indices: indices.into(), data: data.into() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::sparse::spgemm_with_threads;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trip = vec![];
        for r in 0..rows {
            for c in 0..cols {
                if rng.next_f64() < density {
                    trip.push((r, c as u32, rng.next_normal() as f32));
                }
            }
        }
        Csr::from_triplets(rows, cols, &trip)
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn roundtrip_error_bounded_per_block() {
        let mut rng = Rng::new(41);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let m = random_csr(&mut rng, 60, 500, 0.15);
            let q = quantize(&m, mode);
            q.check().unwrap();
            let back = q.dequantize();
            assert_eq!(back.indptr, m.indptr);
            assert_eq!(back.indices, m.indices);
            for i in 0..m.n_rows {
                let (_, vals) = m.row(i);
                let (_, got) = back.row(i);
                for (b, chunk) in vals.chunks(QBLOCK).enumerate() {
                    let max_abs = chunk.iter().fold(0f32, |a, v| a.max(v.abs()));
                    let bound = max_abs * (0.5 / mode.levels()) * 1.001 + 1e-12;
                    for (j, (&v, &vh)) in
                        chunk.iter().zip(&got[b * QBLOCK..b * QBLOCK + chunk.len()]).enumerate()
                    {
                        assert!(
                            (v - vh).abs() <= bound,
                            "{mode:?} row {i} block {b} entry {j}: |{v} - {vh}| > {bound}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn large_deltas_use_escape_and_roundtrip() {
        // Columns far apart force the 0xFF + u32 escape encoding.
        let m = Csr::from_triplets(
            2,
            1_000_000,
            &[(0, 3, 1.0), (0, 999_999, -2.0), (1, 0, 0.5), (1, 254, 0.25), (1, 600, 4.0)],
        );
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let q = quantize(&m, mode);
            q.check().unwrap();
            assert_eq!(q.dequantize().indices, m.indices);
        }
    }

    #[test]
    fn empty_rows_and_matrices() {
        let z = quantize(&Csr::zeros(5, 7), QuantMode::Int8);
        z.check().unwrap();
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.dequantize().to_dense(), vec![0f32; 35]);
        let e = quantize(&Csr::zeros(0, 4), QuantMode::Int4);
        e.check().unwrap();
        assert_eq!(e.dequantize().n_rows, 0);
    }

    #[test]
    fn from_parts_rejects_corrupt_streams() {
        let mut rng = Rng::new(43);
        let m = random_csr(&mut rng, 20, 90, 0.2);
        let q = quantize(&m, QuantMode::Int8);
        // Truncated column stream.
        let mut cb = q.col_bytes.to_vec();
        cb.pop();
        assert!(QCsr::from_parts(
            q.n_rows, q.n_cols, q.mode, q.indptr.clone(), cb, q.qdata.clone(), q.scales.clone()
        )
        .is_err());
        // Wrong value payload size.
        let mut qd = q.qdata.to_vec();
        qd.pop();
        assert!(QCsr::from_parts(
            q.n_rows, q.n_cols, q.mode, q.indptr.clone(), q.col_bytes.clone(), qd,
            q.scales.clone()
        )
        .is_err());
        // Wrong scale count.
        let mut sc = q.scales.to_vec();
        sc.push(1.0);
        assert!(QCsr::from_parts(
            q.n_rows, q.n_cols, q.mode, q.indptr.clone(), q.col_bytes.clone(), q.qdata.clone(), sc
        )
        .is_err());
        // Out-of-bounds column (shrink n_cols below the data).
        assert!(QCsr::from_parts(
            q.n_rows, 1, q.mode, q.indptr.clone(), q.col_bytes.clone(), q.qdata.clone(),
            q.scales.clone()
        )
        .is_err());
    }

    #[test]
    fn quantized_spgemm_matches_dequantized_exact_bitwise() {
        let mut rng = Rng::new(47);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let a = random_csr(&mut rng, 40, 25, 0.3);
            let b = random_csr(&mut rng, 25, 35, 0.3);
            let (qa, qb) = (quantize(&a, mode), quantize(&b, mode));
            let want = spgemm_with_threads(&qa.dequantize(), &qb.dequantize(), 1);
            let got = spgemm_q(&qa, &qb, 1);
            got.check().unwrap();
            assert_eq!(got.indptr, want.indptr, "{mode:?}");
            assert_eq!(got.indices, want.indices, "{mode:?}");
            assert_eq!(bits(&got.data), bits(&want.data), "{mode:?}");
            // Mixed exact×quantized path agrees the same way.
            let mixed = spgemm_csr_q(&a, &qb, 1);
            let mixed_want = spgemm_with_threads(&a, &qb.dequantize(), 1);
            assert_eq!(mixed.indptr, mixed_want.indptr, "{mode:?} mixed");
            assert_eq!(bits(&mixed.data), bits(&mixed_want.data), "{mode:?} mixed");
        }
    }

    #[test]
    fn quantized_spgemm_parallel_bitwise_equals_serial() {
        let mut rng = Rng::new(53);
        let a = random_csr(&mut rng, 70, 30, 0.25);
        let b = random_csr(&mut rng, 30, 50, 0.25);
        let qa = quantize(&a, QuantMode::Int8);
        let qb = quantize(&b, QuantMode::Int8);
        let serial = spgemm_q(&qa, &qb, 1);
        for th in [2usize, 3, 4, 8] {
            let par = spgemm_q(&qa, &qb, th);
            assert_eq!(par.indptr, serial.indptr, "th {th}");
            assert_eq!(par.indices, serial.indices, "th {th}");
            assert_eq!(bits(&par.data), bits(&serial.data), "th {th}");
            let par_mixed = spgemm_csr_q(&a, &qb, th);
            let ser_mixed = spgemm_csr_q(&a, &qb, 1);
            assert_eq!(bits(&par_mixed.data), bits(&ser_mixed.data), "mixed th {th}");
        }
    }

    #[test]
    fn quantized_spmm_matches_dequantized_bitwise() {
        let mut rng = Rng::new(59);
        let m = random_csr(&mut rng, 45, 30, 0.3);
        let q = quantize(&m, QuantMode::Int8);
        let d = q.dequantize();
        for k in [1usize, 3, 17] {
            let x: Vec<f32> = (0..m.n_cols * k).map(|_| rng.next_normal() as f32).collect();
            let mut want = vec![0f32; m.n_rows * k];
            let mut got = vec![0f32; m.n_rows * k];
            d.spmm_with_threads(&x, k, &mut want, 1);
            q.spmm(&x, k, &mut got);
            assert_eq!(bits(&got), bits(&want), "spmm k={k}");
            let xt: Vec<f32> = (0..m.n_rows * k).map(|_| rng.next_normal() as f32).collect();
            let mut want_t = vec![0f32; m.n_cols * k];
            let mut got_t = vec![0f32; m.n_cols * k];
            d.spmm_t_with_threads(&xt, k, &mut want_t, 1);
            q.spmm_t(&xt, k, &mut got_t);
            assert_eq!(bits(&got_t), bits(&want_t), "spmm_t k={k}");
        }
    }

    #[test]
    fn stripe_ranges_concatenate_to_full_product() {
        let mut rng = Rng::new(61);
        let a = random_csr(&mut rng, 33, 20, 0.3);
        let b = random_csr(&mut rng, 20, 28, 0.3);
        let (qa, qb) = (quantize(&a, QuantMode::Int8), quantize(&b, QuantMode::Int8));
        let full = spgemm_q(&qa, &qb, 1);
        let mut spa = SpaScratch::new(0);
        let mut rs = QRowScratch::new();
        let mut row = 0usize;
        for stripe in [10usize, 10, 13] {
            let p = spgemm_q_range(&qa, row..row + stripe, &qb, &mut spa, &mut rs);
            for i in 0..stripe {
                let (fc, fv) = full.row(row + i);
                let (sc, sv) = p.row(i);
                assert_eq!(sc, fc, "row {}", row + i);
                assert_eq!(bits(sv), bits(fv), "row {}", row + i);
            }
            row += stripe;
        }
    }

    #[test]
    fn unrolled_dequant_bitwise_matches_scalar_reference() {
        // Random packed streams (not just quantizer outputs) over both
        // modes and every tail length mod QBLOCK, compared bit-for-bit
        // against the pre-unroll scalar decode.
        let mut rng = Rng::new(71);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            for rep in 0..64 {
                let len = rng.gen_range(4 * QBLOCK + 1);
                let bytes: Vec<u8> =
                    (0..mode.row_bytes(len)).map(|_| rng.gen_range(256) as u8).collect();
                let scales: Vec<f32> = (0..len.div_ceil(QBLOCK))
                    .map(|_| (rng.next_normal() as f32).abs())
                    .collect();
                let mut fast = vec![f32::NAN; 3]; // non-empty: decode appends
                let mut slow = fast.clone();
                decode_vals(mode, len, &bytes, &scales, &mut fast);
                decode_vals_scalar(mode, len, &bytes, &scales, &mut slow);
                assert_eq!(fast.len(), slow.len(), "{mode:?} rep {rep} len {len}");
                assert_eq!(bits(&fast[3..]), bits(&slow[3..]), "{mode:?} rep {rep} len {len}");
            }
        }
        // And through the full row path: decode_vals_into on a real
        // quantized matrix equals the scalar oracle per row.
        let m = random_csr(&mut rng, 50, 300, 0.4);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let q = quantize(&m, mode);
            let mut vals = Vec::new();
            for i in 0..q.n_rows {
                q.decode_vals_into(i, &mut vals);
                let len = q.indptr[i + 1] - q.indptr[i];
                let mut want = Vec::new();
                decode_vals_scalar(
                    mode,
                    len,
                    &q.qdata[q.qdata_ptr[i]..q.qdata_ptr[i + 1]],
                    &q.scales[q.block_ptr[i]..q.block_ptr[i + 1]],
                    &mut want,
                );
                assert_eq!(bits(&vals), bits(&want), "{mode:?} row {i}");
            }
        }
    }

    #[test]
    fn mode_name_and_code_roundtrip() {
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            assert_eq!(QuantMode::from_code(mode.code()), Some(mode));
            assert_eq!(QuantMode::from_name(mode.name()), Some(Some(mode)));
        }
        assert_eq!(QuantMode::from_name("none"), Some(None));
        assert_eq!(QuantMode::from_name("fp16"), None);
        assert_eq!(QuantMode::from_code(0), None);
    }

    #[test]
    fn compression_beats_f32_on_clustered_columns() {
        // Narrow column gaps (the SWLC regime) → ~1-byte deltas, and the
        // resident quantized form is well under half the exact one.
        let mut rng = Rng::new(67);
        let m = random_csr(&mut rng, 200, 400, 0.2);
        let q8 = quantize(&m, QuantMode::Int8);
        let q4 = quantize(&m, QuantMode::Int4);
        assert!((q8.mem_bytes() as f64) < 0.5 * m.mem_bytes() as f64);
        assert!(q4.mem_bytes() < q8.mem_bytes());
    }
}
