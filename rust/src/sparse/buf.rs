//! Borrowed-or-owned array storage for the sparse factor types.
//!
//! [`Buf<T>`] is the `Cow`-style representation that lets one `Csr` /
//! `QCsr` / context-array type back every large array either with a
//! heap `Vec<T>` (the classic path: training, `from_triplets`,
//! heap-decoded bundles) or with a slice *borrowed from a shared
//! memory mapping* (`fk-bundle-v3` served with `--mmap`). Every read
//! path in the crate goes through `Deref<Target = [T]>`, so the two
//! backings are indistinguishable to the kernels — SpGEMM/SpMM over a
//! mapped factor is bitwise-identical to the same product over an
//! owned copy, because it literally reads the same bytes.
//!
//! Mutation is copy-on-write: the first `&mut [T]` access of a mapped
//! buffer materializes an owned copy (`DerefMut`), so in-place editors
//! like `sort_and_dedup_rows` keep working unchanged — they just stop
//! being zero-copy, which is exactly the semantics a private view of a
//! shared read-only artifact should have.
//!
//! The mapped variant carries a type-erased `Arc` anchor keeping the
//! underlying mapping alive (`model::mmap::Mapping` in practice; the
//! erasure keeps `sparse` independent of `model`). Cloning a mapped
//! buffer is an `Arc` bump, never a data copy — which is why a
//! symmetric kernel's `w = q.clone()` stays O(1) on a mapped bundle.
//!
//! # Aliasing and lifetime contract (what the Miri CI job checks)
//!
//! The mapped variant is a `(ptr, len, anchor)` triple built by the
//! `unsafe` [`Buf::from_anchor`] constructor. Its soundness rests on
//! exactly three caller obligations, stated here once because every
//! in-tree constructor (`model::mod`'s section binder and the test
//! helper below) must uphold them:
//!
//! 1. **Validity + alignment**: `ptr..ptr + len` is a readable
//!    allocation of properly aligned `T` for as long as `anchor` is
//!    alive — the section table enforces 64-byte alignment on disk
//!    precisely so this holds for every supported dtype.
//! 2. **Lifetime**: the type-erased `anchor` Arc is the *only* thing
//!    keeping that allocation alive, and `Buf` drops the pointer
//!    strictly before the anchor (field order + no `Drop` impl that
//!    reads `ptr`), so the borrow can never dangle.
//! 3. **Immutability**: nothing writes through the mapping while any
//!    `Buf` borrows it. Shared reads are the only access — mutation
//!    goes through `DerefMut`'s copy-on-write, which materializes an
//!    owned `Vec` and never touches the mapped bytes.
//!
//! The nightly Miri job runs this module's unit tests (with a heap
//! allocation standing in for the `mmap(2)` region, which Miri cannot
//! map) to check the pointer discipline above; the mmap-backed
//! integration paths are exercised natively in the regular test jobs.

use std::any::Any;
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

/// An owned `Vec<T>` or a slice borrowed from a shared anchor (a file
/// mapping). See the module docs for the contract.
pub enum Buf<T: Copy + 'static> {
    Owned(Vec<T>),
    Mapped {
        /// First element; valid for `len` elements as long as `anchor`
        /// is alive, and correctly aligned for `T` (the bundle writer
        /// 64-byte-aligns every section).
        ptr: *const T,
        len: usize,
        /// Keeps the backing storage (the `mmap` region) alive.
        anchor: Arc<dyn Any + Send + Sync>,
    },
}

impl<T: Copy + 'static> Buf<T> {
    /// Wrap a slice of a shared anchor without copying.
    ///
    /// # Safety
    /// `ptr` must point to `len` valid, initialized, `T`-aligned
    /// elements that stay valid and unwritten for the anchor's
    /// lifetime.
    pub unsafe fn from_anchor(
        ptr: *const T,
        len: usize,
        anchor: Arc<dyn Any + Send + Sync>,
    ) -> Buf<T> {
        Buf::Mapped { ptr, len, anchor }
    }

    /// Whether this buffer still borrows a mapping (false once any
    /// mutation has triggered the copy-on-write).
    pub fn is_mapped(&self) -> bool {
        matches!(self, Buf::Mapped { .. })
    }

    pub fn as_slice(&self) -> &[T] {
        self
    }

    /// Extract the owned vector, copying out of a mapping if needed.
    pub fn into_vec(self) -> Vec<T> {
        match self {
            Buf::Owned(v) => v,
            Buf::Mapped { .. } => self.to_vec(),
        }
    }
}

impl<T: Copy + 'static> Deref for Buf<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        match self {
            Buf::Owned(v) => v,
            // SAFETY: `from_anchor`'s contract — valid for `len`
            // elements while `anchor` (held by self) is alive.
            Buf::Mapped { ptr, len, .. } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
        }
    }
}

impl<T: Copy + 'static> DerefMut for Buf<T> {
    /// Copy-on-write: mutation of a mapped buffer first materializes
    /// an owned copy (the mapping itself is read-only).
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        if self.is_mapped() {
            *self = Buf::Owned(self.to_vec());
        }
        match self {
            Buf::Owned(v) => v,
            Buf::Mapped { .. } => unreachable!("just converted to Owned"),
        }
    }
}

// SAFETY: the mapped variant is an immutable view of read-only memory
// whose lifetime is pinned by the Arc anchor; T itself is plain data.
unsafe impl<T: Copy + Send + 'static> Send for Buf<T> {}
unsafe impl<T: Copy + Sync + 'static> Sync for Buf<T> {}

impl<T: Copy + 'static> Clone for Buf<T> {
    /// Owned clones copy the data; mapped clones bump the anchor.
    fn clone(&self) -> Buf<T> {
        match self {
            Buf::Owned(v) => Buf::Owned(v.clone()),
            Buf::Mapped { ptr, len, anchor } => {
                Buf::Mapped { ptr: *ptr, len: *len, anchor: Arc::clone(anchor) }
            }
        }
    }
}

impl<T: Copy + 'static> Default for Buf<T> {
    fn default() -> Buf<T> {
        Buf::Owned(Vec::new())
    }
}

impl<T: Copy + 'static> From<Vec<T>> for Buf<T> {
    fn from(v: Vec<T>) -> Buf<T> {
        Buf::Owned(v)
    }
}

impl<T: Copy + std::fmt::Debug + 'static> std::fmt::Debug for Buf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Render as the slice: backing is an implementation detail.
        std::fmt::Debug::fmt(&**self, f)
    }
}

impl<T: Copy + PartialEq + 'static> PartialEq for Buf<T> {
    fn eq(&self, other: &Buf<T>) -> bool {
        **self == **other
    }
}

impl<T: Copy + PartialEq + 'static> PartialEq<Vec<T>> for Buf<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        **self == **other
    }
}

impl<T: Copy + PartialEq + 'static> PartialEq<Buf<T>> for Vec<T> {
    fn eq(&self, other: &Buf<T>) -> bool {
        **self == **other
    }
}

impl<T: Copy + PartialEq + 'static> PartialEq<[T]> for Buf<T> {
    fn eq(&self, other: &[T]) -> bool {
        **self == *other
    }
}

impl<T: Copy + PartialEq + 'static, const N: usize> PartialEq<[T; N]> for Buf<T> {
    fn eq(&self, other: &[T; N]) -> bool {
        **self == *other
    }
}

impl<'a, T: Copy + 'static> IntoIterator for &'a Buf<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl<T: Copy + 'static> FromIterator<T> for Buf<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Buf<T> {
        Buf::Owned(iter.into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// An owned Vec posing as the anchor, standing in for a Mapping.
    fn mapped_from(v: Vec<u32>) -> Buf<u32> {
        let anchor: Arc<Vec<u32>> = Arc::new(v);
        let ptr = anchor.as_ptr();
        let len = anchor.len();
        // SAFETY: `ptr/len` describe the Arc'd Vec's own allocation,
        // which the anchor keeps alive and nothing mutates — the
        // module-level contract, with a heap Vec standing in for a
        // file mapping (so Miri can execute this test).
        unsafe { Buf::from_anchor(ptr, len, anchor as Arc<dyn Any + Send + Sync>) }
    }

    #[test]
    fn owned_and_mapped_read_identically() {
        let owned: Buf<u32> = vec![3, 1, 4, 1, 5].into();
        let mapped = mapped_from(vec![3, 1, 4, 1, 5]);
        assert!(!owned.is_mapped());
        assert!(mapped.is_mapped());
        assert_eq!(owned, mapped);
        assert_eq!(&mapped[1..3], &[1, 4]);
        assert_eq!(mapped.iter().sum::<u32>(), 14);
        let collected: Vec<u32> = (&mapped).into_iter().copied().collect();
        assert_eq!(collected, vec![3, 1, 4, 1, 5]);
    }

    #[test]
    fn mutation_of_mapped_is_copy_on_write() {
        let mut b = mapped_from(vec![10, 20, 30]);
        assert!(b.is_mapped());
        b[1] = 99;
        assert!(!b.is_mapped(), "first mutation must own the data");
        assert_eq!(b, vec![10, 99, 30]);
    }

    #[test]
    fn clone_of_mapped_shares_the_anchor() {
        let b = mapped_from(vec![7, 8]);
        let c = b.clone();
        assert!(c.is_mapped());
        assert_eq!(b, c);
        if let (Buf::Mapped { ptr: p1, .. }, Buf::Mapped { ptr: p2, .. }) = (&b, &c) {
            assert_eq!(p1, p2, "clone must alias, not copy");
        }
    }

    #[test]
    fn equality_against_vecs_and_slices() {
        let b: Buf<u32> = vec![1, 2].into();
        assert_eq!(b, vec![1, 2]);
        assert_eq!(vec![1, 2], b);
        assert_eq!(b, [1, 2]);
        assert!(b != vec![1, 3]);
        assert_eq!(b.into_vec(), vec![1, 2]);
    }
}
