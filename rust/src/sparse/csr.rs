//! Compressed Sparse Row matrices.

use super::buf::Buf;
use crate::exec;

/// Output-column tile width for `spmm`/`spmm_t`: the dense `X` panel is
/// walked in slices of at most this many columns so the slice stays
/// cache-resident while matrix rows stream past it. Tiling never changes
/// results — each output element accumulates its row's nonzeros in the
/// same order whatever the tile — so tiled output is bitwise-identical
/// to untiled at any thread count.
const SPMM_K_TILE: usize = 16;

/// A CSR matrix over `f32` values with `u32` column indices.
///
/// `u32` indices cap the column dimension at ~4.29e9, comfortably above
/// the largest leaf space we target (L ≈ N·T with N = 10M, T = 100 would
/// overflow; the library asserts on construction), while halving index
/// memory versus `usize` — index traffic dominates SpGEMM bandwidth.
///
/// The three arrays are [`Buf`]s: owned `Vec`s on every construction
/// path, or zero-copy views into a mapped `fk-bundle-v3` file. Reads
/// are identical either way (`Buf: Deref<Target = [T]>`); in-place
/// mutation of a mapped matrix copies-on-write.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Row pointer array, length `n_rows + 1`.
    pub indptr: Buf<usize>,
    /// Column indices, length `nnz`, sorted within each row.
    pub indices: Buf<u32>,
    /// Values, length `nnz`.
    pub data: Buf<f32>,
}

impl Csr {
    /// An all-zero matrix.
    pub fn zeros(n_rows: usize, n_cols: usize) -> Self {
        assert!(n_cols <= u32::MAX as usize, "column dim {n_cols} overflows u32");
        Csr {
            n_rows,
            n_cols,
            indptr: vec![0; n_rows + 1].into(),
            indices: Vec::new().into(),
            data: Vec::new().into(),
        }
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// The (indices, values) slice of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.data[a..b])
    }

    /// Assemble from COO triplets; duplicate coordinates are summed.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, u32, f32)],
    ) -> Self {
        assert!(n_cols <= u32::MAX as usize);
        let mut counts = vec![0usize; n_rows + 1];
        for &(r, c, _) in triplets {
            debug_assert!(r < n_rows && (c as usize) < n_cols);
            counts[r + 1] += 1;
        }
        for i in 0..n_rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut indices = vec![0u32; triplets.len()];
        let mut data = vec![0f32; triplets.len()];
        let mut cursor = counts;
        for &(r, c, v) in triplets {
            let k = cursor[r];
            indices[k] = c;
            data[k] = v;
            cursor[r] += 1;
        }
        let mut m = Csr {
            n_rows,
            n_cols,
            indptr: indptr.into(),
            indices: indices.into(),
            data: data.into(),
        };
        m.sort_and_dedup_rows();
        m
    }

    /// Build a CSR with a known uniform row arity by pushing rows in
    /// order. `fill(i, push)` must call `push(col, val)` for each entry
    /// of row `i` (duplicates allowed; summed). This is the fast path
    /// for leaf-incidence matrices where every row has ≤ T entries.
    pub fn from_rows<F>(n_rows: usize, n_cols: usize, per_row_hint: usize, mut fill: F) -> Self
    where
        F: FnMut(usize, &mut dyn FnMut(u32, f32)),
    {
        assert!(n_cols <= u32::MAX as usize);
        let mut indptr = Vec::with_capacity(n_rows + 1);
        let mut indices = Vec::with_capacity(n_rows * per_row_hint);
        let mut data = Vec::with_capacity(n_rows * per_row_hint);
        indptr.push(0);
        for i in 0..n_rows {
            let start = indices.len();
            {
                let mut push = |c: u32, v: f32| {
                    debug_assert!((c as usize) < n_cols);
                    indices.push(c);
                    data.push(v);
                };
                fill(i, &mut push);
            }
            finalize_row(&mut indices, &mut data, start);
            indptr.push(indices.len());
        }
        Csr { n_rows, n_cols, indptr: indptr.into(), indices: indices.into(), data: data.into() }
    }

    /// Parallel [`Csr::from_rows`]: rows are partitioned across the
    /// shared [`exec`] pool (so `fill` must be `Fn + Sync`), each worker
    /// assembles a contiguous row block, and the blocks are stitched in
    /// row order. Row contents never depend on the partition, so the
    /// result is identical to the serial builder at any thread count.
    /// This is the fast path for leaf-incidence factor construction.
    pub fn from_rows_par<F>(n_rows: usize, n_cols: usize, per_row_hint: usize, fill: F) -> Self
    where
        F: Fn(usize, &mut dyn FnMut(u32, f32)) + Sync,
    {
        assert!(n_cols <= u32::MAX as usize);
        let workers = exec::workers_for(n_rows, 512);
        if workers == 1 {
            return Csr::from_rows(n_rows, n_cols, per_row_hint, |i, push| fill(i, push));
        }
        let blocks = exec::parallel_ranges(n_rows, workers, |_, rows| {
            let mut indptr = Vec::with_capacity(rows.len() + 1);
            let mut indices: Vec<u32> = Vec::with_capacity(rows.len() * per_row_hint);
            let mut data: Vec<f32> = Vec::with_capacity(rows.len() * per_row_hint);
            indptr.push(0usize);
            for i in rows {
                let start = indices.len();
                {
                    let mut push = |c: u32, v: f32| {
                        debug_assert!((c as usize) < n_cols);
                        indices.push(c);
                        data.push(v);
                    };
                    fill(i, &mut push);
                }
                finalize_row(&mut indices, &mut data, start);
                indptr.push(indices.len());
            }
            (indptr, indices, data)
        });
        let nnz: usize = blocks.iter().map(|(_, ix, _)| ix.len()).sum();
        let mut indptr = Vec::with_capacity(n_rows + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut data = Vec::with_capacity(nnz);
        indptr.push(0usize);
        for (bp, bi, bd) in blocks {
            let base = indices.len();
            indptr.extend(bp[1..].iter().map(|&p| base + p));
            indices.extend_from_slice(&bi);
            data.extend_from_slice(&bd);
        }
        if indptr.len() == 1 {
            indptr.resize(n_rows + 1, 0);
        }
        Csr { n_rows, n_cols, indptr: indptr.into(), indices: indices.into(), data: data.into() }
    }

    fn sort_and_dedup_rows(&mut self) {
        let mut new_indices = Vec::with_capacity(self.indices.len());
        let mut new_data = Vec::with_capacity(self.data.len());
        let mut new_indptr = Vec::with_capacity(self.n_rows + 1);
        new_indptr.push(0);
        for i in 0..self.n_rows {
            let (a, b) = (self.indptr[i], self.indptr[i + 1]);
            let mut row: Vec<(u32, f32)> =
                self.indices[a..b].iter().copied().zip(self.data[a..b].iter().copied()).collect();
            row.sort_unstable_by_key(|&(c, _)| c);
            for (c, v) in row {
                if new_indptr.last() != Some(&new_indices.len())
                    && new_indices.len() > *new_indptr.last().unwrap()
                    && *new_indices.last().unwrap() == c
                {
                    *new_data.last_mut().unwrap() += v;
                } else {
                    new_indices.push(c);
                    new_data.push(v);
                }
            }
            new_indptr.push(new_indices.len());
        }
        self.indices = new_indices.into();
        self.data = new_data.into();
        self.indptr = new_indptr.into();
    }

    /// Transpose (CSR of the transposed matrix) by counting sort —
    /// O(nnz), parallelized over the shared [`exec`] pool for large
    /// inputs. Output is identical at any thread count.
    pub fn transpose(&self) -> Csr {
        self.transpose_with_threads(exec::workers_for(self.nnz(), 1 << 15))
    }

    /// Transpose with an explicit worker count (`1` = serial reference).
    ///
    /// The parallel path is a two-pass counting sort: workers count
    /// their row-range's column histogram, a serial prefix pass turns
    /// the per-(range, column) counts into exact write cursors laid out
    /// column-major with ranges in row order, and workers then scatter
    /// into disjoint output positions. Because range r's cursor block
    /// precedes range r+1's within every column, entries keep the
    /// serial row order — the result is byte-for-byte the serial one.
    pub fn transpose_with_threads(&self, n_threads: usize) -> Csr {
        assert!(self.n_rows <= u32::MAX as usize);
        let nt = n_threads.max(1).min(self.n_rows.max(1));
        if nt == 1 || self.nnz() >= u32::MAX as usize {
            return self.transpose_serial();
        }
        let ranges = exec::chunk_ranges(self.n_rows, nt);
        // Pass 1: per-range column counts.
        let counts: Vec<Vec<u32>> = exec::parallel_tasks(ranges.clone(), |_, r| {
            let mut c = vec![0u32; self.n_cols];
            for &col in &self.indices[self.indptr[r.start]..self.indptr[r.end]] {
                c[col as usize] += 1;
            }
            c
        });
        // Serial prefix pass: counts -> global write cursors + indptr.
        let mut starts = counts;
        let mut indptr = vec![0usize; self.n_cols + 1];
        let mut acc = 0usize;
        for c in 0..self.n_cols {
            for s in starts.iter_mut() {
                let cnt = s[c] as usize;
                s[c] = acc as u32;
                acc += cnt;
            }
            indptr[c + 1] = acc;
        }
        // Pass 2: disjoint scatter.
        let nnz = self.nnz();
        let mut indices = vec![0u32; nnz];
        let mut data = vec![0f32; nnz];
        {
            let ish = exec::SharedSlice::new(&mut indices);
            let dsh = exec::SharedSlice::new(&mut data);
            let tasks: Vec<_> = ranges.into_iter().zip(starts).collect();
            exec::parallel_tasks(tasks, |_, (rows, mut cursor)| {
                for r in rows {
                    for k in self.indptr[r]..self.indptr[r + 1] {
                        let c = self.indices[k] as usize;
                        let dst = cursor[c] as usize;
                        cursor[c] += 1;
                        // SAFETY: cursor blocks are disjoint by
                        // construction — every (range, column) owns its
                        // exact output span.
                        unsafe {
                            ish.write(dst, r as u32);
                            dsh.write(dst, self.data[k]);
                        }
                    }
                }
            });
        }
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            indptr: indptr.into(),
            indices: indices.into(),
            data: data.into(),
        }
    }

    fn transpose_serial(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0f32; self.nnz()];
        let mut cursor = counts;
        for r in 0..self.n_rows {
            let (a, b) = (self.indptr[r], self.indptr[r + 1]);
            for k in a..b {
                let c = self.indices[k] as usize;
                let dst = cursor[c];
                indices[dst] = r as u32;
                data[dst] = self.data[k];
                cursor[c] += 1;
            }
        }
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            indptr: indptr.into(),
            indices: indices.into(),
            data: data.into(),
        }
    }

    /// Dense representation (row-major) — tests and small blocks only.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n_rows * self.n_cols];
        for r in 0..self.n_rows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                out[r * self.n_cols + c as usize] += v;
            }
        }
        out
    }

    /// y = A·x (dense vector).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_cols);
        debug_assert_eq!(y.len(), self.n_rows);
        for r in 0..self.n_rows {
            let (cols, vals) = self.row(r);
            let mut acc = 0f32;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c as usize];
            }
            y[r] = acc;
        }
    }

    /// Y = A·X where X is dense column-major `n_cols × k` (`X[c*k + j]`
    /// layout, i.e. row-major with `k` contiguous per row). Output is the
    /// same layout, `n_rows × k`. This layout keeps the k-loop contiguous,
    /// which is what subspace iteration wants. Rows are partitioned
    /// across the shared [`exec`] pool; output is bitwise-identical to
    /// serial at any thread count.
    pub fn spmm(&self, x: &[f32], k: usize, y: &mut [f32]) {
        self.spmm_with_threads(x, k, y, exec::workers_for(self.nnz(), 1 << 14));
    }

    /// [`Csr::spmm`] with an explicit worker count (`1` = serial
    /// reference). Each worker owns a contiguous row block of `Y` and
    /// accumulates every row with the same serial inner loop, so the
    /// result never depends on the partition.
    ///
    /// The k-loop is tiled ([`SPMM_K_TILE`]) so the slice of the dense
    /// `X` panel in flight stays cache-resident while a worker streams
    /// its rows; every output element still accumulates its row's
    /// nonzeros in the same order whatever the tiling, so tiled output
    /// is bitwise-identical to untiled (and across thread counts).
    pub fn spmm_with_threads(&self, x: &[f32], k: usize, y: &mut [f32], n_threads: usize) {
        debug_assert_eq!(x.len(), self.n_cols * k);
        debug_assert_eq!(y.len(), self.n_rows * k);
        let nt = n_threads.max(1).min(self.n_rows.max(1));
        if nt == 1 || k == 0 {
            self.spmm_serial(x, k, y);
            return;
        }
        let ranges = exec::chunk_ranges(self.n_rows, nt);
        let ysh = exec::SharedSlice::new(y);
        exec::parallel_tasks(ranges, |_, rows| {
            let mut acc = vec![0f32; SPMM_K_TILE.min(k)];
            for k0 in (0..k).step_by(SPMM_K_TILE) {
                let kt = SPMM_K_TILE.min(k - k0);
                for r in rows.clone() {
                    let acc = &mut acc[..kt];
                    acc.fill(0.0);
                    let (cols, vals) = self.row(r);
                    for (&c, &v) in cols.iter().zip(vals) {
                        let xr = &x[c as usize * k + k0..c as usize * k + k0 + kt];
                        for j in 0..kt {
                            acc[j] += v * xr[j];
                        }
                    }
                    for j in 0..kt {
                        // SAFETY: row ranges are disjoint and k-tiles
                        // within a range run on the same worker, so
                        // every output slot is written by exactly one
                        // worker.
                        unsafe { ysh.write(r * k + k0 + j, acc[j]) };
                    }
                }
            }
        });
    }

    fn spmm_serial(&self, x: &[f32], k: usize, y: &mut [f32]) {
        y.fill(0.0);
        for k0 in (0..k).step_by(SPMM_K_TILE) {
            let kt = SPMM_K_TILE.min(k - k0);
            for r in 0..self.n_rows {
                let (cols, vals) = self.row(r);
                let out = &mut y[r * k + k0..r * k + k0 + kt];
                for (&c, &v) in cols.iter().zip(vals) {
                    let xr = &x[c as usize * k + k0..c as usize * k + k0 + kt];
                    for j in 0..kt {
                        out[j] += v * xr[j];
                    }
                }
            }
        }
    }

    /// Yᵀ-accumulate: Y += Aᵀ·X with X `n_rows × k`, Y `n_cols × k`
    /// (both row-major-k). Used by the Gram power step `Qᵀ(QV)` without
    /// materializing the transpose. Parallelized over *output column*
    /// ranges on the shared [`exec`] pool; bitwise-identical to serial
    /// at any thread count.
    pub fn spmm_t(&self, x: &[f32], k: usize, y: &mut [f32]) {
        self.spmm_t_with_threads(x, k, y, exec::workers_for(self.nnz(), 1 << 14));
    }

    /// [`Csr::spmm_t`] with an explicit worker count (`1` = serial
    /// reference). Each worker owns a contiguous range of output
    /// columns and scans all rows, locating its columns inside each
    /// sorted row by binary search. A given output column is therefore
    /// accumulated in row order by exactly one worker — the same
    /// association as the serial loop — so the result is
    /// bitwise-identical whatever the partition.
    pub fn spmm_t_with_threads(&self, x: &[f32], k: usize, y: &mut [f32], n_threads: usize) {
        debug_assert_eq!(x.len(), self.n_rows * k);
        debug_assert_eq!(y.len(), self.n_cols * k);
        let nt = n_threads.max(1).min(self.n_cols.max(1));
        if nt == 1 || k == 0 {
            self.spmm_t_serial(x, k, y);
            return;
        }
        let ranges = exec::chunk_ranges(self.n_cols, nt);
        let ysh = exec::SharedSlice::new(y);
        exec::parallel_tasks(ranges, |_, cols_range| {
            let width = cols_range.len();
            let lo = cols_range.start as u32;
            let hi = cols_range.end as u32;
            // Per-worker output tile over its own columns, k-tiled so
            // the live slices of X and the tile fit in cache together.
            let mut tile = vec![0f32; width * SPMM_K_TILE.min(k)];
            for k0 in (0..k).step_by(SPMM_K_TILE) {
                let kt = SPMM_K_TILE.min(k - k0);
                tile[..width * kt].fill(0.0);
                for r in 0..self.n_rows {
                    let (cols, vals) = self.row(r);
                    let a = cols.partition_point(|&c| c < lo);
                    let b = a + cols[a..].partition_point(|&c| c < hi);
                    if a == b {
                        continue;
                    }
                    let xr = &x[r * k + k0..r * k + k0 + kt];
                    for t in a..b {
                        let cl = (cols[t] - lo) as usize;
                        let v = vals[t];
                        let out = &mut tile[cl * kt..cl * kt + kt];
                        for j in 0..kt {
                            out[j] += v * xr[j];
                        }
                    }
                }
                for (ci, col) in cols_range.clone().enumerate() {
                    for j in 0..kt {
                        // SAFETY: column ranges are disjoint and every
                        // k-tile of a range runs on the same worker, so
                        // every output slot is written by exactly one
                        // worker.
                        unsafe { ysh.write(col * k + k0 + j, tile[ci * kt + j]) };
                    }
                }
            }
        });
    }

    fn spmm_t_serial(&self, x: &[f32], k: usize, y: &mut [f32]) {
        y.fill(0.0);
        for k0 in (0..k).step_by(SPMM_K_TILE) {
            let kt = SPMM_K_TILE.min(k - k0);
            for r in 0..self.n_rows {
                let (cols, vals) = self.row(r);
                let xr = &x[r * k + k0..r * k + k0 + kt];
                for (&c, &v) in cols.iter().zip(vals) {
                    let out = &mut y[c as usize * k + k0..c as usize * k + k0 + kt];
                    for j in 0..kt {
                        out[j] += v * xr[j];
                    }
                }
            }
        }
    }

    /// Per-row sums (used for kernel row-normalization in prediction).
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.n_rows).map(|r| self.row(r).1.iter().sum()).collect()
    }

    /// Copy a contiguous row range into a standalone CSR with the same
    /// column dimension (the coordinator's stripe view of Q, and the
    /// factor slicing the multi-process row-range workers use). Row
    /// contents are preserved verbatim, so any per-row computation on a
    /// slice is bitwise-identical to the same rows of the full matrix.
    pub fn slice_rows(&self, rows: std::ops::Range<usize>) -> Csr {
        assert!(rows.start <= rows.end && rows.end <= self.n_rows);
        let lo = self.indptr[rows.start];
        let hi = self.indptr[rows.end];
        Csr {
            n_rows: rows.len(),
            n_cols: self.n_cols,
            indptr: self.indptr[rows.start..=rows.end].iter().map(|&p| p - lo).collect(),
            indices: self.indices[lo..hi].to_vec().into(),
            data: self.data[lo..hi].to_vec().into(),
        }
    }

    /// Extract a dense block `rows × cols` (tests / coordinator assembly).
    pub fn dense_block(&self, rows: std::ops::Range<usize>, cols: std::ops::Range<usize>) -> Vec<f32> {
        let (rn, cn) = (rows.len(), cols.len());
        let mut out = vec![0f32; rn * cn];
        for (ri, r) in rows.clone().enumerate() {
            let (cs, vs) = self.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                let c = c as usize;
                if c >= cols.start && c < cols.end {
                    out[ri * cn + (c - cols.start)] += v;
                }
            }
        }
        out
    }

    /// Memory footprint of the stored representation in bytes.
    pub fn mem_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.data.len() * std::mem::size_of::<f32>()
    }

    /// Check structural invariants (sorted rows, bounds). Test helper.
    pub fn check(&self) -> Result<(), String> {
        if self.indptr.len() != self.n_rows + 1 {
            return Err("indptr length".into());
        }
        if *self.indptr.last().unwrap() != self.nnz() || self.data.len() != self.nnz() {
            return Err("nnz mismatch".into());
        }
        for r in 0..self.n_rows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr not monotone at {r}"));
            }
            let (cols, _) = self.row(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} not strictly sorted"));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.n_cols {
                    return Err(format!("col out of bounds in row {r}"));
                }
            }
        }
        Ok(())
    }
}

/// Sort + merge duplicate columns of the freshly pushed row starting at
/// `start` (shared by the serial and parallel row builders).
fn finalize_row(indices: &mut Vec<u32>, data: &mut Vec<f32>, start: usize) {
    let row_len = indices.len() - start;
    if row_len <= 1 {
        return;
    }
    let mut perm: Vec<usize> = (0..row_len).collect();
    perm.sort_unstable_by_key(|&k| indices[start + k]);
    let idx_sorted: Vec<u32> = perm.iter().map(|&k| indices[start + k]).collect();
    let val_sorted: Vec<f32> = perm.iter().map(|&k| data[start + k]).collect();
    indices.truncate(start);
    data.truncate(start);
    for (c, v) in idx_sorted.into_iter().zip(val_sorted) {
        if indices.len() > start && *indices.last().unwrap() == c {
            *data.last_mut().unwrap() += v;
        } else {
            indices.push(c);
            data.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        Csr::from_triplets(3, 3, &[(0, 0, 1.0), (0, 2, 2.0), (2, 0, 3.0), (2, 1, 4.0)])
    }

    #[test]
    fn triplets_roundtrip() {
        let m = sample();
        m.check().unwrap();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.to_dense(), vec![1., 0., 2., 0., 0., 0., 3., 4., 0.]);
    }

    #[test]
    fn duplicate_triplets_sum() {
        let m = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (0, 1, 2.5), (1, 0, -1.0)]);
        m.check().unwrap();
        assert_eq!(m.to_dense(), vec![0., 3.5, -1., 0.]);
    }

    #[test]
    fn from_rows_matches_triplets() {
        let trip = &[(0usize, 2u32, 1.0f32), (0, 0, 2.0), (1, 1, 3.0), (1, 1, 1.0)];
        let a = Csr::from_triplets(2, 3, trip);
        let b = Csr::from_rows(2, 3, 2, |i, push| {
            for &(r, c, v) in trip {
                if r == i {
                    push(c, v);
                }
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn slice_rows_preserves_row_contents() {
        let m = sample();
        for (range, rows) in [
            (0..3, 3usize),
            (0..1, 1),
            (1..2, 1),
            (1..3, 2),
            (2..2, 0),
        ] {
            let s = m.slice_rows(range.clone());
            s.check().unwrap();
            assert_eq!(s.n_rows, rows);
            assert_eq!(s.n_cols, 3);
            for (local, global) in range.enumerate() {
                assert_eq!(s.row(local), m.row(global));
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let t = m.transpose();
        t.check().unwrap();
        assert_eq!(t.to_dense(), vec![1., 0., 3., 0., 0., 4., 2., 0., 0.]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn parallel_transpose_equals_serial() {
        use crate::rng::Rng;
        let mut rng = Rng::new(31);
        for _ in 0..6 {
            let rows = 1 + rng.gen_range(60);
            let cols = 1 + rng.gen_range(40);
            let mut trip = vec![];
            for r in 0..rows {
                for c in 0..cols {
                    if rng.next_f64() < 0.25 {
                        trip.push((r, c as u32, rng.next_normal() as f32));
                    }
                }
            }
            let m = Csr::from_triplets(rows, cols, &trip);
            let serial = m.transpose_with_threads(1);
            for th in [2usize, 3, 4, 8] {
                let par = m.transpose_with_threads(th);
                par.check().unwrap();
                assert_eq!(par, serial, "threads={th}");
            }
        }
    }

    #[test]
    fn from_rows_par_equals_serial() {
        // Large enough that `from_rows_par` actually fans out on a
        // multi-core host (it degrades to the serial builder below 512
        // rows per worker).
        let n_rows = 2048;
        let n_cols = 19;
        let fill = |i: usize, push: &mut dyn FnMut(u32, f32)| {
            // Deterministic per-row pattern with duplicates and
            // unsorted pushes.
            push(((i * 7) % n_cols) as u32, i as f32);
            push(((i * 3) % n_cols) as u32, 1.0);
            push(((i * 7) % n_cols) as u32, 0.5);
        };
        let serial = Csr::from_rows(n_rows, n_cols, 3, |i, push| fill(i, push));
        let par = Csr::from_rows_par(n_rows, n_cols, 3, fill);
        par.check().unwrap();
        assert_eq!(par, serial);
    }

    #[test]
    fn spmv_matches_dense() {
        let m = sample();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0f32; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 0.0, 11.0]);
    }

    #[test]
    fn spmm_matches_spmv_per_column() {
        let m = sample();
        let k = 2;
        // X columns: [1,2,3] and [0,1,0] in row-major-k layout.
        let x = [1.0, 0.0, 2.0, 1.0, 3.0, 0.0];
        let mut y = vec![0f32; 3 * k];
        m.spmm(&x, k, &mut y);
        assert_eq!(y, vec![7.0, 0.0, 0.0, 0.0, 11.0, 4.0]);
    }

    #[test]
    fn spmm_t_matches_transpose_spmm() {
        let m = sample();
        let k = 2;
        let x = [1.0, 1.0, 0.0, 2.0, 1.0, 0.0]; // 3×2
        let mut y1 = vec![0f32; 3 * k];
        m.spmm_t(&x, k, &mut y1);
        let mut y2 = vec![0f32; 3 * k];
        m.transpose().spmm(&x, k, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn dense_block_extracts() {
        let m = sample();
        assert_eq!(m.dense_block(0..2, 1..3), vec![0., 2., 0., 0.]);
        assert_eq!(m.dense_block(2..3, 0..2), vec![3., 4.]);
    }

    #[test]
    fn row_sums_ok() {
        assert_eq!(sample().row_sums(), vec![3.0, 0.0, 7.0]);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::zeros(4, 5);
        m.check().unwrap();
        assert_eq!(m.nnz(), 0);
        let mut y = vec![1f32; 4];
        m.spmv(&[0.0; 5], &mut y);
        assert_eq!(y, vec![0.0; 4]);
    }
}
