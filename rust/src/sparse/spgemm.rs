//! Gustavson row-wise sparse–sparse matrix multiplication.
//!
//! `spgemm(A, B)` computes `C = A·B` touching, for each row `i` of `A`,
//! only the rows of `B` indexed by `A`'s nonzero columns — exactly the
//! "computation restricted to samples that collide in leaves" mechanism
//! the paper attributes to SciPy (§3.3). For `P = Q_rows · Wᵀ_rows`
//! (both stored sample-major), the flop count is
//! `Σ_i Σ_t n_{t, ℓ_t(x_i)} = N·T·λ̄` — the paper's λ̄ cost model.
//!
//! Rows of `C` are independent, so the product parallelizes by row
//! partitioning on the shared [`crate::exec`] pool: each worker owns a
//! contiguous row range and one private SPA scratch ([`SpaScratch`]),
//! and the per-range outputs are stitched in range order. Every row is
//! accumulated by the same serial inner loop regardless of the
//! partition, so the parallel output is **bitwise-identical** to the
//! serial one at any thread count (verified by
//! `tests/parallel_determinism.rs`).

use super::Csr;
use crate::exec;

/// Per-worker sparse-accumulator (SPA) scratch for Gustavson rows.
///
/// Keeps an `n_cols(B)`-sized value array + row-stamped occupancy:
/// `stamp[c] == row_stamp` ⇔ column c is live in the current row. (A
/// `value == 0.0` sentinel would double-push a column whose partial sum
/// cancels to exactly zero mid-row, and would force a scratch clear per
/// row.) Allocated once per worker and reset per row in O(row nnz).
///
/// A scratch may be reused across *calls* (the coordinator keeps one
/// per pool thread so stripe products stop reallocating `touched` /
/// `radix_tmp` between stripes): [`SpaScratch::begin_rows`] hands out a
/// fresh stamp base per call, so stale stamps from earlier products can
/// never collide with live rows.
pub struct SpaScratch {
    scratch: Vec<f32>,
    stamp: Vec<u32>,
    touched: Vec<u32>,
    radix_tmp: Vec<u32>,
    next_stamp: u32,
}

impl SpaScratch {
    pub fn new(n_out_cols: usize) -> SpaScratch {
        SpaScratch {
            scratch: vec![0f32; n_out_cols],
            stamp: vec![0u32; n_out_cols],
            touched: Vec::new(),
            radix_tmp: Vec::new(),
            next_stamp: 1,
        }
    }

    /// Grow the dense arrays to cover `n_out_cols` output columns
    /// (no-op when already large enough). New stamp slots start at 0,
    /// which is below every stamp [`SpaScratch::begin_rows`] hands out.
    pub fn ensure(&mut self, n_out_cols: usize) {
        if self.scratch.len() < n_out_cols {
            self.scratch.resize(n_out_cols, 0.0);
            self.stamp.resize(n_out_cols, 0);
        }
    }

    /// Reserve `n_rows` consecutive row stamps and return the base
    /// stamp; wraps by clearing the stamp array when the u32 space is
    /// about to run out (once per ~4G accumulated rows).
    pub(crate) fn begin_rows(&mut self, n_rows: usize) -> u32 {
        if n_rows as u64 >= (u32::MAX - self.next_stamp) as u64 {
            self.stamp.fill(0);
            self.next_stamp = 1;
        }
        let base = self.next_stamp;
        self.next_stamp += n_rows as u32;
        base
    }

    /// Scatter-accumulate `alpha · vals` into the SPA at `cols`,
    /// recording first-touched columns. Shared by the exact and
    /// quantized Gustavson inner loops, so both accumulate in exactly
    /// the same order.
    #[inline]
    pub(crate) fn accumulate(&mut self, row_stamp: u32, cols: &[u32], vals: &[f32], alpha: f32) {
        for (&bc, &bv) in cols.iter().zip(vals) {
            let c = bc as usize;
            // SAFETY: both scratch arrays were sized to the product's
            // column count in `new`, and every `bc` comes from a CSR
            // whose `check()`-verified column indices are < n_cols —
            // so `c` is in bounds for both vectors.
            let st = unsafe { self.stamp.get_unchecked_mut(c) };
            // SAFETY: same bound as `stamp` above.
            let slot = unsafe { self.scratch.get_unchecked_mut(c) };
            if *st != row_stamp {
                *st = row_stamp;
                *slot = alpha * bv;
                self.touched.push(bc);
            } else {
                *slot += alpha * bv;
            }
        }
    }

    /// Sort the touched columns and append the finished row to
    /// (`indices`, `data`), keeping exact cancellation zeros (they are
    /// real collisions with zero weight; dropping them would make nnz
    /// structure depend on weight values).
    pub(crate) fn flush(&mut self, key_bytes: usize, indices: &mut Vec<u32>, data: &mut Vec<f32>) {
        if self.touched.len() < 64 {
            self.touched.sort_unstable();
        } else {
            radix_sort_u32(&mut self.touched, &mut self.radix_tmp, key_bytes);
        }
        for &c in &self.touched {
            indices.push(c);
            data.push(self.scratch[c as usize]);
        }
        self.touched.clear();
    }
}

/// Radix key width for sorting column ids below `n_out_cols`.
///
/// §Perf: SWLC kernels have a duplication factor flops/nnz ≈ 1, so
/// per-row key sorting dominates the accumulate loop. An LSD radix-256
/// on the u32 keys (values are gathered from the scratch afterwards, so
/// only keys move) beats the comparison sort ~2× on the λ̄·T-sized rows
/// this workload produces.
pub(crate) fn key_bytes_for(n_out_cols: usize) -> usize {
    (32 - (n_out_cols.max(2) as u32 - 1).leading_zeros()).div_ceil(8) as usize
}

/// One worker's share of the product: a contiguous row range of `C` as
/// (local indptr, indices, data).
struct RowBlock {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f32>,
}

/// Dense-scratch Gustavson over `rows` of `A`, using the worker-local
/// `spa`. The accumulate + sort order per row is fixed, so the output
/// for a row does not depend on which range it lands in.
fn spgemm_rows(a: &Csr, b: &Csr, rows: std::ops::Range<usize>, spa: &mut SpaScratch) -> RowBlock {
    let key_bytes = key_bytes_for(b.n_cols);
    let base = spa.begin_rows(rows.len());

    let mut indptr = Vec::with_capacity(rows.len() + 1);
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    indptr.push(0usize);

    for i in rows.clone() {
        let row_stamp = base + (i - rows.start) as u32;
        let (acols, avals) = a.row(i);
        for (&ac, &av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(ac as usize);
            spa.accumulate(row_stamp, bcols, bvals, av);
        }
        spa.flush(key_bytes, &mut indices, &mut data);
        indptr.push(indices.len());
    }
    RowBlock { indptr, indices, data }
}

/// SpGEMM `C = A·B` on the shared worker pool (thread count from
/// [`exec::threads`], small inputs stay serial).
pub fn spgemm(a: &Csr, b: &Csr) -> Csr {
    spgemm_with_threads(a, b, exec::workers_for(a.n_rows, 256))
}

/// SpGEMM with an explicit worker count; `n_threads = 1` is the serial
/// reference path. Output is bitwise-identical across thread counts.
pub fn spgemm_with_threads(a: &Csr, b: &Csr, n_threads: usize) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "spgemm dim mismatch");
    assert!(a.n_rows < u32::MAX as usize);
    let t0 = crate::obs::stopwatch();
    let blocks = exec::parallel_ranges(a.n_rows, n_threads.max(1), |_, rows| {
        let mut spa = SpaScratch::new(b.n_cols);
        spgemm_rows(a, b, rows, &mut spa)
    });
    // Whole-product accounting only (per call, outside the row loops);
    // the coordinator's stripe path reports its own finer-grained
    // fk_stripe_* series through spgemm_with_scratch.
    crate::metric!(counter "fk_spgemm_calls_total", "Full SpGEMM products computed.").inc();
    crate::metric!(counter "fk_spgemm_rows_total", "Rows produced by full SpGEMM products.")
        .add(a.n_rows as u64);
    crate::metric!(
        counter_secs "fk_spgemm_seconds_total",
        "Cumulative wall time inside full SpGEMM products."
    )
    .add_nanos(t0.elapsed());

    // Stitch the per-range blocks in row order.
    let nnz: usize = blocks.iter().map(|blk| blk.indices.len()).sum();
    let mut indptr = Vec::with_capacity(a.n_rows + 1);
    let mut indices: Vec<u32> = Vec::with_capacity(nnz);
    let mut data: Vec<f32> = Vec::with_capacity(nnz);
    indptr.push(0usize);
    for blk in blocks {
        let base = indices.len();
        indptr.extend(blk.indptr[1..].iter().map(|&p| base + p));
        indices.extend_from_slice(&blk.indices);
        data.extend_from_slice(&blk.data);
    }
    if indptr.len() == 1 {
        // Zero-row input: parallel_ranges produced no blocks.
        indptr.resize(a.n_rows + 1, 0);
    }
    Csr {
        n_rows: a.n_rows,
        n_cols: b.n_cols,
        indptr: indptr.into(),
        indices: indices.into(),
        data: data.into(),
    }
}

/// Serial SpGEMM reusing a caller-owned [`SpaScratch`] across calls —
/// the coordinator's stripe path, where one scratch per pool thread
/// serves every stripe that thread processes. Bitwise-identical to
/// `spgemm_with_threads(a, b, 1)` (same inner loop; the stamp base
/// differs but stamps never leak into the output).
pub fn spgemm_with_scratch(a: &Csr, b: &Csr, spa: &mut SpaScratch) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "spgemm dim mismatch");
    assert!(a.n_rows < u32::MAX as usize);
    spa.ensure(b.n_cols);
    let blk = spgemm_rows(a, b, 0..a.n_rows, spa);
    Csr {
        n_rows: a.n_rows,
        n_cols: b.n_cols,
        indptr: blk.indptr.into(),
        indices: blk.indices.into(),
        data: blk.data.into(),
    }
}

/// In-place LSD radix-256 sort of `keys`, using `tmp` as the ping-pong
/// buffer; only the lowest `key_bytes` bytes are significant.
fn radix_sort_u32(keys: &mut Vec<u32>, tmp: &mut Vec<u32>, key_bytes: usize) {
    let n = keys.len();
    tmp.resize(n, 0);
    let mut src_is_keys = true;
    for pass in 0..key_bytes {
        let shift = pass * 8;
        let mut counts = [0u32; 256];
        {
            let src: &[u32] = if src_is_keys { keys } else { tmp };
            for &k in src {
                counts[((k >> shift) & 0xFF) as usize] += 1;
            }
        }
        // Skip passes where all keys share the byte (common for the
        // high byte): nothing would move.
        if counts.iter().any(|&c| c as usize == n) {
            continue;
        }
        let mut pos = [0u32; 256];
        let mut acc = 0u32;
        for b in 0..256 {
            pos[b] = acc;
            acc += counts[b];
        }
        if src_is_keys {
            scatter_by_byte(keys.as_slice(), tmp.as_mut_slice(), shift, &mut pos);
        } else {
            scatter_by_byte(tmp.as_slice(), keys.as_mut_slice(), shift, &mut pos);
        }
        src_is_keys = !src_is_keys;
    }
    if !src_is_keys {
        keys.copy_from_slice(&tmp[..n]);
    }
}

#[inline]
fn scatter_by_byte(src: &[u32], dst: &mut [u32], shift: usize, pos: &mut [u32; 256]) {
    for &k in src {
        let b = ((k >> shift) & 0xFF) as usize;
        dst[pos[b] as usize] = k;
        pos[b] += 1;
    }
}

/// Predicted SpGEMM work of `A·B` without computing it: returns
/// `(flops, nnz_upper_bound)`.
///
/// `flops = Σ_i Σ_{k∈row_i(A)} nnz(B_k)` — for the SWLC kernel this
/// equals `N·T·λ̄`, the quantity of the paper's §3.3 cost model, so
/// benches report it alongside wall time. The nnz bound is
/// `Σ_i min(row_flops_i, n_cols(B))`: every output nonzero needs at
/// least one accumulate and a row cannot exceed the output width.
pub fn spgemm_nnz_flops(a: &Csr, b: &Csr) -> (u64, u64) {
    let mut flops = 0u64;
    let mut nnz_ub = 0u64;
    for i in 0..a.n_rows {
        let (acols, _) = a.row(i);
        let mut row_flops = 0u64;
        for &ac in acols {
            row_flops += (b.indptr[ac as usize + 1] - b.indptr[ac as usize]) as u64;
        }
        flops += row_flops;
        nnz_ub += row_flops.min(b.n_cols as u64);
    }
    (flops, nnz_ub)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dense_mul(a: &Csr, b: &Csr) -> Vec<f32> {
        let (m, k, n) = (a.n_rows, a.n_cols, b.n_cols);
        let (da, db) = (a.to_dense(), b.to_dense());
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let v = da[i * k + p];
                if v != 0.0 {
                    for j in 0..n {
                        c[i * n + j] += v * db[p * n + j];
                    }
                }
            }
        }
        c
    }

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trip = vec![];
        for r in 0..rows {
            for c in 0..cols {
                if rng.next_f64() < density {
                    trip.push((r, c as u32, rng.next_normal() as f32));
                }
            }
        }
        Csr::from_triplets(rows, cols, &trip)
    }

    #[test]
    fn matches_dense_reference() {
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let a = random_csr(&mut rng, 13, 7, 0.3);
            let b = random_csr(&mut rng, 7, 11, 0.3);
            let c = spgemm(&a, &b);
            c.check().unwrap();
            let exp = dense_mul(&a, &b);
            let got = c.to_dense();
            for (g, e) in got.iter().zip(&exp) {
                assert!((g - e).abs() < 1e-4, "{g} vs {e}");
            }
        }
    }

    #[test]
    fn parallel_bitwise_equals_serial() {
        let mut rng = Rng::new(21);
        for case in 0..8 {
            let rows = 1 + rng.gen_range(40);
            let inner = 1 + rng.gen_range(20);
            let cols = 1 + rng.gen_range(30);
            let a = random_csr(&mut rng, rows, inner, 0.3);
            let b = random_csr(&mut rng, inner, cols, 0.3);
            let serial = spgemm_with_threads(&a, &b, 1);
            for th in [2usize, 3, 4] {
                let par = spgemm_with_threads(&a, &b, th);
                par.check().unwrap();
                assert_eq!(par.indptr, serial.indptr, "case {case} th {th}");
                assert_eq!(par.indices, serial.indices, "case {case} th {th}");
                let pb: Vec<u32> = par.data.iter().map(|v| v.to_bits()).collect();
                let sb: Vec<u32> = serial.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(pb, sb, "case {case} th {th}: values not bitwise equal");
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(6);
        let a = random_csr(&mut rng, 9, 9, 0.4);
        let eye = Csr::from_triplets(9, 9, &(0..9).map(|i| (i, i as u32, 1.0)).collect::<Vec<_>>());
        assert_eq!(spgemm(&a, &eye).to_dense(), a.to_dense());
        assert_eq!(spgemm(&eye, &a).to_dense(), a.to_dense());
    }

    #[test]
    fn empty_rows_and_cols() {
        let a = Csr::zeros(4, 3);
        let b = Csr::zeros(3, 5);
        for th in [1usize, 4] {
            let c = spgemm_with_threads(&a, &b, th);
            assert_eq!(c.nnz(), 0);
            assert_eq!((c.n_rows, c.n_cols), (4, 5));
            assert_eq!(c.indptr.len(), 5);
        }
        let z = spgemm_with_threads(&Csr::zeros(0, 3), &Csr::zeros(3, 5), 4);
        assert_eq!((z.n_rows, z.n_cols, z.nnz()), (0, 5, 0));
        assert_eq!(z.indptr, vec![0]);
    }

    #[test]
    fn flops_counts_collisions() {
        // A row with k nonzeros against B rows of length m each => k*m flops.
        let a = Csr::from_triplets(1, 3, &[(0, 0, 1.0), (0, 2, 1.0)]);
        let b = Csr::from_triplets(
            3,
            4,
            &[(0, 0, 1.0), (0, 1, 1.0), (2, 2, 1.0), (2, 3, 1.0), (2, 0, 1.0)],
        );
        let (flops, nnz_ub) = spgemm_nnz_flops(&a, &b);
        assert_eq!(flops, 2 + 3);
        // The single output row is capped at n_cols(B) = 4.
        assert_eq!(nnz_ub, 4);
        assert!(spgemm(&a, &b).nnz() as u64 <= nnz_ub);
    }

    #[test]
    fn reused_scratch_is_bitwise_identical_across_products() {
        // One scratch serving many differently-shaped products (the
        // stripe pattern) must never change results: stale stamps from
        // earlier calls cannot collide with fresh stamp bases.
        let mut rng = Rng::new(31);
        let mut spa = SpaScratch::new(0);
        for case in 0..12 {
            let rows = 1 + rng.gen_range(40);
            let inner = 1 + rng.gen_range(20);
            let cols = 1 + rng.gen_range(50);
            let a = random_csr(&mut rng, rows, inner, 0.3);
            let b = random_csr(&mut rng, inner, cols, 0.3);
            let want = spgemm_with_threads(&a, &b, 1);
            let got = spgemm_with_scratch(&a, &b, &mut spa);
            assert_eq!(got.indptr, want.indptr, "case {case}");
            assert_eq!(got.indices, want.indices, "case {case}");
            let gb: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "case {case}: values not bitwise equal");
        }
    }

    #[test]
    fn stamp_wraparound_clears_cleanly() {
        let mut rng = Rng::new(33);
        let a = random_csr(&mut rng, 10, 8, 0.4);
        let b = random_csr(&mut rng, 8, 12, 0.4);
        let want = spgemm_with_threads(&a, &b, 1);
        let mut spa = SpaScratch::new(0);
        spa.next_stamp = u32::MAX - 4; // force the wrap path
        let got = spgemm_with_scratch(&a, &b, &mut spa);
        assert_eq!(got.indices, want.indices);
        assert_eq!(got.indptr, want.indptr);
    }

    #[test]
    fn gram_product_is_symmetric() {
        let mut rng = Rng::new(7);
        let q = random_csr(&mut rng, 12, 20, 0.2);
        let p = spgemm(&q, &q.transpose());
        let d = p.to_dense();
        for i in 0..12 {
            for j in 0..12 {
                assert!((d[i * 12 + j] - d[j * 12 + i]).abs() < 1e-4);
            }
        }
    }
}
