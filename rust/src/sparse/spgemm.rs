//! Gustavson row-wise sparse–sparse matrix multiplication.
//!
//! `spgemm(A, B)` computes `C = A·B` touching, for each row `i` of `A`,
//! only the rows of `B` indexed by `A`'s nonzero columns — exactly the
//! "computation restricted to samples that collide in leaves" mechanism
//! the paper attributes to SciPy (§3.3). For `P = Q_rows · Wᵀ_rows`
//! (both stored sample-major), the flop count is
//! `Σ_i Σ_t n_{t, ℓ_t(x_i)} = N·T·λ̄` — the paper's λ̄ cost model.

use super::Csr;

/// Dense-scratch (SPA) accumulator Gustavson SpGEMM: `C = A·B`.
///
/// Keeps an `n_cols(B)`-sized value array + occupancy list. The scratch
/// is allocated once and reset per row in O(row nnz), so the total cost
/// is O(flops + nnz(C) log) (the log from per-row sorting of the
/// occupancy list to keep CSR rows ordered).
pub fn spgemm(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "spgemm dim mismatch");
    let n_out_cols = b.n_cols;
    let mut scratch = vec![0f32; n_out_cols];
    // Row-stamped occupancy: `stamp[c] == row+1` ⇔ column c is live in
    // the current row. (A `value == 0.0` sentinel would double-push a
    // column whose partial sum cancels to exactly zero mid-row, and
    // would force a scratch clear per row.)
    let mut stamp = vec![0u32; n_out_cols];
    let mut touched: Vec<u32> = Vec::new();
    let mut radix_tmp: Vec<u32> = Vec::new();
    // §Perf: SWLC kernels have a duplication factor flops/nnz ≈ 1, so
    // per-row key sorting dominates the accumulate loop. An LSD
    // radix-256 on the u32 keys (values are gathered from the scratch
    // afterwards, so only keys move) beats the comparison sort ~2× on
    // the λ̄·T-sized rows this workload produces.
    let key_bytes = (32 - (n_out_cols.max(2) as u32 - 1).leading_zeros()).div_ceil(8) as usize;

    let mut indptr = Vec::with_capacity(a.n_rows + 1);
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    indptr.push(0usize);

    assert!(a.n_rows < u32::MAX as usize);
    for i in 0..a.n_rows {
        let row_stamp = i as u32 + 1;
        let (acols, avals) = a.row(i);
        for (&ac, &av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(ac as usize);
            for (&bc, &bv) in bcols.iter().zip(bvals) {
                let c = bc as usize;
                let st = unsafe { stamp.get_unchecked_mut(c) };
                let slot = unsafe { scratch.get_unchecked_mut(c) };
                if *st != row_stamp {
                    *st = row_stamp;
                    *slot = av * bv;
                    touched.push(bc);
                } else {
                    *slot += av * bv;
                }
            }
        }
        if touched.len() < 64 {
            touched.sort_unstable();
        } else {
            radix_sort_u32(&mut touched, &mut radix_tmp, key_bytes);
        }
        for &c in &touched {
            // Keep exact zeros produced by cancellation: they are real
            // collisions with zero weight and dropping them would make
            // nnz structure depend on weight values. (Entries never
            // touched are genuinely structural zeros.)
            indices.push(c);
            data.push(scratch[c as usize]);
        }
        touched.clear();
        indptr.push(indices.len());
    }
    Csr { n_rows: a.n_rows, n_cols: n_out_cols, indptr, indices, data }
}

/// In-place LSD radix-256 sort of `keys`, using `tmp` as the ping-pong
/// buffer; only the lowest `key_bytes` bytes are significant.
fn radix_sort_u32(keys: &mut Vec<u32>, tmp: &mut Vec<u32>, key_bytes: usize) {
    let n = keys.len();
    tmp.resize(n, 0);
    let mut src_is_keys = true;
    for pass in 0..key_bytes {
        let shift = pass * 8;
        let mut counts = [0u32; 256];
        {
            let src: &[u32] = if src_is_keys { keys } else { tmp };
            for &k in src {
                counts[((k >> shift) & 0xFF) as usize] += 1;
            }
        }
        // Skip passes where all keys share the byte (common for the
        // high byte): nothing would move.
        if counts.iter().any(|&c| c as usize == n) {
            continue;
        }
        let mut pos = [0u32; 256];
        let mut acc = 0u32;
        for b in 0..256 {
            pos[b] = acc;
            acc += counts[b];
        }
        if src_is_keys {
            scatter_by_byte(keys.as_slice(), tmp.as_mut_slice(), shift, &mut pos);
        } else {
            scatter_by_byte(tmp.as_slice(), keys.as_mut_slice(), shift, &mut pos);
        }
        src_is_keys = !src_is_keys;
    }
    if !src_is_keys {
        keys.copy_from_slice(&tmp[..n]);
    }
}

#[inline]
fn scatter_by_byte(src: &[u32], dst: &mut [u32], shift: usize, pos: &mut [u32; 256]) {
    for &k in src {
        let b = ((k >> shift) & 0xFF) as usize;
        dst[pos[b] as usize] = k;
        pos[b] += 1;
    }
}

/// Predicted SpGEMM work: (flops, nnz upper bound) of `A·B` without
/// computing it — `flops = Σ_i Σ_{k∈row_i(A)} nnz(B_k)`. For the SWLC
/// kernel this equals `N·T·λ̄`, the quantity of the paper's §3.3 cost
/// model, so benches report it alongside wall time.
pub fn spgemm_nnz_flops(a: &Csr, b: &Csr) -> u64 {
    let mut flops = 0u64;
    for i in 0..a.n_rows {
        let (acols, _) = a.row(i);
        for &ac in acols {
            flops += (b.indptr[ac as usize + 1] - b.indptr[ac as usize]) as u64;
        }
    }
    flops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dense_mul(a: &Csr, b: &Csr) -> Vec<f32> {
        let (m, k, n) = (a.n_rows, a.n_cols, b.n_cols);
        let (da, db) = (a.to_dense(), b.to_dense());
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let v = da[i * k + p];
                if v != 0.0 {
                    for j in 0..n {
                        c[i * n + j] += v * db[p * n + j];
                    }
                }
            }
        }
        c
    }

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trip = vec![];
        for r in 0..rows {
            for c in 0..cols {
                if rng.next_f64() < density {
                    trip.push((r, c as u32, rng.next_normal() as f32));
                }
            }
        }
        Csr::from_triplets(rows, cols, &trip)
    }

    #[test]
    fn matches_dense_reference() {
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let a = random_csr(&mut rng, 13, 7, 0.3);
            let b = random_csr(&mut rng, 7, 11, 0.3);
            let c = spgemm(&a, &b);
            c.check().unwrap();
            let exp = dense_mul(&a, &b);
            let got = c.to_dense();
            for (g, e) in got.iter().zip(&exp) {
                assert!((g - e).abs() < 1e-4, "{g} vs {e}");
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(6);
        let a = random_csr(&mut rng, 9, 9, 0.4);
        let eye = Csr::from_triplets(9, 9, &(0..9).map(|i| (i, i as u32, 1.0)).collect::<Vec<_>>());
        assert_eq!(spgemm(&a, &eye).to_dense(), a.to_dense());
        assert_eq!(spgemm(&eye, &a).to_dense(), a.to_dense());
    }

    #[test]
    fn empty_rows_and_cols() {
        let a = Csr::zeros(4, 3);
        let b = Csr::zeros(3, 5);
        let c = spgemm(&a, &b);
        assert_eq!(c.nnz(), 0);
        assert_eq!((c.n_rows, c.n_cols), (4, 5));
    }

    #[test]
    fn flops_counts_collisions() {
        // A row with k nonzeros against B rows of length m each => k*m flops.
        let a = Csr::from_triplets(1, 3, &[(0, 0, 1.0), (0, 2, 1.0)]);
        let b = Csr::from_triplets(
            3,
            4,
            &[(0, 0, 1.0), (0, 1, 1.0), (2, 2, 1.0), (2, 3, 1.0), (2, 0, 1.0)],
        );
        assert_eq!(spgemm_nnz_flops(&a, &b), 2 + 3);
    }

    #[test]
    fn gram_product_is_symmetric() {
        let mut rng = Rng::new(7);
        let q = random_csr(&mut rng, 12, 20, 0.2);
        let p = spgemm(&q, &q.transpose());
        let d = p.to_dense();
        for i in 0..12 {
            for j in 0..12 {
                assert!((d[i * 12 + j] - d[j * 12 + i]).abs() < 1e-4);
            }
        }
    }
}
