//! Measurement helpers shared by the figure/table harnesses.

use std::time::Instant;

/// Wall-clock a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Current process peak RSS in bytes (Linux, /proc/self/status VmHWM).
pub fn peak_rss_bytes() -> usize {
    let s = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: usize = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Current RSS in bytes (VmRSS).
pub fn rss_bytes() -> usize {
    let s = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: usize = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Least-squares slope of log(y) vs log(x) — the "fitted linear
/// regression slope" the paper annotates on its log-log scaling plots.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys)
        .filter(|(&x, &y)| x > 0.0 && y > 0.0)
        .map(|(&x, &y)| (x.ln(), y.ln()))
        .collect();
    let n = pts.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in pts {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    num / den
}

/// Geometric sequence of sample sizes `start, 2·start, …, ≤ max`.
pub fn doubling_sizes(start: usize, max: usize) -> Vec<usize> {
    let mut out = vec![];
    let mut n = start;
    while n <= max {
        out.push(n);
        n *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_of_power_law_recovered() {
        let xs: Vec<f64> = vec![1e3, 1e4, 1e5, 1e6];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(1.5)).collect();
        assert!((loglog_slope(&xs, &ys) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn slope_of_linear_is_one() {
        let xs: Vec<f64> = vec![2.0, 4.0, 8.0, 16.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x).collect();
        assert!((loglog_slope(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rss_measured_positive() {
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes() / 2);
    }

    #[test]
    fn doubling_sizes_doubles() {
        assert_eq!(doubling_sizes(1000, 8000), vec![1000, 2000, 4000, 8000]);
    }

    #[test]
    fn timer_returns_result() {
        let (v, secs) = time(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_json_roundtrips_through_own_parser() {
        let recs = vec![
            BenchRecord {
                name: "spgemm/covertype".into(),
                n: 4096,
                wall_secs: 0.125,
                predicted_flops: 123456,
                threads: 4,
                speedup_vs_serial: 2.5,
            },
            BenchRecord {
                name: "naive \"quote\"".into(),
                n: 512,
                wall_secs: 1.0,
                predicted_flops: 0,
                threads: 1,
                speedup_vs_serial: 1.0,
            },
        ];
        let path = std::env::temp_dir().join("fk_bench_records_test.json");
        write_bench_json(&path, &recs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::runtime::json::Json::parse(&text).unwrap();
        let arr = j.get("records").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("n").and_then(|v| v.as_usize()), Some(4096));
        assert_eq!(arr[0].get("threads").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(
            arr[1].get("name").and_then(|v| v.as_str()),
            Some("naive \"quote\"")
        );
        // And all the way back into records via the reader the
        // bench-compare gate uses.
        let back = read_bench_json(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "spgemm/covertype");
        assert_eq!(back[0].n, 4096);
        assert!((back[0].wall_secs - 0.125).abs() < 1e-9);
        assert_eq!(back[0].predicted_flops, 123456);
        assert!((back[1].speedup_vs_serial - 1.0).abs() < 1e-9);
        assert_eq!(back[1].name, "naive \"quote\"");
    }

    #[test]
    fn read_bench_json_rejects_malformed_documents() {
        let path = std::env::temp_dir().join("fk_bench_records_bad.json");
        std::fs::write(&path, "{\"rows\": []}").unwrap();
        assert!(read_bench_json(&path).is_err());
        std::fs::write(&path, "{\"records\": [{\"name\": \"x\"}]}").unwrap();
        assert!(read_bench_json(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}

/// One machine-readable measurement row for the perf trajectory the
/// ROADMAP tracks (emitted as `BENCH_spgemm.json` by `bench-fig42` /
/// `bench-naive` via `--json-out`).
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Measurement label, e.g. `spgemm/covertype`.
    pub name: String,
    /// Problem size (N samples).
    pub n: usize,
    /// Wall-clock seconds of the measured stage.
    pub wall_secs: f64,
    /// Predicted SpGEMM flops `N·T·λ̄` (§3.3), 0 when not applicable.
    pub predicted_flops: u64,
    /// Worker threads the stage ran with.
    pub threads: usize,
    /// Parallel speedup over the serial reference (1.0 when the stage
    /// has no serial twin).
    pub speedup_vs_serial: f64,
}

impl BenchRecord {
    fn to_json(&self) -> String {
        format!(
            "{{\"name\": {}, \"n\": {}, \"wall_secs\": {:.6}, \"predicted_flops\": {}, \
             \"threads\": {}, \"speedup_vs_serial\": {:.4}}}",
            json_escape(&self.name),
            self.n,
            self.wall_secs,
            self.predicted_flops,
            self.threads,
            self.speedup_vs_serial
        )
    }
}

/// Escape a string as a JSON string literal (quotes included). Shared
/// by the bench-record writer and the shard-manifest writer.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Read a [`write_bench_json`] artifact back into records — the
/// bench-compare regression gate parses baseline and current runs this
/// way. Optional fields fall back to their neutral values so older
/// artifacts (or hand-trimmed baselines) stay comparable.
pub fn read_bench_json(path: &std::path::Path) -> crate::error::Result<Vec<BenchRecord>> {
    use crate::runtime::json::Json;
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::anyhow!("reading {}: {e}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| crate::anyhow!("parsing {}: {e}", path.display()))?;
    let recs = j
        .get("records")
        .and_then(Json::as_arr)
        .ok_or_else(|| crate::anyhow!("{} has no \"records\" array", path.display()))?;
    let mut out = Vec::with_capacity(recs.len());
    for r in recs {
        let name = r.get("name").and_then(Json::as_str);
        let n = r.get("n").and_then(Json::as_usize);
        let wall = r.get("wall_secs").and_then(Json::as_f64);
        let (Some(name), Some(n), Some(wall)) = (name, n, wall) else {
            crate::bail!("{} holds a record without name/n/wall_secs", path.display());
        };
        out.push(BenchRecord {
            name: name.to_string(),
            n,
            wall_secs: wall,
            predicted_flops: r
                .get("predicted_flops")
                .and_then(Json::as_usize)
                .unwrap_or(0) as u64,
            threads: r.get("threads").and_then(Json::as_usize).unwrap_or(1),
            speedup_vs_serial: r
                .get("speedup_vs_serial")
                .and_then(Json::as_f64)
                .unwrap_or(1.0),
        });
    }
    Ok(out)
}

/// Write bench records as a JSON document (hand-rolled — the offline
/// vendor set has no serde). Schema: `{"records": [BenchRecord…]}`.
pub fn write_bench_json(path: &std::path::Path, records: &[BenchRecord]) -> std::io::Result<()> {
    let mut body = String::from("{\"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        body.push_str("  ");
        body.push_str(&r.to_json());
        if i + 1 < records.len() {
            body.push(',');
        }
        body.push('\n');
    }
    body.push_str("]}\n");
    std::fs::write(path, body)
}

/// Micro-bench helper for the `harness = false` benches: runs `f`
/// `iters` times and prints min/median wall time with a label.
pub fn bench<T>(label: &str, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    println!("{label}: median {:.4}s min {:.4}s ({} iters)", median, times[0], iters);
    median
}
