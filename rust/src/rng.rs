//! Deterministic pseudo-random number generation.
//!
//! Everything stochastic in the library (bootstrap resampling, feature
//! subsampling, synthetic data, randomized SVD test matrices, SGD
//! negative sampling) flows through this small PCG-style generator so
//! that every experiment is exactly reproducible from a `u64` seed.

/// A PCG-XSH-RR 64/32-ish generator built on the SplitMix64 stream.
///
/// Not cryptographic; chosen for speed, a 2^64 period, and trivially
/// splittable seeding (`Rng::derive`) so parallel substreams never
/// overlap in practice.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two different seeds give
    /// statistically independent streams.
    pub fn new(seed: u64) -> Self {
        // Run the seed through SplitMix64 once so small seeds (0, 1, 2…)
        // do not produce correlated early outputs.
        let mut r = Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) };
        r.next_u64();
        r
    }

    /// Derive an independent substream, e.g. one per tree.
    pub fn derive(&self, stream: u64) -> Rng {
        Rng::new(self.state ^ stream.wrapping_mul(0xD1342543DE82EF95).wrapping_add(0x63652362_u64))
    }

    /// Next raw 64-bit value (SplitMix64 output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; bias < 2^-32 for n << 2^32).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (((self.next_u64() >> 32) * n as u64) >> 32) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// branch-free enough for data generation).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Bootstrap: draw `n_draws` samples with replacement from `0..n`,
    /// returning per-index multiplicities (the in-bag counts `c_t` of
    /// App. B.4). Indices with count 0 are out-of-bag.
    pub fn bootstrap_counts(&mut self, n: usize, n_draws: usize) -> Vec<u32> {
        let mut counts = vec![0u32; n];
        for _ in 0..n_draws {
            counts[self.gen_range(n)] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..50_000).map(|_| r.next_f64()).sum::<f64>() / 50_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn bootstrap_counts_sum_to_draws() {
        let mut r = Rng::new(17);
        let counts = r.bootstrap_counts(100, 100);
        assert_eq!(counts.iter().sum::<u32>(), 100);
        // OOB fraction should be near (1-1/N)^N ≈ e^-1 ≈ 0.3679 (Prop. G.1's p_N).
        let oob = counts.iter().filter(|&&c| c == 0).count();
        assert!((15..=55).contains(&oob), "oob={oob}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let mut idx = r.sample_indices(50, 20);
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn derived_streams_are_independent() {
        let root = Rng::new(99);
        let mut s1 = root.derive(1);
        let mut s2 = root.derive(2);
        let a: Vec<u64> = (0..10).map(|_| s1.next_u64()).collect();
        let b: Vec<u64> = (0..10).map(|_| s2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
