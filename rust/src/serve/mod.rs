//! Online proximity serving: a long-running, zero-dependency TCP
//! server over a loaded model bundle.
//!
//! The paper's factorization is exactly the shape an online service
//! wants: the `O(NT)` factors stay resident while the `N×N` kernel
//! remains implicit, so every query is one sparse row product. This
//! module turns that observation into a deployable server:
//!
//! * **Transport** — hand-rolled minimal HTTP/1.1 ([`http`]; the crate
//!   is dependency-free by policy) with persistent keep-alive
//!   connections: a per-connection [`http::ConnReader`] carries
//!   over-read bytes across requests, so sub-millisecond queries pay
//!   the TCP connect/teardown once per *client*, not once per query.
//! * **Replica routing** — [`router`] fronts R identical serve
//!   processes (the `model.fkb` bundle is the replication unit) behind
//!   one address over pooled keep-alive connections.
//! * **Micro-batching** — connection threads enqueue single queries
//!   into an [`crate::exec::queue::BoundedQueue`]; a batcher thread
//!   drains them (lingering briefly so trailing requests coalesce) and
//!   executes each batch as one tile on the [`crate::exec`]-pooled
//!   kernels (`Forest::apply`, SpGEMM). Per-query results are bitwise
//!   independent of batch composition — every kernel row depends only
//!   on its own query — so batching is a pure throughput optimization.
//! * **Endpoints** —
//!   `POST /predict` (proximity-weighted OOS prediction: labels from
//!   the factored `predict_oos` path, class scores from the
//!   materialized `cross_proximity` + `scores_from_kernel` path; the
//!   two paths sum identical products in different orders, so on a
//!   float-rounding near-tie the served label can differ from the
//!   argmax of the served scores — the label is the canonical answer,
//!   each path bitwise-faithful to its in-process twin),
//!   `POST /neighbors` (top-k by proximity: OOS queries on the fly
//!   from the factors, or training rows served from the factors or a
//!   materialized shard directory through `ShardReader` — bit-identical
//!   to `spectral::knn::knn_from_kernel`),
//!   `POST /embed` (project queries into the spectral Leaf-PCA
//!   embedding fitted at startup),
//!   `GET /healthz` and `GET /stats` (request counts, batch-size
//!   histogram, p50/p95/p99 latency — see [`stats`]).
//! * **Latency tiers** — a v4 bundle can carry a shallow, subsampled
//!   *companion forest* (`fit --companion depth=D,subsample=F`).
//!   `/predict` requests pick a tier per request via `"budget"`:
//!   `"full"` (default) answers from the main model, `"cheap"` from the
//!   companion (a fraction of the cost, bounded accuracy loss), and
//!   `"auto"` is admission control — full until the batch queue can no
//!   longer absorb the request, then shed to the cheap tier instead of
//!   queueing behind a saturated server. `/neighbors` and `/embed` are
//!   always full-tier. Responses carry `"tier"`; `/stats` reports
//!   per-tier counts and latency reservoirs.
//! * **Hot bundle swap** — the model plane is "always-up". A server
//!   started from `--model` keeps its source path: `POST /admin/reload`
//!   (or `SIGHUP`) re-loads the bundle file — zero-copy mapped when the
//!   load mode allows — fits the embedding basis, and atomically swaps
//!   the new [`ModelState`] in behind an `Arc` generation counter.
//!   In-flight queries keep the snapshot they started on (an `Arc`
//!   clone), new queries see the new generation, and **no request is
//!   ever dropped**: the swap is one pointer store under a briefly-held
//!   write lock. Every response carries `model_generation`; `/healthz`
//!   and `/stats` also report the load mode (`mmap`/`heap`). The
//!   replica router's `POST /admin/reload` drives the same call across
//!   its backends sequentially (rolling), over non-retrying requests.
//!
//! Served answers are **bitwise-identical** to the in-process batch
//! paths (`rust/tests/serve_http.rs` drives a real TCP round trip and
//! compares raw f32 bits).

pub mod http;
pub mod router;
pub mod stats;

use crate::bench_support::json_escape;
use crate::coordinator;
use crate::coordinator::shard::ShardReader;
use crate::coordinator::sink::KernelSource;
use crate::coordinator::Stripe;
use crate::data::Dataset;
use crate::error::{Context, Result};
use crate::exec::queue::BoundedQueue;
use crate::model::{MmapMode, ModelBundle};
use crate::obs;
use crate::runtime::json::Json;
use crate::spectral::knn::{knn_row, rank_row};
use crate::spectral::pca::{leaf_pca, leaf_pca_project, leaf_pca_project_q};
use crate::swlc::predict;
use crate::{anyhow, bail};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

pub use stats::Stats;

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (tests/benches).
    pub addr: String,
    /// Max queries per executed tile.
    pub max_batch: usize,
    /// How long the batcher lingers after the first query so trailing
    /// single requests coalesce into the same tile.
    pub linger: Duration,
    /// Pending-query bound (backpressure: producers block when full).
    pub queue_depth: usize,
    /// Leaf-PCA dimensions of the `/embed` spectral embedding.
    pub embed_dims: usize,
    /// Subspace-iteration sweeps for the embedding basis.
    pub embed_iters: usize,
    /// Seed of the (deterministic) embedding basis.
    pub embed_seed: u64,
    /// Slow-query threshold (the `--slow-ms` flag): requests slower
    /// than this emit a structured `http.slow` event carrying the
    /// request id, endpoint, status, tier, and duration. `None`
    /// disables the slow-query log.
    pub slow_ms: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            max_batch: 32,
            linger: Duration::from_millis(2),
            queue_depth: 1024,
            embed_dims: 8,
            embed_iters: 30,
            embed_seed: 17,
            slow_ms: None,
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum JobKind {
    Predict = 0,
    Embed = 1,
    Neighbors = 2,
}

/// Which model answers a job. `/predict` picks per request via
/// `"budget"` (`"cheap"`/`"full"`/`"auto"`); `/neighbors` and `/embed`
/// are always full-tier — proximity structure comes from the main
/// forest only.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Tier {
    Full,
    Cheap,
}

impl Tier {
    fn name(self) -> &'static str {
        match self {
            Tier::Full => "full",
            Tier::Cheap => "cheap",
        }
    }
}

enum Reply {
    Predict { label: u32, scores: Vec<f32> },
    Embed { coords: Vec<f32> },
    Neighbors { ids: Vec<u32>, proximities: Vec<f32>, dists: Vec<f32> },
}

/// One enqueued query awaiting its tile. The reply travels with the
/// generation of the model snapshot that executed it.
struct Job {
    kind: JobKind,
    tier: Tier,
    x: Vec<f32>,
    /// `/neighbors` only: how many neighbors to return.
    k: usize,
    tx: mpsc::Sender<Result<(u64, Reply)>>,
}

/// Single-stripe LRU over a shard directory for `/neighbors` row mode.
struct ShardCache {
    reader: ShardReader,
    last: Mutex<Option<(usize, Stripe)>>,
}

impl ShardCache {
    fn row(&self, i: usize) -> Result<(Vec<u32>, Vec<f32>)> {
        let si = self
            .reader
            .shard_of_row(i)
            .ok_or_else(|| anyhow!("row {i} out of range"))?;
        // Fast path: copy out of the cached stripe under the lock —
        // the copy is a few hundred bytes, the read it avoids is disk.
        {
            let g = obs::lock_recover(&self.last);
            if let Some((s, stripe)) = g.as_ref() {
                if *s == si {
                    let (c, v) = stripe.rows.row(i - stripe.row_start);
                    crate::metric!(
                        counter "fk_shard_cache_hits_total",
                        "Neighbors row lookups served from the cached stripe."
                    )
                    .inc();
                    return Ok((c.to_vec(), v.to_vec()));
                }
            }
        }
        crate::metric!(
            counter "fk_shard_cache_misses_total",
            "Neighbors row lookups that had to read a stripe from disk."
        )
        .inc();
        // Miss: do the stripe I/O with the lock RELEASED, then swap the
        // result in. Concurrent misses on different stripes no longer
        // serialize behind the slowest disk read; two threads missing
        // the same stripe may both read it — wasted work, never wrong.
        let stripe = self.reader.read_stripe(si)?;
        let (c, v) = stripe.rows.row(i - stripe.row_start);
        let out = (c.to_vec(), v.to_vec());
        *obs::lock_recover(&self.last) = Some((si, stripe));
        Ok(out)
    }
}

/// One immutable model snapshot: the bundle plus everything derived
/// from it (feature dimension, the fitted embedding basis) and the
/// provenance of this particular load. Requests take an `Arc` of the
/// current snapshot and keep it for their whole lifetime, so a reload
/// can swap the pointer without ever invalidating in-flight work.
pub struct ModelState {
    pub bundle: ModelBundle,
    /// Feature dimension the binner was fitted on.
    d: usize,
    /// Leaf-PCA basis fitted at load (deterministic in the config).
    embed_scores: Vec<f32>,
    embed_vals: Vec<f32>,
    /// Monotonic swap counter: 1 at bind, +1 per successful reload.
    pub generation: u64,
    /// How this snapshot's factors are backed: `"mmap"` or `"heap"`.
    pub load_mode: &'static str,
}

impl ModelState {
    fn build(
        bundle: ModelBundle,
        cfg: &ServeConfig,
        generation: u64,
        load_mode: &'static str,
    ) -> ModelState {
        let n = bundle.kernel.ctx.n;
        let dims = cfg.embed_dims.clamp(1, n);
        let (embed_scores, embed_vals) =
            leaf_pca(&bundle.kernel.q, dims, cfg.embed_iters, false, cfg.embed_seed);
        let d = bundle.forest.binner.edges.len();
        ModelState { bundle, d, embed_scores, embed_vals, generation, load_mode }
    }
}

/// Everything the connection and batcher threads share.
pub struct ServerState {
    /// The live model snapshot. Read-locked for an instant per request
    /// (to clone the `Arc`), write-locked for an instant per reload (to
    /// store the new pointer) — queries never wait on a load.
    model: RwLock<Arc<ModelState>>,
    /// Where `/admin/reload` re-loads from; `None` (in-process fit,
    /// no `--model`) makes reload a 400.
    model_source: Option<(PathBuf, MmapMode)>,
    /// Serializes reloads so two concurrent requests can't both build
    /// generation G+1 from G; never held on the query path.
    reload: Mutex<()>,
    cfg: ServeConfig,
    shards: Option<ShardCache>,
    pub stats: Stats,
    queue: BoundedQueue<Job>,
    shutdown: AtomicBool,
}

impl ServerState {
    /// The current model snapshot (an `Arc` clone under a momentary
    /// read lock).
    pub fn model(&self) -> Arc<ModelState> {
        obs::read_recover(&self.model).clone()
    }
}

/// A bound (but not yet running) server.
pub struct Server {
    state: Arc<ServerState>,
    listener: TcpListener,
    addr: SocketAddr,
}

/// Handle to a server running on a background thread (tests/benches).
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    join: std::thread::JoinHandle<()>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Flag shutdown, poke the accept loop, and join.
    pub fn stop(self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        let _ = self.join.join();
    }
}

impl Server {
    /// Bind the listener and fit the `/embed` spectral basis. `shards`
    /// optionally points `/neighbors` row lookups at a materialized
    /// shard directory (must cover the model's N rows with its kind).
    pub fn bind(
        bundle: ModelBundle,
        shards: Option<ShardReader>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        Server::bind_with_source(bundle, shards, cfg, None, "heap")
    }

    /// [`Server::bind`] for a bundle loaded from a file: `source`
    /// records the path + load policy so `POST /admin/reload` (and
    /// SIGHUP) can hot-swap a rewritten bundle, and `load_mode` reports
    /// how this first load was backed (`"mmap"`/`"heap"`).
    pub fn bind_with_source(
        bundle: ModelBundle,
        shards: Option<ShardReader>,
        cfg: ServeConfig,
        source: Option<(PathBuf, MmapMode)>,
        load_mode: &'static str,
    ) -> Result<Server> {
        obs::init();
        let n = bundle.kernel.ctx.n;
        if let Some(r) = &shards {
            if KernelSource::n_rows(r) != n {
                bail!(
                    "shard directory covers {} rows but the model was fitted on {n}",
                    KernelSource::n_rows(r)
                );
            }
            if r.kind() != bundle.kernel.kind.name() {
                bail!(
                    "shard directory holds kind {:?} but the model is {:?}",
                    r.kind(),
                    bundle.kernel.kind.name()
                );
            }
        }
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let model = ModelState::build(bundle, &cfg, 1, load_mode);
        let state = Arc::new(ServerState {
            queue: BoundedQueue::new(cfg.queue_depth),
            model: RwLock::new(Arc::new(model)),
            model_source: source,
            reload: Mutex::new(()),
            shards: shards.map(|reader| ShardCache { reader, last: Mutex::new(None) }),
            stats: Stats::new(),
            shutdown: AtomicBool::new(false),
            cfg,
        });
        Ok(Server { state, listener, addr })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Run the accept loop on the calling thread until shutdown is
    /// flagged (via [`ServerHandle::stop`] from a clone of the state).
    /// Each connection is handled on its own thread; query execution
    /// happens on the single batcher thread, which drives the
    /// exec-pooled kernels.
    pub fn run(self) -> Result<()> {
        let state = self.state;
        let batcher = {
            let st = state.clone();
            std::thread::Builder::new()
                .name("fk-serve-batcher".into())
                .spawn(move || batch_loop(st))
                .context("spawning the batcher thread")?
        };
        #[cfg(unix)]
        if state.model_source.is_some() {
            sighup::install();
            let st = state.clone();
            std::thread::Builder::new()
                .name("fk-serve-sighup".into())
                .spawn(move || {
                    while !st.shutdown.load(Ordering::SeqCst) {
                        std::thread::sleep(Duration::from_millis(100));
                        if sighup::take() {
                            let resp = reload_endpoint(&st);
                            crate::obs::event_logged(
                                "serve.sighup_reload",
                                crate::kv! { status: resp.status as u64, body: resp.body },
                            );
                        }
                    }
                })
                .context("spawning the SIGHUP watcher")?;
        }
        for conn in self.listener.incoming() {
            if state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            let st = state.clone();
            std::thread::spawn(move || handle_connection(&st, stream));
        }
        state.queue.close();
        let _ = batcher.join();
        Ok(())
    }

    /// Run on a background thread; the handle stops it.
    pub fn spawn(self) -> ServerHandle {
        let addr = self.addr;
        let state = self.state.clone();
        let join = std::thread::spawn(move || {
            let _ = self.run();
        });
        ServerHandle { addr, state, join }
    }
}

/// Tiny unix-signal shim: `SIGHUP` sets a flag a watcher thread polls.
/// Raw `signal(2)` FFI keeps the crate dependency-free; the handler
/// body is async-signal-safe (one relaxed atomic store).
#[cfg(unix)]
mod sighup {
    use std::os::raw::c_int;
    use std::sync::atomic::{AtomicBool, Ordering};

    const SIGHUP: c_int = 1;
    static FLAG: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sighup(_sig: c_int) {
        FLAG.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: c_int, handler: usize) -> usize;
        }
        // SAFETY: signal(2) with a handler that only stores into a
        // static AtomicBool — async-signal-safe, no allocation, no
        // locks; the handler address outlives the process.
        unsafe {
            signal(SIGHUP, on_sighup as extern "C" fn(c_int) as usize);
        }
    }

    pub fn take() -> bool {
        FLAG.swap(false, Ordering::Relaxed)
    }
}

/// Drain the queue into per-endpoint tiles until the queue closes.
/// Each drained batch snapshots the model once: every job in it runs —
/// and is answered — on one consistent generation.
fn batch_loop(st: Arc<ServerState>) {
    while let Some(batch) = st.queue.drain_batch(st.cfg.max_batch, st.cfg.linger) {
        st.stats.record_batch(batch.len());
        let ms = st.model();
        // A tile must be homogeneous in (endpoint, tier): slots 0-2 are
        // the full-tier endpoints, slot 3 is cheap-tier `/predict` (the
        // only endpoint the companion model serves).
        let mut groups: [Vec<Job>; 4] = Default::default();
        for job in batch {
            let slot = match (job.kind, job.tier) {
                (JobKind::Predict, Tier::Cheap) => 3,
                (kind, _) => kind as usize,
            };
            groups[slot].push(job);
        }
        for group in groups {
            let (kind, tier) = match group.first() {
                Some(job) => (job.kind, job.tier),
                None => continue,
            };
            match run_tile(&ms, kind, tier, &group) {
                Ok(replies) => {
                    for (job, reply) in group.into_iter().zip(replies) {
                        let _ = job.tx.send(Ok((ms.generation, reply)));
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for job in group {
                        let _ = job.tx.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
    }
}

/// Execute one homogeneous tile: route the whole batch through the
/// forest once, then answer every query from the shared products. Each
/// output row depends only on its own query row, so results are
/// bitwise-independent of how requests were batched. Cheap-tier tiles
/// swap in the companion forest + kernel; the math is identical.
fn run_tile(ms: &ModelState, kind: JobKind, tier: Tier, group: &[Job]) -> Result<Vec<Reply>> {
    let (kernel, forest) = match tier {
        Tier::Full => (&ms.bundle.kernel, &ms.bundle.forest),
        Tier::Cheap => {
            let c = ms.bundle.companion.as_ref().ok_or_else(|| {
                anyhow!("cheap tier requested but the bundle has no companion model")
            })?;
            (&c.kernel, &c.forest)
        }
    };
    let b = group.len();
    let mut x = Vec::with_capacity(b * ms.d);
    for job in group {
        x.extend_from_slice(&job.x);
    }
    let data = Dataset { x, y: vec![0.0; b], n: b, d: ms.d, n_classes: kernel.ctx.n_classes };
    let qn = kernel.oos_query_map(forest, &data);
    match kind {
        JobKind::Predict => {
            let c = kernel.ctx.n_classes;
            // Labels take the factored predictor (the `predict_oos`
            // batch path); scores take the materialized cross-kernel
            // path — each bitwise-identical to its in-process twin.
            let labels = predict::predict_oos(kernel, &qn);
            let cross = kernel.cross_proximity(&qn);
            let scores = predict::scores_from_kernel(&cross, &kernel.ctx.y, c)?;
            Ok((0..b)
                .map(|i| Reply::Predict {
                    label: labels[i],
                    scores: scores[i * c..(i + 1) * c].to_vec(),
                })
                .collect())
        }
        JobKind::Embed => {
            let dims = ms.embed_vals.len();
            // Quantized bundles project tiles off the compressed Q; the
            // exact factor stays the default path.
            let coords = match kernel.quantized() {
                Some(qf) => leaf_pca_project_q(&qf.q, &ms.embed_scores, &ms.embed_vals, &qn),
                None => leaf_pca_project(&kernel.q, &ms.embed_scores, &ms.embed_vals, &qn),
            };
            Ok((0..b)
                .map(|i| Reply::Embed { coords: coords[i * dims..(i + 1) * dims].to_vec() })
                .collect())
        }
        JobKind::Neighbors => {
            let cross = kernel.cross_proximity(&qn);
            Ok(group
                .iter()
                .enumerate()
                .map(|(i, job)| {
                    let (cols, vals) = cross.row(i);
                    let ranked = rank_row(cols, vals, None, job.k);
                    let ids: Vec<u32> = ranked.iter().map(|&(c, _)| c).collect();
                    let proximities: Vec<f32> = ranked.iter().map(|&(_, p)| p).collect();
                    let dists: Vec<f32> =
                        proximities.iter().map(|&p| (1.0 - p).max(0.0).sqrt()).collect();
                    Reply::Neighbors { ids, proximities, dists }
                })
                .collect())
        }
    }
}

/// How long a connection may sit idle before its handler thread gives
/// up — without this, a client that connects and sends nothing (or
/// parks a keep-alive connection forever) would pin a thread.
pub(crate) const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// One routed response. Status and reason travel together so
/// `handle_connection` never has to guess a reason phrase from a bare
/// status code (the old hardcoded "Not Found" covered every non-200).
pub(crate) struct Response {
    pub(crate) status: u16,
    pub(crate) reason: &'static str,
    pub(crate) body: String,
}

impl Response {
    pub(crate) fn ok(body: String) -> Response {
        Response { status: 200, reason: "OK", body }
    }

    pub(crate) fn bad_request(err: impl std::fmt::Display) -> Response {
        Response {
            status: 400,
            reason: "Bad Request",
            body: format!("{{\"error\": {}}}", json_escape(&err.to_string())),
        }
    }
}

/// The shared miss response: **405** when the path exists but the
/// method is wrong, 404 only for genuinely unknown paths. The replica
/// router uses the same function so routed and direct error responses
/// are byte-identical.
pub(crate) fn unroutable(method: &str, path: &str) -> Response {
    let allow = match path {
        "/healthz" | "/stats" | "/metrics" | "/debug/trace" => Some("GET"),
        "/predict" | "/embed" | "/neighbors" | "/admin/reload" => Some("POST"),
        _ => None,
    };
    match allow {
        Some(allow) if allow != method => Response {
            status: 405,
            reason: "Method Not Allowed",
            body: format!(
                "{{\"error\": {}, \"allow\": \"{allow}\"}}",
                json_escape(&format!("{path} only accepts {allow} (got {method})")),
            ),
        },
        _ => Response {
            status: 404,
            reason: "Not Found",
            body: format!(
                "{{\"error\": {}, \"endpoints\": \
                 [\"/predict\", \"/neighbors\", \"/embed\", \"/healthz\", \"/stats\", \
                 \"/metrics\", \"/debug/trace\", \"/admin/reload\"]}}",
                json_escape(&format!("no route for {method} {path}")),
            ),
        },
    }
}

/// A stable, low-cardinality endpoint label for the registry metrics.
fn endpoint_label(path: &str) -> &'static str {
    match path {
        "/predict" => "predict",
        "/neighbors" => "neighbors",
        "/embed" => "embed",
        "/healthz" => "healthz",
        "/stats" => "stats",
        "/admin/reload" => "admin_reload",
        "/metrics" => "metrics",
        "/debug/trace" => "debug_trace",
        _ => "other",
    }
}

/// The per-endpoint request counter + latency histogram. The registry
/// lookup is a short mutex-guarded scan — negligible at request
/// granularity, and it keeps the handle table in one place.
fn http_metrics(endpoint: &'static str) -> (&'static obs::Counter, &'static obs::Histogram) {
    (
        obs::counter_with(
            "fk_http_requests_total",
            "HTTP requests by endpoint (scrape endpoints excluded).",
            &[("endpoint", endpoint)],
        ),
        obs::histogram_with(
            "fk_http_request_seconds",
            "Request latency by endpoint, first byte through response write.",
            &[("endpoint", endpoint)],
            obs::LATENCY_BUCKETS,
        ),
    )
}

/// Pull the `"tier"` field out of a response body for slow-query
/// attribution. Only called on the slow path, so a substring scan is
/// fine.
fn body_tier(body: &str) -> Option<&str> {
    let i = body.find("\"tier\": \"")?;
    let rest = &body[i + 9..];
    Some(&rest[..rest.find('"')?])
}

/// The shared keep-alive connection loop — one copy for the server
/// and the replica router, which differ only in how they route. Waits
/// (untimed) for each request's first byte, times the request from
/// that byte through the response write so malformed-request 400s are
/// recorded like any other response, and closes on
/// `Connection: close`, a write failure, or broken framing (carrying a
/// desynchronized stream forward would corrupt it).
///
/// This is also the observability ingress: every parsed request is
/// stamped with a request id (the client's validated `x-request-id`,
/// or a freshly minted one), the id is echoed in the response header
/// (and, for client-supplied ids only, appended to JSON bodies so
/// untagged traffic keeps byte-identical responses), per-endpoint
/// counters and latency histograms are recorded (except for the
/// `/metrics` and `/debug/trace` scrape endpoints, so scraping does
/// not perturb the numbers it reads), and requests slower than
/// `slow_ms` land in the slow-query log with their tier.
pub(crate) fn connection_loop(
    mut stream: TcpStream,
    stats: &Stats,
    slow_ms: Option<u64>,
    mut route: impl FnMut(&http::Request) -> Result<Response>,
) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    stats.connections.fetch_add(1, Ordering::Relaxed);
    let mut reader = http::ConnReader::new();
    loop {
        // Waiting for the next request on an idle keep-alive
        // connection is not request time; a clean close or an idle
        // timeout here simply ends the connection.
        match reader.await_data(&mut stream) {
            Ok(true) => {}
            Ok(false) | Err(_) => return,
        }
        let t0 = Instant::now();
        let (resp, keep, meta) = match reader.read_request(&mut stream) {
            Ok(Some(mut req)) => {
                let keep = req.keep_alive;
                if req.request_id.is_none() {
                    req.request_id = Some(obs::next_request_id());
                    req.request_id_generated = true;
                }
                let resp = match route(&req) {
                    Ok(resp) => resp,
                    Err(e) => {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        crate::metric!(
                            counter "fk_http_errors_total",
                            "Requests answered with an error response."
                        )
                        .inc();
                        // Attribute the failure to the request so an
                        // operator can chase a 4xx/5xx from the trace
                        // ring straight to the offending request id.
                        let detail = format!("{e:#}");
                        obs::event(
                            "http.error",
                            crate::kv! {
                                request_id: req.request_id.as_deref().unwrap_or(""),
                                path: req.path.as_str(),
                                error: detail.as_str()
                            },
                        );
                        Response::bad_request(e)
                    }
                };
                let rid = req.request_id.unwrap_or_default();
                (resp, keep, Some((rid, req.request_id_generated, req.path)))
            }
            Ok(None) => return,
            Err(e) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                crate::metric!(
                    counter "fk_http_errors_total",
                    "Requests answered with an error response."
                )
                .inc();
                // Framing failure: no request was parsed, so there is
                // no id to attribute — log the transport error itself.
                let detail = format!("{e:#}");
                obs::event("http.error", crate::kv! { path: "", error: detail.as_str() });
                (Response::bad_request(e), false, None)
            }
        };
        let sent = match meta {
            Some((rid, generated, path)) => {
                let mut body = resp.body;
                // Body echo only for client-supplied ids: the id a
                // replica sees on a router hop is marked generated, so
                // echo happens exactly once, at the edge that received
                // it — and untagged traffic keeps byte-identical
                // bodies.
                if !generated && body.starts_with('{') && body.ends_with('}') {
                    body.pop();
                    body.push_str(", \"request_id\": ");
                    body.push_str(&json_escape(&rid));
                    body.push('}');
                }
                let content_type = if path == "/metrics" {
                    "text/plain; version=0.0.4"
                } else {
                    "application/json"
                };
                let sent = http::write_response_ext(
                    &mut stream,
                    resp.status,
                    resp.reason,
                    content_type,
                    &body,
                    keep,
                    Some(&rid),
                );
                let dt = t0.elapsed().as_secs_f64();
                if !matches!(path.as_str(), "/metrics" | "/debug/trace") {
                    let (requests, latency) = http_metrics(endpoint_label(&path));
                    requests.inc();
                    latency.observe(dt);
                }
                if let Some(ms) = slow_ms {
                    if dt * 1e3 >= ms as f64 {
                        obs::slow_query(
                            &rid,
                            endpoint_label(&path),
                            resp.status,
                            body_tier(&body),
                            dt,
                        );
                    }
                }
                sent
            }
            None => {
                http::write_response(&mut stream, resp.status, resp.reason, &resp.body, keep)
            }
        };
        stats.record_latency(t0.elapsed().as_secs_f64());
        if !keep || sent.is_err() {
            return;
        }
    }
}

fn handle_connection(st: &Arc<ServerState>, stream: TcpStream) {
    connection_loop(stream, &st.stats, st.cfg.slow_ms, |req| route(st, req));
}

fn route(st: &ServerState, req: &http::Request) -> Result<Response> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            st.stats.healthz.fetch_add(1, Ordering::Relaxed);
            Ok(Response::ok(healthz_body(st)))
        }
        ("GET", "/stats") => {
            st.stats.stats.fetch_add(1, Ordering::Relaxed);
            // Prepend the model-plane and build fields to the counter
            // document so operators can see which generation and
            // binary the numbers describe.
            let ms = st.model();
            let counters = st.stats.to_json();
            Ok(Response::ok(format!(
                "{{\"model_generation\": {}, \"load_mode\": {}, \
                 \"uptime_secs\": {}, \"version\": {}, \"git_sha\": {}, {}",
                ms.generation,
                json_escape(ms.load_mode),
                obs::uptime_secs() as u64,
                json_escape(obs::build_version()),
                json_escape(obs::build_sha()),
                counters.strip_prefix('{').unwrap_or(&counters),
            )))
        }
        ("GET", "/metrics") => Ok(Response::ok(obs::render_prometheus())),
        ("GET", "/debug/trace") => Ok(Response::ok(obs::recent_events_json())),
        ("POST", "/admin/reload") => Ok(reload_endpoint(st)),
        ("POST", "/predict") => {
            st.stats.predict.fetch_add(1, Ordering::Relaxed);
            Ok(Response::ok(predict_endpoint(st, req)?))
        }
        ("POST", "/embed") => {
            st.stats.embed.fetch_add(1, Ordering::Relaxed);
            Ok(Response::ok(embed_endpoint(st, req)?))
        }
        ("POST", "/neighbors") => {
            st.stats.neighbors.fetch_add(1, Ordering::Relaxed);
            Ok(Response::ok(neighbors_endpoint(st, req)?))
        }
        (m, p) => Ok(unroutable(m, p)),
    }
}

fn parse_body(req: &http::Request) -> Result<Json> {
    let text =
        std::str::from_utf8(&req.body).map_err(|_| anyhow!("request body is not UTF-8"))?;
    if text.trim().is_empty() {
        bail!("empty request body");
    }
    Json::parse(text).map_err(|e| anyhow!("bad JSON body: {e}"))
}

fn as_f32(j: &Json) -> Result<f32> {
    match j {
        Json::Num(v) => Ok(*v as f32),
        _ => Err(anyhow!("expected a number")),
    }
}

/// `"x"` as query rows: a flat array is one query, an array of arrays
/// is a client-side batch. Every row must have the model's feature
/// dimension.
fn parse_queries(j: &Json, d: usize) -> Result<Vec<Vec<f32>>> {
    let x = j.get("x").ok_or_else(|| anyhow!("body missing \"x\""))?;
    let arr = x.as_arr().ok_or_else(|| anyhow!("\"x\" must be an array"))?;
    if arr.is_empty() {
        bail!("\"x\" is empty");
    }
    let rows: Vec<Vec<f32>> = if matches!(arr.first(), Some(Json::Arr(_))) {
        arr.iter()
            .map(|row| {
                row.as_arr()
                    .ok_or_else(|| anyhow!("\"x\" rows must all be arrays"))?
                    .iter()
                    .map(as_f32)
                    .collect::<Result<Vec<f32>>>()
            })
            .collect::<Result<Vec<_>>>()?
    } else {
        vec![arr.iter().map(as_f32).collect::<Result<Vec<f32>>>()?]
    };
    for r in &rows {
        if r.len() != d {
            bail!("query has {} features but the model expects {d}", r.len());
        }
    }
    Ok(rows)
}

/// Enqueue one job per query row and await the replies in row order.
/// Each reply carries the generation of the snapshot that computed it
/// (rows of one request can straddle a hot swap; each row reports the
/// model that actually answered it).
fn submit(
    st: &ServerState,
    kind: JobKind,
    tier: Tier,
    rows: Vec<Vec<f32>>,
    k: usize,
) -> Result<Vec<(u64, Reply)>> {
    let mut rxs = Vec::with_capacity(rows.len());
    for x in rows {
        let (tx, rx) = mpsc::channel();
        st.queue
            .push(Job { kind, tier, x, k, tx })
            .map_err(|_| anyhow!("server is shutting down"))?;
        rxs.push(rx);
    }
    rxs.into_iter()
        .map(|rx| rx.recv().map_err(|_| anyhow!("batch executor unavailable"))?)
        .collect()
}

/// Render f32 with Rust's shortest round-trip formatting: parsing the
/// decimal back (even through f64) recovers the exact same bits, so
/// JSON numbers are a lossless transport for the bitwise tests.
fn json_f32(v: f32) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "\"nan\"".into()
    } else if v > 0.0 {
        "\"inf\"".into()
    } else {
        "\"-inf\"".into()
    }
}

fn json_f32_array(vs: &[f32]) -> String {
    let mut out = String::from("[");
    for (i, &v) in vs.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&json_f32(v));
    }
    out.push(']');
    out
}

fn json_u32_array(vs: &[u32]) -> String {
    let mut out = String::from("[");
    for (i, &v) in vs.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&v.to_string());
    }
    out.push(']');
    out
}

/// `POST /admin/reload`: re-load the bundle from the server's source
/// path and swap it in. The old snapshot keeps serving until the
/// moment of the pointer store, and in-flight requests finish on it —
/// a failed load leaves the server exactly as it was (status 500, old
/// generation reported). Returns 400 when the server has no file
/// source (fitted in-process) or the new bundle is shaped incompatibly
/// with the live one (different N / kind / feature dim — the roster
/// invariants the replica router and queued jobs rely on).
/// One reload outcome for the registry and the trace ring. `outcome`
/// is the `fk_reload_total` label: "ok", "failed" (load error), or
/// "refused" (no source / shape mismatch).
fn note_reload(outcome: &'static str, detail: &str) {
    obs::counter_with(
        "fk_reload_total",
        "Bundle reload attempts by outcome (ok / failed / refused).",
        &[("outcome", outcome)],
    )
    .inc();
    obs::event(
        "serve.reload",
        crate::kv! { outcome: outcome, detail: detail },
    );
}

fn reload_endpoint(st: &ServerState) -> Response {
    let Some((path, mode)) = &st.model_source else {
        note_reload("refused", "no file source (fitted in-process)");
        return Response {
            status: 400,
            reason: "Bad Request",
            body: format!(
                "{{\"error\": {}}}",
                json_escape("this server was fitted in-process; start with --model to enable /admin/reload"),
            ),
        };
    };
    // One reload at a time; queries never touch this lock.
    let _g = obs::lock_recover(&st.reload);
    let old = st.model();
    let (bundle, load_mode) = match ModelBundle::load_with_mode(path, *mode) {
        Ok(v) => v,
        Err(e) => {
            st.stats.errors.fetch_add(1, Ordering::Relaxed);
            note_reload("failed", &format!("{e:#}"));
            return Response {
                status: 500,
                reason: "Internal Server Error",
                body: format!(
                    "{{\"error\": {}, \"model_generation\": {}}}",
                    json_escape(&format!("reload failed, still serving the old bundle: {e:#}")),
                    old.generation,
                ),
            };
        }
    };
    let (ok, wk) = (&old.bundle.kernel, &bundle.kernel);
    let new_d = bundle.forest.binner.edges.len();
    if wk.ctx.n != ok.ctx.n || wk.kind.name() != ok.kind.name() || new_d != old.d {
        note_reload("refused", "incompatible bundle shape");
        return Response {
            status: 400,
            reason: "Bad Request",
            body: format!(
                "{{\"error\": {}, \"model_generation\": {}}}",
                json_escape(&format!(
                    "bundle at {} is shaped incompatibly with the live model \
                     (n {} -> {}, kind {} -> {}, features {} -> {}); restart to switch models",
                    path.display(),
                    ok.ctx.n, wk.ctx.n,
                    ok.kind.name(), wk.kind.name(),
                    old.d, new_d,
                )),
                old.generation,
            ),
        };
    }
    let next = Arc::new(ModelState::build(bundle, &st.cfg, old.generation + 1, load_mode));
    let generation = next.generation;
    *obs::write_recover(&st.model) = next;
    note_reload("ok", &format!("generation {generation} ({load_mode})"));
    Response::ok(format!(
        "{{\"status\": \"reloaded\", \"model_generation\": {generation}, \
         \"load_mode\": {}, \"path\": {}}}",
        json_escape(load_mode),
        json_escape(&path.display().to_string()),
    ))
}

fn healthz_body(st: &ServerState) -> String {
    let ms = st.model();
    let m = &ms.bundle.meta;
    let k = &ms.bundle.kernel;
    let companion = match &ms.bundle.companion {
        Some(c) => format!(
            "{{\"depth\": {}, \"subsample\": {}, \"trees\": {}, \"leaves\": {}}}",
            c.depth,
            json_f32(c.subsample),
            c.forest.trees.len(),
            c.kernel.ctx.l,
        ),
        None => "null".into(),
    };
    format!(
        "{{\"status\": \"ok\", \"model\": {{\"dataset\": {}, \"n\": {}, \"trees\": {}, \
         \"kind\": {}, \"forest\": {}, \"classes\": {}, \"features\": {}, \"leaves\": {}}}, \
         \"companion\": {companion}, \
         \"neighbors_source\": {}, \"embed_dims\": {}, \"model_generation\": {}, \
         \"load_mode\": {}, \"reloadable\": {}, \"uptime_secs\": {}, \
         \"version\": {}, \"git_sha\": {}}}",
        json_escape(&m.dataset),
        k.ctx.n,
        k.ctx.t,
        json_escape(k.kind.name()),
        json_escape(&format!("{:?}", ms.bundle.forest.kind)),
        k.ctx.n_classes,
        ms.d,
        k.ctx.l,
        if st.shards.is_some() { "\"shards\"" } else { "\"factors\"" },
        ms.embed_vals.len(),
        ms.generation,
        json_escape(ms.load_mode),
        st.model_source.is_some(),
        obs::uptime_secs() as u64,
        json_escape(obs::build_version()),
        json_escape(obs::build_sha()),
    )
}

/// Pick the serving tier for one `/predict` request. `"full"` (the
/// default) and `"cheap"` are explicit; `"auto"` is the admission
/// valve — it serves full until the batch queue can no longer absorb
/// the request, then degrades to the companion tier instead of letting
/// the caller block behind a saturated full-tier queue. Returns the
/// tier and whether this was a pressure shed.
fn choose_tier(
    st: &ServerState,
    budget: &str,
    n_rows: usize,
    has_companion: bool,
) -> Result<(Tier, bool)> {
    match budget {
        "full" => Ok((Tier::Full, false)),
        "cheap" => {
            if !has_companion {
                bail!(
                    "budget \"cheap\" needs a bundle with a companion model \
                     (re-fit with --companion depth=D,subsample=F)"
                );
            }
            Ok((Tier::Cheap, false))
        }
        "auto" => {
            st.stats.predict_auto.fetch_add(1, Ordering::Relaxed);
            let pressured = st.queue.len() + n_rows > st.queue.capacity();
            if has_companion && pressured {
                Ok((Tier::Cheap, true))
            } else {
                Ok((Tier::Full, false))
            }
        }
        other => {
            bail!("unknown budget {other:?} (expected \"cheap\", \"full\", or \"auto\")")
        }
    }
}

fn predict_endpoint(st: &ServerState, req: &http::Request) -> Result<String> {
    let ms = st.model();
    let c = ms.bundle.kernel.ctx.n_classes;
    if c < 2 {
        bail!("/predict needs a classification model (bundle has {c} classes)");
    }
    let body = parse_body(req)?;
    let rows = parse_queries(&body, ms.d)?;
    let budget = match body.get("budget") {
        None => "full",
        Some(j) => j.as_str().ok_or_else(|| anyhow!("\"budget\" must be a string"))?,
    };
    let (tier, shed) = choose_tier(st, budget, rows.len(), ms.bundle.companion.is_some())?;
    if shed {
        st.stats.shed_to_cheap.fetch_add(1, Ordering::Relaxed);
        crate::metric!(
            counter "fk_shed_to_cheap_total",
            "Auto-budget predicts degraded to the cheap tier under queue pressure."
        )
        .inc();
    }
    let (tier_counter, tier_latency) = match tier {
        Tier::Full => (&st.stats.predict_full, &st.stats.full_tier_latency),
        Tier::Cheap => (&st.stats.predict_cheap, &st.stats.cheap_tier_latency),
    };
    tier_counter.fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();
    let replies = submit(st, JobKind::Predict, tier, rows, 0)?;
    let dt = t0.elapsed().as_secs_f64();
    tier_latency.record(dt);
    obs::histogram_with(
        "fk_tier_latency_seconds",
        "Predict latency by serving tier (queue wait + batch execution).",
        &[("tier", tier.name())],
        obs::LATENCY_BUCKETS,
    )
    .observe(dt);
    let gen = replies.first().map_or(ms.generation, |r| r.0);
    let mut preds = String::from("[");
    let mut scores = String::from("[");
    for (i, (_, r)) in replies.iter().enumerate() {
        let (label, s) = match r {
            Reply::Predict { label, scores } => (label, scores),
            _ => bail!("internal: unexpected reply kind"),
        };
        if i > 0 {
            preds.push_str(", ");
            scores.push_str(", ");
        }
        preds.push_str(&label.to_string());
        scores.push_str(&json_f32_array(s));
    }
    preds.push(']');
    scores.push(']');
    Ok(format!(
        "{{\"predictions\": {preds}, \"scores\": {scores}, \"tier\": \"{}\", \
         \"model_generation\": {gen}}}",
        tier.name(),
    ))
}

fn embed_endpoint(st: &ServerState, req: &http::Request) -> Result<String> {
    let ms = st.model();
    let body = parse_body(req)?;
    let rows = parse_queries(&body, ms.d)?;
    let replies = submit(st, JobKind::Embed, Tier::Full, rows, 0)?;
    let gen = replies.first().map_or(ms.generation, |r| r.0);
    let mut coords = String::from("[");
    for (i, (_, r)) in replies.iter().enumerate() {
        let c = match r {
            Reply::Embed { coords } => coords,
            _ => bail!("internal: unexpected reply kind"),
        };
        if i > 0 {
            coords.push_str(", ");
        }
        coords.push_str(&json_f32_array(c));
    }
    coords.push(']');
    Ok(format!(
        "{{\"dims\": {}, \"coords\": {coords}, \"model_generation\": {gen}}}",
        ms.embed_vals.len()
    ))
}

fn neighbors_endpoint(st: &ServerState, req: &http::Request) -> Result<String> {
    let ms = st.model();
    let body = parse_body(req)?;
    let k = match body.get("k") {
        Some(v) => v.as_usize().ok_or_else(|| anyhow!("\"k\" must be a positive integer"))?,
        None => 10,
    };
    if k == 0 {
        bail!("\"k\" must be >= 1");
    }
    let n = ms.bundle.kernel.ctx.n;
    if let Some(row_json) = body.get("row") {
        // Training-row lookup: serve the materialized kernel row (from
        // the shard directory when attached, else computed on the fly —
        // the stripe product is bitwise what a shard holds) and rank it
        // exactly as `knn_from_kernel` would.
        let row = row_json
            .as_usize()
            .ok_or_else(|| anyhow!("\"row\" must be a non-negative integer"))?;
        if row >= n {
            bail!("row {row} out of range for a {n}-row kernel");
        }
        if k >= n {
            bail!("row lookups need k < n (k={k}, n={n})");
        }
        let (cols, vals) = match &st.shards {
            Some(cache) => cache.row(row)?,
            None => {
                let stripe = coordinator::stripe_product(&ms.bundle.kernel, row, row + 1);
                let (c, v) = stripe.row(0);
                (c.to_vec(), v.to_vec())
            }
        };
        let (ids, dists) = knn_row(row, n, &cols, &vals, k);
        return Ok(format!(
            "{{\"row\": {row}, \"k\": {k}, \"ids\": {}, \"dists\": {}, \"source\": {}, \
             \"model_generation\": {}}}",
            json_u32_array(&ids),
            json_f32_array(&dists),
            if st.shards.is_some() { "\"shards\"" } else { "\"factors\"" },
            ms.generation,
        ));
    }
    // OOS query: rank the cross-proximity row from the factors.
    let rows = parse_queries(&body, ms.d)?;
    if rows.len() != 1 {
        bail!("/neighbors takes one query per request (got {})", rows.len());
    }
    if k > n {
        bail!("k={k} exceeds the {n}-row gallery");
    }
    let replies = submit(st, JobKind::Neighbors, Tier::Full, rows, k)?;
    match replies.first() {
        Some((gen, Reply::Neighbors { ids, proximities, dists })) => Ok(format!(
            "{{\"k\": {k}, \"ids\": {}, \"proximities\": {}, \"dists\": {}, \
             \"source\": \"factors\", \"model_generation\": {gen}}}",
            json_u32_array(ids),
            json_f32_array(proximities),
            json_f32_array(dists),
        )),
        _ => bail!("internal: unexpected reply kind"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_parsing_accepts_flat_and_nested() {
        let j = Json::parse("{\"x\": [1.0, 2.5]}").unwrap();
        let rows = parse_queries(&j, 2).unwrap();
        assert_eq!(rows, vec![vec![1.0, 2.5]]);
        let j = Json::parse("{\"x\": [[1, 2], [3, 4]]}").unwrap();
        let rows = parse_queries(&j, 2).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec![3.0, 4.0]);
        // Dimension mismatch and malformed bodies fail.
        assert!(parse_queries(&j, 3).is_err());
        let j = Json::parse("{\"x\": []}").unwrap();
        assert!(parse_queries(&j, 2).is_err());
        let j = Json::parse("{\"y\": [1]}").unwrap();
        assert!(parse_queries(&j, 1).is_err());
    }

    #[test]
    fn f32_json_transport_is_bit_exact() {
        // format! → parse-as-f64 → cast-to-f32 must recover the bits.
        for v in [0.1f32, -0.0, 1.0 / 3.0, f32::MIN_POSITIVE, 1e30, -7.25] {
            let s = json_f32(v);
            let back = s.parse::<f64>().unwrap() as f32;
            assert_eq!(back.to_bits(), v.to_bits(), "{s}");
        }
        assert_eq!(json_f32(f32::INFINITY), "\"inf\"");
        assert_eq!(json_f32(f32::NEG_INFINITY), "\"-inf\"");
        assert_eq!(json_f32(f32::NAN), "\"nan\"");
    }

    #[test]
    fn array_rendering() {
        assert_eq!(json_u32_array(&[1, 2, 3]), "[1, 2, 3]");
        assert_eq!(json_f32_array(&[]), "[]");
        assert_eq!(json_f32_array(&[0.5]), "[0.5]");
    }
}
