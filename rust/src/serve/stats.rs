//! Serving observability: request counters, a batch-size histogram
//! (how well the micro-batcher coalesces), and latency percentiles
//! from a bounded reservoir — everything `GET /stats` reports.

use crate::runtime::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Latency reservoir capacity (most recent samples win).
const RESERVOIR: usize = 4096;
/// Power-of-two batch-size buckets: 1, 2, 4, …, 2^15, plus overflow.
const HIST_BUCKETS: usize = 17;

#[derive(Default)]
struct LatencyRing {
    samples: Vec<f64>,
    next: usize,
}

/// A bounded latency reservoir (most recent [`RESERVOIR`] samples win)
/// with a monotonic total. One instance covers all requests; the tier
/// layer keeps one more per serving tier.
pub struct Reservoir {
    ring: Mutex<LatencyRing>,
    total: AtomicU64,
}

impl Reservoir {
    fn new() -> Reservoir {
        Reservoir { ring: Mutex::new(LatencyRing::default()), total: AtomicU64::new(0) }
    }

    /// Record one latency sample (seconds).
    pub fn record(&self, secs: f64) {
        self.total.fetch_add(1, Ordering::Relaxed);
        let mut ring = crate::obs::lock_recover(&self.ring);
        if ring.samples.len() < RESERVOIR {
            ring.samples.push(secs);
        } else {
            let slot = ring.next;
            ring.samples[slot] = secs;
        }
        ring.next = (ring.next + 1) % RESERVOIR;
    }

    /// Samples ever recorded (not capped at the reservoir size).
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// `(p50, p95, p99)` over the reservoir, `None` when empty.
    pub fn percentiles(&self) -> Option<(f64, f64, f64)> {
        let ring = crate::obs::lock_recover(&self.ring);
        if ring.samples.is_empty() {
            return None;
        }
        let mut sorted = ring.samples.clone();
        drop(ring);
        // total_cmp, not partial_cmp().unwrap(): a NaN sample (e.g. a
        // poisoned clock delta) must not abort the stats path. NaNs
        // order after every number under IEEE total order, so they
        // land at the tail and only perturb the extreme percentiles.
        sorted.sort_by(f64::total_cmp);
        let pick = |q: f64| sorted[(((sorted.len() - 1) as f64) * q).round() as usize];
        Some((pick(0.50), pick(0.95), pick(0.99)))
    }

    fn to_json(&self) -> String {
        let (p50, p95, p99) = self.percentiles().unwrap_or((0.0, 0.0, 0.0));
        format!(
            "{{\"samples\": {}, \"p50\": {:.6}, \"p95\": {:.6}, \"p99\": {:.6}}}",
            self.count(),
            p50,
            p95,
            p99,
        )
    }
}

/// Shared serving counters. All methods are `&self` (atomics + one
/// short-lived mutex), so connection threads record without contention
/// on the hot path.
pub struct Stats {
    pub predict: AtomicU64,
    pub neighbors: AtomicU64,
    pub embed: AtomicU64,
    pub healthz: AtomicU64,
    pub stats: AtomicU64,
    pub errors: AtomicU64,
    /// Accepted TCP connections — with keep-alive this grows much
    /// slower than the request counters, which is the whole point.
    pub connections: AtomicU64,
    /// `/predict` requests *served* on the full tier.
    pub predict_full: AtomicU64,
    /// `/predict` requests *served* on the cheap (companion) tier.
    pub predict_cheap: AtomicU64,
    /// `/predict` requests that *asked* for `"budget": "auto"` (the
    /// router counts these without knowing the serving outcome).
    pub predict_auto: AtomicU64,
    /// Auto requests degraded to the cheap tier under queue pressure
    /// (a subset of `predict_cheap`).
    pub shed_to_cheap: AtomicU64,
    /// Queue-wait + execution latency per serving tier.
    pub full_tier_latency: Reservoir,
    pub cheap_tier_latency: Reservoir,
    batch_hist: [AtomicU64; HIST_BUCKETS],
    batches: AtomicU64,
    batched_jobs: AtomicU64,
    latency: Reservoir,
}

impl Stats {
    pub fn new() -> Stats {
        Stats {
            predict: AtomicU64::new(0),
            neighbors: AtomicU64::new(0),
            embed: AtomicU64::new(0),
            healthz: AtomicU64::new(0),
            stats: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            predict_full: AtomicU64::new(0),
            predict_cheap: AtomicU64::new(0),
            predict_auto: AtomicU64::new(0),
            shed_to_cheap: AtomicU64::new(0),
            full_tier_latency: Reservoir::new(),
            cheap_tier_latency: Reservoir::new(),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            batches: AtomicU64::new(0),
            batched_jobs: AtomicU64::new(0),
            latency: Reservoir::new(),
        }
    }

    /// Record one executed micro-batch of `size` jobs.
    pub fn record_batch(&self, size: usize) {
        let bucket = (usize::BITS - size.max(1).leading_zeros() - 1) as usize;
        self.batch_hist[bucket.min(HIST_BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_jobs.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one request's end-to-end latency (seconds).
    pub fn record_latency(&self, secs: f64) {
        self.latency.record(secs);
    }

    /// `(p50, p95, p99)` over the reservoir, `None` when empty.
    pub fn latency_percentiles(&self) -> Option<(f64, f64, f64)> {
        self.latency.percentiles()
    }

    /// The `GET /stats` document.
    pub fn to_json(&self) -> String {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let mut hist = String::from("{");
        let mut first = true;
        for (i, c) in self.batch_hist.iter().enumerate() {
            let v = c.load(Ordering::Relaxed);
            if v == 0 {
                continue;
            }
            if !first {
                hist.push_str(", ");
            }
            first = false;
            let label = if i == HIST_BUCKETS - 1 {
                "65536+".to_string()
            } else {
                format!("{}", 1usize << i)
            };
            hist.push_str(&format!("\"{label}\": {v}"));
        }
        hist.push('}');
        let batches = g(&self.batches);
        let jobs = g(&self.batched_jobs);
        format!(
            "{{\"requests\": {{\"predict\": {}, \"neighbors\": {}, \"embed\": {}, \
             \"healthz\": {}, \"stats\": {}}}, \"errors\": {}, \"connections\": {}, \
             \"tiers\": {{\"predict_full\": {}, \"predict_cheap\": {}, \"predict_auto\": {}, \
             \"shed_to_cheap\": {}, \"full_latency_secs\": {}, \"cheap_latency_secs\": {}}}, \
             \"batches\": {batches}, \"batched_jobs\": {jobs}, \
             \"mean_batch\": {:.3}, \"batch_size_hist\": {hist}, \
             \"latency_secs\": {}}}",
            g(&self.predict),
            g(&self.neighbors),
            g(&self.embed),
            g(&self.healthz),
            g(&self.stats),
            g(&self.errors),
            g(&self.connections),
            g(&self.predict_full),
            g(&self.predict_cheap),
            g(&self.predict_auto),
            g(&self.shed_to_cheap),
            self.full_tier_latency.to_json(),
            self.cheap_tier_latency.to_json(),
            if batches > 0 { jobs as f64 / batches as f64 } else { 0.0 },
            self.latency.to_json(),
        )
    }
}

impl Default for Stats {
    fn default() -> Self {
        Stats::new()
    }
}

/// Sum the counter fields of several backend `/stats` documents into
/// one `"totals"` object — what the replica router reports for the
/// fleet. Latency percentiles don't merge (quantiles aren't additive),
/// so callers keep the per-backend documents for those.
pub fn merge_counter_totals(docs: &[Json]) -> String {
    let sum = |path: &[&str]| -> u64 {
        docs.iter()
            .map(|d| {
                let mut j = Some(d);
                for key in path {
                    j = j.and_then(|x| x.get(key));
                }
                j.and_then(Json::as_usize).unwrap_or(0) as u64
            })
            .sum()
    };
    format!(
        "{{\"requests\": {{\"predict\": {}, \"neighbors\": {}, \"embed\": {}, \
         \"healthz\": {}, \"stats\": {}}}, \"errors\": {}, \"connections\": {}, \
         \"tiers\": {{\"predict_full\": {}, \"predict_cheap\": {}, \"predict_auto\": {}, \
         \"shed_to_cheap\": {}}}, \"batches\": {}, \"batched_jobs\": {}}}",
        sum(&["requests", "predict"]),
        sum(&["requests", "neighbors"]),
        sum(&["requests", "embed"]),
        sum(&["requests", "healthz"]),
        sum(&["requests", "stats"]),
        sum(&["errors"]),
        sum(&["connections"]),
        sum(&["tiers", "predict_full"]),
        sum(&["tiers", "predict_cheap"]),
        sum(&["tiers", "predict_auto"]),
        sum(&["tiers", "shed_to_cheap"]),
        sum(&["batches"]),
        sum(&["batched_jobs"]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::json::Json;

    #[test]
    fn batch_histogram_buckets_by_power_of_two() {
        let s = Stats::new();
        for size in [1usize, 1, 2, 3, 4, 7, 8, 1000] {
            s.record_batch(size);
        }
        assert_eq!(s.batch_hist[0].load(Ordering::Relaxed), 2); // 1, 1
        assert_eq!(s.batch_hist[1].load(Ordering::Relaxed), 2); // 2, 3
        assert_eq!(s.batch_hist[2].load(Ordering::Relaxed), 2); // 4, 7
        assert_eq!(s.batch_hist[3].load(Ordering::Relaxed), 1); // 8
        assert_eq!(s.batch_hist[9].load(Ordering::Relaxed), 1); // 1000 ∈ [512, 1024)
    }

    #[test]
    fn percentiles_over_known_samples() {
        let s = Stats::new();
        for i in 1..=100 {
            s.record_latency(i as f64);
        }
        let (p50, p95, p99) = s.latency_percentiles().unwrap();
        assert!((p50 - 51.0).abs() < 1.5, "p50={p50}");
        assert!((p95 - 95.0).abs() < 1.5, "p95={p95}");
        assert!((p99 - 99.0).abs() < 1.5, "p99={p99}");
    }

    #[test]
    fn percentiles_survive_a_nan_sample() {
        // Regression: sort_by(partial_cmp().unwrap()) panicked the
        // stats path the moment a NaN latency landed in the ring.
        let s = Stats::new();
        for i in 1..=99 {
            s.record_latency(i as f64);
        }
        s.record_latency(f64::NAN);
        let (p50, _p95, _p99) = s.latency_percentiles().expect("non-empty reservoir");
        assert!(p50.is_finite(), "median must ignore the NaN tail, got {p50}");
        assert!((p50 - 50.0).abs() < 2.0, "p50={p50}");
        // The JSON render must not panic either.
        let _ = s.to_json();
    }

    #[test]
    fn reservoir_wraps_without_growing() {
        let s = Stats::new();
        for i in 0..(RESERVOIR + 100) {
            s.record_latency(i as f64);
        }
        assert_eq!(s.latency.ring.lock().unwrap().samples.len(), RESERVOIR);
        assert_eq!(s.latency.count(), (RESERVOIR + 100) as u64);
    }

    #[test]
    fn tier_counters_and_reservoirs_render() {
        let s = Stats::new();
        s.predict_full.fetch_add(4, Ordering::Relaxed);
        s.predict_cheap.fetch_add(2, Ordering::Relaxed);
        s.predict_auto.fetch_add(3, Ordering::Relaxed);
        s.shed_to_cheap.fetch_add(1, Ordering::Relaxed);
        s.full_tier_latency.record(0.010);
        s.cheap_tier_latency.record(0.001);
        let j = Json::parse(&s.to_json()).unwrap();
        let tier = |k: &str| j.get("tiers").and_then(|t| t.get(k)).and_then(Json::as_usize);
        assert_eq!(tier("predict_full"), Some(4));
        assert_eq!(tier("predict_cheap"), Some(2));
        assert_eq!(tier("predict_auto"), Some(3));
        assert_eq!(tier("shed_to_cheap"), Some(1));
        let samples = |k: &str| {
            j.get("tiers")
                .and_then(|t| t.get(k))
                .and_then(|r| r.get("samples"))
                .and_then(Json::as_usize)
        };
        assert_eq!(samples("full_latency_secs"), Some(1));
        assert_eq!(samples("cheap_latency_secs"), Some(1));
    }

    #[test]
    fn tier_counters_merge_across_documents() {
        let a = Stats::new();
        a.predict_cheap.fetch_add(2, Ordering::Relaxed);
        a.shed_to_cheap.fetch_add(1, Ordering::Relaxed);
        let b = Stats::new();
        b.predict_cheap.fetch_add(3, Ordering::Relaxed);
        b.predict_full.fetch_add(7, Ordering::Relaxed);
        let docs =
            vec![Json::parse(&a.to_json()).unwrap(), Json::parse(&b.to_json()).unwrap()];
        let t = Json::parse(&merge_counter_totals(&docs)).unwrap();
        let tier = |k: &str| t.get("tiers").and_then(|x| x.get(k)).and_then(Json::as_usize);
        assert_eq!(tier("predict_cheap"), Some(5));
        assert_eq!(tier("predict_full"), Some(7));
        assert_eq!(tier("shed_to_cheap"), Some(1));
    }

    #[test]
    fn counter_totals_merge_across_documents() {
        let a = Stats::new();
        a.predict.fetch_add(3, Ordering::Relaxed);
        a.errors.fetch_add(1, Ordering::Relaxed);
        a.connections.fetch_add(2, Ordering::Relaxed);
        let b = Stats::new();
        b.predict.fetch_add(2, Ordering::Relaxed);
        b.neighbors.fetch_add(5, Ordering::Relaxed);
        let docs =
            vec![Json::parse(&a.to_json()).unwrap(), Json::parse(&b.to_json()).unwrap()];
        let t = Json::parse(&merge_counter_totals(&docs)).unwrap();
        let req = |k: &str| t.get("requests").and_then(|r| r.get(k)).and_then(Json::as_usize);
        assert_eq!(req("predict"), Some(5));
        assert_eq!(req("neighbors"), Some(5));
        assert_eq!(t.get("errors").and_then(Json::as_usize), Some(1));
        assert_eq!(t.get("connections").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn stats_json_parses_with_in_repo_parser() {
        let s = Stats::new();
        s.predict.fetch_add(3, Ordering::Relaxed);
        s.record_batch(4);
        s.record_latency(0.002);
        let j = Json::parse(&s.to_json()).unwrap();
        assert_eq!(
            j.get("requests").and_then(|r| r.get("predict")).and_then(Json::as_usize),
            Some(3)
        );
        assert_eq!(j.get("batches").and_then(Json::as_usize), Some(1));
        assert!(j.get("latency_secs").is_some());
    }
}
