//! Hand-rolled minimal HTTP/1.1 — just enough for the serving layer.
//!
//! The offline vendor set has no hyper/tiny-http, so this module
//! implements the slice the server, the replica router, and their
//! bench/test clients need: request-line + header parsing with
//! `Content-Length` bodies, **persistent keep-alive connections** on
//! both sides, and a thread-safe connection pool. Chunked transfer
//! encoding and HTTP/2 are deliberately out of scope.
//!
//! The load-bearing piece is [`ConnReader`]: a per-connection buffer
//! that carries over-read bytes across requests. A single
//! `stream.read` may return the tail of one request *plus* the head of
//! the next (two small requests routinely land in one TCP segment);
//! dropping that tail — what the old one-shot reader did — corrupts
//! the stream the moment two requests share a connection, which is why
//! keep-alive was previously impossible.

use crate::error::Result;
use crate::{anyhow, bail};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

/// Maximum accepted header block (64 KB) and body (64 MB).
const MAX_HEADER: usize = 64 * 1024;
const MAX_BODY: usize = 64 * 1024 * 1024;

/// Client-side connect/read/write timeouts — the mirror of the
/// server's per-connection `IO_TIMEOUT`. Without these, a backend that
/// accepts but never answers (stopped process, deadlocked batcher)
/// would hang the caller forever and the router's failover could never
/// trigger: only an I/O error lets it move to the next replica.
const CLIENT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Open a client connection with the timeout discipline applied.
fn connect(addr: &SocketAddr) -> std::io::Result<TcpStream> {
    let stream = TcpStream::connect_timeout(addr, CLIENT_IO_TIMEOUT)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(CLIENT_IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(CLIENT_IO_TIMEOUT)).ok();
    Ok(stream)
}

/// One parsed request.
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the peer asked to keep the connection open after this
    /// request: the HTTP/1.1 default, overridden by
    /// `Connection: close`; HTTP/1.0 closes unless it sends an
    /// explicit `Connection: keep-alive`.
    pub keep_alive: bool,
    /// `x-request-id` header, when the peer sent a well-formed one
    /// (printable ASCII, ≤128 bytes — anything else is ignored so a
    /// hostile value can never be reflected into a response header).
    /// The ingress loop fills this with a freshly minted id otherwise.
    pub request_id: Option<String>,
    /// True when the id was minted by this process or an upstream
    /// router (`x-request-id-gen: 1`) rather than supplied by the edge
    /// client. Generated ids are echoed in the response *header* only;
    /// client-supplied ids are additionally echoed in the JSON body —
    /// keeping bodies byte-identical for clients that send no id.
    pub request_id_generated: bool,
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Per-connection read buffer. Every read appends here and every
/// parsed message drains exactly its own bytes, so anything the kernel
/// delivered past the current message — the start of a pipelined next
/// request — is waiting in `buf` for the next parse instead of being
/// discarded with the temporary read buffer.
pub struct ConnReader {
    buf: Vec<u8>,
}

impl Default for ConnReader {
    fn default() -> Self {
        ConnReader::new()
    }
}

impl ConnReader {
    pub fn new() -> ConnReader {
        ConnReader { buf: Vec::with_capacity(1024) }
    }

    /// Block until at least one byte of the next message is buffered.
    /// `Ok(false)` means the peer closed cleanly with nothing pending —
    /// the normal end of a keep-alive connection. Lets callers separate
    /// idle keep-alive time (not request latency) from request time.
    pub fn await_data(&mut self, stream: &mut TcpStream) -> Result<bool> {
        if !self.buf.is_empty() {
            return Ok(true);
        }
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Ok(false);
        }
        self.buf.extend_from_slice(&tmp[..n]);
        Ok(true)
    }

    /// Fill until the `\r\n\r\n` header terminator is buffered and
    /// return its position. `Ok(None)` on clean EOF with an empty
    /// buffer.
    fn fill_header(&mut self, stream: &mut TcpStream) -> Result<Option<usize>> {
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(pos) = find_subsequence(&self.buf, b"\r\n\r\n") {
                return Ok(Some(pos));
            }
            if self.buf.len() > MAX_HEADER {
                bail!("message header exceeds {MAX_HEADER} bytes");
            }
            let n = stream.read(&mut tmp)?;
            if n == 0 {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid-header");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }

    /// Fill until `total` bytes are buffered (header + body).
    fn fill_body(&mut self, stream: &mut TcpStream, total: usize) -> Result<()> {
        let mut tmp = [0u8; 4096];
        while self.buf.len() < total {
            let n = stream.read(&mut tmp)?;
            if n == 0 {
                bail!("connection closed mid-body ({} of {total} bytes)", self.buf.len());
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
        Ok(())
    }

    /// Read one request. `Ok(None)` means the peer closed the
    /// connection cleanly before sending anything (end of keep-alive).
    /// Over-read bytes stay buffered for the next call.
    pub fn read_request(&mut self, stream: &mut TcpStream) -> Result<Option<Request>> {
        let header_end = match self.fill_header(stream)? {
            Some(pos) => pos,
            None => return Ok(None),
        };
        let header = std::str::from_utf8(&self.buf[..header_end])
            .map_err(|_| anyhow!("request header is not UTF-8"))?;
        let mut lines = header.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("HTTP/1.1");
        if method.is_empty() || path.is_empty() {
            bail!("malformed request line {request_line:?}");
        }
        let mut content_len = 0usize;
        let mut keep_alive = !version.eq_ignore_ascii_case("HTTP/1.0");
        let mut request_id = None;
        let mut request_id_generated = false;
        for line in lines {
            if let Some((k, v)) = line.split_once(':') {
                let k = k.trim();
                if k.eq_ignore_ascii_case("content-length") {
                    content_len = v
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("bad Content-Length {:?}", v.trim()))?;
                } else if k.eq_ignore_ascii_case("connection") {
                    let v = v.trim();
                    if v.eq_ignore_ascii_case("close") {
                        keep_alive = false;
                    } else if v.eq_ignore_ascii_case("keep-alive") {
                        keep_alive = true;
                    }
                } else if k.eq_ignore_ascii_case("x-request-id") {
                    let v = v.trim();
                    if crate::obs::valid_request_id(v) {
                        request_id = Some(v.to_string());
                    }
                } else if k.eq_ignore_ascii_case("x-request-id-gen") {
                    request_id_generated = v.trim() == "1";
                }
            }
        }
        if content_len > MAX_BODY {
            bail!("request body of {content_len} bytes exceeds {MAX_BODY}");
        }
        let body_start = header_end + 4;
        self.fill_body(stream, body_start + content_len)?;
        let body = self.buf[body_start..body_start + content_len].to_vec();
        // Drain exactly this request; a pipelined successor stays put.
        self.buf.drain(..body_start + content_len);
        Ok(Some(Request {
            method,
            path,
            body,
            keep_alive,
            request_id,
            request_id_generated,
        }))
    }
}

/// Write a full response and flush. `keep_alive` echoes the client's
/// wish back as `Connection: keep-alive`/`close` so both sides agree
/// on the connection's fate.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_ext(stream, status, reason, "application/json", body, keep_alive, None)
}

/// [`write_response`] with an explicit content type and an optional
/// `x-request-id` echo header — the ingress loop's variant (`/metrics`
/// serves Prometheus text, and every response carries its request id).
/// The id is validated at parse/mint time, so it is header-safe here.
pub fn write_response_ext(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
    keep_alive: bool,
    request_id: Option<&str>,
) -> std::io::Result<()> {
    let id_header = match request_id {
        Some(id) => format!("x-request-id: {id}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {}\r\n{id_header}\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Request-id relay parameter: the id plus whether it was *generated*
/// inside the serving fabric (router ingress) rather than supplied by
/// the edge client. Forwarded as `x-request-id` (+ `x-request-id-gen:
/// 1` when generated) so replicas log the id but only body-echo
/// client-supplied ones.
pub type RequestIdFwd<'a> = Option<(&'a str, bool)>;

fn send_request(
    stream: &mut TcpStream,
    addr: &SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    keep_alive: bool,
    rid: RequestIdFwd<'_>,
) -> std::io::Result<()> {
    let id_headers = match rid {
        Some((id, true)) => format!("x-request-id: {id}\r\nx-request-id-gen: 1\r\n"),
        Some((id, false)) => format!("x-request-id: {id}\r\n"),
        None => String::new(),
    };
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {}\r\n{id_headers}\r\n{body}",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(req.as_bytes())?;
    stream.flush()
}

/// Read one `Content-Length`-framed response off `stream` through the
/// carry buffer; returns `(status, body, server_keeps_alive)`. Framed
/// reads (not `read_to_end`) are what make response boundaries visible
/// on a connection that stays open.
pub fn read_response(
    stream: &mut TcpStream,
    reader: &mut ConnReader,
) -> Result<(u16, String, bool)> {
    let header_end = reader
        .fill_header(stream)?
        .ok_or_else(|| anyhow!("connection closed before any response byte"))?;
    let header = std::str::from_utf8(&reader.buf[..header_end])
        .map_err(|_| anyhow!("response header is not UTF-8"))?;
    let mut lines = header.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line {status_line:?}"))?;
    let mut content_len: Option<usize> = None;
    let mut keep_alive = true;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let k = k.trim();
            if k.eq_ignore_ascii_case("content-length") {
                content_len = Some(
                    v.trim()
                        .parse()
                        .map_err(|_| anyhow!("bad response Content-Length {:?}", v.trim()))?,
                );
            } else if k.eq_ignore_ascii_case("connection")
                && v.trim().eq_ignore_ascii_case("close")
            {
                keep_alive = false;
            }
        }
    }
    let content_len =
        content_len.ok_or_else(|| anyhow!("response has no Content-Length header"))?;
    if content_len > MAX_BODY {
        bail!("response body of {content_len} bytes exceeds {MAX_BODY}");
    }
    let body_start = header_end + 4;
    reader.fill_body(stream, body_start + content_len)?;
    let body = String::from_utf8(reader.buf[body_start..body_start + content_len].to_vec())
        .map_err(|_| anyhow!("response body is not UTF-8"))?;
    reader.buf.drain(..body_start + content_len);
    Ok((status, body, keep_alive))
}

/// One-shot client: open a fresh connection, send `Connection: close`,
/// read the framed response, return `(status, body)`. The
/// connection-per-request baseline `bench-serve` measures keep-alive
/// against; tests use it wherever connection reuse is irrelevant.
pub fn http_request(
    addr: &SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    let mut stream = connect(addr)?;
    send_request(&mut stream, addr, method, path, body, false, None)?;
    let mut reader = ConnReader::new();
    let (status, body, _) = read_response(&mut stream, &mut reader)?;
    Ok((status, body))
}

/// A persistent keep-alive client: one TCP connection reused across
/// requests, transparently re-established when the server closes it
/// (idle reaping, restart). Retry-on-reuse is **per request**:
/// [`HttpClient::request`] retries once on a fresh connection when the
/// reused one fails — safe for the read endpoints it serves — while
/// [`HttpClient::request_once`] never retries, which is what
/// non-idempotent calls (`POST /admin/reload`) must use: a request
/// whose response was lost may still have been *applied*, and a blind
/// resend would apply it twice.
pub struct HttpClient {
    addr: SocketAddr,
    conn: Option<(TcpStream, ConnReader)>,
}

impl HttpClient {
    pub fn new(addr: SocketAddr) -> HttpClient {
        HttpClient { addr, conn: None }
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn try_request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
        rid: RequestIdFwd<'_>,
    ) -> Result<(u16, String)> {
        if self.conn.is_none() {
            self.conn = Some((connect(&self.addr)?, ConnReader::new()));
        }
        let (stream, reader) =
            self.conn.as_mut().ok_or_else(|| anyhow!("pooled connection vanished"))?;
        let addr = self.addr;
        send_request(stream, &addr, method, path, body, true, rid)?;
        let (status, resp, server_keeps) = read_response(stream, reader)?;
        if !server_keeps {
            self.conn = None;
        }
        Ok((status, resp))
    }

    /// Send one request on the pooled connection and read its framed
    /// response. A failure on a reused connection drops it and retries
    /// exactly once on a fresh one — only safe for idempotent (read)
    /// requests; use [`HttpClient::request_once`] for anything that
    /// mutates server state.
    pub fn request(&mut self, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        self.request_fwd(method, path, body, None, true)
    }

    /// [`HttpClient::request`] without the reuse retry: a transport
    /// failure surfaces immediately, even on a stale pooled connection.
    /// Required for non-idempotent requests, where "resend blindly"
    /// risks applying the action twice.
    pub fn request_once(&mut self, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        self.request_fwd(method, path, body, None, false)
    }

    /// Full-control request: optional request-id relay headers plus
    /// the retry-on-reuse switch. The router's relay path.
    pub fn request_fwd(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
        rid: RequestIdFwd<'_>,
        retry_on_reuse: bool,
    ) -> Result<(u16, String)> {
        let reused = self.conn.is_some();
        match self.try_request(method, path, body, rid) {
            Ok(out) => Ok(out),
            Err(e) => {
                self.conn = None;
                if !reused || !retry_on_reuse {
                    return Err(e);
                }
                let out = self.try_request(method, path, body, rid);
                if out.is_err() {
                    // Leave no half-read connection behind.
                    self.conn = None;
                }
                out
            }
        }
    }
}

/// Thread-safe pool of keep-alive connections to one address: threads
/// check a connection out per request and return it on success, so
/// concurrent callers never share a stream mid-message and broken
/// connections are simply dropped. Grows to the caller concurrency.
pub struct ClientPool {
    addr: SocketAddr,
    idle: Mutex<Vec<HttpClient>>,
}

impl ClientPool {
    pub fn new(addr: SocketAddr) -> ClientPool {
        ClientPool { addr, idle: Mutex::new(Vec::new()) }
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Run one request on a pooled connection (creating one when all
    /// are busy); the connection returns to the pool only on success.
    /// Retries once on a stale reused connection — reads only.
    pub fn request(&self, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        self.request_fwd(method, path, body, None, true)
    }

    /// [`ClientPool::request`] without the reuse retry, for
    /// non-idempotent requests (`POST /admin/reload`).
    pub fn request_once(&self, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        self.request_fwd(method, path, body, None, false)
    }

    /// Pooled request with request-id relay headers — what the router
    /// uses so `x-request-id` survives the hop to the replica.
    pub fn request_fwd(
        &self,
        method: &str,
        path: &str,
        body: &str,
        rid: RequestIdFwd<'_>,
        retry_on_reuse: bool,
    ) -> Result<(u16, String)> {
        let mut client = crate::obs::lock_recover(&self.idle)
            .pop()
            .unwrap_or_else(|| HttpClient::new(self.addr));
        let out = client.request_fwd(method, path, body, rid, retry_on_reuse);
        if out.is_ok() {
            crate::obs::lock_recover(&self.idle).push(client);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn echo_server(
        listener: TcpListener,
        requests: usize,
    ) -> std::thread::JoinHandle<Vec<(String, String)>> {
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut reader = ConnReader::new();
            let mut seen = vec![];
            for _ in 0..requests {
                let req = match reader.read_request(&mut stream).unwrap() {
                    Some(r) => r,
                    None => break,
                };
                let keep = req.keep_alive;
                seen.push((req.path.clone(), String::from_utf8(req.body).unwrap()));
                let body = format!("{{\"path\": \"{}\"}}", req.path);
                write_response(&mut stream, 200, "OK", &body, keep).unwrap();
                if !keep {
                    break;
                }
            }
            seen
        })
    }

    #[test]
    fn one_shot_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut reader = ConnReader::new();
            let req = reader.read_request(&mut stream).unwrap().unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/echo");
            assert!(!req.keep_alive, "one-shot client must ask for close");
            let body = String::from_utf8(req.body).unwrap();
            write_response(&mut stream, 200, "OK", &body, false).unwrap();
        });
        let (status, body) = http_request(&addr, "POST", "/echo", "{\"x\": [1, 2]}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"x\": [1, 2]}");
        server.join().unwrap();
    }

    #[test]
    fn empty_connection_reads_none() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            assert!(ConnReader::new().read_request(&mut stream).unwrap().is_none());
        });
        drop(TcpStream::connect(addr).unwrap());
        server.join().unwrap();
    }

    #[test]
    fn keep_alive_client_reuses_one_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // The server thread accepts exactly ONE connection; if the
        // client reconnected per request, later requests would hang or
        // fail instead of being answered.
        let server = echo_server(listener, 3);
        let mut client = HttpClient::new(addr);
        for i in 0..3 {
            let (status, body) =
                client.request("POST", &format!("/r{i}"), &format!("{{\"i\": {i}}}")).unwrap();
            assert_eq!(status, 200);
            assert_eq!(body, format!("{{\"path\": \"/r{i}\"}}"));
        }
        let seen = server.join().unwrap();
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[2], ("/r2".to_string(), "{\"i\": 2}".to_string()));
    }

    #[test]
    fn two_requests_in_one_segment_are_both_served() {
        // The carried-buffer regression: both requests land in the
        // server's buffer in ONE read; the old reader discarded the
        // second one with the bytes past Content-Length.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = echo_server(listener, 2);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).ok();
        let one = "POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let two = "POST /b HTTP/1.1\r\nContent-Length: 3\r\nConnection: close\r\n\r\nbye";
        stream.write_all(format!("{one}{two}").as_bytes()).unwrap();
        stream.flush().unwrap();
        let mut reader = ConnReader::new();
        let (s1, b1, keep1) = read_response(&mut stream, &mut reader).unwrap();
        let (s2, b2, keep2) = read_response(&mut stream, &mut reader).unwrap();
        assert_eq!((s1, b1.as_str(), keep1), (200, "{\"path\": \"/a\"}", true));
        assert_eq!((s2, b2.as_str(), keep2), (200, "{\"path\": \"/b\"}", false));
        let seen = server.join().unwrap();
        assert_eq!(seen, vec![
            ("/a".to_string(), "hi".to_string()),
            ("/b".to_string(), "bye".to_string()),
        ]);
    }

    #[test]
    fn client_reconnects_when_server_closes_between_requests() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // A server that closes after every response despite the
        // client's keep-alive wish (Connection: close in the reply).
        let server = std::thread::spawn(move || {
            for _ in 0..2 {
                let (mut stream, _) = listener.accept().unwrap();
                let mut reader = ConnReader::new();
                let req = reader.read_request(&mut stream).unwrap().unwrap();
                write_response(&mut stream, 200, "OK", "{}", false).unwrap();
                drop(req);
            }
        });
        let mut client = HttpClient::new(addr);
        assert_eq!(client.request("GET", "/x", "").unwrap().0, 200);
        // The client saw Connection: close, so the second request
        // opens a fresh connection instead of writing into a corpse.
        assert_eq!(client.request("GET", "/y", "").unwrap().0, 200);
        server.join().unwrap();
    }

    #[test]
    fn request_once_does_not_retry_on_a_stale_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Answer one request claiming keep-alive, then close the
        // connection anyway — the classic stale-pooled-connection shape.
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut reader = ConnReader::new();
            let _ = reader.read_request(&mut stream).unwrap().unwrap();
            write_response(&mut stream, 200, "OK", "{}", true).unwrap();
            // Dropping listener + stream: any reconnect attempt fails.
        });
        let mut client = HttpClient::new(addr);
        assert_eq!(client.request("GET", "/x", "").unwrap().0, 200);
        server.join().unwrap();
        // The pooled connection is now dead. `request` would eat the
        // failure and retry; `request_once` must surface it so a
        // non-idempotent call is never silently resent.
        assert!(client.request_once("POST", "/admin/reload", "").is_err());
    }

    #[test]
    fn http_10_defaults_to_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = ConnReader::new().read_request(&mut stream).unwrap().unwrap();
            assert!(!req.keep_alive);
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /z HTTP/1.0\r\nContent-Length: 0\r\n\r\n").unwrap();
        server.join().unwrap();
    }
}
