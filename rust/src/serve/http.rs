//! Hand-rolled minimal HTTP/1.1 — just enough for the serving layer.
//!
//! The offline vendor set has no hyper/tiny-http, so this module
//! implements the slice the server and its bench/test clients need:
//! request-line + header parsing with `Content-Length` bodies on the
//! server side, and a one-shot `Connection: close` client. Chunked
//! transfer encoding, pipelining, and keep-alive are deliberately out
//! of scope (keep-alive pooling is queued in the ROADMAP).

use crate::error::Result;
use crate::{anyhow, bail};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

/// Maximum accepted header block (64 KB) and body (64 MB).
const MAX_HEADER: usize = 64 * 1024;
const MAX_BODY: usize = 64 * 1024 * 1024;

/// One parsed request.
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one request from the stream. `Ok(None)` means the peer closed
/// the connection cleanly before sending anything.
pub fn read_request(stream: &mut TcpStream) -> Result<Option<Request>> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subsequence(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER {
            bail!("request header exceeds {MAX_HEADER} bytes");
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            bail!("connection closed mid-header");
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let header = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| anyhow!("request header is not UTF-8"))?;
    let mut lines = header.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {request_line:?}");
    }
    let mut content_len = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad Content-Length {:?}", v.trim()))?;
            }
        }
    }
    if content_len > MAX_BODY {
        bail!("request body of {content_len} bytes exceeds {MAX_BODY}");
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-body ({} of {content_len} bytes)", body.len());
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_len);
    Ok(Some(Request { method, path, body }))
}

/// Write a full response and flush. Every response closes the
/// connection (`Connection: close`) — one request per connection.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// One-shot client: send `method path` with a JSON body, read the full
/// response (the server closes the connection), return
/// `(status, body)`. Shared by `bench-serve` and the end-to-end tests.
pub fn http_request(
    addr: &SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf)?;
    let header_end = find_subsequence(&buf, b"\r\n\r\n")
        .ok_or_else(|| anyhow!("response has no header terminator"))?;
    let header = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| anyhow!("response header is not UTF-8"))?;
    let status_line = header.split("\r\n").next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line {status_line:?}"))?;
    let body = String::from_utf8(buf[header_end + 4..].to_vec())
        .map_err(|_| anyhow!("response body is not UTF-8"))?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn request_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream).unwrap().unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/echo");
            let body = String::from_utf8(req.body).unwrap();
            write_response(&mut stream, 200, "OK", &body).unwrap();
        });
        let (status, body) = http_request(&addr, "POST", "/echo", "{\"x\": [1, 2]}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"x\": [1, 2]}");
        server.join().unwrap();
    }

    #[test]
    fn empty_connection_reads_none() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            assert!(read_request(&mut stream).unwrap().is_none());
        });
        drop(TcpStream::connect(addr).unwrap());
        server.join().unwrap();
    }
}
