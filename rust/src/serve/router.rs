//! Replica routing: one address in front of R identical serve
//! processes.
//!
//! The `model.fkb` bundle is the replication unit — every replica
//! loads the same file and produces bitwise-identical answers, so the
//! router needs **no coordination**: it health-checks its backends
//! once at bind, then forwards requests over pooled keep-alive
//! connections ([`http::ClientPool`]) and relays the responses
//! verbatim (routed bytes == direct bytes).
//!
//! * `POST /predict`, `/embed`, and OOS `/neighbors` queries are
//!   **round-robin**: any replica answers any query. `/predict` bodies
//!   may carry a `"budget"` SLO (`cheap`/`full`/`auto`); the router
//!   tallies the requested tier in its own stats and forwards the body
//!   untouched — cheap-tier traffic fans out to any replica (every
//!   replica holds the same companion), and the backend's admission
//!   control makes the final `auto` call from its local queue depth.
//! * `/neighbors` **row-mode** lookups go to the row-range *owner* —
//!   the static partition of `[0, N)` into R contiguous ranges. Any
//!   replica could answer (they are full copies), but pinning a row to
//!   one replica keeps that replica's single-stripe shard cache hot
//!   for its range instead of thrashing all caches over all stripes.
//!   Row pinning is inherently a full-tier concern: `/neighbors` never
//!   takes a budget and always runs the full factors.
//! * `GET /stats` merges the fleet: summed counters via
//!   [`stats::merge_counter_totals`] plus each backend's full document
//!   (latency percentiles aren't additive, so they stay per-backend).
//! * `GET /healthz` answers from the router itself with the backend
//!   roster.
//!
//! A backend that stops answering is skipped: forwards fail over to
//! the next replica (every endpoint is a read, so a retry is safe),
//! and only when *all* replicas are down does the client see a
//! 502 Bad Gateway.

use super::{unroutable, Response};
use crate::bench_support::json_escape;
use crate::error::{Context, Result};
use crate::obs;
use crate::runtime::json::Json;
use crate::serve::http::{self, ClientPool};
use crate::serve::stats::{merge_counter_totals, Stats};
use crate::{anyhow, bail};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Router tuning knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Bind address; port 0 picks an ephemeral port (tests/benches).
    pub addr: String,
    /// Backend serve addresses (`host:port`), health-checked at bind.
    pub backends: Vec<String>,
}

struct Backend {
    addr: SocketAddr,
    /// Keep-alive connections to this replica, shared by all router
    /// connection threads.
    pool: ClientPool,
    /// The contiguous slice of `[0, N)` whose row-mode lookups pin
    /// here.
    rows: Range<usize>,
}

/// Everything the router's connection threads share.
pub struct RouterState {
    backends: Vec<Backend>,
    /// Training rows of the (replicated) model.
    n: usize,
    /// Model kind reported by the backends (must agree).
    kind: String,
    /// Round-robin cursor for the OOS endpoints.
    rr: AtomicUsize,
    pub stats: Stats,
    /// Slow-query threshold in milliseconds; 0 disables the log. An
    /// atomic (set via [`Router::set_slow_ms`]) rather than a
    /// [`RouterConfig`] field so existing struct-literal constructions
    /// of the config stay source-compatible.
    slow_ms: AtomicU64,
    shutdown: AtomicBool,
}

/// A bound (but not yet running) router.
pub struct Router {
    state: Arc<RouterState>,
    listener: TcpListener,
    addr: SocketAddr,
}

/// Handle to a router running on a background thread (tests/benches).
pub struct RouterHandle {
    addr: SocketAddr,
    state: Arc<RouterState>,
    join: std::thread::JoinHandle<()>,
}

impl RouterHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Flag shutdown, poke the accept loop, and join.
    pub fn stop(self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        let _ = self.join.join();
    }
}

/// Partition `[0, n)` into `parts` contiguous near-even ranges (the
/// deterministic row-ownership map; replica `i` owns range `i`).
pub fn row_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1);
    (0..parts).map(|i| (i * n / parts)..((i + 1) * n / parts)).collect()
}

impl Router {
    /// Resolve and health-check every backend (each must answer
    /// `GET /healthz` and agree on the model's N and kind), then bind
    /// the listener.
    pub fn bind(cfg: RouterConfig) -> Result<Router> {
        obs::init();
        if cfg.backends.is_empty() {
            bail!("router needs at least one --backends address");
        }
        let mut resolved = Vec::with_capacity(cfg.backends.len());
        let mut n_kind: Option<(usize, String)> = None;
        for b in &cfg.backends {
            let addr = b
                .to_socket_addrs()
                .with_context(|| format!("resolving backend {b}"))?
                .next()
                .ok_or_else(|| anyhow!("backend {b} resolved to no address"))?;
            let (status, body) = http::http_request(&addr, "GET", "/healthz", "")
                .with_context(|| format!("health-checking backend {b}"))?;
            if status != 200 {
                bail!("backend {b} /healthz returned {status}: {body}");
            }
            let j = Json::parse(&body)
                .map_err(|e| anyhow!("backend {b} /healthz is not JSON: {e}"))?;
            let model = j.get("model").ok_or_else(|| anyhow!("backend {b} reports no model"))?;
            let n = model
                .get("n")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("backend {b} reports no model.n"))?;
            let kind = model
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            if let Some((n0, k0)) = &n_kind {
                if *n0 != n || *k0 != kind {
                    bail!(
                        "backend {b} serves n={n} kind={kind} but {} serves n={n0} \
                         kind={k0} — replicas must share one bundle",
                        cfg.backends.first().map_or("?", String::as_str)
                    );
                }
            } else {
                n_kind = Some((n, kind));
            }
            resolved.push(addr);
        }
        let (n, kind) =
            n_kind.ok_or_else(|| anyhow!("router needs at least one resolvable backend"))?;
        let ranges = row_ranges(n, resolved.len());
        let backends = resolved
            .into_iter()
            .zip(ranges)
            .map(|(addr, rows)| Backend { addr, pool: ClientPool::new(addr), rows })
            .collect();
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding router {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(RouterState {
            backends,
            n,
            kind,
            rr: AtomicUsize::new(0),
            stats: Stats::new(),
            slow_ms: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });
        Ok(Router { state, listener, addr })
    }

    /// Enable the slow-query log (the `--slow-ms` flag): requests
    /// slower than `ms` milliseconds emit a structured `http.slow`
    /// event with the request id, endpoint, status, and tier.
    pub fn set_slow_ms(&self, ms: u64) {
        self.state.slow_ms.store(ms, Ordering::Relaxed);
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The backend addresses, in row-range-owner order.
    pub fn backends(&self) -> Vec<SocketAddr> {
        self.state.backends.iter().map(|b| b.addr).collect()
    }

    /// Run the accept loop on the calling thread until shutdown is
    /// flagged. Each connection is handled on its own thread with the
    /// same keep-alive semantics as the serve process.
    pub fn run(self) -> Result<()> {
        let state = self.state;
        for conn in self.listener.incoming() {
            if state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            let st = state.clone();
            std::thread::spawn(move || handle_connection(&st, stream));
        }
        Ok(())
    }

    /// Run on a background thread; the handle stops it.
    pub fn spawn(self) -> RouterHandle {
        let addr = self.addr;
        let state = self.state.clone();
        let join = std::thread::spawn(move || {
            let _ = self.run();
        });
        RouterHandle { addr, state, join }
    }
}

fn handle_connection(st: &Arc<RouterState>, stream: TcpStream) {
    let slow_ms = match st.slow_ms.load(Ordering::Relaxed) {
        0 => None,
        ms => Some(ms),
    };
    super::connection_loop(stream, &st.stats, slow_ms, |req| Ok(route(st, req)));
}

fn route(st: &RouterState, req: &http::Request) -> Response {
    // Relay the ingress request id on every backend hop, marked
    // generated: the replica echoes it in its header and slow-query
    // log but leaves the body alone — the router's connection loop
    // does the (single) body echo for client-supplied ids.
    let rid = req.request_id.as_deref();
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            st.stats.healthz.fetch_add(1, Ordering::Relaxed);
            Response::ok(healthz_body(st))
        }
        ("GET", "/stats") => {
            st.stats.stats.fetch_add(1, Ordering::Relaxed);
            Response::ok(merged_stats(st))
        }
        ("GET", "/metrics") => merged_metrics(st),
        ("GET", "/debug/trace") => Response::ok(obs::recent_events_json()),
        ("POST", "/admin/reload") => reload_fleet(st, rid),
        ("POST", "/predict") => {
            st.stats.predict.fetch_add(1, Ordering::Relaxed);
            note_predict_budget(st, &req.body);
            forward(st, rr_next(st), "/predict", &req.body, rid)
        }
        ("POST", "/embed") => {
            st.stats.embed.fetch_add(1, Ordering::Relaxed);
            forward(st, rr_next(st), "/embed", &req.body, rid)
        }
        ("POST", "/neighbors") => {
            st.stats.neighbors.fetch_add(1, Ordering::Relaxed);
            // Row-mode lookups pin to the range owner; OOS queries (or
            // anything unparseable — the backend's 400 must match a
            // direct request's) round-robin.
            let start = row_owner(st, &req.body).unwrap_or_else(|| rr_next(st));
            forward(st, start, "/neighbors", &req.body, rid)
        }
        (m, p) => unroutable(m, p),
    }
}

fn rr_next(st: &RouterState) -> usize {
    st.rr.fetch_add(1, Ordering::Relaxed) % st.backends.len()
}

/// Tally the tier a `/predict` body *requests* in the router's own
/// stats. The router forwards the body verbatim and cannot see which
/// tier the backend ultimately serves (`auto` resolves against the
/// backend's local queue), so: `full`/`cheap` count as the requested
/// tier, `auto` counts only `predict_auto`, and malformed bodies or
/// unknown budgets count nothing — the backend's 400 is authoritative.
/// Fleet-wide served-by-tier truth lives in the backends' counters,
/// which `/stats` sums under `"totals"`.
fn note_predict_budget(st: &RouterState, body: &[u8]) {
    let Some(j) = std::str::from_utf8(body).ok().and_then(|text| Json::parse(text).ok())
    else {
        return;
    };
    match j.get("budget").and_then(Json::as_str) {
        None => st.stats.predict_full.fetch_add(1, Ordering::Relaxed),
        Some("full") => st.stats.predict_full.fetch_add(1, Ordering::Relaxed),
        Some("cheap") => st.stats.predict_cheap.fetch_add(1, Ordering::Relaxed),
        Some("auto") => st.stats.predict_auto.fetch_add(1, Ordering::Relaxed),
        Some(_) => 0,
    };
}

/// The backend owning the `"row"` in a row-mode `/neighbors` body, or
/// `None` for OOS queries, malformed bodies, and out-of-range rows.
fn row_owner(st: &RouterState, body: &[u8]) -> Option<usize> {
    let text = std::str::from_utf8(body).ok()?;
    let j = Json::parse(text).ok()?;
    let row = j.get("row")?.as_usize()?;
    st.backends.iter().position(|b| b.rows.contains(&row))
}

fn reason_for(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        502 => "Bad Gateway",
        _ => "Error",
    }
}

/// Forward one request, starting at backend `start` and failing over
/// replica by replica. The backend's response body is relayed
/// **verbatim** — routed answers are byte-identical to direct ones.
/// Only the read endpoints go through here (retry/failover is safe for
/// them); `/admin/reload` mutates and takes [`reload_fleet`] instead.
fn forward(st: &RouterState, start: usize, path: &str, body: &[u8], rid: Option<&str>) -> Response {
    let body = match std::str::from_utf8(body) {
        Ok(s) => s,
        Err(_) => {
            st.stats.errors.fetch_add(1, Ordering::Relaxed);
            return Response::bad_request("request body is not UTF-8");
        }
    };
    let rid_fwd = rid.map(|r| (r, true));
    let nb = st.backends.len();
    for attempt in 0..nb {
        let backend = &st.backends[(start + attempt) % nb];
        match backend.pool.request_fwd("POST", path, body, rid_fwd, true) {
            Ok((status, resp)) => {
                return Response { status, reason: reason_for(status), body: resp }
            }
            // Transport failure (replica down/restarting): every
            // endpoint is a read, so retrying on a sibling is safe.
            Err(_) => continue,
        }
    }
    st.stats.errors.fetch_add(1, Ordering::Relaxed);
    Response {
        status: 502,
        reason: "Bad Gateway",
        body: format!("{{\"error\": \"all {nb} backend replica(s) unreachable\"}}"),
    }
}

/// `POST /admin/reload` at the router: a **rolling** reload — backends
/// are reloaded one at a time, in roster order, so at every instant the
/// rest of the fleet is serving and the round-robin failover keeps
/// queries flowing (zero dropped requests across the swap). Each
/// backend call is **non-retrying** ([`ClientPool::request_once`]):
/// reload is not idempotent-safe to resend blindly — a lost response
/// may still have applied, and a blind retry would bump the generation
/// twice. 200 only when every backend reloaded; otherwise 502 with the
/// per-backend outcomes.
fn reload_fleet(st: &RouterState, rid: Option<&str>) -> Response {
    let rid_fwd = rid.map(|r| (r, true));
    let mut all_ok = true;
    let mut out = String::from("[");
    for (i, b) in st.backends.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        match b.pool.request_fwd("POST", "/admin/reload", "", rid_fwd, false) {
            Ok((status, body)) => {
                if status != 200 {
                    all_ok = false;
                }
                out.push_str(&format!(
                    "{{\"addr\": \"{}\", \"status\": {status}, \"response\": {body}}}",
                    b.addr
                ));
            }
            Err(e) => {
                all_ok = false;
                out.push_str(&format!(
                    "{{\"addr\": \"{}\", \"error\": {}}}",
                    b.addr,
                    json_escape(&e.to_string())
                ));
            }
        }
    }
    out.push(']');
    if !all_ok {
        st.stats.errors.fetch_add(1, Ordering::Relaxed);
    }
    let status = if all_ok { 200 } else { 502 };
    Response {
        status,
        reason: reason_for(status),
        body: format!("{{\"role\": \"router\", \"reload\": {out}}}"),
    }
}

/// `GET /metrics` at the router: the fleet-wide merged exposition.
/// Each backend's `/metrics` is scraped over the pooled connections,
/// parsed, and merged — counters and histograms **sum** across
/// replicas, gauges stay **per-replica** behind a `backend` label
/// (summing a queue depth across replicas would be a lie). Only the
/// data plane is merged: the router's own counters live in its
/// `/stats` document, so a router colocated with its backends (tests)
/// never double-counts. Unreachable or malformed backends are skipped;
/// the merged exposition stays valid.
fn merged_metrics(st: &RouterState) -> Response {
    let mut scrapes: Vec<(String, obs::Scrape)> = Vec::new();
    for b in &st.backends {
        if let Ok((200, body)) = b.pool.request("GET", "/metrics", "") {
            if let Ok(s) = obs::parse_prometheus(&body) {
                scrapes.push((b.addr.to_string(), s));
            }
        }
    }
    Response::ok(obs::merge_prometheus(&scrapes))
}

fn healthz_body(st: &RouterState) -> String {
    let mut backends = String::from("[");
    for (i, b) in st.backends.iter().enumerate() {
        if i > 0 {
            backends.push_str(", ");
        }
        backends.push_str(&format!(
            "{{\"addr\": \"{}\", \"rows\": [{}, {}]}}",
            b.addr, b.rows.start, b.rows.end
        ));
    }
    backends.push(']');
    format!(
        "{{\"status\": \"ok\", \"role\": \"router\", \"n\": {}, \"kind\": \"{}\", \
         \"backends\": {backends}, \"uptime_secs\": {}, \"version\": {}, \
         \"git_sha\": {}}}",
        st.n,
        st.kind,
        obs::uptime_secs() as u64,
        json_escape(obs::build_version()),
        json_escape(obs::build_sha()),
    )
}

/// The merged `GET /stats` document: the router's own counters, the
/// fleet-wide counter totals, and each backend's full document.
fn merged_stats(st: &RouterState) -> String {
    let mut docs: Vec<Json> = Vec::with_capacity(st.backends.len());
    let mut per_backend = String::from("[");
    for (i, b) in st.backends.iter().enumerate() {
        if i > 0 {
            per_backend.push_str(", ");
        }
        match b.pool.request("GET", "/stats", "") {
            Ok((200, body)) => {
                per_backend
                    .push_str(&format!("{{\"addr\": \"{}\", \"stats\": {body}}}", b.addr));
                if let Ok(j) = Json::parse(&body) {
                    docs.push(j);
                }
            }
            _ => {
                per_backend.push_str(&format!(
                    "{{\"addr\": \"{}\", \"error\": \"unreachable\"}}",
                    b.addr
                ));
            }
        }
    }
    per_backend.push(']');
    format!(
        "{{\"role\": \"router\", \"router\": {}, \"totals\": {}, \"backends\": {per_backend}}}",
        st.stats.to_json(),
        merge_counter_totals(&docs),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_ranges_tile_exactly() {
        for (n, parts) in [(10, 3), (7, 2), (160, 4), (5, 8), (1, 1)] {
            let ranges = row_ranges(n, parts);
            assert_eq!(ranges.len(), parts);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges[parts - 1].end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "n={n} parts={parts}");
            }
        }
    }

    #[test]
    fn reason_strings_cover_the_relayed_statuses() {
        assert_eq!(reason_for(200), "OK");
        assert_eq!(reason_for(400), "Bad Request");
        assert_eq!(reason_for(405), "Method Not Allowed");
        assert_eq!(reason_for(418), "Error");
    }
}
