//! The weight assignments (q, w) of App. B.
//!
//! Each proximity is two `N×T` weight tables; zeros encode "no
//! contribution" and are *dropped* from the sparse factors, which is
//! where the extra sparsity of the OOB-querying schemes comes from
//! (Remark 3.8 / the Fig. 4.2-middle ordering).

use super::context::EnsembleContext;
use super::ProximityKind;

/// Dense `N×T` weight tables for the two proximity arguments.
/// `q[i*T + t] = q_t(x_i)`, likewise for `w`.
pub struct WeightSpec {
    pub q: Vec<f32>,
    pub w: Vec<f32>,
    pub symmetric: bool,
}

/// Compute the training-set weight tables for `kind` (App. B).
pub fn assign(kind: ProximityKind, ctx: &EnsembleContext) -> WeightSpec {
    if kind.needs_bootstrap() {
        assert!(
            ctx.has_bootstrap(),
            "{:?} requires a bootstrap ensemble (RandomForest); \
             ExtraTrees/GBT have no OOB samples",
            kind
        );
    }
    let (n, t) = (ctx.n, ctx.t);
    let nt = n * t;
    match kind {
        ProximityKind::Original => {
            let v = 1.0 / (t as f32).sqrt();
            let q = vec![v; nt];
            WeightSpec { w: q.clone(), q, symmetric: true }
        }
        ProximityKind::Kerf => {
            let tf = t as f32;
            let mut q = vec![0f32; nt];
            for i in 0..n {
                for tt in 0..t {
                    let m = ctx.leaf_mass[ctx.leaf(i, tt) as usize];
                    q[i * t + tt] = 1.0 / (tf * m).sqrt();
                }
            }
            WeightSpec { w: q.clone(), q, symmetric: true }
        }
        ProximityKind::OobSeparable => {
            // q_t(x) = w_t(x) = o_t(x)·√T / S(x)  (App. G); samples that
            // are never OOB contribute nothing.
            let sqrt_t = (t as f32).sqrt();
            let mut q = vec![0f32; nt];
            for i in 0..n {
                let s = ctx.oob_count[i];
                if s == 0 {
                    continue;
                }
                let v = sqrt_t / s as f32;
                for tt in 0..t {
                    if ctx.is_oob(i, tt) {
                        q[i * t + tt] = v;
                    }
                }
            }
            WeightSpec { w: q.clone(), q, symmetric: true }
        }
        ProximityKind::RfGap => {
            // q_t(x) = o_t(x)/S(x): query side looks from OOB trees.
            // w_t(x) = c_t(x)/M_inbag(ℓ_t(x)): reference side carries
            // in-bag multiplicity over in-bag leaf mass.
            let mut q = vec![0f32; nt];
            let mut w = vec![0f32; nt];
            for i in 0..n {
                let s = ctx.oob_count[i];
                for tt in 0..t {
                    let c = ctx.inbag(i, tt);
                    if c == 0 {
                        if s > 0 {
                            q[i * t + tt] = 1.0 / s as f32;
                        }
                    } else {
                        let m = ctx.inbag_mass[ctx.leaf(i, tt) as usize];
                        w[i * t + tt] = c as f32 / m;
                    }
                }
            }
            WeightSpec { q, w, symmetric: false }
        }
        ProximityKind::InstanceHardness => {
            let q = vec![1.0 / t as f32; nt];
            let dis = ctx.leaf_disagreement();
            let w: Vec<f32> = dis.into_iter().map(|d| 1.0 - d).collect();
            WeightSpec { q, w, symmetric: false }
        }
        ProximityKind::Boosted => {
            let total: f32 = ctx.tree_weights.iter().sum();
            assert!(total > 0.0, "boosted proximity needs positive tree weights");
            let mut q = vec![0f32; nt];
            for i in 0..n {
                for tt in 0..t {
                    q[i * t + tt] = (ctx.tree_weights[tt] / total).sqrt();
                }
            }
            WeightSpec { w: q.clone(), q, symmetric: true }
        }
    }
}

/// OOS query-side weights for `n_new` unseen samples (Remark 3.9 and the
/// query/reference convention of Lemma 3.5). Unseen samples are treated
/// as OOB in every tree: `o_t = 1`, `S = T`.
///
/// `leaf_of_new` is the routed sample-major `n_new × T` global leaf
/// table of the new samples.
pub fn assign_oos_query(
    kind: ProximityKind,
    ctx: &EnsembleContext,
    leaf_of_new: &[u32],
    n_new: usize,
) -> Vec<f32> {
    let t = ctx.t;
    assert_eq!(leaf_of_new.len(), n_new * t);
    match kind {
        ProximityKind::Original => vec![1.0 / (t as f32).sqrt(); n_new * t],
        ProximityKind::Kerf => {
            let tf = t as f32;
            let mut q = vec![0f32; n_new * t];
            for i in 0..n_new {
                for tt in 0..t {
                    // Leaf mass of the *training* population in that leaf;
                    // empty leaves cannot occur (every leaf holds >= 1
                    // training sample by construction).
                    let m = ctx.leaf_mass[leaf_of_new[i * t + tt] as usize].max(1.0);
                    q[i * t + tt] = 1.0 / (tf * m).sqrt();
                }
            }
            q
        }
        // OOB everywhere ⇒ o_t = 1, S = T ⇒ √T/T = 1/√T.
        ProximityKind::OobSeparable => vec![1.0 / (t as f32).sqrt(); n_new * t],
        // RF-GAP query: o_t/S = 1/T.
        ProximityKind::RfGap => vec![1.0 / t as f32; n_new * t],
        ProximityKind::InstanceHardness => vec![1.0 / t as f32; n_new * t],
        ProximityKind::Boosted => {
            let total: f32 = ctx.tree_weights.iter().sum();
            let mut q = vec![0f32; n_new * t];
            for i in 0..n_new {
                for tt in 0..t {
                    q[i * t + tt] = (ctx.tree_weights[tt] / total).sqrt();
                }
            }
            q
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{Criterion, Forest, ForestKind, TrainConfig};

    fn ctx_rf(n: usize, seed: u64) -> EnsembleContext {
        let data = synth::gaussian_blobs(n, 4, 3, 2.0, seed);
        let f = Forest::train(&data, &TrainConfig { n_trees: 10, seed, ..Default::default() });
        EnsembleContext::build(&f, &data)
    }

    #[test]
    fn original_weights_constant() {
        let ctx = ctx_rf(80, 1);
        let ws = assign(ProximityKind::Original, &ctx);
        assert!(ws.symmetric);
        let expect = 1.0 / (10f32).sqrt();
        assert!(ws.q.iter().all(|&v| (v - expect).abs() < 1e-7));
        assert_eq!(ws.q, ws.w);
    }

    #[test]
    fn kerf_product_recovers_definition() {
        // On a collision, q_t(x)·w_t(x') must equal 1/(T·M(leaf)).
        let ctx = ctx_rf(60, 2);
        let ws = assign(ProximityKind::Kerf, &ctx);
        let (i, tt) = (3, 4);
        let m = ctx.leaf_mass[ctx.leaf(i, tt) as usize];
        let prod = ws.q[i * ctx.t + tt] * ws.w[i * ctx.t + tt];
        assert!((prod - 1.0 / (ctx.t as f32 * m)).abs() < 1e-7);
    }

    #[test]
    fn oob_weights_zero_when_inbag() {
        let ctx = ctx_rf(100, 3);
        let ws = assign(ProximityKind::OobSeparable, &ctx);
        for i in 0..ctx.n {
            for tt in 0..ctx.t {
                let v = ws.q[i * ctx.t + tt];
                if ctx.is_oob(i, tt) {
                    assert!(v > 0.0);
                    assert!((v - (ctx.t as f32).sqrt() / ctx.oob_count[i] as f32).abs() < 1e-6);
                } else {
                    assert_eq!(v, 0.0);
                }
            }
        }
    }

    #[test]
    fn gap_sides_are_disjoint_per_tree() {
        // In a given tree a sample is either OOB (query side active) or
        // in-bag (reference side active), never both.
        let ctx = ctx_rf(100, 4);
        let ws = assign(ProximityKind::RfGap, &ctx);
        assert!(!ws.symmetric);
        for k in 0..ctx.n * ctx.t {
            assert!(ws.q[k] == 0.0 || ws.w[k] == 0.0);
        }
    }

    #[test]
    fn gap_query_rows_sum_to_one_when_oob() {
        let ctx = ctx_rf(100, 5);
        let ws = assign(ProximityKind::RfGap, &ctx);
        for i in 0..ctx.n {
            let s: f32 = (0..ctx.t).map(|tt| ws.q[i * ctx.t + tt]).sum();
            if ctx.oob_count[i] > 0 {
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            } else {
                assert_eq!(s, 0.0);
            }
        }
    }

    #[test]
    fn ih_reference_weights_in_unit_interval() {
        let ctx = ctx_rf(120, 6);
        let ws = assign(ProximityKind::InstanceHardness, &ctx);
        assert!(ws.w.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ws.q.iter().all(|&v| (v - 0.1).abs() < 1e-7));
    }

    #[test]
    fn boosted_weights_squared_sum_to_one() {
        let data = synth::gaussian_blobs(150, 4, 2, 2.0, 7);
        let f = Forest::train(
            &data,
            &TrainConfig {
                kind: ForestKind::GradientBoosting,
                n_trees: 8,
                max_depth: Some(3),
                criterion: Criterion::Mse,
                seed: 8,
                ..Default::default()
            },
        );
        let ctx = EnsembleContext::build(&f, &data);
        let ws = assign(ProximityKind::Boosted, &ctx);
        let sumsq: f32 = (0..ctx.t).map(|tt| ws.q[tt] * ws.q[tt]).sum();
        assert!((sumsq - 1.0).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "requires a bootstrap")]
    fn gap_rejects_extratrees() {
        let data = synth::gaussian_blobs(80, 4, 2, 2.0, 9);
        let f = Forest::train(
            &data,
            &TrainConfig { kind: ForestKind::ExtraTrees, n_trees: 4, seed: 1, ..Default::default() },
        );
        let ctx = EnsembleContext::build(&f, &data);
        assign(ProximityKind::RfGap, &ctx);
    }

    #[test]
    fn oos_query_weights_shapes_and_values() {
        let ctx = ctx_rf(60, 10);
        let leaf_new: Vec<u32> = ctx.leaf_of[..5 * ctx.t].to_vec();
        for kind in ProximityKind::ALL {
            if kind == ProximityKind::Boosted {
                continue; // tree_weights all 1 here; still fine but tested above
            }
            let q = assign_oos_query(kind, &ctx, &leaf_new, 5);
            assert_eq!(q.len(), 5 * ctx.t);
            assert!(q.iter().all(|&v| v > 0.0));
        }
    }
}
