//! Separable Weighted Leaf-Collision (SWLC) proximities — the paper's
//! contribution.
//!
//! Definition 3.1: `P_{q,w}(x, x') = Σ_t q_t(x) w_t(x') 1[ℓ_t(x) = ℓ_t(x')]`.
//!
//! * [`context`] — the ensemble context `θ`: leaf maps, leaf masses,
//!   in-bag multiplicities, OOB counts, tree weights (§2.2).
//! * [`weights`] — the weight assignments `(q, w)` of App. B for each
//!   supported proximity.
//! * [`kernel`] — leaf-incidence factors `Q, W` (Def. 3.3) and the exact
//!   sparse factorization `P = Q Wᵀ` (Prop. 3.6; row-major sample
//!   convention), including out-of-sample extension (Remark 3.9).
//! * [`naive`] — the O(N²T) all-pairs baselines, including the exact
//!   non-separable OOB proximity (App. B.3) and the Fig. 4.1 ratio
//!   statistics.
//! * [`predict`] — proximity-weighted prediction (App. I) straight from
//!   the factors, never materializing P.
//! * [`custom`] — §5 extensions: user-defined SWLC kernels,
//!   impurity-enriched proximities, learned tree reweighting.

pub mod context;
pub mod custom;
pub mod kernel;
pub mod naive;
pub mod predict;
pub mod weights;

pub use context::EnsembleContext;
pub use kernel::{ForestKernel, QuantizedFactors};
pub use weights::WeightSpec;

/// Which SWLC proximity to build (App. B). OOB here is the *separable*
/// surrogate `P̃_oob` of App. G; the exact pair-normalized OOB proximity
/// is available as a baseline in [`naive`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProximityKind {
    /// Breiman's original proximity: `q = w = 1/√T` (App. B.1).
    Original,
    /// KeRF leaf-mass normalization: `q = w = 1/√(T·M(ℓ))` (App. B.2).
    Kerf,
    /// Separable OOB surrogate: `q = w = o_t(x)·√T / S(x)` (App. G).
    OobSeparable,
    /// RF-GAP: `q = o_t(x)/S(x)`, `w = c_t(x)/M_inbag(ℓ_t(x))` (App. B.4).
    RfGap,
    /// Instance-hardness reweighting: `q = 1/T`, `w = 1 - kDN_t(x)`
    /// (App. B.5; see [`context`] for the leaf-neighborhood kDN we use).
    InstanceHardness,
    /// Boosted-tree proximity: `q = w = √(w_t/Σ_s w_s)` (App. B.6).
    Boosted,
}

impl ProximityKind {
    pub const ALL: [ProximityKind; 6] = [
        ProximityKind::Original,
        ProximityKind::Kerf,
        ProximityKind::OobSeparable,
        ProximityKind::RfGap,
        ProximityKind::InstanceHardness,
        ProximityKind::Boosted,
    ];

    /// `q == w` ⇒ Gram kernel, symmetric PSD (Cor. 3.7).
    pub fn symmetric(&self) -> bool {
        matches!(
            self,
            ProximityKind::Original
                | ProximityKind::Kerf
                | ProximityKind::OobSeparable
                | ProximityKind::Boosted
        )
    }

    /// Whether the scheme needs bootstrap (in-bag/OOB) bookkeeping.
    pub fn needs_bootstrap(&self) -> bool {
        matches!(self, ProximityKind::OobSeparable | ProximityKind::RfGap)
    }

    pub fn name(&self) -> &'static str {
        match self {
            ProximityKind::Original => "original",
            ProximityKind::Kerf => "kerf",
            ProximityKind::OobSeparable => "oob",
            ProximityKind::RfGap => "gap",
            ProximityKind::InstanceHardness => "ih",
            ProximityKind::Boosted => "boosted",
        }
    }

    pub fn from_name(name: &str) -> Option<ProximityKind> {
        Self::ALL.into_iter().find(|k| k.name() == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for k in ProximityKind::ALL {
            assert_eq!(ProximityKind::from_name(k.name()), Some(k));
        }
        assert_eq!(ProximityKind::from_name("bogus"), None);
    }

    #[test]
    fn symmetry_flags_match_appendix_b() {
        assert!(ProximityKind::Original.symmetric());
        assert!(ProximityKind::Kerf.symmetric());
        assert!(ProximityKind::OobSeparable.symmetric());
        assert!(ProximityKind::Boosted.symmetric());
        assert!(!ProximityKind::RfGap.symmetric());
        assert!(!ProximityKind::InstanceHardness.symmetric());
    }
}
