//! The ensemble context θ of §2.2.
//!
//! Everything the App. B weight assignments need, computed in one
//! routing pass over the training set plus local leaf aggregation —
//! cost `O(NT h̄) + O(NT)`, never quadratic (§3.3):
//!
//! * global leaf ids `ℓ_t(x_i)` (sample-major `N×T`),
//! * leaf masses `M(j)` (training samples per leaf; KeRF),
//! * in-bag leaf masses `M_inbag(j)` (bootstrap draws per leaf; RF-GAP),
//! * in-bag multiplicities `c_t(x_i)` and OOB tree counts `S(x_i)`,
//! * per-tree additive weights (boosted proximity),
//! * per-(sample, tree) leaf label-disagreement (our kDN_t; App. B.5).

use crate::data::Dataset;
use crate::forest::Forest;
use crate::sparse::Buf;

/// Ensemble context θ for a trained forest over its training set.
///
/// The per-sample/per-leaf arrays are [`Buf`]s so a mapped
/// `fk-bundle-v3` can serve them zero-copy; every constructor in this
/// module builds owned vectors.
pub struct EnsembleContext {
    pub n: usize,
    pub t: usize,
    /// Total number of leaves L across the ensemble.
    pub l: usize,
    /// Sample-major `N×T` global leaf ids: `leaf_of[i*T + t] = ℓ_t(x_i)`.
    pub leaf_of: Buf<u32>,
    /// `M(j)`: number of training samples routed to leaf j (length L).
    pub leaf_mass: Buf<f32>,
    /// `M_inbag(j)`: bootstrap draws in leaf j (length L). Equals
    /// `leaf_mass` when the ensemble has no bootstrap.
    pub inbag_mass: Buf<f32>,
    /// `c_t(x_i)` in sample-major `N×T`; empty ⇒ no bootstrap (every
    /// sample in-bag once, never OOB).
    pub inbag_count: Buf<u16>,
    /// `S(x_i) = Σ_t o_t(x_i)`: number of trees where sample i is OOB.
    pub oob_count: Buf<u32>,
    /// Additive model weights `w_t` (GBT; all 1 for bagged kinds).
    pub tree_weights: Buf<f32>,
    /// Training labels as class ids (classification) — used by kDN and
    /// proximity-weighted prediction. Empty for regression.
    pub y: Buf<u32>,
    pub n_classes: usize,
}

impl EnsembleContext {
    /// Build the context by routing `data` (the training set) through
    /// `forest` and aggregating leaf statistics.
    pub fn build(forest: &Forest, data: &Dataset) -> EnsembleContext {
        assert_eq!(
            forest.n_train, data.n,
            "context must be built on the forest's training set"
        );
        let (n, t) = (data.n, forest.n_trees());
        let l = forest.n_leaves_total();
        let leaf_of = forest.apply(data);

        let mut leaf_mass = vec![0f32; l];
        for i in 0..n {
            for &g in &leaf_of[i * t..(i + 1) * t] {
                leaf_mass[g as usize] += 1.0;
            }
        }

        // Flatten per-tree in-bag vectors to sample-major N×T and
        // accumulate in-bag leaf masses.
        let (inbag_count, inbag_mass, oob_count) = if forest.inbag.is_empty() {
            (vec![], leaf_mass.clone(), vec![0u32; n])
        } else {
            let mut ib = vec![0u16; n * t];
            let mut im = vec![0f32; l];
            let mut oob = vec![0u32; n];
            for (tt, bag) in forest.inbag.iter().enumerate() {
                for i in 0..n {
                    let c = bag[i];
                    ib[i * t + tt] = c;
                    if c == 0 {
                        oob[i] += 1;
                    } else {
                        im[leaf_of[i * t + tt] as usize] += c as f32;
                    }
                }
            }
            (ib, im, oob)
        };

        let y: Vec<u32> = if data.n_classes > 0 {
            data.y.iter().map(|&v| v as u32).collect()
        } else {
            vec![]
        };

        EnsembleContext {
            n,
            t,
            l,
            leaf_of: leaf_of.into(),
            leaf_mass: leaf_mass.into(),
            inbag_mass: inbag_mass.into(),
            inbag_count: inbag_count.into(),
            oob_count: oob_count.into(),
            tree_weights: forest.tree_weights.clone().into(),
            y: y.into(),
            n_classes: data.n_classes,
        }
    }

    /// Global leaf id of sample `i` in tree `t`.
    #[inline]
    pub fn leaf(&self, i: usize, t: usize) -> u32 {
        self.leaf_of[i * self.t + t]
    }

    /// OOB indicator `o_t(x_i)`. Without bootstrap bookkeeping every
    /// sample is in-bag, so this is `false`.
    #[inline]
    pub fn is_oob(&self, i: usize, t: usize) -> bool {
        !self.inbag_count.is_empty() && self.inbag_count[i * self.t + t] == 0
    }

    /// In-bag multiplicity `c_t(x_i)` (1 when there is no bootstrap).
    #[inline]
    pub fn inbag(&self, i: usize, t: usize) -> u16 {
        if self.inbag_count.is_empty() {
            1
        } else {
            self.inbag_count[i * self.t + t]
        }
    }

    /// Whether bootstrap (in-bag/OOB) information is available.
    pub fn has_bootstrap(&self) -> bool {
        !self.inbag_count.is_empty()
    }

    /// Per-(sample, tree) leaf label-disagreement `kDN_t(x_i)` — our
    /// tree-local instance-hardness score (App. B.5): the fraction of
    /// *other* training samples in `x_i`'s leaf of tree `t` whose label
    /// differs. RFProxIH defines kDN via k-NN in the subspace of the
    /// decision path's split features; we use the leaf population itself
    /// as the tree-dependent neighborhood (DESIGN.md §Substitutions) —
    /// it is the neighborhood the tree actually induces and needs no
    /// extra parameter k.
    pub fn leaf_disagreement(&self) -> Vec<f32> {
        assert!(self.n_classes > 0, "kDN needs class labels");
        // Per-leaf class histograms.
        let c = self.n_classes;
        let mut hist = vec![0f32; self.l * c];
        for i in 0..self.n {
            let yi = self.y[i] as usize;
            for tt in 0..self.t {
                hist[self.leaf(i, tt) as usize * c + yi] += 1.0;
            }
        }
        let mut out = vec![0f32; self.n * self.t];
        for i in 0..self.n {
            let yi = self.y[i] as usize;
            for tt in 0..self.t {
                let g = self.leaf(i, tt) as usize;
                let same = hist[g * c + yi];
                let total = self.leaf_mass[g];
                out[i * self.t + tt] = if total > 1.0 {
                    (total - same) / (total - 1.0)
                } else {
                    0.0
                };
            }
        }
        out
    }

    /// Average leaf-collision factor λ̄ of §3.3: mean over (sample, tree)
    /// of the population of the sample's leaf. This is the quantity that
    /// drives the sparse-product cost `O(NT λ̄)`.
    pub fn mean_lambda(&self) -> f64 {
        let mut acc = 0f64;
        for &g in &self.leaf_of {
            acc += self.leaf_mass[g as usize] as f64;
        }
        acc / (self.n * self.t) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{ForestKind, TrainConfig};

    fn fixture(n: usize) -> (Forest, Dataset) {
        let data = synth::gaussian_blobs(n, 4, 3, 3.5, 5);
        let f = Forest::train(&data, &TrainConfig { n_trees: 12, seed: 6, ..Default::default() });
        (f, data)
    }

    #[test]
    fn leaf_mass_sums_to_n_per_tree() {
        let (f, data) = fixture(200);
        let ctx = EnsembleContext::build(&f, &data);
        for t in 0..ctx.t {
            let (lo, hi) = (f.leaf_offsets[t] as usize, f.leaf_offsets[t + 1] as usize);
            let mass: f32 = ctx.leaf_mass[lo..hi].iter().sum();
            assert_eq!(mass, 200.0);
        }
    }

    #[test]
    fn inbag_mass_sums_to_draws_per_tree() {
        let (f, data) = fixture(150);
        let ctx = EnsembleContext::build(&f, &data);
        for t in 0..ctx.t {
            let (lo, hi) = (f.leaf_offsets[t] as usize, f.leaf_offsets[t + 1] as usize);
            let mass: f32 = ctx.inbag_mass[lo..hi].iter().sum();
            assert_eq!(mass, 150.0);
        }
    }

    #[test]
    fn oob_counts_match_inbag_zeros() {
        let (f, data) = fixture(100);
        let ctx = EnsembleContext::build(&f, &data);
        for i in 0..ctx.n {
            let manual = (0..ctx.t).filter(|&t| ctx.inbag(i, t) == 0).count() as u32;
            assert_eq!(ctx.oob_count[i], manual);
            for t in 0..ctx.t {
                assert_eq!(ctx.is_oob(i, t), ctx.inbag(i, t) == 0);
            }
        }
    }

    #[test]
    fn extratrees_context_has_no_bootstrap() {
        let data = synth::gaussian_blobs(120, 4, 2, 2.0, 7);
        let f = Forest::train(
            &data,
            &TrainConfig { kind: ForestKind::ExtraTrees, n_trees: 6, seed: 8, ..Default::default() },
        );
        let ctx = EnsembleContext::build(&f, &data);
        assert!(!ctx.has_bootstrap());
        assert!(ctx.oob_count.iter().all(|&s| s == 0));
        assert_eq!(ctx.inbag(3, 2), 1);
        assert_eq!(ctx.inbag_mass, ctx.leaf_mass);
    }

    #[test]
    fn disagreement_in_unit_interval_and_low_on_pure_leaves() {
        let (f, data) = fixture(250);
        let ctx = EnsembleContext::build(&f, &data);
        let dis = ctx.leaf_disagreement();
        assert!(dis.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // Unconstrained trees on separable blobs are grown ~pure on the
        // bootstrap; the full-population disagreement stays small (a few
        // stray OOB points per leaf at most).
        let mean = dis.iter().sum::<f32>() / dis.len() as f32;
        assert!(mean < 0.1, "mean disagreement {mean}");
    }

    #[test]
    fn mean_lambda_at_least_one() {
        let (f, data) = fixture(150);
        let ctx = EnsembleContext::build(&f, &data);
        assert!(ctx.mean_lambda() >= 1.0);
        assert!(ctx.mean_lambda() <= 150.0);
    }

    #[test]
    #[should_panic(expected = "training set")]
    fn rejects_wrong_dataset_size() {
        let (f, _) = fixture(100);
        let other = synth::gaussian_blobs(50, 4, 3, 2.0, 9);
        EnsembleContext::build(&f, &other);
    }
}
