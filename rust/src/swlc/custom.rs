//! Extensions from the paper's §5 ("the framework opens several natural
//! directions"): user-defined SWLC kernels, impurity-enriched
//! proximities, and learned tree reweighting on a fixed forest topology
//! (forest-based kernel learning à la multiple-kernel learning).
//!
//! Everything here stays inside the SWLC family — a custom kernel is
//! just another `(q, w)` assignment — so the sparse factorization,
//! OOS extension, and prediction machinery apply unchanged.

use super::context::EnsembleContext;
use super::kernel::incidence_matrix;
use super::weights::WeightSpec;
use crate::sparse::{spgemm, Csr};

/// A user-defined SWLC proximity: any per-(sample, tree) weight pair.
///
/// `q_fn`/`w_fn` receive `(sample, tree, &context)` and return the
/// weight; zeros are dropped from the factors (Remark 3.8 sparsity).
pub struct CustomKernel;

impl CustomKernel {
    /// Build the weight tables from closures.
    pub fn assign(
        ctx: &EnsembleContext,
        q_fn: impl Fn(usize, usize, &EnsembleContext) -> f32,
        w_fn: impl Fn(usize, usize, &EnsembleContext) -> f32,
        symmetric: bool,
    ) -> WeightSpec {
        let (n, t) = (ctx.n, ctx.t);
        let mut q = vec![0f32; n * t];
        let mut w = vec![0f32; n * t];
        for i in 0..n {
            for tt in 0..t {
                q[i * t + tt] = q_fn(i, tt, ctx);
                w[i * t + tt] = if symmetric { q[i * t + tt] } else { w_fn(i, tt, ctx) };
            }
        }
        WeightSpec { q, w, symmetric }
    }

    /// Factor a custom weight spec into `(Q, Wᵀ)` and the kernel
    /// `P = Q Wᵀ` (Prop. 3.6 for the custom member of the family).
    pub fn factor(ctx: &EnsembleContext, spec: &WeightSpec) -> (Csr, Csr) {
        let q = incidence_matrix(&ctx.leaf_of, &spec.q, ctx.n, ctx.t, ctx.l);
        let w = if spec.symmetric {
            q.clone()
        } else {
            incidence_matrix(&ctx.leaf_of, &spec.w, ctx.n, ctx.t, ctx.l)
        };
        let wt = w.transpose();
        (q, wt)
    }

    pub fn proximity(ctx: &EnsembleContext, spec: &WeightSpec) -> Csr {
        let (q, wt) = Self::factor(ctx, spec);
        spgemm(&q, &wt)
    }
}

/// Per-leaf Gini impurity over the *training* population — the
/// "leaf-quality statistic" enrichment suggested in §5. Returns a
/// length-L vector with `1 - Σ_k p_k²` per leaf (0 = pure).
pub fn leaf_impurity(ctx: &EnsembleContext) -> Vec<f32> {
    assert!(ctx.n_classes > 0, "impurity needs class labels");
    let c = ctx.n_classes;
    let mut hist = vec![0f32; ctx.l * c];
    for i in 0..ctx.n {
        let yi = ctx.y[i] as usize;
        for tt in 0..ctx.t {
            hist[ctx.leaf(i, tt) as usize * c + yi] += 1.0;
        }
    }
    (0..ctx.l)
        .map(|g| {
            let m = ctx.leaf_mass[g];
            if m <= 0.0 {
                return 0.0;
            }
            let mut s = 0f32;
            for k in 0..c {
                let p = hist[g * c + k] / m;
                s += p * p;
            }
            1.0 - s
        })
        .collect()
}

/// Impurity-weighted KeRF (a §5 "enriched" symmetric SWLC member):
/// collisions in pure leaves count fully, impure leaves are
/// down-weighted — `q = w = √((1 - gini(ℓ)) / (T·M(ℓ)))`.
pub fn impurity_kerf(ctx: &EnsembleContext) -> WeightSpec {
    let imp = leaf_impurity(ctx);
    let tf = ctx.t as f32;
    CustomKernel::assign(
        ctx,
        move |i, tt, ctx| {
            let g = ctx.leaf(i, tt) as usize;
            let purity = (1.0 - imp[g]).max(0.0);
            (purity / (tf * ctx.leaf_mass[g])).sqrt()
        },
        |_, _, _| 0.0,
        true,
    )
}

/// Learned tree reweighting on a fixed topology (§5's "move from fixed
/// weighting rules to learned ones"): find per-tree weights `α_t ≥ 0`
/// so that the proximity-weighted predictor's class margins improve on
/// the training labels, by multiplicative (exponentiated-gradient)
/// updates — a simple multiple-kernel-learning-style scheme where each
/// tree contributes the rank-restricted kernel `K_t`.
///
/// Returns the learned `α` (mean 1) to be used as tree weights in a
/// boosted-style SWLC kernel: `q = w = √(α_t / Σ α)`.
pub fn learn_tree_weights(ctx: &EnsembleContext, epochs: usize, lr: f32) -> Vec<f32> {
    assert!(ctx.n_classes > 0);
    let (n, t, c) = (ctx.n, ctx.t, ctx.n_classes);
    // Per-tree, per-sample correctness signal: the fraction of same-leaf
    // training samples sharing the sample's label (leaf label agreement).
    // A tree whose partitions agree with the labels gets pushed up.
    let mut hist = vec![0f32; ctx.l * c];
    for i in 0..n {
        let yi = ctx.y[i] as usize;
        for tt in 0..t {
            hist[ctx.leaf(i, tt) as usize * c + yi] += 1.0;
        }
    }
    let mut alpha = vec![1f32; t];
    for _ in 0..epochs {
        // Gradient: mean margin contribution of tree t =
        //   E_i [ p_t(y_i | leaf) - max_{k≠y} p_t(k | leaf) ].
        for tt in 0..t {
            let mut g = 0f64;
            for i in 0..n {
                let leaf = ctx.leaf(i, tt) as usize;
                let m = ctx.leaf_mass[leaf].max(1.0);
                let yi = ctx.y[i] as usize;
                let own = hist[leaf * c + yi] / m;
                let mut other = 0f32;
                for k in 0..c {
                    if k != yi {
                        other = other.max(hist[leaf * c + k] / m);
                    }
                }
                g += (own - other) as f64;
            }
            let g = (g / n as f64) as f32;
            alpha[tt] *= (lr * g).exp();
        }
        // Renormalize to mean 1 (scale of the kernel is irrelevant).
        let mean: f32 = alpha.iter().sum::<f32>() / t as f32;
        for a in alpha.iter_mut() {
            *a /= mean.max(1e-12);
        }
    }
    alpha
}

/// SWLC weights from learned tree weights (symmetric, boosted-style).
pub fn learned_weight_spec(ctx: &EnsembleContext, alpha: &[f32]) -> WeightSpec {
    assert_eq!(alpha.len(), ctx.t);
    let total: f32 = alpha.iter().sum();
    let per_tree: Vec<f32> = alpha.iter().map(|&a| (a / total).max(0.0).sqrt()).collect();
    CustomKernel::assign(ctx, move |_, tt, _| per_tree[tt], |_, _, _| 0.0, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{Forest, TrainConfig};
    use crate::swlc::{naive, predict, ProximityKind};

    fn fixture(n: usize, seed: u64) -> (Forest, crate::data::Dataset) {
        let data = synth::gaussian_blobs(n, 4, 3, 2.0, seed);
        let f = Forest::train(&data, &TrainConfig { n_trees: 12, seed, ..Default::default() });
        (f, data)
    }

    #[test]
    fn custom_reproduces_original_proximity() {
        // A custom kernel with q = w = 1/√T must equal the built-in.
        let (f, data) = fixture(60, 1);
        let ctx = EnsembleContext::build(&f, &data);
        let spec = CustomKernel::assign(
            &ctx,
            |_, _, ctx| 1.0 / (ctx.t as f32).sqrt(),
            |_, _, _| 0.0,
            true,
        );
        let p = CustomKernel::proximity(&ctx, &spec).to_dense();
        let expect = naive::naive_proximity(ProximityKind::Original, &ctx);
        for (a, b) in p.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn leaf_impurity_in_unit_interval_and_low_on_separable_data() {
        let (f, data) = fixture(200, 2);
        let ctx = EnsembleContext::build(&f, &data);
        let imp = leaf_impurity(&ctx);
        assert_eq!(imp.len(), ctx.l);
        assert!(imp.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mean: f32 = imp.iter().sum::<f32>() / imp.len() as f32;
        assert!(mean < 0.3, "mean impurity {mean}");
    }

    #[test]
    fn impurity_kerf_bounded_by_kerf() {
        // Purity factor ≤ 1 ⇒ impurity-KeRF ≤ KeRF entrywise.
        let (f, data) = fixture(80, 3);
        let ctx = EnsembleContext::build(&f, &data);
        let enriched = CustomKernel::proximity(&ctx, &impurity_kerf(&ctx)).to_dense();
        let plain = naive::naive_proximity(ProximityKind::Kerf, &ctx);
        for (a, b) in enriched.iter().zip(&plain) {
            assert!(*a <= b + 1e-5, "{a} > {b}");
        }
    }

    #[test]
    fn impurity_kerf_is_symmetric_psd_swlc() {
        let (f, data) = fixture(50, 4);
        let ctx = EnsembleContext::build(&f, &data);
        let p = CustomKernel::proximity(&ctx, &impurity_kerf(&ctx)).to_dense();
        for i in 0..50 {
            for j in 0..50 {
                assert!((p[i * 50 + j] - p[j * 50 + i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn learned_weights_upweight_informative_trees() {
        // Train on data where labels are random for half the trees'
        // effective structure: simplest check — weights stay positive,
        // mean 1, and the learned kernel's training prediction is at
        // least as accurate as uniform boosted-style weights.
        let (f, data) = fixture(300, 5);
        let ctx = EnsembleContext::build(&f, &data);
        let alpha = learn_tree_weights(&ctx, 10, 0.5);
        assert_eq!(alpha.len(), ctx.t);
        assert!(alpha.iter().all(|&a| a > 0.0));
        let mean: f32 = alpha.iter().sum::<f32>() / alpha.len() as f32;
        assert!((mean - 1.0).abs() < 1e-3);

        let spec = learned_weight_spec(&ctx, &alpha);
        let q = incidence_matrix(&ctx.leaf_of, &spec.q, ctx.n, ctx.t, ctx.l);
        let m = predict::leaf_class_mass(&q, &ctx.y, ctx.n_classes);
        let scores = predict::class_scores(&q, &m, ctx.n_classes);
        let preds = predict::argmax_scores(&scores, ctx.n_classes, 0);
        let acc = predict::accuracy(&preds, &data.y);
        assert!(acc > 0.9, "learned-kernel acc {acc}");
    }

    #[test]
    fn learned_weights_deterministic() {
        let (f, data) = fixture(100, 6);
        let ctx = EnsembleContext::build(&f, &data);
        let a1 = learn_tree_weights(&ctx, 5, 0.3);
        let a2 = learn_tree_weights(&ctx, 5, 0.3);
        assert_eq!(a1, a2);
    }
}
