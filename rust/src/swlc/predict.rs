//! Proximity-weighted prediction (App. I) computed from the factors.
//!
//! Class scores are `S = P·Y = Q·(Wᵀ Y)` where `Y` is the one-hot label
//! matrix: instead of materializing the N×N kernel, we aggregate the
//! reference map into a per-leaf class-mass table `M = Wᵀ Y ∈ R^{L×C}`
//! (one pass over nnz(W)) and score queries by `Q·M` (one pass over
//! nnz(Q)) — `O(NTC)` total. For RF-GAP this reproduces the forest's
//! OOB vote ordering exactly (the defining property of [38], tested in
//! `rust/tests/proptest_swlc.rs`).

use super::kernel::ForestKernel;
use crate::bail;
use crate::coordinator::sink::KernelSource;
use crate::error::Result;
use crate::sparse::qcsr::{QCsr, QRowScratch};
use crate::sparse::Csr;

/// Per-leaf class mass `M = Wᵀ·onehot(y) ∈ R^{L×C}` (row-major).
pub fn leaf_class_mass(w: &Csr, y: &[u32], n_classes: usize) -> Vec<f32> {
    assert_eq!(w.n_rows, y.len());
    let mut m = vec![0f32; w.n_cols * n_classes];
    for j in 0..w.n_rows {
        let cls = y[j] as usize;
        let (cols, vals) = w.row(j);
        for (&leaf, &v) in cols.iter().zip(vals) {
            m[leaf as usize * n_classes + cls] += v;
        }
    }
    m
}

/// [`leaf_class_mass`] from the *quantized transpose* `Wᵀ` (L×N):
/// rows are leaves, so the class mass of leaf `ℓ` accumulates that
/// row's decoded sample weights bucketed by label. Leaf-major
/// accumulation order (deterministic, serial) — the quantized path is
/// validated on ranking/prediction quality, not bitwise against the
/// sample-major exact pass.
pub fn leaf_class_mass_q(wt: &QCsr, y: &[u32], n_classes: usize) -> Vec<f32> {
    assert_eq!(wt.n_cols, y.len());
    let mut m = vec![0f32; wt.n_rows * n_classes];
    let mut rs = QRowScratch::new();
    for leaf in 0..wt.n_rows {
        wt.decode_row_into(leaf, &mut rs);
        let out = &mut m[leaf * n_classes..(leaf + 1) * n_classes];
        for (&j, &v) in rs.cols.iter().zip(&rs.vals) {
            out[y[j as usize] as usize] += v;
        }
    }
    m
}

/// Class scores `Q·M ∈ R^{NQ×C}` for an arbitrary query map.
pub fn class_scores(q: &Csr, leaf_mass: &[f32], n_classes: usize) -> Vec<f32> {
    assert_eq!(leaf_mass.len(), q.n_cols * n_classes);
    let mut s = vec![0f32; q.n_rows * n_classes];
    for i in 0..q.n_rows {
        let (cols, vals) = q.row(i);
        let out = &mut s[i * n_classes..(i + 1) * n_classes];
        for (&leaf, &v) in cols.iter().zip(vals) {
            let m = &leaf_mass[leaf as usize * n_classes..leaf as usize * n_classes + n_classes];
            for c in 0..n_classes {
                out[c] += v * m[c];
            }
        }
    }
    s
}

/// Argmax with deterministic tie-break (lowest class id); rows with all
/// zero scores return `fallback`.
pub fn argmax_scores(scores: &[f32], n_classes: usize, fallback: u32) -> Vec<u32> {
    let n = scores.len() / n_classes;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let row = &scores[i * n_classes..(i + 1) * n_classes];
        let mut best = 0usize;
        let mut any = false;
        for (c, &v) in row.iter().enumerate() {
            if v != 0.0 {
                any = true;
            }
            if v > row[best] {
                best = c;
            }
        }
        out.push(if any { best as u32 } else { fallback });
    }
    out
}

/// Proximity-weighted prediction for the *training* samples (the
/// Table I.1 quantity, left column block).
pub fn predict_train(kernel: &ForestKernel) -> Vec<u32> {
    let c = kernel.ctx.n_classes;
    assert!(c >= 2, "proximity-weighted prediction needs classification labels");
    let m = leaf_class_mass(&kernel.w, &kernel.ctx.y, c);
    let scores = class_scores(&kernel.q, &m, c);
    argmax_scores(&scores, c, majority_class(&kernel.ctx.y, c))
}

/// Proximity-weighted prediction for OOS queries given their query map.
/// When the kernel's quantized mode is on, the leaf class-mass table is
/// built from the compressed `Wᵀ` instead of the exact `W`.
pub fn predict_oos(kernel: &ForestKernel, q_new: &Csr) -> Vec<u32> {
    let c = kernel.ctx.n_classes;
    assert!(c >= 2);
    let m = match kernel.quantized() {
        Some(qf) => leaf_class_mass_q(&qf.wt, &kernel.ctx.y, c),
        None => leaf_class_mass(&kernel.w, &kernel.ctx.y, c),
    };
    let scores = class_scores(q_new, &m, c);
    argmax_scores(&scores, c, majority_class(&kernel.ctx.y, c))
}

/// Class scores `S = P·Y` streamed row-by-row from a *materialized*
/// kernel (in-memory CSR or out-of-core shard directory, via the shared
/// [`KernelSource`] interface) — one pass over nnz(P), never more than
/// one stripe resident. The factored `Q·(WᵀY)` path above is cheaper
/// when the factors are at hand; this one serves consumers that only
/// hold a materialized (possibly sparsified) kernel.
pub fn scores_from_kernel(
    src: &dyn KernelSource,
    y: &[u32],
    n_classes: usize,
) -> Result<Vec<f32>> {
    if src.n_cols() != y.len() {
        bail!("kernel has {} columns but {} labels", src.n_cols(), y.len());
    }
    let mut s = vec![0f32; src.n_rows() * n_classes];
    src.for_each_row(&mut |i, cols, vals| {
        let out = &mut s[i * n_classes..(i + 1) * n_classes];
        for (&j, &v) in cols.iter().zip(vals) {
            out[y[j as usize] as usize] += v;
        }
    })?;
    Ok(s)
}

/// Proximity-weighted prediction from a materialized kernel (streamed).
pub fn predict_from_kernel(
    src: &dyn KernelSource,
    y: &[u32],
    n_classes: usize,
) -> Result<Vec<u32>> {
    let scores = scores_from_kernel(src, y, n_classes)?;
    Ok(argmax_scores(&scores, n_classes, majority_class(y, n_classes)))
}

/// Accuracy of predicted class ids against f32 labels.
pub fn accuracy(pred: &[u32], y: &[f32]) -> f64 {
    assert_eq!(pred.len(), y.len());
    let hits = pred.iter().zip(y).filter(|(p, y)| **p as f32 == **y).count();
    hits as f64 / pred.len().max(1) as f64
}

fn majority_class(y: &[u32], n_classes: usize) -> u32 {
    let mut counts = vec![0usize; n_classes];
    for &v in y {
        counts[v as usize] += 1;
    }
    (0..n_classes).max_by_key(|&c| counts[c]).unwrap_or(0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{Forest, TrainConfig};
    use crate::swlc::ProximityKind;

    fn fixture(n: usize, seed: u64) -> (Forest, crate::data::Dataset) {
        let data = synth::gaussian_blobs(n, 4, 3, 2.5, seed);
        let f = Forest::train(&data, &TrainConfig { n_trees: 30, seed, ..Default::default() });
        (f, data)
    }

    #[test]
    fn scores_match_materialized_kernel_times_onehot() {
        let (f, data) = fixture(60, 1);
        let k = ForestKernel::fit(&f, &data, ProximityKind::Kerf);
        let c = 3;
        let m = leaf_class_mass(&k.w, &k.ctx.y, c);
        let scores = class_scores(&k.q, &m, c);
        // Reference: dense P @ onehot(y).
        let p = k.proximity_matrix().to_dense();
        for i in 0..60 {
            for cls in 0..c {
                let mut expect = 0f32;
                for j in 0..60 {
                    if k.ctx.y[j] as usize == cls {
                        expect += p[i * 60 + j];
                    }
                }
                let got = scores[i * c + cls];
                assert!((got - expect).abs() < 1e-3, "({i},{cls}): {got} vs {expect}");
            }
        }
    }

    #[test]
    fn train_prediction_is_accurate_on_separable_data() {
        let (f, data) = fixture(300, 2);
        for kind in [ProximityKind::Original, ProximityKind::Kerf, ProximityKind::RfGap] {
            let k = ForestKernel::fit(&f, &data, kind);
            let pred = predict_train(&k);
            let acc = accuracy(&pred, &data.y);
            assert!(acc > 0.9, "{kind:?}: acc={acc}");
        }
    }

    #[test]
    fn oos_prediction_generalizes() {
        let data = synth::gaussian_blobs(500, 4, 3, 2.5, 3);
        let (train, test) = data.train_test_split(0.2, 4);
        let f = Forest::train(&train, &TrainConfig { n_trees: 30, seed: 5, ..Default::default() });
        let k = ForestKernel::fit(&f, &train, ProximityKind::RfGap);
        let qn = k.oos_query_map(&f, &test);
        let pred = predict_oos(&k, &qn);
        let acc = accuracy(&pred, &test.y);
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn streamed_kernel_scores_match_dense_reference() {
        let (f, data) = fixture(60, 4);
        let k = ForestKernel::fit(&f, &data, ProximityKind::Kerf);
        let c = 3;
        let p = k.proximity_matrix();
        let scores = scores_from_kernel(&p, &k.ctx.y, c).unwrap();
        let dense = p.to_dense();
        for i in 0..60 {
            for cls in 0..c {
                let mut expect = 0f32;
                for j in 0..60 {
                    if k.ctx.y[j] as usize == cls {
                        expect += dense[i * 60 + j];
                    }
                }
                let got = scores[i * c + cls];
                assert!((got - expect).abs() < 1e-3, "({i},{cls}): {got} vs {expect}");
            }
        }
        // And the prediction agrees with the factored path.
        let pred_stream = predict_from_kernel(&p, &k.ctx.y, c).unwrap();
        let pred_factor = predict_train(&k);
        let agree = pred_stream.iter().zip(&pred_factor).filter(|(a, b)| a == b).count();
        assert!(agree >= 58, "only {agree}/60 predictions agree");
    }

    #[test]
    fn argmax_fallback_on_zero_rows() {
        let scores = vec![0.0, 0.0, 0.5, 0.2];
        let out = argmax_scores(&scores, 2, 1);
        assert_eq!(out, vec![1, 0]);
    }

    #[test]
    fn gap_prediction_matches_forest_oob_votes() {
        // RF-GAP's defining property (design goal of [38]): the
        // proximity-weighted predictor reproduces the forest's OOB vote
        // argmax for every sample with at least one OOB tree.
        let (f, data) = fixture(150, 6);
        let k = ForestKernel::fit(&f, &data, ProximityKind::RfGap);
        let pred = predict_train(&k);
        let binned = f.binner.bin(&data);
        let votes = f.oob_votes(&binned);
        let c = 3;
        for i in 0..150 {
            if k.ctx.oob_count[i] == 0 {
                continue;
            }
            let row = &votes[i * c..(i + 1) * c];
            let best = (0..c).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap();
            // Ties can legitimately differ; require match when the vote
            // argmax is strict.
            let strict = (0..c).filter(|&j| (row[j] - row[best]).abs() < 1e-12).count() == 1;
            if strict {
                assert_eq!(pred[i], best as u32, "sample {i}: votes {row:?}");
            }
        }
    }
}
