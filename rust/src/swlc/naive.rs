//! Naive O(N²T) baselines and the exact non-separable OOB proximity.
//!
//! Two roles: (a) ground truth for the property tests — the factored
//! kernel must equal the all-pairs evaluation of Def. 3.1 exactly;
//! (b) the quadratic baseline the paper's scaling claims are measured
//! against, plus the pairwise OOB statistics behind Fig. 4.1.

use super::context::EnsembleContext;
use super::weights::{self, WeightSpec};
use super::ProximityKind;

/// All-pairs SWLC evaluation of Def. 3.1: dense `N×N`, O(N²T) time.
pub fn naive_proximity(kind: ProximityKind, ctx: &EnsembleContext) -> Vec<f32> {
    let WeightSpec { q, w, .. } = weights::assign(kind, ctx);
    let (n, t) = (ctx.n, ctx.t);
    let mut p = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0f32;
            for tt in 0..t {
                if ctx.leaf(i, tt) == ctx.leaf(j, tt) {
                    acc += q[i * t + tt] * w[j * t + tt];
                }
            }
            p[i * n + j] = acc;
        }
    }
    if kind == ProximityKind::OobSeparable {
        for i in 0..n {
            p[i * n + i] = 1.0; // Remark G.2
        }
    }
    p
}

/// The exact (non-separable) OOB proximity of App. B.3:
/// `P_oob(x,x') = Σ_t o_t o_t' 1[match] / S(x,x')`, with `P_oob(x,x)=1`.
/// Pairs with `S(x,x') = 0` get proximity 0.
pub fn naive_oob_exact(ctx: &EnsembleContext) -> Vec<f32> {
    assert!(ctx.has_bootstrap(), "exact OOB needs bootstrap bookkeeping");
    let (n, t) = (ctx.n, ctx.t);
    let mut p = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                p[i * n + j] = 1.0;
                continue;
            }
            let mut shared = 0u32;
            let mut collide = 0u32;
            for tt in 0..t {
                if ctx.is_oob(i, tt) && ctx.is_oob(j, tt) {
                    shared += 1;
                    if ctx.leaf(i, tt) == ctx.leaf(j, tt) {
                        collide += 1;
                    }
                }
            }
            if shared > 0 {
                p[i * n + j] = collide as f32 / shared as f32;
            }
        }
    }
    p
}

/// Statistics of the Fig. 4.1 ratio
/// `R(x,x') = S(x,x') / (S(x)S(x')/T)` over distinct pairs with
/// `S(x,x') > 0`. For large N a uniformly subsampled set of pairs is
/// used (`max_pairs`), which is how the paper's mean ± std curves are
/// estimated anyway.
pub struct RatioStats {
    pub mean: f64,
    pub std: f64,
    pub n_pairs: usize,
}

pub fn oob_ratio_stats(ctx: &EnsembleContext, max_pairs: usize, seed: u64) -> RatioStats {
    assert!(ctx.has_bootstrap());
    let (n, t) = (ctx.n, ctx.t);
    let mut rng = crate::rng::Rng::new(seed);
    let total_pairs = n * (n - 1) / 2;
    let mut acc = 0f64;
    let mut acc2 = 0f64;
    let mut count = 0usize;

    let eval_pair = |i: usize, j: usize, acc: &mut f64, acc2: &mut f64, count: &mut usize| {
        let (si, sj) = (ctx.oob_count[i], ctx.oob_count[j]);
        if si == 0 || sj == 0 {
            return;
        }
        let mut shared = 0u32;
        for tt in 0..t {
            if ctx.is_oob(i, tt) && ctx.is_oob(j, tt) {
                shared += 1;
            }
        }
        if shared == 0 {
            return;
        }
        let r = shared as f64 / (si as f64 * sj as f64 / t as f64);
        *acc += r;
        *acc2 += r * r;
        *count += 1;
    };

    if total_pairs <= max_pairs {
        for i in 0..n {
            for j in (i + 1)..n {
                eval_pair(i, j, &mut acc, &mut acc2, &mut count);
            }
        }
    } else {
        let mut drawn = 0usize;
        while drawn < max_pairs {
            let i = rng.gen_range(n);
            let j = rng.gen_range(n);
            if i == j {
                continue;
            }
            drawn += 1;
            eval_pair(i, j, &mut acc, &mut acc2, &mut count);
        }
    }
    let mean = acc / count.max(1) as f64;
    let var = (acc2 / count.max(1) as f64 - mean * mean).max(0.0);
    RatioStats { mean, std: var.sqrt(), n_pairs: count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{Forest, TrainConfig};
    use crate::swlc::ForestKernel;

    fn fixture(n: usize, t: usize, seed: u64) -> (Forest, crate::data::Dataset) {
        let data = synth::gaussian_blobs(n, 4, 3, 2.0, seed);
        let f = Forest::train(&data, &TrainConfig { n_trees: t, seed, ..Default::default() });
        (f, data)
    }

    #[test]
    fn factored_equals_naive_for_all_kinds() {
        // The core correctness statement of Prop. 3.6.
        let (f, data) = fixture(60, 10, 1);
        for kind in ProximityKind::ALL {
            if kind == ProximityKind::Boosted {
                continue; // needs GBT; covered in proptest_swlc.rs
            }
            let k = ForestKernel::fit(&f, &data, kind);
            let dense = k.proximity_matrix().to_dense();
            let naive = naive_proximity(kind, &k.ctx);
            for (a, b) in dense.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-4, "{kind:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn exact_oob_diagonal_one_offdiag_in_unit_interval() {
        let (f, data) = fixture(50, 20, 2);
        let ctx = crate::swlc::EnsembleContext::build(&f, &data);
        let p = naive_oob_exact(&ctx);
        for i in 0..50 {
            assert_eq!(p[i * 50 + i], 1.0);
            for j in 0..50 {
                assert!((0.0..=1.0).contains(&p[i * 50 + j]));
            }
        }
    }

    #[test]
    fn separable_oob_tracks_exact_oob() {
        // The surrogate should be close to the exact OOB proximity up to
        // the 1 - O(1/N) factor of Prop. G.1 — on average within ~20%
        // at this scale.
        let (f, data) = fixture(150, 60, 3);
        let ctx = crate::swlc::EnsembleContext::build(&f, &data);
        let exact = naive_oob_exact(&ctx);
        let sep = naive_proximity(ProximityKind::OobSeparable, &ctx);
        let mut num = 0f64;
        let mut den = 0f64;
        for k in 0..exact.len() {
            num += ((exact[k] - sep[k]) as f64).abs();
            den += exact[k] as f64;
        }
        assert!(num / den < 0.25, "relative L1 gap = {}", num / den);
    }

    #[test]
    fn ratio_stats_in_expected_band() {
        // Prop. G.1: mean R ∈ (0, 1], approaching 1 from below.
        let (f, data) = fixture(200, 80, 4);
        let ctx = crate::swlc::EnsembleContext::build(&f, &data);
        let stats = oob_ratio_stats(&ctx, 20_000, 5);
        assert!(stats.n_pairs > 100);
        assert!(stats.mean > 0.7 && stats.mean <= 1.05, "mean={}", stats.mean);
        assert!(stats.std < 0.5);
    }

    #[test]
    fn ratio_mean_increases_with_n() {
        // The bias term is O(1/N): larger training sets ⇒ ratio closer to 1.
        let t = 60;
        let (f1, d1) = fixture(60, t, 6);
        let (f2, d2) = fixture(400, t, 6);
        let c1 = crate::swlc::EnsembleContext::build(&f1, &d1);
        let c2 = crate::swlc::EnsembleContext::build(&f2, &d2);
        let r1 = oob_ratio_stats(&c1, 20_000, 7).mean;
        let r2 = oob_ratio_stats(&c2, 20_000, 7).mean;
        assert!(r2 > r1 - 0.02, "r1={r1} r2={r2}");
    }

    #[test]
    fn subsampled_pairs_close_to_exhaustive() {
        let (f, data) = fixture(120, 40, 8);
        let ctx = crate::swlc::EnsembleContext::build(&f, &data);
        let full = oob_ratio_stats(&ctx, usize::MAX, 1);
        let sub = oob_ratio_stats(&ctx, 3_000, 2);
        assert!((full.mean - sub.mean).abs() < 0.05, "{} vs {}", full.mean, sub.mean);
    }
}
