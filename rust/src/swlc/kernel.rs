//! Leaf-incidence factors and the exact sparse factorization.
//!
//! Definition 3.3 / Prop. 3.6, in the row-sample convention of the
//! paper's implementation (App. D): `Q, W ∈ R^{N×L}` stack the weighted
//! leaf-incidence vectors `φ_q(x_i)` as *rows*, each with at most T
//! nonzeros (Lemma 3.4), and the proximity matrix is the sparse product
//! `P = Q Wᵀ` computed by Gustavson SpGEMM — cost `O(NT λ̄)` (§3.3).
//! Out-of-sample proximities are `Q_new Wᵀ` (Remark 3.9).

use super::context::EnsembleContext;
use super::weights::{self, WeightSpec};
use super::ProximityKind;
use crate::data::Dataset;
use crate::exec;
use crate::forest::Forest;
use crate::sparse::qcsr::{self, QCsr, QuantMode};
use crate::sparse::{spgemm, spgemm_nnz_flops, Csr};

/// Block-quantized companions of the kernel factors (see
/// [`crate::sparse::qcsr`]): `Q` and `Wᵀ` in int8/int4 form, enough to
/// drive every product the kernel exposes. Present only when the
/// quantized mode is enabled; the exact factors always remain canonical.
pub struct QuantizedFactors {
    pub mode: QuantMode,
    /// Quantized query-side map (N×L).
    pub q: QCsr,
    /// Quantized cached transpose `Wᵀ` (L×N).
    pub wt: QCsr,
}

/// A fitted SWLC kernel in factored form.
pub struct ForestKernel {
    pub kind: ProximityKind,
    pub ctx: EnsembleContext,
    /// Query-side map `Q ∈ R^{N×L}` (rows = `φ_q(x_i)`).
    pub q: Csr,
    /// Reference-side map `W ∈ R^{N×L}`; identical object to `q`'s
    /// content when the scheme is symmetric (`Q = W`, Cor. 3.7).
    pub w: Csr,
    /// `Wᵀ` cached for products (L×N).
    wt: Csr,
    pub symmetric: bool,
    /// Opt-in quantized fast path; `None` (the default) keeps every
    /// product on the exact f32 factors, bitwise-unchanged.
    quant: Option<QuantizedFactors>,
}

/// Build an `N×L` leaf-incidence CSR from a sample-major leaf table and
/// a dense `N×T` weight table, dropping zero weights (the source of the
/// scheme-dependent sparsity of Remark 3.8). Rows are assembled in
/// parallel on the shared [`exec`] pool.
pub fn incidence_matrix(leaf_of: &[u32], wtab: &[f32], n: usize, t: usize, l: usize) -> Csr {
    assert_eq!(leaf_of.len(), n * t);
    assert_eq!(wtab.len(), n * t);
    Csr::from_rows_par(n, l, t, |i, push| {
        for tt in 0..t {
            let v = wtab[i * t + tt];
            if v != 0.0 {
                push(leaf_of[i * t + tt], v);
            }
        }
    })
}

impl ForestKernel {
    /// Fit the kernel: build the context θ, the App. B weight tables,
    /// and the sparse factors. Everything downstream (full kernel, OOS,
    /// prediction, embedding) reuses these factors.
    pub fn fit(forest: &Forest, data: &Dataset, kind: ProximityKind) -> ForestKernel {
        let ctx = EnsembleContext::build(forest, data);
        let WeightSpec { q, w, symmetric } = weights::assign(kind, &ctx);
        // Q and W are independent given the weight tables, so build them
        // concurrently on the shared pool; Wᵀ follows (its transpose is
        // itself row-parallel internally). For symmetric schemes the
        // clone and the transpose of Q are likewise independent.
        let (qm, wm, wt) = if symmetric {
            let qm = incidence_matrix(&ctx.leaf_of, &q, ctx.n, ctx.t, ctx.l);
            // The clone is a memcpy; the transpose (row-parallel
            // internally) is the real work — no join needed here.
            let wm = qm.clone();
            let wt = qm.transpose();
            (qm, wm, wt)
        } else {
            let (qm, wm) = exec::join(
                || incidence_matrix(&ctx.leaf_of, &q, ctx.n, ctx.t, ctx.l),
                || incidence_matrix(&ctx.leaf_of, &w, ctx.n, ctx.t, ctx.l),
            );
            let wt = wm.transpose();
            (qm, wm, wt)
        };
        ForestKernel { kind, ctx, q: qm, w: wm, wt, symmetric, quant: None }
    }

    /// Reassemble a kernel from persisted parts (the model-bundle load
    /// path): the cached transpose `Wᵀ` is recomputed here with the
    /// same deterministic parallel transpose `fit` uses, so a loaded
    /// kernel is bitwise-identical to the originally fitted one —
    /// factors, products, and predictions all round-trip exactly.
    pub fn from_parts(
        kind: ProximityKind,
        ctx: EnsembleContext,
        q: Csr,
        w: Csr,
        symmetric: bool,
    ) -> ForestKernel {
        assert_eq!(q.n_rows, ctx.n);
        assert_eq!(q.n_cols, ctx.l);
        assert_eq!(w.n_rows, ctx.n);
        assert_eq!(w.n_cols, ctx.l);
        let wt = w.transpose();
        ForestKernel { kind, ctx, q, w, wt, symmetric, quant: None }
    }

    /// [`ForestKernel::from_parts`] with the cached transpose supplied
    /// by the caller instead of recomputed — the `fk-bundle-v3` load
    /// path, which persists `Wᵀ` so a mapped bundle binds without any
    /// O(nnz) work. The caller vouches that `wt` is the transpose of
    /// `w` (the v3 writer stores the fitted one verbatim); only shape
    /// consistency is checked here.
    pub fn from_parts_with_wt(
        kind: ProximityKind,
        ctx: EnsembleContext,
        q: Csr,
        w: Csr,
        wt: Csr,
        symmetric: bool,
    ) -> ForestKernel {
        assert_eq!(q.n_rows, ctx.n);
        assert_eq!(q.n_cols, ctx.l);
        assert_eq!(w.n_rows, ctx.n);
        assert_eq!(w.n_cols, ctx.l);
        assert_eq!(wt.n_rows, ctx.l);
        assert_eq!(wt.n_cols, ctx.n);
        assert_eq!(wt.nnz(), w.nnz());
        ForestKernel { kind, ctx, q, w, wt, symmetric, quant: None }
    }

    /// Switch the quantized fast path on (`Some(mode)`) or off (`None`).
    /// Enabling quantizes `Q` and `Wᵀ` with the deterministic block rule
    /// of [`qcsr::quantize`]; the exact factors are kept — quantization
    /// is always an overlay, never a replacement.
    pub fn set_quantization(&mut self, mode: Option<QuantMode>) {
        self.quant = mode.map(|m| QuantizedFactors {
            mode: m,
            q: qcsr::quantize(&self.q, m),
            wt: qcsr::quantize(&self.wt, m),
        });
    }

    /// Attach pre-built quantized factors (the bundle-load path, where
    /// the stored `QCsr` must survive bitwise rather than being
    /// re-derived from dequantized values).
    pub fn attach_quantized(&mut self, qf: QuantizedFactors) {
        assert_eq!(qf.q.n_rows, self.q.n_rows, "quantized Q row mismatch");
        assert_eq!(qf.q.n_cols, self.q.n_cols, "quantized Q col mismatch");
        assert_eq!(qf.wt.n_rows, self.wt.n_rows, "quantized Wt row mismatch");
        assert_eq!(qf.wt.n_cols, self.wt.n_cols, "quantized Wt col mismatch");
        self.quant = Some(qf);
    }

    /// Active quantization mode, if the fast path is enabled.
    pub fn quantization(&self) -> Option<QuantMode> {
        self.quant.as_ref().map(|q| q.mode)
    }

    /// The quantized factors, if the fast path is enabled.
    pub fn quantized(&self) -> Option<&QuantizedFactors> {
        self.quant.as_ref()
    }

    /// In-memory bytes of the quantized factor overlay (0 when off).
    pub fn quantized_bytes(&self) -> usize {
        self.quant.as_ref().map_or(0, |q| q.q.mem_bytes() + q.wt.mem_bytes())
    }

    /// The training proximity matrix `P = Q Wᵀ` (Prop. 3.6) as a sparse
    /// `N×N` CSR. For the separable OOB kernel the diagonal is then
    /// forced to 1 (Remark G.2). When the quantized mode is on this is
    /// the quantized product (bitwise-identical to the exact product of
    /// the *dequantized* factors); otherwise it is the exact product.
    pub fn proximity_matrix(&self) -> Csr {
        let mut p = match &self.quant {
            Some(qf) => qcsr::spgemm_q(&qf.q, &qf.wt, exec::workers_for(self.q.n_rows, 64)),
            None => spgemm(&self.q, &self.wt),
        };
        if self.kind == ProximityKind::OobSeparable {
            set_unit_diagonal(&mut p);
        }
        p
    }

    /// Predicted SpGEMM work `N·T·λ̄` for the full kernel (§3.3) —
    /// reported by the benches next to measured wall time.
    pub fn predicted_flops(&self) -> u64 {
        spgemm_nnz_flops(&self.q, &self.wt).0
    }

    /// Per-row predicted SpGEMM work: row `i` of `P = Q Wᵀ` costs
    /// `Σ_{k ∈ Q.row(i)} nnz(Wᵀ.row(k))` Gustavson updates (the row
    /// terms of §3.3's `N·T·λ̄`). Floored at 1 so structurally empty
    /// rows (e.g. never-OOB samples) still carry weight — the
    /// multi-process partition planner balances shards by these costs,
    /// not by raw row count.
    pub fn row_flops(&self) -> Vec<u64> {
        let wt = &self.wt;
        (0..self.q.n_rows)
            .map(|i| {
                let (cols, _) = self.q.row(i);
                cols.iter()
                    .map(|&k| (wt.indptr[k as usize + 1] - wt.indptr[k as usize]) as u64)
                    .sum::<u64>()
                    .max(1)
            })
            .collect()
    }

    /// Route unseen samples and build their query-side map `Q_new`
    /// (Remark 3.9; OOS samples are treated as the query argument).
    pub fn oos_query_map(&self, forest: &Forest, newdata: &Dataset) -> Csr {
        let leaf_new = forest.apply(newdata);
        let q = weights::assign_oos_query(self.kind, &self.ctx, &leaf_new, newdata.n);
        incidence_matrix(&leaf_new, &q, newdata.n, self.ctx.t, self.ctx.l)
    }

    /// Cross-proximities `Q_new Wᵀ ∈ R^{N_new×N}` against the training
    /// gallery. Query rows stay exact f32; only the gallery side `Wᵀ`
    /// is read in quantized form when the fast path is on.
    pub fn cross_proximity(&self, q_new: &Csr) -> Csr {
        assert_eq!(q_new.n_cols, self.ctx.l);
        match &self.quant {
            Some(qf) => {
                qcsr::spgemm_csr_q(q_new, &qf.wt, exec::workers_for(q_new.n_rows, 64))
            }
            None => spgemm(q_new, &self.wt),
        }
    }

    /// Total factor memory (bytes) — the `O(NT)` term of §3.3's space
    /// bound.
    pub fn factor_bytes(&self) -> usize {
        if self.symmetric {
            self.q.mem_bytes() + self.wt.mem_bytes()
        } else {
            self.q.mem_bytes() + self.w.mem_bytes() + self.wt.mem_bytes()
        }
    }

    /// Reference to the cached transpose `Wᵀ` (L×N).
    pub fn w_transpose(&self) -> &Csr {
        &self.wt
    }
}

/// Force `P_ii = 1` (inserting the entry if absent). Remark G.2: OOB
/// self-similarity is deterministically 1 and the separable surrogate
/// must preserve that.
pub fn set_unit_diagonal(p: &mut Csr) {
    set_unit_diagonal_offset(p, 0);
}

/// [`set_unit_diagonal`] for a row *stripe* of a larger matrix: local
/// row `i` corresponds to global row (and thus diagonal column)
/// `row_offset + i`. Used by the coordinator so every stripe sink sees
/// exactly the diagonal `proximity_matrix` would produce.
pub fn set_unit_diagonal_offset(p: &mut Csr, row_offset: usize) {
    // First try in-place (diagonal entry present).
    let mut missing = Vec::new();
    for i in 0..p.n_rows {
        let gcol = row_offset + i;
        if gcol >= p.n_cols {
            break;
        }
        let (lo, hi) = (p.indptr[i], p.indptr[i + 1]);
        match p.indices[lo..hi].binary_search(&(gcol as u32)) {
            Ok(k) => p.data[lo + k] = 1.0,
            Err(_) => missing.push(i),
        }
    }
    if missing.is_empty() {
        return;
    }
    // Rebuild with the missing diagonal entries inserted.
    let mut indptr = Vec::with_capacity(p.n_rows + 1);
    let mut indices = Vec::with_capacity(p.nnz() + missing.len());
    let mut data = Vec::with_capacity(p.nnz() + missing.len());
    indptr.push(0);
    let mut miss_iter = missing.iter().peekable();
    for i in 0..p.n_rows {
        let gcol = (row_offset + i) as u32;
        let (lo, hi) = (p.indptr[i], p.indptr[i + 1]);
        let needs = miss_iter.peek() == Some(&&i);
        if needs {
            miss_iter.next();
        }
        let mut inserted = false;
        for k in lo..hi {
            let c = p.indices[k];
            if needs && !inserted && c > gcol {
                indices.push(gcol);
                data.push(1.0);
                inserted = true;
            }
            indices.push(c);
            data.push(p.data[k]);
        }
        if needs && !inserted {
            indices.push(gcol);
            data.push(1.0);
        }
        indptr.push(indices.len());
    }
    p.indices = indices.into();
    p.data = data.into();
    p.indptr = indptr.into();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::TrainConfig;

    fn fixture(n: usize, t: usize, seed: u64) -> (Forest, Dataset) {
        let data = synth::gaussian_blobs(n, 4, 3, 2.0, seed);
        let f = Forest::train(&data, &TrainConfig { n_trees: t, seed, ..Default::default() });
        (f, data)
    }

    #[test]
    fn rows_are_t_sparse() {
        // Lemma 3.4: ||φ_q(x)||_0 = ||q(x)||_0 <= T.
        let (f, data) = fixture(80, 12, 1);
        for kind in ProximityKind::ALL {
            if kind == ProximityKind::Boosted {
                continue;
            }
            let k = ForestKernel::fit(&f, &data, kind);
            for i in 0..k.q.n_rows {
                let (cols, _) = k.q.row(i);
                assert!(cols.len() <= 12, "{kind:?} row {i}: {}", cols.len());
            }
        }
    }

    #[test]
    fn original_proximity_values() {
        // P_ij = (#trees colliding)/T; check against direct counting.
        let (f, data) = fixture(40, 8, 2);
        let k = ForestKernel::fit(&f, &data, ProximityKind::Original);
        let p = k.proximity_matrix().to_dense();
        let ctx = &k.ctx;
        for i in 0..10 {
            for j in 0..10 {
                let collisions =
                    (0..8).filter(|&t| ctx.leaf(i, t) == ctx.leaf(j, t)).count() as f32;
                let expect = collisions / 8.0;
                assert!(
                    (p[i * 40 + j] - expect).abs() < 1e-5,
                    "P[{i},{j}]={} expect {expect}",
                    p[i * 40 + j]
                );
            }
        }
    }

    #[test]
    fn diagonal_of_original_is_one() {
        let (f, data) = fixture(30, 10, 3);
        let k = ForestKernel::fit(&f, &data, ProximityKind::Original);
        let p = k.proximity_matrix().to_dense();
        for i in 0..30 {
            assert!((p[i * 30 + i] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn symmetric_kinds_give_symmetric_p() {
        let (f, data) = fixture(50, 10, 4);
        for kind in [ProximityKind::Original, ProximityKind::Kerf, ProximityKind::OobSeparable] {
            let k = ForestKernel::fit(&f, &data, kind);
            let p = k.proximity_matrix().to_dense();
            for i in 0..50 {
                for j in 0..50 {
                    assert!(
                        (p[i * 50 + j] - p[j * 50 + i]).abs() < 1e-5,
                        "{kind:?} asymmetric at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn oob_diagonal_forced_to_one() {
        let (f, data) = fixture(60, 15, 5);
        let k = ForestKernel::fit(&f, &data, ProximityKind::OobSeparable);
        let p = k.proximity_matrix().to_dense();
        for i in 0..60 {
            assert_eq!(p[i * 60 + i], 1.0);
        }
    }

    #[test]
    fn gap_rows_sum_to_one() {
        // Σ_j P_gap(i, j) = (1/S) Σ_{t oob} Σ_j c_t(j) 1[match]/M_in(ℓ) = 1
        // whenever S(i) > 0 — the RF-GAP normalization property.
        let (f, data) = fixture(70, 12, 6);
        let k = ForestKernel::fit(&f, &data, ProximityKind::RfGap);
        let p = k.proximity_matrix();
        let sums = p.row_sums();
        for i in 0..70 {
            if k.ctx.oob_count[i] > 0 {
                assert!((sums[i] - 1.0).abs() < 1e-4, "row {i} sums to {}", sums[i]);
            }
        }
    }

    #[test]
    fn oos_cross_proximity_matches_training_block() {
        // Querying training points through the OOS path with the same
        // weights must reproduce the training kernel rows (Original:
        // OOS weights == training weights).
        let (f, data) = fixture(40, 9, 7);
        let k = ForestKernel::fit(&f, &data, ProximityKind::Original);
        let sub = data.head(10);
        let qn = k.oos_query_map(&f, &sub);
        let cross = k.cross_proximity(&qn).to_dense();
        let full = k.proximity_matrix().to_dense();
        for i in 0..10 {
            for j in 0..40 {
                assert!((cross[i * 40 + j] - full[i * 40 + j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn set_unit_diagonal_inserts_missing() {
        let mut p = Csr::from_triplets(3, 3, &[(0, 1, 0.5), (2, 2, 0.3)]);
        set_unit_diagonal(&mut p);
        p.check().unwrap();
        let d = p.to_dense();
        assert_eq!(d[0], 1.0);
        assert_eq!(d[4], 1.0);
        assert_eq!(d[8], 1.0);
        assert_eq!(d[1], 0.5);
    }

    #[test]
    fn set_unit_diagonal_offset_targets_global_columns() {
        // A 2-row stripe starting at global row 3 of a 6-column matrix:
        // row 0's diagonal is column 3 (present, overwritten), row 1's
        // is column 4 (absent, inserted between 2 and 5).
        let mut p = Csr::from_triplets(2, 6, &[(0, 3, 0.4), (1, 2, 0.2), (1, 5, 0.7)]);
        set_unit_diagonal_offset(&mut p, 3);
        p.check().unwrap();
        let d = p.to_dense();
        assert_eq!(d[3], 1.0);
        assert_eq!(d[6 + 4], 1.0);
        assert_eq!(d[6 + 2], 0.2);
        assert_eq!(d[6 + 5], 0.7);
        assert_eq!(p.nnz(), 4);
    }

    #[test]
    fn predicted_flops_positive_and_bounded() {
        let (f, data) = fixture(50, 8, 8);
        let k = ForestKernel::fit(&f, &data, ProximityKind::Original);
        let flops = k.predicted_flops();
        assert!(flops >= (50 * 8) as u64); // λ̄ >= 1
        assert!(flops <= (50u64 * 50 * 8)); // never worse than dense
    }

    #[test]
    fn row_flops_sum_to_predicted_total() {
        let (f, data) = fixture(60, 10, 9);
        let k = ForestKernel::fit(&f, &data, ProximityKind::Original);
        let rows = k.row_flops();
        assert_eq!(rows.len(), 60);
        assert!(rows.iter().all(|&c| c >= 1));
        // Every row of Q is nonempty under Original weights, so the
        // max(1) floor never fires and the per-row costs sum exactly to
        // the aggregate §3.3 prediction.
        assert_eq!(rows.iter().sum::<u64>(), k.predicted_flops());
    }
}
