//! XLA-backed dense gallery scoring: the OOS serving path.
//!
//! A gallery holds the reference-side panels (per-tree leaf ids and
//! SWLC weights of the training samples). Incoming query blocks are
//! routed through the forest, given OOS query weights (Remark 3.9),
//! padded to the AOT tile shape, and scored against every gallery tile
//! by the compiled Pallas proximity kernel (`prox_{BQ}x{BR}x{T}`). This
//! is the "dense fast path" of DESIGN.md: the request path is pure Rust
//! + PJRT — Python never runs.

use crate::anyhow;
use crate::data::Dataset;
use crate::error::Result;
use crate::forest::Forest;
use crate::runtime::Runtime;
use crate::swlc::{weights, EnsembleContext, ProximityKind};

/// Dense reference-side gallery with tile-padded panels.
pub struct GalleryService<'a> {
    runtime: &'a Runtime,
    pub kind: ProximityKind,
    /// Tile shape `(BQ, BR, T_pad)` chosen from the loaded artifacts.
    pub tile: (usize, usize, usize),
    pub n_ref: usize,
    pub t: usize,
    /// Padded gallery panels: per tile `g`, `BR×T_pad` leaf ids / weights.
    leaves: Vec<i32>,
    weights: Vec<f32>,
    n_tiles: usize,
    /// Reference labels (for proximity-weighted voting).
    pub labels: Vec<u32>,
    pub n_classes: usize,
}

impl<'a> GalleryService<'a> {
    /// Build the gallery from a trained forest and its training set.
    pub fn new(
        runtime: &'a Runtime,
        forest: &Forest,
        train: &Dataset,
        kind: ProximityKind,
    ) -> Result<GalleryService<'a>> {
        let ctx = EnsembleContext::build(forest, train);
        let spec = weights::assign(kind, &ctx);
        let (n, t) = (ctx.n, ctx.t);
        let tile = runtime
            .best_prox_variant(1, 1, t)
            .ok_or_else(|| anyhow!("no prox artifact can hold T={t} trees"))?;
        let (_bq, br, t_pad) = tile;
        let n_tiles = n.div_ceil(br);

        // Pad gallery to n_tiles*BR rows and T_pad trees. Padded rows get
        // leaf -2 / weight 0; padded trees get leaf -2 / weight 0 too —
        // query padding uses -1, so no phantom collisions are possible.
        let mut leaves = vec![-2i32; n_tiles * br * t_pad];
        let mut wts = vec![0f32; n_tiles * br * t_pad];
        for i in 0..n {
            let dst = i * t_pad;
            for tt in 0..t {
                leaves[dst + tt] = ctx.leaf(i, tt) as i32;
                wts[dst + tt] = spec.w[i * t + tt];
            }
        }
        Ok(GalleryService {
            runtime,
            kind,
            tile,
            n_ref: n,
            t,
            leaves,
            weights: wts,
            n_tiles,
            labels: ctx.y.to_vec(),
            n_classes: ctx.n_classes,
        })
    }

    /// Route and score a query block against the whole gallery.
    /// Returns the dense `n_q × n_ref` proximity block.
    pub fn score(&self, forest: &Forest, queries: &Dataset) -> Result<Vec<f32>> {
        let n_q = queries.n;
        let (bq, br, t_pad) = self.tile;
        // Route queries and build padded panels with OOS weights.
        let leaf_new = forest.apply(queries);
        let ctx_stub = ProxQueryPanels::build(self.kind, forest, &leaf_new, n_q, self.t, t_pad, bq);

        let mut out = vec![0f32; n_q * self.n_ref];
        let q_tiles = n_q.div_ceil(bq);
        for qt in 0..q_tiles {
            let ql = &ctx_stub.leaves[qt * bq * t_pad..(qt + 1) * bq * t_pad];
            let qw = &ctx_stub.weights[qt * bq * t_pad..(qt + 1) * bq * t_pad];
            for gt in 0..self.n_tiles {
                let gl = &self.leaves[gt * br * t_pad..(gt + 1) * br * t_pad];
                let gw = &self.weights[gt * br * t_pad..(gt + 1) * br * t_pad];
                let tile_out = self.runtime.prox_block(bq, br, t_pad, ql, qw, gl, gw)?;
                // Scatter the valid region into the output.
                for i in 0..bq {
                    let gi = qt * bq + i;
                    if gi >= n_q {
                        break;
                    }
                    for j in 0..br {
                        let gj = gt * br + j;
                        if gj >= self.n_ref {
                            break;
                        }
                        out[gi * self.n_ref + gj] = tile_out[i * br + j];
                    }
                }
            }
        }
        Ok(out)
    }

    /// Top-k most proximal gallery entries per query row.
    pub fn top_k(&self, scores: &[f32], n_q: usize, k: usize) -> Vec<Vec<(u32, f32)>> {
        let mut out = Vec::with_capacity(n_q);
        for i in 0..n_q {
            let row = &scores[i * self.n_ref..(i + 1) * self.n_ref];
            let mut idx: Vec<(u32, f32)> =
                row.iter().enumerate().map(|(j, &v)| (j as u32, v)).collect();
            let kk = k.min(idx.len());
            idx.select_nth_unstable_by(kk - 1, |a, b| b.1.partial_cmp(&a.1).unwrap());
            idx.truncate(kk);
            idx.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            out.push(idx);
        }
        out
    }

    /// Proximity-weighted class votes from a dense score block.
    pub fn vote(&self, scores: &[f32], n_q: usize) -> Vec<u32> {
        let c = self.n_classes;
        let mut preds = Vec::with_capacity(n_q);
        for i in 0..n_q {
            let row = &scores[i * self.n_ref..(i + 1) * self.n_ref];
            let mut acc = vec![0f64; c];
            for (j, &v) in row.iter().enumerate() {
                acc[self.labels[j] as usize] += v as f64;
            }
            preds.push(crate::forest::argmax(&acc) as u32);
        }
        preds
    }
}

/// Query-side padded panels.
struct ProxQueryPanels {
    leaves: Vec<i32>,
    weights: Vec<f32>,
}

impl ProxQueryPanels {
    fn build(
        kind: ProximityKind,
        forest: &Forest,
        leaf_new: &[u32],
        n_q: usize,
        t: usize,
        t_pad: usize,
        bq: usize,
    ) -> ProxQueryPanels {
        // OOS query weights need only T and tree weights from the
        // context; build a minimal stub via the public API.
        let q_tiles = n_q.div_ceil(bq);
        let mut leaves = vec![-1i32; q_tiles * bq * t_pad];
        let mut wts = vec![0f32; q_tiles * bq * t_pad];
        let qw = oos_query_weights(kind, forest, t, n_q);
        for i in 0..n_q {
            let dst = i * t_pad;
            for tt in 0..t {
                leaves[dst + tt] = leaf_new[i * t + tt] as i32;
                wts[dst + tt] = qw[i * t + tt];
            }
        }
        ProxQueryPanels { leaves, weights: wts }
    }
}

/// OOS query weights without a full context (Remark 3.9 conventions).
/// KeRF needs leaf masses, so the gallery path supports the schemes
/// whose query side is leaf-independent; KeRF queries fall back to
/// original weighting (its reference side still carries the leaf-mass
/// normalization via the *gallery* weights).
fn oos_query_weights(kind: ProximityKind, forest: &Forest, t: usize, n_q: usize) -> Vec<f32> {
    let v = match kind {
        ProximityKind::Original | ProximityKind::OobSeparable | ProximityKind::Kerf => {
            1.0 / (t as f32).sqrt()
        }
        ProximityKind::RfGap | ProximityKind::InstanceHardness => 1.0 / t as f32,
        ProximityKind::Boosted => {
            let total: f32 = forest.tree_weights.iter().sum();
            return (0..n_q * t)
                .map(|k| (forest.tree_weights[k % t] / total).sqrt())
                .collect();
        }
    };
    vec![v; n_q * t]
}
