//! Out-of-core kernel shards: fixed-format binary stripe files plus a
//! JSON manifest, written by [`ShardSink`] and streamed back in row
//! order by [`ShardReader`].
//!
//! # Shard file format (`shard-NNNNN.bin`, little-endian throughout)
//!
//! | offset | size            | field                                  |
//! |--------|-----------------|----------------------------------------|
//! | 0      | 8               | magic `b"FKSHARD1"`                    |
//! | 8      | 8 (u64)         | `row_start` — global first row         |
//! | 16     | 8 (u64)         | `n_rows` — rows in this shard          |
//! | 24     | 8 (u64)         | `n_cols` — global column count         |
//! | 32     | 8 (u64)         | `nnz` — stored entries                 |
//! | 40     | 8·(n_rows+1)    | `indptr` as u64, shard-relative        |
//! | …      | 4·nnz           | `indices` as u32, sorted within rows   |
//! | …      | 4·nnz           | `data` as f32 raw bits                 |
//!
//! Values round-trip bit-for-bit (f32 bits are stored verbatim), so a
//! shard directory reproduces the in-memory CSR exactly.
//!
//! # Manifest (`manifest.json`)
//!
//! ```text
//! { "format": "fk-shards-v1", "n_rows": N, "n_cols": N,
//!   "dtype": "f32", "index_dtype": "u32", "kind": "<proximity name>",
//!   "total_nnz": nnz,
//!   "shards": [ {"file": "shard-00000.bin", "row_start": 0,
//!                "n_rows": r, "nnz": z,
//!                "checksum": "<16 hex digits>"}, … ] }
//! ```
//!
//! `checksum` is 64-bit FNV-1a ([`fnv1a64`]) over the *complete* shard
//! file, header included, rendered as 16 lowercase hex digits. Readers
//! verify it when present and tolerate its absence, so directories
//! written by the pre-checksum layout still open.
//!
//! # Fragment manifests (`manifest-part-KKK.json`)
//!
//! Multi-process materialization runs one coordinator per OS process
//! over a disjoint global row range (planned by
//! [`crate::coordinator::partition_rows`]). Worker `K` opens the sink
//! with [`ShardSink::create_fragment`]: its shard files are named
//! `part-KKK-shard-NNNNN.bin` (collision-free across workers) and its
//! manifest is written as `manifest-part-KKK.json` with format
//! `fk-shards-frag-v1` — the same fields as the canonical manifest
//! plus `"part": K`, `"row_start": A` (the fragment's global base
//! row), and `"total_rows": N` (the WHOLE kernel's row count, repeated
//! in every fragment so a missing tail fragment is as detectable as an
//! interior gap); `n_rows`/`total_nnz` cover only the fragment. A
//! directory holding fragments but no merged `manifest.json` is *not*
//! readable: [`ShardReader::open`] fails with a pointer to the repair
//! path. [`merge_fragments`] (CLI: `repro shards merge`) fuses the
//! fragments into one canonical `fk-shards-v1` manifest, checking that
//! the shards tile exactly `[0, total_rows)` contiguously with no
//! overlap or gap and that every file exists at exactly the size its
//! metadata implies;
//! [`validate_dir`] (CLI: `repro shards validate`) additionally
//! re-reads every shard, verifying checksums, header/manifest
//! agreement, and structural CSR invariants.
//!
//! All manifests are parsed with the in-repo [`crate::runtime::json`]
//! parser (the same one the AOT artifact manifests use), keeping the
//! on-disk story serde-free.

use super::sink::{CsrSink, KernelSink, KernelSource};
use super::Stripe;
use crate::bench_support::json_escape;
use crate::error::{Context, Result};
use crate::runtime::json::Json;
use crate::sparse::Csr;
use crate::{anyhow, bail};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"FKSHARD1";
const FORMAT: &str = "fk-shards-v1";
const FRAG_FORMAT: &str = "fk-shards-frag-v1";
const HEADER_BYTES: usize = 40;

/// 64-bit FNV-1a over a byte slice — the shard-file checksum (in-repo;
/// the offline vendor set has no hashing crates).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Per-shard bookkeeping, mirrored in the manifest. `checksum` is
/// [`fnv1a64`] of the whole shard file; `None` only when reading a
/// manifest from the pre-checksum layout.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMeta {
    pub file: String,
    pub row_start: usize,
    pub n_rows: usize,
    pub nnz: usize,
    pub checksum: Option<u64>,
}

impl ShardMeta {
    /// Exact on-disk size (bytes) the shard file must have.
    fn file_bytes(&self) -> usize {
        HEADER_BYTES + 8 * (self.n_rows + 1) + 8 * self.nnz
    }
}

/// Spill-to-disk [`KernelSink`]: every consumed stripe becomes one
/// shard file under `dir`; [`ShardSink::finish`] writes the manifest
/// (canonical, or a `manifest-part-KKK.json` fragment in worker mode).
/// Peak memory is one stripe regardless of N.
pub struct ShardSink {
    dir: PathBuf,
    n_cols: usize,
    kind: String,
    shards: Vec<ShardMeta>,
    /// Global row at which this sink's coverage starts (0 for the
    /// single-process canonical sink; the worker's range start in
    /// fragment mode).
    base_row: usize,
    /// Fragment id and the kernel's TOTAL row count in multi-process
    /// worker mode; `None` writes the canonical `manifest.json`
    /// directly. The total is recorded in every fragment so the merge
    /// can prove the parts tile all of `[0, N)` — without it a missing
    /// *tail* fragment would be undetectable.
    part: Option<(usize, usize)>,
    rows_seen: usize,
    nnz_total: u64,
    bytes_written: u64,
}

/// The on-disk name of fragment `part`'s manifest.
fn fragment_manifest_name(part: usize) -> String {
    format!("manifest-part-{part:03}.json")
}

impl ShardSink {
    /// Create the shard directory, clearing any previous generation
    /// (manifest first, then `shard-*.bin`): a stale manifest must
    /// never pair with freshly written shards after a crash mid-run —
    /// a directory with shards but no manifest fails cleanly instead.
    pub fn create(dir: &Path, n_cols: usize, kind: &str) -> Result<ShardSink> {
        Self::create_inner(dir, n_cols, kind, None, 0)
    }

    /// Open the sink in multi-process worker mode: this process covers
    /// global rows `[row_start, …)` as fragment `part` of a shard
    /// directory shared with the other workers; `total_rows` is the
    /// whole kernel's N, recorded in the fragment manifest so the
    /// merge can prove complete coverage. Only *this* part's previous
    /// files are cleared (workers run concurrently), plus any stale
    /// merged `manifest.json` — removing it is idempotent across
    /// concurrently starting workers, and a half-written generation
    /// must never pair with an old merged manifest.
    pub fn create_fragment(
        dir: &Path,
        n_cols: usize,
        kind: &str,
        part: usize,
        row_start: usize,
        total_rows: usize,
    ) -> Result<ShardSink> {
        Self::create_inner(dir, n_cols, kind, Some((part, total_rows)), row_start)
    }

    fn create_inner(
        dir: &Path,
        n_cols: usize,
        kind: &str,
        part: Option<(usize, usize)>,
        base_row: usize,
    ) -> Result<ShardSink> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating shard dir {}", dir.display()))?;
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        if let Some((k, _)) = part {
            let _ = std::fs::remove_file(dir.join(fragment_manifest_name(k)));
        }
        let bin_prefix = match part {
            Some((k, _)) => format!("part-{k:03}-shard-"),
            None => String::new(),
        };
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                let stale = match part {
                    // Worker mode: clear only this part's previous
                    // generation (siblings are writing concurrently).
                    Some(_) => name.starts_with(&bin_prefix) && name.ends_with(".bin"),
                    // Canonical mode owns the whole directory: clear
                    // plain shards AND any leftover fragment files so
                    // a later `shards merge` cannot resurrect a stale
                    // generation over this manifest.
                    None => {
                        (name.ends_with(".bin")
                            && (name.starts_with("shard-") || name.starts_with("part-")))
                            || (name.starts_with("manifest-part-") && name.ends_with(".json"))
                    }
                };
                if stale {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        Ok(ShardSink {
            dir: dir.to_path_buf(),
            n_cols,
            kind: kind.to_string(),
            shards: vec![],
            base_row,
            part,
            rows_seen: 0,
            nnz_total: 0,
            bytes_written: 0,
        })
    }

    /// Total bytes written to shard files so far.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    /// Write the manifest — canonical `manifest.json`, or this worker's
    /// `manifest-part-KKK.json` fragment — and return the layout.
    pub fn finish(self) -> Result<Vec<ShardMeta>> {
        let frag = self.part.map(|(k, total)| (k, self.base_row, total));
        let body = manifest_body(
            frag,
            self.rows_seen,
            self.n_cols,
            &self.kind,
            self.nnz_total,
            &self.shards,
        );
        let name = match self.part {
            Some((k, _)) => fragment_manifest_name(k),
            None => "manifest.json".to_string(),
        };
        let path = self.dir.join(name);
        std::fs::write(&path, body)
            .with_context(|| format!("writing manifest {}", path.display()))?;
        Ok(self.shards)
    }
}

/// Render a manifest document: the canonical `fk-shards-v1` layout
/// when `frag` is `None`, else the `fk-shards-frag-v1` fragment layout
/// with its `part`/`row_start` fields. Shared by [`ShardSink::finish`]
/// and [`merge_fragments`].
fn manifest_body(
    frag: Option<(usize, usize, usize)>,
    n_rows: usize,
    n_cols: usize,
    kind: &str,
    total_nnz: u64,
    shards: &[ShardMeta],
) -> String {
    let mut body = String::new();
    body.push_str("{\n");
    match frag {
        Some((part, row_start, total_rows)) => {
            body.push_str(&format!("  \"format\": \"{FRAG_FORMAT}\",\n"));
            body.push_str(&format!("  \"part\": {part},\n"));
            body.push_str(&format!("  \"row_start\": {row_start},\n"));
            body.push_str(&format!("  \"total_rows\": {total_rows},\n"));
        }
        None => body.push_str(&format!("  \"format\": \"{FORMAT}\",\n")),
    }
    body.push_str(&format!("  \"n_rows\": {n_rows},\n"));
    body.push_str(&format!("  \"n_cols\": {n_cols},\n"));
    body.push_str("  \"dtype\": \"f32\",\n");
    body.push_str("  \"index_dtype\": \"u32\",\n");
    body.push_str(&format!("  \"kind\": {},\n", json_escape(kind)));
    body.push_str(&format!("  \"total_nnz\": {total_nnz},\n"));
    body.push_str("  \"shards\": [\n");
    for (i, s) in shards.iter().enumerate() {
        let checksum = match s.checksum {
            Some(c) => format!(", \"checksum\": \"{c:016x}\""),
            None => String::new(),
        };
        body.push_str(&format!(
            "    {{\"file\": {}, \"row_start\": {}, \"n_rows\": {}, \"nnz\": {}{}}}{}\n",
            json_escape(&s.file),
            s.row_start,
            s.n_rows,
            s.nnz,
            checksum,
            if i + 1 < shards.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n}\n");
    body
}

impl KernelSink for ShardSink {
    fn consume(&mut self, stripe: Stripe) -> Result<()> {
        if stripe.row_start != self.base_row + self.rows_seen {
            bail!(
                "stripe out of order: row_start {} but sink covers rows {}..{}",
                stripe.row_start,
                self.base_row,
                self.base_row + self.rows_seen
            );
        }
        let rows = &stripe.rows;
        if rows.n_cols != self.n_cols {
            bail!("stripe n_cols {} != sink n_cols {}", rows.n_cols, self.n_cols);
        }
        let file = match self.part {
            Some((k, _)) => format!("part-{k:03}-shard-{:05}.bin", self.shards.len()),
            None => format!("shard-{:05}.bin", self.shards.len()),
        };
        let nnz = rows.nnz();
        let mut buf: Vec<u8> =
            Vec::with_capacity(HEADER_BYTES + 8 * (rows.n_rows + 1) + 8 * nnz);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(stripe.row_start as u64).to_le_bytes());
        buf.extend_from_slice(&(rows.n_rows as u64).to_le_bytes());
        buf.extend_from_slice(&(rows.n_cols as u64).to_le_bytes());
        buf.extend_from_slice(&(nnz as u64).to_le_bytes());
        for &p in &rows.indptr {
            buf.extend_from_slice(&(p as u64).to_le_bytes());
        }
        for &c in &rows.indices {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        for &v in &rows.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let checksum = fnv1a64(&buf);
        let path = self.dir.join(&file);
        std::fs::write(&path, &buf)
            .with_context(|| format!("writing shard {}", path.display()))?;
        self.bytes_written += buf.len() as u64;
        self.shards.push(ShardMeta {
            file,
            row_start: stripe.row_start,
            n_rows: rows.n_rows,
            nnz,
            checksum: Some(checksum),
        });
        self.rows_seen += rows.n_rows;
        self.nnz_total += nnz as u64;
        Ok(())
    }
}

/// Streams a shard directory back in row order — the out-of-core twin
/// of an in-memory CSR (both implement [`KernelSource`]).
pub struct ShardReader {
    dir: PathBuf,
    n_rows: usize,
    n_cols: usize,
    kind: String,
    total_nnz: u64,
    shards: Vec<ShardMeta>,
}

/// A parsed manifest document — canonical or fragment.
struct ManifestDoc {
    format: String,
    /// Fragment id (`None` for the canonical manifest).
    part: Option<usize>,
    /// Fragment global base row (0 for the canonical manifest).
    row_start: usize,
    /// The whole kernel's row count as recorded by a fragment (`None`
    /// for the canonical manifest, whose `n_rows` IS the total).
    total_rows: Option<usize>,
    n_rows: usize,
    n_cols: usize,
    kind: String,
    total_nnz: u64,
    shards: Vec<ShardMeta>,
}

/// Parse a manifest file (either format); shard-entry ordering is NOT
/// checked here — callers apply their own coverage rules.
fn parse_manifest(path: &Path) -> Result<ManifestDoc> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading manifest {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let format = j.get("format").and_then(Json::as_str).unwrap_or("").to_string();
    let n_rows = j
        .get("n_rows")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("{}: manifest missing n_rows", path.display()))?;
    let n_cols = j
        .get("n_cols")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("{}: manifest missing n_cols", path.display()))?;
    let kind = j.get("kind").and_then(Json::as_str).unwrap_or("unknown").to_string();
    let total_nnz = j.get("total_nnz").and_then(Json::as_usize).unwrap_or(0) as u64;
    let part = j.get("part").and_then(Json::as_usize);
    let row_start = j.get("row_start").and_then(Json::as_usize).unwrap_or(0);
    let total_rows = j.get("total_rows").and_then(Json::as_usize);
    let entries = j
        .get("shards")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{}: manifest missing shards array", path.display()))?;
    let mut shards = Vec::with_capacity(entries.len());
    for e in entries {
        shards.push(ShardMeta {
            file: e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("shard entry missing file"))?
                .to_string(),
            row_start: e
                .get("row_start")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("shard entry missing row_start"))?,
            n_rows: e
                .get("n_rows")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("shard entry missing n_rows"))?,
            nnz: e
                .get("nnz")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("shard entry missing nnz"))?,
            // Absent => legitimately legacy (pre-checksum layout);
            // present but unparseable => corrupt manifest, a hard error
            // (silently skipping verification would defeat the field).
            checksum: match e.get("checksum") {
                None => None,
                Some(c) => {
                    let s = c.as_str().ok_or_else(|| {
                        anyhow!("{}: shard entry checksum is not a string", path.display())
                    })?;
                    Some(u64::from_str_radix(s, 16).map_err(|_| {
                        anyhow!("{}: shard entry checksum {s:?} is not hex", path.display())
                    })?)
                }
            },
        });
    }
    Ok(ManifestDoc {
        format,
        part,
        row_start,
        total_rows,
        n_rows,
        n_cols,
        kind,
        total_nnz,
        shards,
    })
}

/// The fragment manifests (`manifest-part-*.json`) present in `dir`,
/// sorted by file name (i.e. by part id — parts are zero-padded).
pub fn fragment_manifests(dir: &Path) -> Result<Vec<PathBuf>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing shard dir {}", dir.display()))?;
    let mut out = vec![];
    for e in entries.flatten() {
        let name = e.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("manifest-part-") && name.ends_with(".json") {
            out.push(e.path());
        }
    }
    out.sort();
    Ok(out)
}

impl ShardReader {
    /// Open and validate `dir/manifest.json`. A directory holding
    /// fragment manifests but no merged manifest (a crashed or
    /// unfinished multi-process run) fails with a pointer to
    /// `shards merge`, which repairs it.
    pub fn open(dir: &Path) -> Result<ShardReader> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            let frags = fragment_manifests(dir).unwrap_or_default();
            if !frags.is_empty() {
                bail!(
                    "{}: no merged manifest.json, but {} fragment manifest(s) present — \
                     run `repro shards merge --dir {}` to fuse them",
                    dir.display(),
                    frags.len(),
                    dir.display()
                );
            }
        }
        let doc = parse_manifest(&path)?;
        if doc.format != FORMAT {
            bail!("unsupported shard format {:?} (expected {FORMAT:?})", doc.format);
        }
        let mut expect_row = 0usize;
        for meta in &doc.shards {
            if meta.row_start != expect_row {
                bail!("shard {} starts at row {} (expected {expect_row})", meta.file, meta.row_start);
            }
            expect_row += meta.n_rows;
        }
        if expect_row != doc.n_rows {
            bail!("shards cover {expect_row} rows but manifest says {}", doc.n_rows);
        }
        Ok(ShardReader {
            dir: dir.to_path_buf(),
            n_rows: doc.n_rows,
            n_cols: doc.n_cols,
            kind: doc.kind,
            total_nnz: doc.total_nnz,
            shards: doc.shards,
        })
    }

    pub fn kind(&self) -> &str {
        &self.kind
    }

    pub fn total_nnz(&self) -> u64 {
        self.total_nnz
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[ShardMeta] {
        &self.shards
    }

    /// Index of the shard holding global `row`, or `None` when the row
    /// is out of range. O(log #shards) — the random-access entry point
    /// the online `/neighbors` row lookups and the sampled validator
    /// share.
    pub fn shard_of_row(&self, row: usize) -> Option<usize> {
        if row >= self.n_rows {
            return None;
        }
        let si = self.shards.partition_point(|m| m.row_start + m.n_rows <= row);
        (si < self.shards.len()).then_some(si)
    }

    /// Read one global kernel row as owned `(columns, values)`. Reads
    /// (and checksum-verifies) the containing shard; callers doing many
    /// nearby lookups should cache the [`Stripe`] from
    /// [`ShardReader::read_stripe`] keyed by [`ShardReader::shard_of_row`].
    pub fn read_row(&self, row: usize) -> Result<(Vec<u32>, Vec<f32>)> {
        let si = self
            .shard_of_row(row)
            .ok_or_else(|| anyhow!("row {row} out of range for a {}-row kernel", self.n_rows))?;
        let stripe = self.read_stripe(si)?;
        let (cols, vals) = stripe.rows.row(row - stripe.row_start);
        Ok((cols.to_vec(), vals.to_vec()))
    }

    /// Read and validate one shard as a [`Stripe`].
    pub fn read_stripe(&self, i: usize) -> Result<Stripe> {
        let meta = &self.shards[i];
        let path = self.dir.join(&meta.file);
        let buf = std::fs::read(&path)
            .with_context(|| format!("reading shard {}", path.display()))?;
        if let Some(want) = meta.checksum {
            let got = fnv1a64(&buf);
            if got != want {
                bail!(
                    "{}: checksum mismatch (manifest {want:016x}, file {got:016x})",
                    meta.file
                );
            }
        }
        parse_stripe_buf(meta, self.n_cols, &buf)
    }

    /// Visit every shard as a [`Stripe`], in row order.
    pub fn for_each_stripe(&self, mut f: impl FnMut(Stripe) -> Result<()>) -> Result<()> {
        for i in 0..self.shards.len() {
            f(self.read_stripe(i)?)?;
        }
        Ok(())
    }

    /// Load the whole kernel back into one in-memory CSR (tests and
    /// small-N verification; defeats the point at large N).
    pub fn read_csr(&self) -> Result<Csr> {
        let mut sink = CsrSink::new(self.n_cols);
        self.for_each_stripe(|s| sink.consume(s))?;
        Ok(sink.finish())
    }
}

impl KernelSource for ShardReader {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn for_each_row(&self, f: &mut dyn FnMut(usize, &[u32], &[f32])) -> Result<()> {
        self.for_each_stripe(|s| {
            for r in 0..s.rows.n_rows {
                let (cols, vals) = s.rows.row(r);
                f(s.row_start + r, cols, vals);
            }
            Ok(())
        })
    }
}

/// Remove every fragment artifact (`manifest-part-*.json`,
/// `part-*-shard-*.bin`) from `dir`. The parent of a multi-process run
/// calls this before spawning workers: each worker only clears its
/// *own* part, so without this a rerun with fewer parts would leave
/// higher-numbered fragments from the previous generation on disk and
/// [`merge_fragments`] would reject the directory as overlapping. A
/// missing directory is fine (the workers create it).
pub fn clear_fragments(dir: &Path) -> Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()),
    };
    for e in entries.flatten() {
        let name = e.file_name();
        let name = name.to_string_lossy();
        let frag = (name.starts_with("manifest-part-") && name.ends_with(".json"))
            || (name.starts_with("part-") && name.ends_with(".bin"));
        if frag {
            std::fs::remove_file(e.path())
                .with_context(|| format!("clearing stale fragment {}", e.path().display()))?;
        }
    }
    Ok(())
}

/// What [`merge_fragments`] fused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergeReport {
    pub parts: usize,
    pub shards: usize,
    pub n_rows: usize,
    pub n_cols: usize,
    pub total_nnz: u64,
}

/// Fuse the `manifest-part-*.json` fragments in `dir` into one
/// canonical `fk-shards-v1` manifest. Checks that every fragment
/// agrees on `n_cols`/`kind`, that the shards (ordered by global
/// `row_start`) tile `[0, N)` contiguously with no overlap or gap, and
/// that every shard file exists at exactly the size its metadata
/// implies. Idempotent: re-running over an already-merged directory
/// that still has its fragments rewrites the same manifest.
pub fn merge_fragments(dir: &Path) -> Result<MergeReport> {
    let frags = fragment_manifests(dir)?;
    if frags.is_empty() {
        bail!("{}: no manifest fragments (manifest-part-*.json) to merge", dir.display());
    }
    let mut docs = Vec::with_capacity(frags.len());
    for p in &frags {
        let doc = parse_manifest(p)?;
        if doc.format != FRAG_FORMAT {
            bail!(
                "{}: format {:?} is not a fragment manifest (expected {FRAG_FORMAT:?})",
                p.display(),
                doc.format
            );
        }
        docs.push(doc);
    }
    let n_cols = docs[0].n_cols;
    let kind = docs[0].kind.clone();
    // Every fragment records the whole kernel's N; requiring agreement
    // and full coverage below makes a missing TAIL fragment (which
    // leaves a perfectly contiguous prefix) as detectable as an
    // interior gap.
    let kernel_rows = docs[0]
        .total_rows
        .ok_or_else(|| anyhow!("{}: fragment manifest missing total_rows", frags[0].display()))?;
    for (p, d) in frags.iter().zip(&docs) {
        if d.total_rows != Some(kernel_rows) {
            bail!(
                "{}: fragment claims a kernel of {:?} rows but part {:?} claims {kernel_rows}",
                p.display(),
                d.total_rows,
                docs[0].part
            );
        }
        if d.n_cols != n_cols || d.kind != kind {
            bail!(
                "{}: fragment disagrees with part {:?} \
                 (n_cols {} kind {:?} vs n_cols {n_cols} kind {kind:?})",
                p.display(),
                docs[0].part,
                d.n_cols,
                d.kind
            );
        }
        let covered: usize = d.shards.iter().map(|s| s.n_rows).sum();
        if covered != d.n_rows {
            bail!(
                "{}: fragment shards cover {covered} rows but it claims {}",
                p.display(),
                d.n_rows
            );
        }
        if let Some(first) = d.shards.first() {
            if first.row_start != d.row_start {
                bail!(
                    "{}: fragment claims base row {} but its first shard starts at {}",
                    p.display(),
                    d.row_start,
                    first.row_start
                );
            }
        }
    }
    let mut shards: Vec<ShardMeta> =
        docs.iter().flat_map(|d| d.shards.iter().cloned()).collect();
    shards.sort_by_key(|s| s.row_start);
    let mut expect_row = 0usize;
    let mut total_nnz = 0u64;
    for s in &shards {
        if s.row_start < expect_row {
            bail!(
                "shard {} overlaps: starts at row {} but rows are already \
                 covered through {expect_row}",
                s.file,
                s.row_start
            );
        }
        if s.row_start > expect_row {
            bail!(
                "coverage gap: rows {expect_row}..{} missing before shard {}",
                s.row_start,
                s.file
            );
        }
        let path = dir.join(&s.file);
        let len = std::fs::metadata(&path)
            .with_context(|| format!("stat shard {}", path.display()))?
            .len();
        if len != s.file_bytes() as u64 {
            bail!("{}: {len} bytes on disk, expected {}", s.file, s.file_bytes());
        }
        expect_row += s.n_rows;
        total_nnz += s.nnz as u64;
    }
    if expect_row != kernel_rows {
        bail!(
            "fragments cover rows 0..{expect_row} but the kernel has {kernel_rows} rows — \
             a tail fragment is missing (rerun its worker, then merge again)"
        );
    }
    let body = manifest_body(None, expect_row, n_cols, &kind, total_nnz, &shards);
    let path = dir.join("manifest.json");
    std::fs::write(&path, body)
        .with_context(|| format!("writing merged manifest {}", path.display()))?;
    Ok(MergeReport {
        parts: docs.len(),
        shards: shards.len(),
        n_rows: expect_row,
        n_cols,
        total_nnz,
    })
}

/// What [`validate_dir`] checked.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ValidateReport {
    pub shards: usize,
    pub n_rows: usize,
    pub total_nnz: u64,
    pub bytes: u64,
}

/// Full offline validation of a merged shard directory: manifest
/// coverage/ordering/format (via [`ShardReader::open`]), then for every
/// shard the exact file size, the FNV-1a checksum, header/manifest
/// agreement, and the structural CSR invariants (monotone indptr,
/// sorted in-bounds columns). Strict about checksums: entries written
/// by the pre-checksum layout fail validation (re-materialize to
/// upgrade them) even though the read path still accepts them.
pub fn validate_dir(dir: &Path) -> Result<ValidateReport> {
    let reader = ShardReader::open(dir)?;
    let mut bytes = 0u64;
    let mut nnz = 0u64;
    let mut rows = 0usize;
    for meta in &reader.shards {
        let path = reader.dir.join(&meta.file);
        let buf = std::fs::read(&path)
            .with_context(|| format!("reading shard {}", path.display()))?;
        if buf.len() != meta.file_bytes() {
            bail!("{}: {} bytes on disk, expected {}", meta.file, buf.len(), meta.file_bytes());
        }
        match meta.checksum {
            Some(want) => {
                let got = fnv1a64(&buf);
                if got != want {
                    bail!(
                        "{}: checksum mismatch (manifest {want:016x}, file {got:016x})",
                        meta.file
                    );
                }
            }
            None => bail!(
                "{}: manifest entry carries no checksum (pre-checksum layout) — \
                 re-materialize to upgrade",
                meta.file
            ),
        }
        // Structural checks on the buffer already in hand (one read +
        // one hash per shard, not two of each via read_stripe).
        let stripe = parse_stripe_buf(meta, reader.n_cols, &buf)?;
        rows += stripe.rows.n_rows;
        nnz += stripe.rows.nnz() as u64;
        bytes += buf.len() as u64;
    }
    if nnz != reader.total_nnz {
        bail!("shards hold {nnz} nnz but the manifest claims {}", reader.total_nnz);
    }
    Ok(ValidateReport { shards: reader.shards.len(), n_rows: rows, total_nnz: nnz, bytes })
}

/// Decode shard-file bytes into a [`Stripe`], checking magic, header
/// agreement with `meta`, exact length, and the full structural CSR
/// invariants (monotone indptr, sorted in-bounds columns) so corrupt
/// payload bytes surface as a clean error rather than a panic in a
/// downstream consumer. Checksum verification is the caller's job —
/// [`ShardReader::read_stripe`] hashes what it reads, [`validate_dir`]
/// hashes the buffer it already holds.
fn parse_stripe_buf(meta: &ShardMeta, n_cols_expect: usize, buf: &[u8]) -> Result<Stripe> {
    let mut off = 0usize;
    if buf.len() < HEADER_BYTES || buf[..8] != MAGIC[..] {
        bail!("{}: bad shard magic", meta.file);
    }
    off += 8;
    let row_start = take_u64(buf, &mut off, &meta.file)? as usize;
    let n_rows = take_u64(buf, &mut off, &meta.file)? as usize;
    let n_cols = take_u64(buf, &mut off, &meta.file)? as usize;
    let nnz = take_u64(buf, &mut off, &meta.file)? as usize;
    if row_start != meta.row_start || n_rows != meta.n_rows || nnz != meta.nnz {
        bail!("{}: header disagrees with manifest", meta.file);
    }
    if n_cols != n_cols_expect {
        bail!("{}: n_cols {} != manifest {}", meta.file, n_cols, n_cols_expect);
    }
    let need = HEADER_BYTES + 8 * (n_rows + 1) + 8 * nnz;
    if buf.len() != need {
        bail!("{}: {} bytes on disk, expected {need}", meta.file, buf.len());
    }
    let mut indptr = Vec::with_capacity(n_rows + 1);
    for b in buf[off..off + 8 * (n_rows + 1)].chunks_exact(8) {
        indptr.push(u64::from_le_bytes(b.try_into().unwrap()) as usize);
    }
    off += 8 * (n_rows + 1);
    if indptr[0] != 0 || indptr[n_rows] != nnz {
        bail!("{}: corrupt indptr", meta.file);
    }
    let mut indices = Vec::with_capacity(nnz);
    for b in buf[off..off + 4 * nnz].chunks_exact(4) {
        indices.push(u32::from_le_bytes(b.try_into().unwrap()));
    }
    off += 4 * nnz;
    let mut data = Vec::with_capacity(nnz);
    for b in buf[off..off + 4 * nnz].chunks_exact(4) {
        data.push(f32::from_le_bytes(b.try_into().unwrap()));
    }
    let rows = Csr { n_rows, n_cols, indptr: indptr.into(), indices: indices.into(), data: data.into() };
    rows.check().map_err(|e| anyhow!("{}: corrupt shard: {e}", meta.file))?;
    Ok(Stripe { row_start, rows })
}

fn take_u64(buf: &[u8], off: &mut usize, file: &str) -> Result<u64> {
    let end = *off + 8;
    if end > buf.len() {
        bail!("{file}: truncated at byte {off}");
    }
    let b: [u8; 8] = buf[*off..end].try_into().unwrap();
    *off = end;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fk-shard-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample_stripes() -> Vec<Stripe> {
        vec![
            Stripe {
                row_start: 0,
                rows: Csr::from_triplets(2, 4, &[(0, 0, 1.5), (0, 3, -0.25), (1, 1, 2.0)]),
            },
            Stripe { row_start: 2, rows: Csr::from_triplets(1, 4, &[]) },
            Stripe { row_start: 3, rows: Csr::from_triplets(1, 4, &[(0, 2, 0.125)]) },
        ]
    }

    #[test]
    fn shard_write_read_roundtrip() {
        let dir = tmpdir("roundtrip");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        assert!(sink.bytes_written() > 0);
        let metas = sink.finish().unwrap();
        assert_eq!(metas.len(), 3);

        let reader = ShardReader::open(&dir).unwrap();
        assert_eq!(KernelSource::n_rows(&reader), 4);
        assert_eq!(KernelSource::n_cols(&reader), 4);
        assert_eq!(reader.kind(), "kerf");
        assert_eq!(reader.n_shards(), 3);
        assert_eq!(reader.total_nnz(), 4);
        let p = reader.read_csr().unwrap();
        p.check().unwrap();
        let expect = Csr::from_triplets(
            4,
            4,
            &[(0, 0, 1.5), (0, 3, -0.25), (1, 1, 2.0), (3, 2, 0.125)],
        );
        assert_eq!(p, expect);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_order_stripe_rejected() {
        let dir = tmpdir("order");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        let bad = Stripe { row_start: 5, rows: Csr::from_triplets(1, 4, &[]) };
        assert!(sink.consume(bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = tmpdir("magic");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        sink.finish().unwrap();
        // Flip the magic of the first shard.
        let path = dir.join("shard-00000.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let reader = ShardReader::open(&dir).unwrap();
        assert!(reader.read_stripe(0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = tmpdir("missing");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ShardReader::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn expected_csr() -> Csr {
        Csr::from_triplets(
            4,
            4,
            &[(0, 0, 1.5), (0, 3, -0.25), (1, 1, 2.0), (3, 2, 0.125)],
        )
    }

    /// Part 0 covers global rows 0..3 (two stripes), part 1 covers 3..4.
    fn write_fragments(dir: &Path) {
        let mut it = sample_stripes().into_iter();
        let (a, b, c) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut s0 = ShardSink::create_fragment(dir, 4, "kerf", 0, 0, 4).unwrap();
        s0.consume(a).unwrap();
        s0.consume(b).unwrap();
        s0.finish().unwrap();
        let mut s1 = ShardSink::create_fragment(dir, 4, "kerf", 1, 3, 4).unwrap();
        s1.consume(c).unwrap();
        s1.finish().unwrap();
    }

    #[test]
    fn fnv1a64_reference_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fragments_merge_into_readable_directory() {
        let dir = tmpdir("frag-merge");
        write_fragments(&dir);
        let report = merge_fragments(&dir).unwrap();
        assert_eq!(report, MergeReport { parts: 2, shards: 3, n_rows: 4, n_cols: 4, total_nnz: 4 });
        let reader = ShardReader::open(&dir).unwrap();
        assert_eq!(reader.kind(), "kerf");
        assert_eq!(reader.n_shards(), 3);
        assert!(reader.shards().iter().all(|s| s.checksum.is_some()));
        assert_eq!(reader.read_csr().unwrap(), expected_csr());
        // Merge is idempotent while the fragments remain on disk.
        assert_eq!(merge_fragments(&dir).unwrap(), report);
        assert_eq!(ShardReader::open(&dir).unwrap().read_csr().unwrap(), expected_csr());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unmerged_fragments_fail_cleanly_and_merge_repairs() {
        // Crash-recovery: a directory with fragments but no merged
        // manifest must fail with a pointer to the repair path, and
        // `merge_fragments` must then make it readable.
        let dir = tmpdir("frag-crash");
        write_fragments(&dir);
        let err = ShardReader::open(&dir).unwrap_err().to_string();
        assert!(err.contains("shards merge"), "unhelpful error: {err}");
        assert!(err.contains("2 fragment"), "unhelpful error: {err}");
        merge_fragments(&dir).unwrap();
        assert_eq!(ShardReader::open(&dir).unwrap().read_csr().unwrap(), expected_csr());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_rejects_gap_and_overlap() {
        // Gap: part 1 starts at row 4 while part 0 covers 0..3.
        let dir = tmpdir("frag-gap");
        let mut it = sample_stripes().into_iter();
        let (a, b, _) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut s0 = ShardSink::create_fragment(&dir, 4, "kerf", 0, 0, 5).unwrap();
        s0.consume(a).unwrap();
        s0.consume(b).unwrap();
        s0.finish().unwrap();
        let mut s1 = ShardSink::create_fragment(&dir, 4, "kerf", 1, 4, 5).unwrap();
        s1.consume(Stripe { row_start: 4, rows: Csr::from_triplets(1, 4, &[]) }).unwrap();
        s1.finish().unwrap();
        let err = merge_fragments(&dir).unwrap_err().to_string();
        assert!(err.contains("gap"), "wrong error: {err}");
        std::fs::remove_dir_all(&dir).ok();

        // Overlap: part 1 re-covers row 2.
        let dir = tmpdir("frag-overlap");
        let mut it = sample_stripes().into_iter();
        let (a, b, _) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut s0 = ShardSink::create_fragment(&dir, 4, "kerf", 0, 0, 4).unwrap();
        s0.consume(a).unwrap();
        s0.consume(b).unwrap();
        s0.finish().unwrap();
        let mut s1 = ShardSink::create_fragment(&dir, 4, "kerf", 1, 2, 4).unwrap();
        s1.consume(Stripe { row_start: 2, rows: Csr::from_triplets(1, 4, &[]) }).unwrap();
        s1.finish().unwrap();
        let err = merge_fragments(&dir).unwrap_err().to_string();
        assert!(err.contains("overlap"), "wrong error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_detects_missing_tail_fragment() {
        // Only part 0 of a 2-part run over a 4-row kernel is present:
        // the surviving shards tile [0, 3) contiguously, so without the
        // recorded total the merge would silently truncate the kernel.
        let dir = tmpdir("tail");
        let mut it = sample_stripes().into_iter();
        let (a, b, _) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut s0 = ShardSink::create_fragment(&dir, 4, "kerf", 0, 0, 4).unwrap();
        s0.consume(a).unwrap();
        s0.consume(b).unwrap();
        s0.finish().unwrap();
        let err = merge_fragments(&dir).unwrap_err().to_string();
        assert!(err.contains("tail fragment is missing"), "wrong error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clear_fragments_enables_rerun_with_fewer_parts() {
        // First generation: 4 single-row parts. A rerun with 2 parts
        // only overwrites parts 0 and 1, so without clearing, parts 2
        // and 3 would survive and the merge would see overlap.
        let dir = tmpdir("rerun");
        for k in 0..4usize {
            let mut s = ShardSink::create_fragment(&dir, 4, "kerf", k, k, 4).unwrap();
            s.consume(Stripe { row_start: k, rows: Csr::from_triplets(1, 4, &[]) }).unwrap();
            s.finish().unwrap();
        }
        merge_fragments(&dir).unwrap();
        clear_fragments(&dir).unwrap();
        write_fragments(&dir);
        let report = merge_fragments(&dir).unwrap();
        assert_eq!(report.parts, 2);
        assert_eq!(ShardReader::open(&dir).unwrap().read_csr().unwrap(), expected_csr());
        // Clearing a directory that does not exist is fine.
        clear_fragments(Path::new("/definitely/not/a/dir")).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_checksum_is_a_parse_error_not_skipped() {
        let dir = tmpdir("badsum");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        sink.finish().unwrap();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        // Garble one checksum into non-hex: verification must fail
        // loudly, not silently downgrade to "no checksum".
        let garbled = text.replacen("\"checksum\": \"", "\"checksum\": \"zz", 1);
        assert_ne!(garbled, text);
        std::fs::write(&path, garbled).unwrap();
        let err = ShardReader::open(&dir).unwrap_err().to_string();
        assert!(err.contains("checksum"), "wrong error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checksum_catches_payload_corruption() {
        let dir = tmpdir("checksum");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        sink.finish().unwrap();
        assert!(validate_dir(&dir).is_ok());
        // Flip one payload byte (last byte = value bits of the final
        // entry) — size and header stay intact, only the checksum and
        // the value change.
        let path = dir.join("shard-00000.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let reader = ShardReader::open(&dir).unwrap();
        let err = reader.read_stripe(0).unwrap_err().to_string();
        assert!(err.contains("checksum"), "wrong error: {err}");
        assert!(validate_dir(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_reports_totals() {
        let dir = tmpdir("validate");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        let written = sink.bytes_written();
        sink.finish().unwrap();
        let report = validate_dir(&dir).unwrap();
        assert_eq!(
            report,
            ValidateReport { shards: 3, n_rows: 4, total_nnz: 4, bytes: written }
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fragment_sink_rejects_rows_outside_its_range() {
        let dir = tmpdir("frag-order");
        let mut sink = ShardSink::create_fragment(&dir, 4, "kerf", 0, 10, 12).unwrap();
        // First stripe must start exactly at the fragment base row.
        let bad = Stripe { row_start: 0, rows: Csr::from_triplets(1, 4, &[]) };
        assert!(sink.consume(bad).is_err());
        let good = Stripe { row_start: 10, rows: Csr::from_triplets(1, 4, &[]) };
        sink.consume(good).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn random_access_row_reads_match_csr() {
        let dir = tmpdir("rowread");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        sink.finish().unwrap();
        let reader = ShardReader::open(&dir).unwrap();
        let csr = reader.read_csr().unwrap();
        for row in 0..4 {
            let (cols, vals) = reader.read_row(row).unwrap();
            let (ec, ev) = csr.row(row);
            assert_eq!(cols, ec, "row {row}");
            assert_eq!(vals, ev, "row {row}");
        }
        assert_eq!(reader.shard_of_row(0), Some(0));
        assert_eq!(reader.shard_of_row(3), Some(2));
        assert_eq!(reader.shard_of_row(4), None);
        assert!(reader.read_row(4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_rows_match_csr_rows() {
        let dir = tmpdir("rows");
        let mut sink = ShardSink::create(&dir, 4, "original").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        sink.finish().unwrap();
        let reader = ShardReader::open(&dir).unwrap();
        let csr = reader.read_csr().unwrap();
        let mut rows_seen = 0usize;
        KernelSource::for_each_row(&reader, &mut |r, cols, vals| {
            assert_eq!(r, rows_seen);
            let (ec, ev) = csr.row(r);
            assert_eq!(cols, ec);
            assert_eq!(vals, ev);
            rows_seen += 1;
        })
        .unwrap();
        assert_eq!(rows_seen, 4);
        std::fs::remove_dir_all(&dir).ok();
    }
}
