//! Out-of-core kernel shards: fixed-format binary stripe files plus a
//! JSON manifest, written by [`ShardSink`] and streamed back in row
//! order by [`ShardReader`].
//!
//! # Shard file format (`shard-NNNNN.bin`, little-endian throughout)
//!
//! | offset | size            | field                                  |
//! |--------|-----------------|----------------------------------------|
//! | 0      | 8               | magic `b"FKSHARD1"`                    |
//! | 8      | 8 (u64)         | `row_start` — global first row         |
//! | 16     | 8 (u64)         | `n_rows` — rows in this shard          |
//! | 24     | 8 (u64)         | `n_cols` — global column count         |
//! | 32     | 8 (u64)         | `nnz` — stored entries                 |
//! | 40     | 8·(n_rows+1)    | `indptr` as u64, shard-relative        |
//! | …      | 4·nnz           | `indices` as u32, sorted within rows   |
//! | …      | 4·nnz           | `data` as f32 raw bits                 |
//!
//! Values round-trip bit-for-bit (f32 bits are stored verbatim), so a
//! shard directory reproduces the in-memory CSR exactly.
//!
//! # Manifest (`manifest.json`)
//!
//! ```text
//! { "format": "fk-shards-v1", "n_rows": N, "n_cols": N,
//!   "dtype": "f32", "index_dtype": "u32", "kind": "<proximity name>",
//!   "total_nnz": nnz,
//!   "shards": [ {"file": "shard-00000.bin", "row_start": 0,
//!                "n_rows": r, "nnz": z}, … ] }
//! ```
//!
//! The manifest is parsed with the in-repo [`crate::runtime::json`]
//! parser (the same one the AOT artifact manifests use), keeping the
//! on-disk story serde-free.

use super::sink::{CsrSink, KernelSink, KernelSource};
use super::Stripe;
use crate::bench_support::json_escape;
use crate::error::{Context, Result};
use crate::runtime::json::Json;
use crate::sparse::Csr;
use crate::{anyhow, bail};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"FKSHARD1";
const FORMAT: &str = "fk-shards-v1";
const HEADER_BYTES: usize = 40;

/// Per-shard bookkeeping, mirrored in the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMeta {
    pub file: String,
    pub row_start: usize,
    pub n_rows: usize,
    pub nnz: usize,
}

/// Spill-to-disk [`KernelSink`]: every consumed stripe becomes one
/// shard file under `dir`; [`ShardSink::finish`] writes the manifest.
/// Peak memory is one stripe regardless of N.
pub struct ShardSink {
    dir: PathBuf,
    n_cols: usize,
    kind: String,
    shards: Vec<ShardMeta>,
    rows_seen: usize,
    nnz_total: u64,
    bytes_written: u64,
}

impl ShardSink {
    /// Create the shard directory, clearing any previous generation
    /// (manifest first, then `shard-*.bin`): a stale manifest must
    /// never pair with freshly written shards after a crash mid-run —
    /// a directory with shards but no manifest fails cleanly instead.
    pub fn create(dir: &Path, n_cols: usize, kind: &str) -> Result<ShardSink> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating shard dir {}", dir.display()))?;
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if name.starts_with("shard-") && name.ends_with(".bin") {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        Ok(ShardSink {
            dir: dir.to_path_buf(),
            n_cols,
            kind: kind.to_string(),
            shards: vec![],
            rows_seen: 0,
            nnz_total: 0,
            bytes_written: 0,
        })
    }

    /// Total bytes written to shard files so far.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    /// Write the manifest and return the shard directory layout.
    pub fn finish(self) -> Result<Vec<ShardMeta>> {
        let mut body = String::new();
        body.push_str("{\n");
        body.push_str(&format!("  \"format\": \"{FORMAT}\",\n"));
        body.push_str(&format!("  \"n_rows\": {},\n", self.rows_seen));
        body.push_str(&format!("  \"n_cols\": {},\n", self.n_cols));
        body.push_str("  \"dtype\": \"f32\",\n");
        body.push_str("  \"index_dtype\": \"u32\",\n");
        body.push_str(&format!("  \"kind\": {},\n", json_escape(&self.kind)));
        body.push_str(&format!("  \"total_nnz\": {},\n", self.nnz_total));
        body.push_str("  \"shards\": [\n");
        for (i, s) in self.shards.iter().enumerate() {
            body.push_str(&format!(
                "    {{\"file\": {}, \"row_start\": {}, \"n_rows\": {}, \"nnz\": {}}}{}\n",
                json_escape(&s.file),
                s.row_start,
                s.n_rows,
                s.nnz,
                if i + 1 < self.shards.len() { "," } else { "" }
            ));
        }
        body.push_str("  ]\n}\n");
        let path = self.dir.join("manifest.json");
        std::fs::write(&path, body)
            .with_context(|| format!("writing manifest {}", path.display()))?;
        Ok(self.shards)
    }
}

impl KernelSink for ShardSink {
    fn consume(&mut self, stripe: Stripe) -> Result<()> {
        if stripe.row_start != self.rows_seen {
            bail!(
                "stripe out of order: row_start {} but {} rows consumed",
                stripe.row_start,
                self.rows_seen
            );
        }
        let rows = &stripe.rows;
        if rows.n_cols != self.n_cols {
            bail!("stripe n_cols {} != sink n_cols {}", rows.n_cols, self.n_cols);
        }
        let file = format!("shard-{:05}.bin", self.shards.len());
        let nnz = rows.nnz();
        let mut buf: Vec<u8> =
            Vec::with_capacity(HEADER_BYTES + 8 * (rows.n_rows + 1) + 8 * nnz);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(stripe.row_start as u64).to_le_bytes());
        buf.extend_from_slice(&(rows.n_rows as u64).to_le_bytes());
        buf.extend_from_slice(&(rows.n_cols as u64).to_le_bytes());
        buf.extend_from_slice(&(nnz as u64).to_le_bytes());
        for &p in &rows.indptr {
            buf.extend_from_slice(&(p as u64).to_le_bytes());
        }
        for &c in &rows.indices {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        for &v in &rows.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let path = self.dir.join(&file);
        std::fs::write(&path, &buf)
            .with_context(|| format!("writing shard {}", path.display()))?;
        self.bytes_written += buf.len() as u64;
        self.shards.push(ShardMeta { file, row_start: stripe.row_start, n_rows: rows.n_rows, nnz });
        self.rows_seen += rows.n_rows;
        self.nnz_total += nnz as u64;
        Ok(())
    }
}

/// Streams a shard directory back in row order — the out-of-core twin
/// of an in-memory CSR (both implement [`KernelSource`]).
pub struct ShardReader {
    dir: PathBuf,
    n_rows: usize,
    n_cols: usize,
    kind: String,
    total_nnz: u64,
    shards: Vec<ShardMeta>,
}

impl ShardReader {
    /// Open and validate `dir/manifest.json`.
    pub fn open(dir: &Path) -> Result<ShardReader> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let format = j.get("format").and_then(Json::as_str).unwrap_or("");
        if format != FORMAT {
            bail!("unsupported shard format {format:?} (expected {FORMAT:?})");
        }
        let n_rows = j
            .get("n_rows")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing n_rows"))?;
        let n_cols = j
            .get("n_cols")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing n_cols"))?;
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let total_nnz = j.get("total_nnz").and_then(Json::as_usize).unwrap_or(0) as u64;
        let entries = j
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing shards array"))?;
        let mut shards = Vec::with_capacity(entries.len());
        let mut expect_row = 0usize;
        for e in entries {
            let meta = ShardMeta {
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("shard entry missing file"))?
                    .to_string(),
                row_start: e
                    .get("row_start")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("shard entry missing row_start"))?,
                n_rows: e
                    .get("n_rows")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("shard entry missing n_rows"))?,
                nnz: e
                    .get("nnz")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("shard entry missing nnz"))?,
            };
            if meta.row_start != expect_row {
                bail!("shard {} starts at row {} (expected {expect_row})", meta.file, meta.row_start);
            }
            expect_row += meta.n_rows;
            shards.push(meta);
        }
        if expect_row != n_rows {
            bail!("shards cover {expect_row} rows but manifest says {n_rows}");
        }
        Ok(ShardReader { dir: dir.to_path_buf(), n_rows, n_cols, kind, total_nnz, shards })
    }

    pub fn kind(&self) -> &str {
        &self.kind
    }

    pub fn total_nnz(&self) -> u64 {
        self.total_nnz
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[ShardMeta] {
        &self.shards
    }

    /// Read and validate one shard as a [`Stripe`].
    pub fn read_stripe(&self, i: usize) -> Result<Stripe> {
        let meta = &self.shards[i];
        let path = self.dir.join(&meta.file);
        let buf = std::fs::read(&path)
            .with_context(|| format!("reading shard {}", path.display()))?;
        let mut off = 0usize;
        if buf.len() < HEADER_BYTES || buf[..8] != MAGIC[..] {
            bail!("{}: bad shard magic", meta.file);
        }
        off += 8;
        let row_start = take_u64(&buf, &mut off, &meta.file)? as usize;
        let n_rows = take_u64(&buf, &mut off, &meta.file)? as usize;
        let n_cols = take_u64(&buf, &mut off, &meta.file)? as usize;
        let nnz = take_u64(&buf, &mut off, &meta.file)? as usize;
        if row_start != meta.row_start || n_rows != meta.n_rows || nnz != meta.nnz {
            bail!("{}: header disagrees with manifest", meta.file);
        }
        if n_cols != self.n_cols {
            bail!("{}: n_cols {} != manifest {}", meta.file, n_cols, self.n_cols);
        }
        let need = HEADER_BYTES + 8 * (n_rows + 1) + 8 * nnz;
        if buf.len() != need {
            bail!("{}: {} bytes on disk, expected {need}", meta.file, buf.len());
        }
        let mut indptr = Vec::with_capacity(n_rows + 1);
        for b in buf[off..off + 8 * (n_rows + 1)].chunks_exact(8) {
            indptr.push(u64::from_le_bytes(b.try_into().unwrap()) as usize);
        }
        off += 8 * (n_rows + 1);
        if indptr[0] != 0 || indptr[n_rows] != nnz {
            bail!("{}: corrupt indptr", meta.file);
        }
        let mut indices = Vec::with_capacity(nnz);
        for b in buf[off..off + 4 * nnz].chunks_exact(4) {
            indices.push(u32::from_le_bytes(b.try_into().unwrap()));
        }
        off += 4 * nnz;
        let mut data = Vec::with_capacity(nnz);
        for b in buf[off..off + 4 * nnz].chunks_exact(4) {
            data.push(f32::from_le_bytes(b.try_into().unwrap()));
        }
        let rows = Csr { n_rows, n_cols, indptr, indices, data };
        // Full structural validation (monotone indptr, sorted in-bounds
        // columns) so corrupt payload bytes surface as a clean error
        // here rather than a panic in a downstream consumer.
        rows.check().map_err(|e| anyhow!("{}: corrupt shard: {e}", meta.file))?;
        Ok(Stripe { row_start, rows })
    }

    /// Visit every shard as a [`Stripe`], in row order.
    pub fn for_each_stripe(&self, mut f: impl FnMut(Stripe) -> Result<()>) -> Result<()> {
        for i in 0..self.shards.len() {
            f(self.read_stripe(i)?)?;
        }
        Ok(())
    }

    /// Load the whole kernel back into one in-memory CSR (tests and
    /// small-N verification; defeats the point at large N).
    pub fn read_csr(&self) -> Result<Csr> {
        let mut sink = CsrSink::new(self.n_cols);
        self.for_each_stripe(|s| sink.consume(s))?;
        Ok(sink.finish())
    }
}

impl KernelSource for ShardReader {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn for_each_row(&self, f: &mut dyn FnMut(usize, &[u32], &[f32])) -> Result<()> {
        self.for_each_stripe(|s| {
            for r in 0..s.rows.n_rows {
                let (cols, vals) = s.rows.row(r);
                f(s.row_start + r, cols, vals);
            }
            Ok(())
        })
    }
}

fn take_u64(buf: &[u8], off: &mut usize, file: &str) -> Result<u64> {
    let end = *off + 8;
    if end > buf.len() {
        bail!("{file}: truncated at byte {off}");
    }
    let b: [u8; 8] = buf[*off..end].try_into().unwrap();
    *off = end;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fk-shard-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample_stripes() -> Vec<Stripe> {
        vec![
            Stripe {
                row_start: 0,
                rows: Csr::from_triplets(2, 4, &[(0, 0, 1.5), (0, 3, -0.25), (1, 1, 2.0)]),
            },
            Stripe { row_start: 2, rows: Csr::from_triplets(1, 4, &[]) },
            Stripe { row_start: 3, rows: Csr::from_triplets(1, 4, &[(0, 2, 0.125)]) },
        ]
    }

    #[test]
    fn shard_write_read_roundtrip() {
        let dir = tmpdir("roundtrip");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        assert!(sink.bytes_written() > 0);
        let metas = sink.finish().unwrap();
        assert_eq!(metas.len(), 3);

        let reader = ShardReader::open(&dir).unwrap();
        assert_eq!(KernelSource::n_rows(&reader), 4);
        assert_eq!(KernelSource::n_cols(&reader), 4);
        assert_eq!(reader.kind(), "kerf");
        assert_eq!(reader.n_shards(), 3);
        assert_eq!(reader.total_nnz(), 4);
        let p = reader.read_csr().unwrap();
        p.check().unwrap();
        let expect = Csr::from_triplets(
            4,
            4,
            &[(0, 0, 1.5), (0, 3, -0.25), (1, 1, 2.0), (3, 2, 0.125)],
        );
        assert_eq!(p, expect);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_order_stripe_rejected() {
        let dir = tmpdir("order");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        let bad = Stripe { row_start: 5, rows: Csr::from_triplets(1, 4, &[]) };
        assert!(sink.consume(bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = tmpdir("magic");
        let mut sink = ShardSink::create(&dir, 4, "kerf").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        sink.finish().unwrap();
        // Flip the magic of the first shard.
        let path = dir.join("shard-00000.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let reader = ShardReader::open(&dir).unwrap();
        assert!(reader.read_stripe(0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = tmpdir("missing");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ShardReader::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_rows_match_csr_rows() {
        let dir = tmpdir("rows");
        let mut sink = ShardSink::create(&dir, 4, "original").unwrap();
        for s in sample_stripes() {
            sink.consume(s).unwrap();
        }
        sink.finish().unwrap();
        let reader = ShardReader::open(&dir).unwrap();
        let csr = reader.read_csr().unwrap();
        let mut rows_seen = 0usize;
        KernelSource::for_each_row(&reader, &mut |r, cols, vals| {
            assert_eq!(r, rows_seen);
            let (ec, ev) = csr.row(r);
            assert_eq!(cols, ec);
            assert_eq!(vals, ev);
            rows_seen += 1;
        })
        .unwrap();
        assert_eq!(rows_seen, 4);
        std::fs::remove_dir_all(&dir).ok();
    }
}
