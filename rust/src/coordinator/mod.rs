//! The block coordinator: sharded kernel materialization with bounded
//! queues, plus the XLA-backed dense gallery path.
//!
//! The paper's pipeline is "build factors once, then stream products".
//! For N×N materialization the coordinator partitions the query rows
//! into stripes, fans them out to a worker pool over a *bounded* job
//! channel (backpressure: a slow sink throttles the producers instead
//! of buffering the whole kernel), and streams completed stripes to the
//! caller's sink in order. For OOS serving it batches query requests
//! into fixed-size tiles executed on the PJRT runtime (the L1 Pallas
//! tile kernel) — see [`gallery`].
//!
//! Built on std threads + `sync_channel` (the offline vendor set has no
//! tokio; on this 1-core testbed an async reactor would buy nothing —
//! DESIGN.md §Substitutions).

pub mod gallery;

use crate::sparse::{spgemm, Csr};
use crate::swlc::ForestKernel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Query rows per stripe job.
    pub stripe_rows: usize,
    /// Worker threads.
    pub n_workers: usize,
    /// Bounded queue depth (jobs in flight) — the backpressure knob.
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { stripe_rows: 4096, n_workers: 2, queue_depth: 4 }
    }
}

/// Shared counters exposed after a run.
#[derive(Default, Debug)]
pub struct Metrics {
    pub jobs: AtomicU64,
    pub nnz: AtomicU64,
    pub busy_ns: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> (u64, u64, f64) {
        (
            self.jobs.load(Ordering::Relaxed),
            self.nnz.load(Ordering::Relaxed),
            self.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

/// One completed stripe of the proximity matrix: rows
/// `[row_start, row_start + rows.n_rows)` over all N columns.
pub struct Stripe {
    pub row_start: usize,
    pub rows: Csr,
}

/// Materialize the full training kernel `P = Q Wᵀ` stripe by stripe,
/// invoking `sink` for every stripe **in row order**. Returns metrics.
///
/// The sink runs on the caller thread; jobs flow through a bounded
/// channel so at most `queue_depth` stripes are ever buffered.
pub fn materialize_kernel(
    kernel: &ForestKernel,
    cfg: &CoordinatorConfig,
    mut sink: impl FnMut(Stripe),
) -> Metrics {
    let metrics = Metrics::default();
    let n = kernel.q.n_rows;
    let stripe = cfg.stripe_rows.max(1);
    let n_jobs = n.div_ceil(stripe);

    std::thread::scope(|scope| {
        let (job_tx, job_rx) = sync_channel::<usize>(cfg.queue_depth);
        let job_rx = Arc::new(std::sync::Mutex::new(job_rx));
        let (res_tx, res_rx) = sync_channel::<Stripe>(cfg.queue_depth);

        for _ in 0..cfg.n_workers.max(1) {
            let job_rx = Arc::clone(&job_rx);
            let res_tx = res_tx.clone();
            let metrics = &metrics;
            scope.spawn(move || loop {
                let job = { job_rx.lock().unwrap().recv() };
                let Ok(j) = job else { break };
                let t0 = std::time::Instant::now();
                let row_start = j * stripe;
                let row_end = (row_start + stripe).min(n);
                let rows = stripe_product(kernel, row_start, row_end);
                metrics.jobs.fetch_add(1, Ordering::Relaxed);
                metrics.nnz.fetch_add(rows.nnz() as u64, Ordering::Relaxed);
                metrics
                    .busy_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if res_tx.send(Stripe { row_start, rows }).is_err() {
                    break;
                }
            });
        }
        drop(res_tx);

        // Producer: enqueue job ids (blocks when the queue is full —
        // that is the backpressure). Run it on its own thread so the
        // caller thread can drain results.
        scope.spawn(move || {
            for j in 0..n_jobs {
                if job_tx.send(j).is_err() {
                    break;
                }
            }
        });

        // Reorder results so the sink sees stripes in row order.
        let mut pending: std::collections::BTreeMap<usize, Stripe> =
            std::collections::BTreeMap::new();
        let mut next_row = 0usize;
        for s in res_rx {
            pending.insert(s.row_start, s);
            while let Some(s) = pending.remove(&next_row) {
                next_row += s.rows.n_rows;
                sink(s);
            }
        }
        while let Some(s) = pending.remove(&next_row) {
            next_row += s.rows.n_rows;
            sink(s);
        }
    });
    metrics
}

/// Compute one stripe `P[row_start..row_end, :]` by Gustavson over the
/// factor rows (same cost model as the monolithic product, §3.3).
fn stripe_product(kernel: &ForestKernel, row_start: usize, row_end: usize) -> Csr {
    // Build a view of Q's stripe as a small CSR borrowing the data.
    let q = &kernel.q;
    let lo = q.indptr[row_start];
    let hi = q.indptr[row_end];
    let qs = Csr {
        n_rows: row_end - row_start,
        n_cols: q.n_cols,
        indptr: q.indptr[row_start..=row_end].iter().map(|&p| p - lo).collect(),
        indices: q.indices[lo..hi].to_vec(),
        data: q.data[lo..hi].to_vec(),
    };
    let mut p = spgemm(&qs, kernel.w_transpose());
    if kernel.kind == crate::swlc::ProximityKind::OobSeparable {
        // Remark G.2 on the stripe's diagonal block.
        for i in 0..p.n_rows {
            let gcol = (row_start + i) as u32;
            let (a, b) = (p.indptr[i], p.indptr[i + 1]);
            if let Ok(k) = p.indices[a..b].binary_search(&gcol) {
                p.data[a + k] = 1.0;
            }
            // If absent we leave it: `materialize` consumers that need
            // exact OOB diagonals use `ForestKernel::proximity_matrix`.
        }
    }
    p
}

/// Materialize the whole kernel into one CSR via the coordinator
/// (convenience used by tests and benches to compare against
/// `ForestKernel::proximity_matrix`).
pub fn materialize_to_csr(kernel: &ForestKernel, cfg: &CoordinatorConfig) -> (Csr, Metrics) {
    let n = kernel.q.n_rows;
    let mut indptr = vec![0usize];
    let mut indices = vec![];
    let mut data = vec![];
    let metrics = materialize_kernel(kernel, cfg, |s| {
        let base = *indptr.last().unwrap();
        for r in 0..s.rows.n_rows {
            indptr.push(base + s.rows.indptr[r + 1]);
        }
        indices.extend_from_slice(&s.rows.indices);
        data.extend_from_slice(&s.rows.data);
    });
    (
        Csr { n_rows: n, n_cols: kernel.w.n_rows, indptr, indices, data },
        metrics,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{Forest, TrainConfig};
    use crate::swlc::ProximityKind;

    fn fixture(n: usize) -> ForestKernel {
        let data = synth::gaussian_blobs(n, 4, 3, 2.0, 3);
        let f = Forest::train(&data, &TrainConfig { n_trees: 10, seed: 4, ..Default::default() });
        ForestKernel::fit(&f, &data, ProximityKind::Kerf)
    }

    #[test]
    fn coordinator_matches_monolithic_product() {
        let k = fixture(150);
        let cfg = CoordinatorConfig { stripe_rows: 32, n_workers: 3, queue_depth: 2 };
        let (p, metrics) = materialize_to_csr(&k, &cfg);
        let expect = k.proximity_matrix();
        assert_eq!(p.to_dense(), expect.to_dense());
        let (jobs, nnz, _) = metrics.snapshot();
        assert_eq!(jobs, 150usize.div_ceil(32) as u64);
        assert_eq!(nnz, expect.nnz() as u64);
    }

    #[test]
    fn stripes_arrive_in_row_order() {
        let k = fixture(100);
        let cfg = CoordinatorConfig { stripe_rows: 17, n_workers: 4, queue_depth: 2 };
        let mut seen = vec![];
        materialize_kernel(&k, &cfg, |s| seen.push((s.row_start, s.rows.n_rows)));
        let mut expect_start = 0;
        for &(start, rows) in &seen {
            assert_eq!(start, expect_start);
            expect_start += rows;
        }
        assert_eq!(expect_start, 100);
    }

    #[test]
    fn single_worker_single_stripe_edge_cases() {
        let k = fixture(40);
        for cfg in [
            CoordinatorConfig { stripe_rows: 1000, n_workers: 1, queue_depth: 1 },
            CoordinatorConfig { stripe_rows: 1, n_workers: 2, queue_depth: 1 },
        ] {
            let (p, _) = materialize_to_csr(&k, &cfg);
            assert_eq!(p.to_dense(), k.proximity_matrix().to_dense());
        }
    }

    #[test]
    fn metrics_busy_time_positive() {
        let k = fixture(80);
        let (_, m) = materialize_to_csr(&k, &CoordinatorConfig::default());
        let (_, _, busy) = m.snapshot();
        assert!(busy >= 0.0);
    }
}
