//! The block coordinator: sharded kernel materialization with bounded
//! queues, plus the XLA-backed dense gallery path.
//!
//! The paper's pipeline is "build factors once, then stream products".
//! For N×N materialization the coordinator partitions the query rows
//! into stripes and runs them through the shared [`exec`] pool's
//! [`exec::ordered_stream`]: workers claim stripe jobs from a shared
//! counter, completed stripes flow through a *bounded* result channel
//! (backpressure: a slow sink throttles the workers instead of
//! buffering the whole kernel), and the sink observes stripes in row
//! order on the caller thread. For OOS serving it batches query
//! requests into fixed-size tiles executed on the PJRT runtime (the L1
//! Pallas tile kernel) — see [`gallery`].
//!
//! Before the [`exec`] layer existed this module hand-rolled its own
//! `sync_channel` worker pool; the rewrite keeps the exact job/stripe
//! semantics and metrics while sharing the pool abstraction with
//! SpGEMM, transpose, and forest training.
//!
//! **Sinks.** What happens to each completed stripe is abstracted
//! behind the [`sink::KernelSink`] trait: the coordinator drives *any*
//! consumer — the in-memory CSR assembler ([`sink::CsrSink`]), the
//! spill-to-disk shard writer ([`shard::ShardSink`], binary stripe
//! files + JSON manifest, format documented in [`shard`]), or the
//! per-row top-k/ε sparsifier ([`sink::SparsifySink`]) that emits the
//! kNN-graph-shaped kernel the spectral layer wants. Shard directories
//! stream back in row order through [`shard::ShardReader`], which
//! shares the [`sink::KernelSource`] read interface with in-memory
//! CSRs — so `spectral::knn`, prediction, and the experiment drivers
//! consume kernels larger than RAM unchanged. This sink layer is the
//! substrate the multi-process sharding and NUMA stories build on.
//!
//! [`CoordinatorConfig::with_mem_budget`] sizes `stripe_rows` from a
//! byte budget using the measured factor density, so `--mem-budget`
//! bounds resident kernel memory regardless of N.
//!
//! **Multi-process sharding.** Every entry point also exists in a
//! row-range form ([`materialize_range_into`]): a worker process
//! materializes only `P[A..B, :]`, streaming its stripes into a
//! fragment [`shard::ShardSink`] under a directory shared with the
//! other workers. [`partition_rows`] plans the ranges — balanced by
//! the per-row SpGEMM cost measured from the factors
//! ([`ForestKernel::row_flops`]), not by raw row count — and
//! [`shard::merge_fragments`] / [`shard::validate_dir`] fuse and check
//! the result. Because each kernel row is a function of that row of Q
//! and all of Wᵀ alone, the merged directory is bitwise-identical to a
//! single-process run at any process count, stripe size, or thread
//! count (CLI: `repro shards {plan,run,merge,validate}`).

pub mod gallery;
pub mod shard;
pub mod sink;

use crate::exec::{self, StreamConfig};
use crate::obs;
use crate::sparse::qcsr::{self, QRowScratch};
use crate::sparse::{spgemm_nnz_flops, spgemm_with_scratch, Csr, SpaScratch};
use crate::swlc::ForestKernel;
use sink::KernelSink;
use std::cell::RefCell;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Query rows per stripe job.
    pub stripe_rows: usize,
    /// Worker threads; `0` = the shared [`exec::threads`] knob.
    pub n_workers: usize,
    /// Bounded queue depth (stripes in flight) — the backpressure knob.
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { stripe_rows: 4096, n_workers: 0, queue_depth: 4 }
    }
}

impl CoordinatorConfig {
    /// Size `stripe_rows` from a resident-memory budget (bytes) using
    /// the kernel's *measured* factor density: the predicted SpGEMM
    /// work `N·T·λ̄` (§3.3) upper-bounds nnz(P), so the expected stripe
    /// footprint is `rows · (flops/N) · 8 B` (u32 index + f32 value)
    /// plus 8 B of indptr per row. Up to `queue_depth + workers + 1`
    /// stripes are resident at once (in flight + the one in the sink),
    /// so the budget is divided across them. Clamped to `[1, N]`.
    pub fn with_mem_budget(kernel: &ForestKernel, budget_bytes: usize) -> CoordinatorConfig {
        let mut cfg = CoordinatorConfig::default();
        let n = kernel.q.n_rows.max(1);
        let (flops, _) = spgemm_nnz_flops(&kernel.q, kernel.w_transpose());
        let est_row_nnz = ((flops / n as u64) as usize).max(1);
        let row_bytes = est_row_nnz * 8 + 8;
        let workers = if cfg.n_workers == 0 { exec::threads() } else { cfg.n_workers };
        let in_flight = cfg.queue_depth + workers + 1;
        cfg.stripe_rows = (budget_bytes / row_bytes / in_flight).clamp(1, n);
        cfg
    }
}

/// Shared counters exposed after a run.
#[derive(Default, Debug)]
pub struct Metrics {
    pub jobs: AtomicU64,
    pub nnz: AtomicU64,
    pub busy_ns: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> (u64, u64, f64) {
        (
            self.jobs.load(Ordering::Relaxed),
            self.nnz.load(Ordering::Relaxed),
            self.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

/// One completed stripe of the proximity matrix: rows
/// `[row_start, row_start + rows.n_rows)` over all N columns.
pub struct Stripe {
    pub row_start: usize,
    pub rows: Csr,
}

/// Materialize the full training kernel `P = Q Wᵀ` stripe by stripe,
/// invoking `sink` for every stripe **in row order**. Returns metrics.
///
/// The sink runs on the caller thread; completed stripes flow through
/// the pool's bounded channel so at most `queue_depth` (plus one per
/// in-flight worker) are ever buffered.
pub fn materialize_kernel(
    kernel: &ForestKernel,
    cfg: &CoordinatorConfig,
    mut sink: impl FnMut(Stripe),
) -> Metrics {
    let n = kernel.q.n_rows;
    materialize_cancellable(kernel, cfg, 0..n, &AtomicBool::new(false), |s| sink(s))
}

/// [`materialize_kernel`] restricted to global rows `range`, with a
/// cancellation flag: once `cancel` is set, workers stop computing
/// products and emit empty placeholder stripes instead, so a failed
/// sink (disk full mid-spill) does not pay for the rest of a
/// multi-hour product. Already-claimed jobs finish.
fn materialize_cancellable(
    kernel: &ForestKernel,
    cfg: &CoordinatorConfig,
    range: Range<usize>,
    cancel: &AtomicBool,
    mut sink: impl FnMut(Stripe),
) -> Metrics {
    let metrics = Metrics::default();
    let stripe = cfg.stripe_rows.max(1);
    let n_jobs = range.len().div_ceil(stripe);
    let pool = StreamConfig {
        n_workers: if cfg.n_workers == 0 { exec::threads() } else { cfg.n_workers },
        queue_depth: cfg.queue_depth.max(1),
    };
    exec::ordered_stream(
        n_jobs,
        &pool,
        |j| {
            let row_start = range.start + j * stripe;
            if cancel.load(Ordering::Relaxed) {
                return Stripe { row_start, rows: Csr::zeros(0, 0) };
            }
            let t0 = std::time::Instant::now();
            let row_end = (row_start + stripe).min(range.end);
            let rows = stripe_product(kernel, row_start, row_end);
            let elapsed = t0.elapsed();
            metrics.jobs.fetch_add(1, Ordering::Relaxed);
            metrics.nnz.fetch_add(rows.nnz() as u64, Ordering::Relaxed);
            metrics.busy_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
            // Process-wide mirrors of the per-call metrics, plus one
            // trace event per stripe — all recorded after the product
            // is computed, so instrumentation cannot perturb it.
            crate::metric!(counter "fk_stripe_jobs_total", "SpGEMM stripe jobs completed.").inc();
            crate::metric!(counter "fk_stripe_rows_total", "Kernel rows materialized by stripe jobs.")
                .add((row_end - row_start) as u64);
            crate::metric!(counter "fk_stripe_nnz_total", "Nonzeros produced by stripe jobs.")
                .add(rows.nnz() as u64);
            crate::metric!(
                counter_secs "fk_stripe_seconds_total",
                "Cumulative wall time inside stripe SpGEMM products."
            )
            .add_nanos(elapsed);
            obs::event(
                "spgemm.stripe",
                crate::kv! {
                    row_start: row_start,
                    rows: row_end - row_start,
                    nnz: rows.nnz(),
                    secs: elapsed.as_secs_f64(),
                },
            );
            Stripe { row_start, rows }
        },
        |_, s| sink(s),
    );
    metrics
}

/// Drive the coordinator into a [`KernelSink`]: stripes are consumed in
/// row order; the first sink error cancels the remaining stripe
/// computation (in-flight jobs finish, later ones are skipped) and is
/// returned.
pub fn materialize_into<S: KernelSink>(
    kernel: &ForestKernel,
    cfg: &CoordinatorConfig,
    sink: &mut S,
) -> crate::error::Result<Metrics> {
    materialize_range_into(kernel, cfg, 0..kernel.q.n_rows, sink)
}

/// [`materialize_into`] restricted to the global row range
/// `[range.start, range.end)` — the multi-process worker entry point:
/// each OS process materializes one [`partition_rows`] range into a
/// fragment [`shard::ShardSink`]. Stripe boundaries never change row
/// contents (each kernel row depends only on that row of Q and all of
/// Wᵀ), so any partition of `[0, N)` reassembles bitwise-identically
/// to the single-process result.
pub fn materialize_range_into<S: KernelSink>(
    kernel: &ForestKernel,
    cfg: &CoordinatorConfig,
    range: Range<usize>,
    sink: &mut S,
) -> crate::error::Result<Metrics> {
    let n = kernel.q.n_rows;
    if range.start > range.end || range.end > n {
        crate::bail!("row range {}..{} out of bounds for N={n}", range.start, range.end);
    }
    let cancel = AtomicBool::new(false);
    let mut err: Option<crate::error::Error> = None;
    let metrics = materialize_cancellable(kernel, cfg, range, &cancel, |s| {
        if err.is_none() {
            if let Err(e) = sink.consume(s) {
                err = Some(e);
                cancel.store(true, Ordering::Relaxed);
            }
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok(metrics),
    }
}

thread_local! {
    /// Per-worker SpGEMM scratch, reused across every stripe this thread
    /// computes: the dense accumulator, stamps, and radix buffers stop
    /// being reallocated per call (they start as `Vec::new()` in a fresh
    /// `SpaScratch`), and the quantized decode buffers ride along.
    /// Stamp generations ([`SpaScratch::begin_rows`]) make the reuse
    /// bitwise-invisible.
    static STRIPE_SCRATCH: RefCell<(SpaScratch, QRowScratch)> =
        RefCell::new((SpaScratch::new(0), QRowScratch::new()));
}

/// Compute one stripe `P[row_start..row_end, :]` by Gustavson over the
/// factor rows (same cost model as the monolithic product, §3.3). Runs
/// single-threaded: stripes are already the coordinator's parallelism
/// unit, so nesting the row-parallel SpGEMM would only oversubscribe.
/// Public as the row-exact reference the `shards validate --verify`
/// sampled cross-check compares against. Routes through the quantized
/// factors when the kernel's quantized mode is on.
pub fn stripe_product(kernel: &ForestKernel, row_start: usize, row_end: usize) -> Csr {
    let mut p = STRIPE_SCRATCH.with(|cell| {
        let (spa, rs) = &mut *cell.borrow_mut();
        match kernel.quantized() {
            Some(qf) => qcsr::spgemm_q_range(&qf.q, row_start..row_end, &qf.wt, spa, rs),
            None => {
                let qs = kernel.q.slice_rows(row_start..row_end);
                spgemm_with_scratch(&qs, kernel.w_transpose(), spa)
            }
        }
    });
    if kernel.kind == crate::swlc::ProximityKind::OobSeparable {
        // Remark G.2 on the stripe's diagonal block: force `P_ii = 1`,
        // inserting entries that the product left structurally absent
        // (samples never OOB have empty factor rows). This keeps every
        // sink path bitwise-identical to `ForestKernel::proximity_matrix`.
        crate::swlc::kernel::set_unit_diagonal_offset(&mut p, row_start);
    }
    p
}

/// Plan a multi-process run: split `[0, N)` into `parts` contiguous
/// ranges balanced by the *measured* per-row SpGEMM cost
/// ([`ForestKernel::row_flops`]), so a skewed kernel (dense hub rows,
/// empty never-OOB rows) still spreads work evenly across worker
/// processes. Deterministic; every range is non-empty when `parts ≤ N`.
pub fn partition_rows(kernel: &ForestKernel, parts: usize) -> Vec<Range<usize>> {
    partition_by_cost(&kernel.row_flops(), parts)
}

/// [`partition_rows`] on an explicit per-row cost vector: each range
/// greedily takes rows until it holds `remaining_cost / remaining_parts`
/// (re-derived after every cut, so one hub row absorbing several
/// targets' worth of cost cannot starve the ranges after it), clamped
/// so every remaining range keeps at least one row.
pub fn partition_by_cost(costs: &[u64], parts: usize) -> Vec<Range<usize>> {
    let n = costs.len();
    if n == 0 {
        return vec![];
    }
    let p = parts.max(1).min(n);
    let mut remaining: u128 = costs.iter().map(|&c| c as u128).sum();
    let mut out = Vec::with_capacity(p);
    let mut start = 0usize;
    for j in 1..=p {
        let end = if j == p {
            n
        } else {
            let target = remaining / (p - j + 1) as u128;
            // Leave at least one row for each of the `p - j` ranges
            // still to come; take at least one row ourselves.
            let max_end = n - (p - j);
            let mut end = start;
            let mut taken: u128 = 0;
            while end < max_end && (end == start || taken < target) {
                taken += costs[end] as u128;
                end += 1;
            }
            remaining -= taken;
            end
        };
        out.push(start..end);
        start = end;
    }
    out
}

/// Materialize the whole kernel into one CSR via a [`sink::CsrSink`]
/// (convenience used by tests, benches, and small-N CLI paths to
/// compare against `ForestKernel::proximity_matrix`).
pub fn materialize_to_csr(kernel: &ForestKernel, cfg: &CoordinatorConfig) -> (Csr, Metrics) {
    let mut sink = sink::CsrSink::new(kernel.w.n_rows);
    let metrics = materialize_into(kernel, cfg, &mut sink)
        .expect("coordinator stripes arrive in row order");
    (sink.finish(), metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{Forest, TrainConfig};
    use crate::swlc::ProximityKind;

    fn fixture(n: usize) -> ForestKernel {
        let data = synth::gaussian_blobs(n, 4, 3, 2.0, 3);
        let f = Forest::train(&data, &TrainConfig { n_trees: 10, seed: 4, ..Default::default() });
        ForestKernel::fit(&f, &data, ProximityKind::Kerf)
    }

    #[test]
    fn coordinator_matches_monolithic_product() {
        let k = fixture(150);
        let cfg = CoordinatorConfig { stripe_rows: 32, n_workers: 3, queue_depth: 2 };
        let (p, metrics) = materialize_to_csr(&k, &cfg);
        let expect = k.proximity_matrix();
        assert_eq!(p.to_dense(), expect.to_dense());
        let (jobs, nnz, _) = metrics.snapshot();
        assert_eq!(jobs, 150usize.div_ceil(32) as u64);
        assert_eq!(nnz, expect.nnz() as u64);
    }

    #[test]
    fn stripes_arrive_in_row_order() {
        let k = fixture(100);
        let cfg = CoordinatorConfig { stripe_rows: 17, n_workers: 4, queue_depth: 2 };
        let mut seen = vec![];
        materialize_kernel(&k, &cfg, |s| seen.push((s.row_start, s.rows.n_rows)));
        let mut expect_start = 0;
        for &(start, rows) in &seen {
            assert_eq!(start, expect_start);
            expect_start += rows;
        }
        assert_eq!(expect_start, 100);
    }

    #[test]
    fn single_worker_single_stripe_edge_cases() {
        let k = fixture(40);
        for cfg in [
            CoordinatorConfig { stripe_rows: 1000, n_workers: 1, queue_depth: 1 },
            CoordinatorConfig { stripe_rows: 1, n_workers: 2, queue_depth: 1 },
        ] {
            let (p, _) = materialize_to_csr(&k, &cfg);
            assert_eq!(p.to_dense(), k.proximity_matrix().to_dense());
        }
    }

    #[test]
    fn worker_count_never_changes_the_result() {
        let k = fixture(90);
        let reference = materialize_to_csr(
            &k,
            &CoordinatorConfig { stripe_rows: 16, n_workers: 1, queue_depth: 1 },
        )
        .0;
        for workers in [2usize, 4, 8] {
            let cfg = CoordinatorConfig { stripe_rows: 16, n_workers: workers, queue_depth: 3 };
            let (p, _) = materialize_to_csr(&k, &cfg);
            assert_eq!(p, reference, "workers={workers}");
        }
    }

    #[test]
    fn oob_separable_stripes_match_monolithic_bitwise() {
        // Regression: with few trees some samples are never OOB, their
        // kernel rows are empty, and the stripe product used to drop
        // the forced unit diagonal that `proximity_matrix` inserts.
        let data = synth::gaussian_blobs(150, 4, 3, 2.0, 9);
        for n_trees in [3usize, 5, 10] {
            let f = Forest::train(
                &data,
                &TrainConfig { n_trees, seed: 9, ..Default::default() },
            );
            let k = ForestKernel::fit(&f, &data, ProximityKind::OobSeparable);
            let expect = k.proximity_matrix();
            let cfg = CoordinatorConfig { stripe_rows: 16, n_workers: 3, queue_depth: 2 };
            let (p, _) = materialize_to_csr(&k, &cfg);
            assert_eq!(p.indptr, expect.indptr, "T={n_trees}: structure differs");
            assert_eq!(p.indices, expect.indices, "T={n_trees}: columns differ");
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&p.data), bits(&expect.data), "T={n_trees}: values differ");
            // And the diagonal is really all-ones.
            let d = p.to_dense();
            for i in 0..150 {
                assert_eq!(d[i * 150 + i], 1.0, "T={n_trees}: diagonal at {i}");
            }
        }
    }

    #[test]
    fn mem_budget_picks_bounded_stripe_rows() {
        let k = fixture(200);
        let p = k.proximity_matrix();
        // A budget far below the kernel's own footprint must shrink
        // stripes below N; a huge budget must clamp to N.
        let small = CoordinatorConfig::with_mem_budget(&k, p.mem_bytes() / 8);
        assert!(small.stripe_rows >= 1);
        assert!(small.stripe_rows < 200, "stripe_rows={}", small.stripe_rows);
        let huge = CoordinatorConfig::with_mem_budget(&k, usize::MAX / 2);
        assert_eq!(huge.stripe_rows, 200);
        // Materializing under the small budget still reproduces the
        // monolithic kernel exactly.
        let (pp, _) = materialize_to_csr(&k, &small);
        assert_eq!(pp, p);
    }

    #[test]
    fn range_materialization_reproduces_the_slice_bitwise() {
        let k = fixture(120);
        let full = k.proximity_matrix();
        let cfg = CoordinatorConfig { stripe_rows: 13, n_workers: 3, queue_depth: 2 };
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for range in [0..120usize, 0..50, 50..120, 37..38, 60..60] {
            let mut sink = sink::CsrSink::with_base(120, range.start);
            let m = materialize_range_into(&k, &cfg, range.clone(), &mut sink).unwrap();
            let got = sink.finish();
            assert_eq!(got.n_rows, range.len());
            let expect = full.slice_rows(range.clone());
            assert_eq!(got.indptr, expect.indptr, "{range:?}");
            assert_eq!(got.indices, expect.indices, "{range:?}");
            assert_eq!(bits(&got.data), bits(&expect.data), "{range:?}");
            let (_, nnz, _) = m.snapshot();
            assert_eq!(nnz, expect.nnz() as u64);
        }
        // Out-of-bounds ranges fail instead of panicking.
        assert!(materialize_range_into(
            &k,
            &cfg,
            0..121,
            &mut sink::CsrSink::with_base(120, 0)
        )
        .is_err());
    }

    #[test]
    fn partitioned_ranges_reassemble_the_full_kernel() {
        let k = fixture(110);
        let reference = materialize_to_csr(&k, &CoordinatorConfig::default()).0;
        for parts in [1usize, 2, 3, 7] {
            let ranges = partition_rows(&k, parts);
            assert_eq!(ranges.len(), parts);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, 110);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert!(!w[0].is_empty());
            }
            let mut whole = sink::CsrSink::new(110);
            for r in &ranges {
                let cfg = CoordinatorConfig { stripe_rows: 16, n_workers: 2, queue_depth: 2 };
                let mut part = sink::CsrSink::with_base(110, r.start);
                materialize_range_into(&k, &cfg, r.clone(), &mut part).unwrap();
                let rows = part.finish();
                whole
                    .consume(Stripe { row_start: r.start, rows })
                    .expect("partition ranges are contiguous");
            }
            assert_eq!(whole.finish(), reference, "parts={parts}");
        }
    }

    #[test]
    fn partition_by_cost_balances_skewed_costs() {
        // One hub row dominating the cost must get its own range while
        // the cheap tail is spread across the rest.
        let mut costs = vec![1u64; 100];
        costs[0] = 1_000;
        let ranges = partition_by_cost(&costs, 4);
        assert_eq!(ranges.len(), 4);
        assert_eq!(ranges[0], 0..1);
        let weight = |r: &Range<usize>| costs[r.clone()].iter().sum::<u64>();
        let rest: Vec<u64> = ranges[1..].iter().map(weight).collect();
        let (lo, hi) = (rest.iter().min().unwrap(), rest.iter().max().unwrap());
        assert!(hi - lo <= 2, "tail ranges unbalanced: {rest:?}");

        // Degenerate shapes.
        assert_eq!(partition_by_cost(&[], 4), vec![]);
        assert_eq!(partition_by_cost(&[5], 4), vec![0..1]);
        let uniform = partition_by_cost(&[3u64; 8], 4);
        assert_eq!(uniform, vec![0..2, 2..4, 4..6, 6..8]);
    }

    #[test]
    fn auto_worker_default_runs() {
        // n_workers = 0 resolves through the shared exec knob.
        let k = fixture(80);
        let (p, m) = materialize_to_csr(&k, &CoordinatorConfig::default());
        assert_eq!(p.to_dense(), k.proximity_matrix().to_dense());
        let (_, _, busy) = m.snapshot();
        assert!(busy >= 0.0);
    }
}
