//! The block coordinator: sharded kernel materialization with bounded
//! queues, plus the XLA-backed dense gallery path.
//!
//! The paper's pipeline is "build factors once, then stream products".
//! For N×N materialization the coordinator partitions the query rows
//! into stripes and runs them through the shared [`exec`] pool's
//! [`exec::ordered_stream`]: workers claim stripe jobs from a shared
//! counter, completed stripes flow through a *bounded* result channel
//! (backpressure: a slow sink throttles the workers instead of
//! buffering the whole kernel), and the sink observes stripes in row
//! order on the caller thread. For OOS serving it batches query
//! requests into fixed-size tiles executed on the PJRT runtime (the L1
//! Pallas tile kernel) — see [`gallery`].
//!
//! Before the [`exec`] layer existed this module hand-rolled its own
//! `sync_channel` worker pool; the rewrite keeps the exact job/stripe
//! semantics and metrics while sharing the pool abstraction with
//! SpGEMM, transpose, and forest training.

pub mod gallery;

use crate::exec::{self, StreamConfig};
use crate::sparse::{spgemm_with_threads, Csr};
use crate::swlc::ForestKernel;
use std::sync::atomic::{AtomicU64, Ordering};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Query rows per stripe job.
    pub stripe_rows: usize,
    /// Worker threads; `0` = the shared [`exec::threads`] knob.
    pub n_workers: usize,
    /// Bounded queue depth (stripes in flight) — the backpressure knob.
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { stripe_rows: 4096, n_workers: 0, queue_depth: 4 }
    }
}

/// Shared counters exposed after a run.
#[derive(Default, Debug)]
pub struct Metrics {
    pub jobs: AtomicU64,
    pub nnz: AtomicU64,
    pub busy_ns: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> (u64, u64, f64) {
        (
            self.jobs.load(Ordering::Relaxed),
            self.nnz.load(Ordering::Relaxed),
            self.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

/// One completed stripe of the proximity matrix: rows
/// `[row_start, row_start + rows.n_rows)` over all N columns.
pub struct Stripe {
    pub row_start: usize,
    pub rows: Csr,
}

/// Materialize the full training kernel `P = Q Wᵀ` stripe by stripe,
/// invoking `sink` for every stripe **in row order**. Returns metrics.
///
/// The sink runs on the caller thread; completed stripes flow through
/// the pool's bounded channel so at most `queue_depth` (plus one per
/// in-flight worker) are ever buffered.
pub fn materialize_kernel(
    kernel: &ForestKernel,
    cfg: &CoordinatorConfig,
    mut sink: impl FnMut(Stripe),
) -> Metrics {
    let metrics = Metrics::default();
    let n = kernel.q.n_rows;
    let stripe = cfg.stripe_rows.max(1);
    let n_jobs = n.div_ceil(stripe);
    let pool = StreamConfig {
        n_workers: if cfg.n_workers == 0 { exec::threads() } else { cfg.n_workers },
        queue_depth: cfg.queue_depth.max(1),
    };
    exec::ordered_stream(
        n_jobs,
        &pool,
        |j| {
            let t0 = std::time::Instant::now();
            let row_start = j * stripe;
            let row_end = (row_start + stripe).min(n);
            let rows = stripe_product(kernel, row_start, row_end);
            metrics.jobs.fetch_add(1, Ordering::Relaxed);
            metrics.nnz.fetch_add(rows.nnz() as u64, Ordering::Relaxed);
            metrics.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            Stripe { row_start, rows }
        },
        |_, s| sink(s),
    );
    metrics
}

/// Compute one stripe `P[row_start..row_end, :]` by Gustavson over the
/// factor rows (same cost model as the monolithic product, §3.3). Runs
/// single-threaded: stripes are already the coordinator's parallelism
/// unit, so nesting the row-parallel SpGEMM would only oversubscribe.
fn stripe_product(kernel: &ForestKernel, row_start: usize, row_end: usize) -> Csr {
    // Build a view of Q's stripe as a small CSR borrowing the data.
    let q = &kernel.q;
    let lo = q.indptr[row_start];
    let hi = q.indptr[row_end];
    let qs = Csr {
        n_rows: row_end - row_start,
        n_cols: q.n_cols,
        indptr: q.indptr[row_start..=row_end].iter().map(|&p| p - lo).collect(),
        indices: q.indices[lo..hi].to_vec(),
        data: q.data[lo..hi].to_vec(),
    };
    let mut p = spgemm_with_threads(&qs, kernel.w_transpose(), 1);
    if kernel.kind == crate::swlc::ProximityKind::OobSeparable {
        // Remark G.2 on the stripe's diagonal block.
        for i in 0..p.n_rows {
            let gcol = (row_start + i) as u32;
            let (a, b) = (p.indptr[i], p.indptr[i + 1]);
            if let Ok(k) = p.indices[a..b].binary_search(&gcol) {
                p.data[a + k] = 1.0;
            }
            // If absent we leave it: `materialize` consumers that need
            // exact OOB diagonals use `ForestKernel::proximity_matrix`.
        }
    }
    p
}

/// Materialize the whole kernel into one CSR via the coordinator
/// (convenience used by tests and benches to compare against
/// `ForestKernel::proximity_matrix`).
pub fn materialize_to_csr(kernel: &ForestKernel, cfg: &CoordinatorConfig) -> (Csr, Metrics) {
    let n = kernel.q.n_rows;
    let mut indptr = vec![0usize];
    let mut indices = vec![];
    let mut data = vec![];
    let metrics = materialize_kernel(kernel, cfg, |s| {
        let base = *indptr.last().unwrap();
        for r in 0..s.rows.n_rows {
            indptr.push(base + s.rows.indptr[r + 1]);
        }
        indices.extend_from_slice(&s.rows.indices);
        data.extend_from_slice(&s.rows.data);
    });
    (
        Csr { n_rows: n, n_cols: kernel.w.n_rows, indptr, indices, data },
        metrics,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::{Forest, TrainConfig};
    use crate::swlc::ProximityKind;

    fn fixture(n: usize) -> ForestKernel {
        let data = synth::gaussian_blobs(n, 4, 3, 2.0, 3);
        let f = Forest::train(&data, &TrainConfig { n_trees: 10, seed: 4, ..Default::default() });
        ForestKernel::fit(&f, &data, ProximityKind::Kerf)
    }

    #[test]
    fn coordinator_matches_monolithic_product() {
        let k = fixture(150);
        let cfg = CoordinatorConfig { stripe_rows: 32, n_workers: 3, queue_depth: 2 };
        let (p, metrics) = materialize_to_csr(&k, &cfg);
        let expect = k.proximity_matrix();
        assert_eq!(p.to_dense(), expect.to_dense());
        let (jobs, nnz, _) = metrics.snapshot();
        assert_eq!(jobs, 150usize.div_ceil(32) as u64);
        assert_eq!(nnz, expect.nnz() as u64);
    }

    #[test]
    fn stripes_arrive_in_row_order() {
        let k = fixture(100);
        let cfg = CoordinatorConfig { stripe_rows: 17, n_workers: 4, queue_depth: 2 };
        let mut seen = vec![];
        materialize_kernel(&k, &cfg, |s| seen.push((s.row_start, s.rows.n_rows)));
        let mut expect_start = 0;
        for &(start, rows) in &seen {
            assert_eq!(start, expect_start);
            expect_start += rows;
        }
        assert_eq!(expect_start, 100);
    }

    #[test]
    fn single_worker_single_stripe_edge_cases() {
        let k = fixture(40);
        for cfg in [
            CoordinatorConfig { stripe_rows: 1000, n_workers: 1, queue_depth: 1 },
            CoordinatorConfig { stripe_rows: 1, n_workers: 2, queue_depth: 1 },
        ] {
            let (p, _) = materialize_to_csr(&k, &cfg);
            assert_eq!(p.to_dense(), k.proximity_matrix().to_dense());
        }
    }

    #[test]
    fn worker_count_never_changes_the_result() {
        let k = fixture(90);
        let reference = materialize_to_csr(
            &k,
            &CoordinatorConfig { stripe_rows: 16, n_workers: 1, queue_depth: 1 },
        )
        .0;
        for workers in [2usize, 4, 8] {
            let cfg = CoordinatorConfig { stripe_rows: 16, n_workers: workers, queue_depth: 3 };
            let (p, _) = materialize_to_csr(&k, &cfg);
            assert_eq!(p, reference, "workers={workers}");
        }
    }

    #[test]
    fn auto_worker_default_runs() {
        // n_workers = 0 resolves through the shared exec knob.
        let k = fixture(80);
        let (p, m) = materialize_to_csr(&k, &CoordinatorConfig::default());
        assert_eq!(p.to_dense(), k.proximity_matrix().to_dense());
        let (_, _, busy) = m.snapshot();
        assert!(busy >= 0.0);
    }
}
