//! Kernel sinks and sources: the consumers of coordinator stripes.
//!
//! The coordinator produces the proximity matrix as an ordered stream
//! of [`Stripe`]s; what happens to each stripe is the sink's business.
//! [`KernelSink`] abstracts that consumer so the same driver serves
//! every materialization target:
//!
//! * [`CsrSink`] — the classic in-memory path: stripes are concatenated
//!   into one `N×N` CSR (what `materialize_to_csr` returns).
//! * [`crate::coordinator::shard::ShardSink`] — the out-of-core path:
//!   stripes are written to fixed-format binary shard files plus a JSON
//!   manifest, so the kernel never has to fit in RAM.
//! * [`SparsifySink`] — a composable adapter that thins each stripe
//!   (per-row top-k and/or ε-threshold) before forwarding it to any
//!   inner sink, emitting the kNN-graph-shaped kernel the spectral and
//!   embedding layers actually consume.
//!
//! The read side is [`KernelSource`]: row-ordered streaming access to a
//! materialized kernel, implemented both by the in-memory [`Csr`] and by
//! [`crate::coordinator::shard::ShardReader`], so downstream consumers
//! (`spectral::knn::knn_from_kernel`, `swlc::predict` streaming scores,
//! the experiment drivers) are agnostic to whether the kernel lives in
//! memory or on disk.

use super::Stripe;
use crate::bail;
use crate::error::Result;
use crate::sparse::Csr;

/// A consumer of coordinator stripes. `consume` observes stripes in row
/// order on the caller thread; a returned error aborts the drive (the
/// remaining stripes are still produced but dropped).
pub trait KernelSink {
    fn consume(&mut self, stripe: Stripe) -> Result<()>;
}

/// Row-ordered streaming access to a materialized kernel — the common
/// read interface over in-memory CSRs and on-disk shard directories.
pub trait KernelSource {
    fn n_rows(&self) -> usize;
    fn n_cols(&self) -> usize;
    /// Visit every row in row order as `f(row, cols, vals)`.
    fn for_each_row(&self, f: &mut dyn FnMut(usize, &[u32], &[f32])) -> Result<()>;
}

impl KernelSource for Csr {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn for_each_row(&self, f: &mut dyn FnMut(usize, &[u32], &[f32])) -> Result<()> {
        for r in 0..self.n_rows {
            let (cols, vals) = self.row(r);
            f(r, cols, vals);
        }
        Ok(())
    }
}

/// In-memory sink: concatenates stripes into one CSR (the pre-refactor
/// `materialize_to_csr` behavior, now one [`KernelSink`] among several).
pub struct CsrSink {
    n_cols: usize,
    /// Global row the first stripe must start at (0 for whole-kernel
    /// assembly; a range start when consuming a row-range
    /// materialization — the resulting CSR holds only those rows).
    base_row: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f32>,
}

impl CsrSink {
    pub fn new(n_cols: usize) -> CsrSink {
        CsrSink::with_base(n_cols, 0)
    }

    /// A sink whose coverage starts at global row `base_row`, for
    /// consuming `coordinator::materialize_range_into` output.
    pub fn with_base(n_cols: usize, base_row: usize) -> CsrSink {
        CsrSink { n_cols, base_row, indptr: vec![0], indices: vec![], data: vec![] }
    }

    /// The assembled kernel.
    pub fn finish(self) -> Csr {
        Csr {
            n_rows: self.indptr.len() - 1,
            n_cols: self.n_cols,
            indptr: self.indptr.into(),
            indices: self.indices.into(),
            data: self.data.into(),
        }
    }
}

impl KernelSink for CsrSink {
    fn consume(&mut self, stripe: Stripe) -> Result<()> {
        let rows_seen = self.indptr.len() - 1;
        if stripe.row_start != self.base_row + rows_seen {
            bail!(
                "stripe out of order: row_start {} but sink covers rows {}..{}",
                stripe.row_start,
                self.base_row,
                self.base_row + rows_seen
            );
        }
        let base = *self.indptr.last().unwrap();
        for r in 0..stripe.rows.n_rows {
            self.indptr.push(base + stripe.rows.indptr[r + 1]);
        }
        self.indices.extend_from_slice(&stripe.rows.indices);
        self.data.extend_from_slice(&stripe.rows.data);
        Ok(())
    }
}

/// Per-row thinning policy for [`SparsifySink`].
#[derive(Clone, Copy, Debug)]
pub struct SparsifyConfig {
    /// Keep at most this many off-diagonal entries per row (largest
    /// values first, ties broken toward the smaller column id so the
    /// output is deterministic). `0` disables the cap.
    pub top_k: usize,
    /// Drop entries with `|value| < epsilon` before the top-k cap.
    pub epsilon: f32,
    /// Always keep the row's global diagonal entry (if present), on top
    /// of the `top_k` budget — self-proximity anchors the kNN graph.
    pub keep_diagonal: bool,
}

impl Default for SparsifyConfig {
    fn default() -> Self {
        SparsifyConfig { top_k: 0, epsilon: 0.0, keep_diagonal: true }
    }
}

/// Composable sparsifying adapter: thins each stripe per-row and
/// forwards the result to the inner sink. Never holds more than one
/// stripe, so `topk → shards` streams kernels larger than RAM end to
/// end. With `top_k = 0` and `epsilon = 0` the stripe passes through
/// bit-for-bit.
pub struct SparsifySink<S: KernelSink> {
    cfg: SparsifyConfig,
    inner: S,
    /// Entries dropped so far (observability for the CLI).
    pub dropped: u64,
}

impl<S: KernelSink> SparsifySink<S> {
    pub fn new(cfg: SparsifyConfig, inner: S) -> SparsifySink<S> {
        SparsifySink { cfg, inner, dropped: 0 }
    }

    /// Hand back the inner sink (to `finish` it).
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: KernelSink> KernelSink for SparsifySink<S> {
    fn consume(&mut self, stripe: Stripe) -> Result<()> {
        let src = &stripe.rows;
        let cap = if self.cfg.top_k > 0 {
            src.nnz().min(src.n_rows * (self.cfg.top_k + 1))
        } else {
            src.nnz()
        };
        let mut indptr = Vec::with_capacity(src.n_rows + 1);
        let mut indices: Vec<u32> = Vec::with_capacity(cap);
        let mut data: Vec<f32> = Vec::with_capacity(cap);
        indptr.push(0usize);
        let mut keep: Vec<(u32, f32)> = Vec::new();
        for r in 0..src.n_rows {
            let gdiag = (stripe.row_start + r) as u32;
            let (cols, vals) = src.row(r);
            keep.clear();
            let mut diag: Option<(u32, f32)> = None;
            for (&c, &v) in cols.iter().zip(vals) {
                if self.cfg.keep_diagonal && c == gdiag {
                    diag = Some((c, v));
                } else if v.abs() >= self.cfg.epsilon {
                    keep.push((c, v));
                }
            }
            if self.cfg.top_k > 0 && keep.len() > self.cfg.top_k {
                keep.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
                keep.truncate(self.cfg.top_k);
                keep.sort_unstable_by_key(|&(c, _)| c);
            }
            if let Some(d) = diag {
                keep.push(d);
                keep.sort_unstable_by_key(|&(c, _)| c);
            }
            self.dropped += (cols.len() - keep.len()) as u64;
            for &(c, v) in &keep {
                indices.push(c);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        self.inner.consume(Stripe {
            row_start: stripe.row_start,
            rows: Csr {
                n_rows: src.n_rows,
                n_cols: src.n_cols,
                indptr: indptr.into(),
                indices: indices.into(),
                data: data.into(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stripe(row_start: usize, rows: Csr) -> Stripe {
        Stripe { row_start, rows }
    }

    #[test]
    fn csr_sink_concatenates_stripes() {
        let mut sink = CsrSink::new(3);
        sink.consume(stripe(0, Csr::from_triplets(2, 3, &[(0, 1, 1.0), (1, 0, 2.0)]))).unwrap();
        sink.consume(stripe(2, Csr::from_triplets(1, 3, &[(0, 2, 3.0)]))).unwrap();
        let p = sink.finish();
        p.check().unwrap();
        assert_eq!(p.n_rows, 3);
        assert_eq!(p.to_dense(), vec![0., 1., 0., 2., 0., 0., 0., 0., 3.]);
    }

    #[test]
    fn sparsify_passthrough_is_bitwise_identity() {
        let m = Csr::from_triplets(3, 3, &[(0, 0, 0.5), (0, 2, 0.25), (2, 1, -1.0)]);
        let mut sink = SparsifySink::new(SparsifyConfig::default(), CsrSink::new(3));
        sink.consume(stripe(0, m.clone())).unwrap();
        assert_eq!(sink.dropped, 0);
        let p = sink.into_inner().finish();
        assert_eq!(p, m);
    }

    #[test]
    fn sparsify_topk_keeps_largest_and_diagonal() {
        // Row 0 of a global stripe starting at row 0: diag 0.1 plus
        // off-diagonals 0.9, 0.8, 0.2 — top-2 keeps 0.9, 0.8 and the
        // diagonal rides along for free.
        let m = Csr::from_triplets(
            1,
            5,
            &[(0, 0, 0.1), (0, 1, 0.9), (0, 2, 0.2), (0, 3, 0.8)],
        );
        let cfg = SparsifyConfig { top_k: 2, epsilon: 0.0, keep_diagonal: true };
        let mut sink = SparsifySink::new(cfg, CsrSink::new(5));
        sink.consume(stripe(0, m)).unwrap();
        assert_eq!(sink.dropped, 1);
        let p = sink.into_inner().finish();
        assert_eq!(p.to_dense(), vec![0.1, 0.9, 0.0, 0.8, 0.0]);
    }

    #[test]
    fn sparsify_epsilon_drops_small_entries() {
        let m = Csr::from_triplets(2, 4, &[(0, 1, 0.05), (0, 2, 0.5), (1, 0, 0.3)]);
        let cfg = SparsifyConfig { top_k: 0, epsilon: 0.1, keep_diagonal: true };
        let mut sink = SparsifySink::new(cfg, CsrSink::new(4));
        sink.consume(stripe(0, m)).unwrap();
        let p = sink.into_inner().finish();
        assert_eq!(p.to_dense(), vec![0.0, 0.0, 0.5, 0.0, 0.3, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn sparsify_ties_break_toward_smaller_column() {
        // keep_diagonal off ⇒ no entry is special-cased; the three
        // equal values must resolve to the two smallest column ids.
        let m = Csr::from_triplets(1, 4, &[(0, 1, 0.5), (0, 2, 0.5), (0, 3, 0.5)]);
        let cfg = SparsifyConfig { top_k: 2, epsilon: 0.0, keep_diagonal: false };
        let mut sink = SparsifySink::new(cfg, CsrSink::new(4));
        sink.consume(stripe(0, m)).unwrap();
        let p = sink.into_inner().finish();
        assert_eq!(p.row(0).0, &[1u32, 2]);
    }

    #[test]
    fn csr_sink_with_base_assembles_a_row_range() {
        let mut sink = CsrSink::with_base(3, 5);
        // The first stripe must start exactly at the base row.
        assert!(sink.consume(stripe(0, Csr::from_triplets(1, 3, &[]))).is_err());
        sink.consume(stripe(5, Csr::from_triplets(2, 3, &[(0, 1, 1.0)]))).unwrap();
        sink.consume(stripe(7, Csr::from_triplets(1, 3, &[(0, 2, 2.0)]))).unwrap();
        let p = sink.finish();
        assert_eq!(p.n_rows, 3);
        assert_eq!(p.to_dense(), vec![0., 1., 0., 0., 0., 0., 0., 0., 2.]);
    }

    #[test]
    fn csr_sink_rejects_out_of_order_stripes() {
        let mut sink = CsrSink::new(3);
        let bad = stripe(5, Csr::from_triplets(1, 3, &[]));
        assert!(sink.consume(bad).is_err());
    }

    #[test]
    fn kernel_source_over_csr_streams_rows_in_order() {
        let m = Csr::from_triplets(3, 3, &[(0, 1, 1.0), (2, 0, 2.0)]);
        let mut seen = vec![];
        KernelSource::for_each_row(&m, &mut |r, cols, vals| {
            seen.push((r, cols.to_vec(), vals.to_vec()));
        })
        .unwrap();
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0], (0, vec![1u32], vec![1.0f32]));
        assert_eq!(seen[1], (1, vec![], vec![]));
        assert_eq!(seen[2], (2, vec![0u32], vec![2.0f32]));
    }
}
