//! PCA via implicit Gram operators: Leaf PCA on the sparse incidence
//! matrix `Q` (§4.3) and plain PCA on dense feature matrices, sharing
//! the subspace-iteration core.
//!
//! Centering is implicit: the operator for the centered Gram matrix
//! `(Q - 1μᵀ)(Q - 1μᵀ)ᵀ` is applied as
//! `y = Q(Qᵀx - μ s) - 1·(μᵀ(Qᵀx - μ s))` with `s = 1ᵀx`, never forming
//! the (dense!) centered matrix — the sklearn-ARPACK trick the paper
//! leans on for sparse inputs.

use super::subspace::symmetric_topk;
use crate::sparse::qcsr::QCsr;
use crate::sparse::Csr;

/// Leaf-PCA scores: top-k principal components of the row-sample leaf
/// matrix `Q` (N×L). Returns `(scores N×k row-major-k, eigvals)`;
/// scores are `U·Σ` of the (optionally centered) `Q`, i.e. the kernel-PCA
/// coordinates of the SWLC Gram kernel (Cor. 3.7).
pub fn leaf_pca(q: &Csr, k: usize, iters: usize, center: bool, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let n = q.n_rows;
    let l = q.n_cols;
    let kk_max = k + 4; // subspace oversampling width used by symmetric_topk
    let mut tmp = vec![0f32; l * kk_max];
    // Column means μ (length L) for implicit centering.
    let mu: Vec<f32> = if center {
        let mut m = vec![0f32; l];
        for r in 0..n {
            let (cols, vals) = q.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                m[c as usize] += v;
            }
        }
        let inv = 1.0 / n as f32;
        m.iter_mut().for_each(|v| *v *= inv);
        m
    } else {
        vec![]
    };

    let (vals, vecs) = symmetric_topk(n, k, iters, seed, |x, y| {
        let kb = x.len() / n;
        let tmp = &mut tmp[..l * kb];
        // tmp = Qᵀ x  (L×kb)
        q.spmm_t(x, kb, tmp);
        if center {
            // tmp -= μ · (1ᵀ x)
            let mut colsum = vec![0f64; kb];
            for i in 0..n {
                for j in 0..kb {
                    colsum[j] += x[i * kb + j] as f64;
                }
            }
            for c in 0..l {
                let m = mu[c];
                if m != 0.0 {
                    for j in 0..kb {
                        tmp[c * kb + j] -= m * colsum[j] as f32;
                    }
                }
            }
        }
        // y = Q tmp
        q.spmm(tmp, kb, y);
        if center {
            // y -= 1 · (μᵀ tmp)
            let mut mudot = vec![0f64; kb];
            for c in 0..l {
                let m = mu[c];
                if m != 0.0 {
                    for j in 0..kb {
                        mudot[j] += (m * tmp[c * kb + j]) as f64;
                    }
                }
            }
            for i in 0..n {
                for j in 0..kb {
                    y[i * kb + j] -= mudot[j] as f32;
                }
            }
        }
    });

    // Scores = V · diag(sqrt(λ)): eigenvectors of the Gram operator are
    // the left singular vectors U, so U·Σ = V·sqrt(λ).
    let mut scores = vecs;
    for i in 0..n {
        for j in 0..k {
            scores[i * k + j] *= vals[j].max(0.0).sqrt();
        }
    }
    (scores, vals)
}

/// Project *new* rows onto an existing Leaf-PCA basis: given training
/// `(q_train, scores, vals)` and an OOS incidence map `q_new`, the OOS
/// scores are `Q_new · V_right` where `V_right = Q_trainᵀ U Σ⁻¹ =
/// Q_trainᵀ · scores · Σ⁻²·Σ = Q_trainᵀ scores / λ ... `; computed
/// stably as `Q_new (Q_trainᵀ scores) diag(1/λ) · diag(sqrt(λ))
/// = Q_new (Q_trainᵀ scores) diag(1/sqrt(λ))`.
/// (Uncentered variant; matches `leaf_pca(center=false)`.)
pub fn leaf_pca_project(
    q_train: &Csr,
    scores: &[f32],
    vals: &[f32],
    q_new: &Csr,
) -> Vec<f32> {
    let k = vals.len();
    let n = q_train.n_rows;
    let l = q_train.n_cols;
    assert_eq!(scores.len(), n * k);
    assert_eq!(q_new.n_cols, l);
    // basis = Q_trainᵀ · scores  (L×k), then scale columns by 1/λ_j
    // (scores = U sqrt(λ) ⇒ Qᵀ U = V_right sqrt(λ) ⇒ basis = V_right λ).
    let mut basis = vec![0f32; l * k];
    q_train.spmm_t(scores, k, &mut basis);
    for c in 0..l {
        for j in 0..k {
            let lam = vals[j].max(1e-12);
            basis[c * k + j] /= lam;
        }
    }
    // new scores = Q_new · basis · diag(sqrt(λ)) = Q_new·V_right·sqrt(λ)…
    // wait: OOS kernel-PCA scores are Q_new V_right = U_new Σ-coords.
    // Training scores are U Σ = Q_train V_right, so the consistent OOS
    // map is simply Q_new · V_right — basis already equals V_right.
    let mut out = vec![0f32; q_new.n_rows * k];
    q_new.spmm(&basis, k, &mut out);
    out
}

/// [`leaf_pca_project`] with the *training* factor in quantized form
/// (the serve-path variant: replicas holding a quantized bundle project
/// embed tiles without dequantizing `Q`). The basis is built by
/// [`QCsr::spmm_t`], whose accumulation order matches the exact
/// [`Csr::spmm_t`], so this is bitwise-identical to
/// `leaf_pca_project(&q_train.dequantize(), …)`.
pub fn leaf_pca_project_q(
    q_train: &QCsr,
    scores: &[f32],
    vals: &[f32],
    q_new: &Csr,
) -> Vec<f32> {
    let k = vals.len();
    let n = q_train.n_rows;
    let l = q_train.n_cols;
    assert_eq!(scores.len(), n * k);
    assert_eq!(q_new.n_cols, l);
    let mut basis = vec![0f32; l * k];
    q_train.spmm_t(scores, k, &mut basis);
    for c in 0..l {
        for j in 0..k {
            let lam = vals[j].max(1e-12);
            basis[c * k + j] /= lam;
        }
    }
    let mut out = vec![0f32; q_new.n_rows * k];
    q_new.spmm(&basis, k, &mut out);
    out
}

/// Plain PCA on a dense row-major `n×d` feature matrix (centered),
/// returning `(scores n×k, eigvals)` — the "raw" pipelines of Fig. 4.3.
pub fn dense_pca(x: &[f32], n: usize, d: usize, k: usize, iters: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), n * d);
    let mut mean = vec![0f64; d];
    for i in 0..n {
        for f in 0..d {
            mean[f] += x[i * d + f] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mean32: Vec<f32> = mean.iter().map(|&m| m as f32).collect();

    let kk_max = k + 4;
    let mut tmp = vec![0f32; d * kk_max];
    let (vals, vecs) = symmetric_topk(n, k, iters, seed, |v, y| {
        let kb = v.len() / n;
        let tmp = &mut tmp[..d * kb];
        tmp.fill(0.0);
        // tmp = Xcᵀ v where Xc = X - 1·meanᵀ.
        let mut colsum = vec![0f64; kb];
        for i in 0..n {
            for j in 0..kb {
                colsum[j] += v[i * kb + j] as f64;
            }
        }
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            let vi = &v[i * kb..(i + 1) * kb];
            for f in 0..d {
                let xv = xi[f];
                if xv != 0.0 {
                    let trow = &mut tmp[f * kb..(f + 1) * kb];
                    for j in 0..kb {
                        trow[j] += xv * vi[j];
                    }
                }
            }
        }
        for f in 0..d {
            let m = mean32[f];
            for j in 0..kb {
                tmp[f * kb + j] -= m * colsum[j] as f32;
            }
        }
        // y = Xc tmp.
        let mut mudot = vec![0f64; kb];
        for f in 0..d {
            let m = mean32[f];
            for j in 0..kb {
                mudot[j] += (m * tmp[f * kb + j]) as f64;
            }
        }
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            let yi = &mut y[i * kb..(i + 1) * kb];
            yi.fill(0.0);
            for f in 0..d {
                let xv = xi[f];
                if xv != 0.0 {
                    let trow = &tmp[f * kb..(f + 1) * kb];
                    for j in 0..kb {
                        yi[j] += xv * trow[j];
                    }
                }
            }
            for j in 0..kb {
                yi[j] -= mudot[j] as f32;
            }
        }
    });

    let mut scores = vecs;
    for i in 0..n {
        for j in 0..k {
            scores[i * k + j] *= vals[j].max(0.0).sqrt();
        }
    }
    (scores, vals)
}

/// Project new dense rows onto the training dense-PCA basis.
pub fn dense_pca_project(
    x_train: &[f32],
    n: usize,
    d: usize,
    scores: &[f32],
    vals: &[f32],
    x_new: &[f32],
) -> Vec<f32> {
    let k = vals.len();
    let n_new = x_new.len() / d;
    // Column means of training data.
    let mut mean = vec![0f32; d];
    for i in 0..n {
        for f in 0..d {
            mean[f] += x_train[i * d + f];
        }
    }
    mean.iter_mut().for_each(|m| *m /= n as f32);
    // V_right λ = Xcᵀ·scores ⇒ basis = Xcᵀ scores / λ.
    let mut basis = vec![0f32; d * k];
    // score col sums for centering Xcᵀ = Xᵀ - mean·1ᵀ.
    let mut ssum = vec![0f64; k];
    for i in 0..n {
        for j in 0..k {
            ssum[j] += scores[i * k + j] as f64;
        }
    }
    for i in 0..n {
        let xi = &x_train[i * d..(i + 1) * d];
        let si = &scores[i * k..(i + 1) * k];
        for f in 0..d {
            let xv = xi[f];
            if xv != 0.0 {
                for j in 0..k {
                    basis[f * k + j] += xv * si[j];
                }
            }
        }
    }
    for f in 0..d {
        for j in 0..k {
            basis[f * k + j] = (basis[f * k + j] - mean[f] * ssum[j] as f32)
                / vals[j].max(1e-12);
        }
    }
    let mut out = vec![0f32; n_new * k];
    for i in 0..n_new {
        let xi = &x_new[i * d..(i + 1) * d];
        let oi = &mut out[i * k..(i + 1) * k];
        for f in 0..d {
            let xv = xi[f] - mean[f];
            if xv != 0.0 {
                for j in 0..k {
                    oi[j] += xv * basis[f * k + j];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn dense_pca_finds_dominant_direction() {
        // Data stretched along (1,1)/√2 in 2D.
        let mut rng = Rng::new(1);
        let n = 300;
        let mut x = vec![0f32; n * 2];
        for i in 0..n {
            let a = rng.next_normal() as f32 * 5.0;
            let b = rng.next_normal() as f32 * 0.3;
            x[i * 2] = a + b;
            x[i * 2 + 1] = a - b;
        }
        let (scores, vals) = dense_pca(&x, n, 2, 2, 25, 2);
        assert!(vals[0] / vals[1] > 30.0, "vals={vals:?}");
        // PC1 score should correlate with x0 + x1.
        let mut dot = 0f64;
        let mut na = 0f64;
        let mut nb = 0f64;
        for i in 0..n {
            let a = scores[i * 2] as f64;
            let b = (x[i * 2] + x[i * 2 + 1]) as f64;
            dot += a * b;
            na += a * a;
            nb += b * b;
        }
        assert!(dot.abs() / (na.sqrt() * nb.sqrt()) > 0.99);
    }

    #[test]
    fn leaf_pca_matches_dense_gram_spectrum() {
        // Small sparse Q: compare eigvals of Q Qᵀ with dense Jacobi.
        let mut rng = Rng::new(3);
        let mut trip = vec![];
        let (n, l) = (25, 40);
        for r in 0..n {
            for c in 0..l {
                if rng.next_f64() < 0.15 {
                    trip.push((r, c as u32, rng.next_f32()));
                }
            }
        }
        let q = Csr::from_triplets(n, l, &trip);
        let (_, vals) = leaf_pca(&q, 4, 40, false, 5);
        // Dense reference.
        let qd = q.to_dense();
        let mut gram = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                gram[i * n + j] = (0..l).map(|c| qd[i * l + c] * qd[j * l + c]).sum();
            }
        }
        let (full, _) = super::super::linalg::jacobi_eigh(&gram, n);
        for j in 0..4 {
            assert!(
                (vals[j] - full[j]).abs() / full[0] < 1e-2,
                "eig {j}: {} vs {}",
                vals[j],
                full[j]
            );
        }
    }

    #[test]
    fn leaf_pca_scores_reproduce_gram_kernel() {
        // With k = rank, scores·scoresᵀ ≈ Q Qᵀ (uncentered kernel PCA).
        let mut rng = Rng::new(7);
        let (n, l) = (20, 8); // rank <= 8
        let mut trip = vec![];
        for r in 0..n {
            for c in 0..l {
                if rng.next_f64() < 0.4 {
                    trip.push((r, c as u32, rng.next_f32()));
                }
            }
        }
        let q = Csr::from_triplets(n, l, &trip);
        let (scores, _) = leaf_pca(&q, 8, 60, false, 9);
        let qd = q.to_dense();
        for i in 0..n {
            for j in 0..n {
                let gram: f32 = (0..l).map(|c| qd[i * l + c] * qd[j * l + c]).sum();
                let rec: f32 = (0..8).map(|p| scores[i * 8 + p] * scores[j * 8 + p]).sum();
                assert!((gram - rec).abs() < 0.05, "({i},{j}): {gram} vs {rec}");
            }
        }
    }

    #[test]
    fn centered_leaf_pca_scores_have_zero_mean() {
        let mut rng = Rng::new(11);
        let (n, l) = (30, 20);
        let mut trip = vec![];
        for r in 0..n {
            for c in 0..l {
                if rng.next_f64() < 0.3 {
                    trip.push((r, c as u32, 1.0f32));
                }
            }
        }
        let q = Csr::from_triplets(n, l, &trip);
        let (scores, _) = leaf_pca(&q, 3, 40, true, 13);
        for j in 0..3 {
            let mean: f32 = (0..n).map(|i| scores[i * 3 + j]).sum::<f32>() / n as f32;
            assert!(mean.abs() < 1e-3, "component {j} mean {mean}");
        }
    }

    #[test]
    fn oos_projection_consistent_on_training_rows() {
        // Projecting the training rows must reproduce the training scores.
        let mut rng = Rng::new(15);
        let (n, l) = (25, 30);
        let mut trip = vec![];
        for r in 0..n {
            for c in 0..l {
                if rng.next_f64() < 0.25 {
                    trip.push((r, c as u32, rng.next_f32()));
                }
            }
        }
        let q = Csr::from_triplets(n, l, &trip);
        let (scores, vals) = leaf_pca(&q, 3, 50, false, 17);
        let proj = leaf_pca_project(&q, &scores, &vals, &q);
        for i in 0..n * 3 {
            assert!(
                (proj[i] - scores[i]).abs() < 0.02 * vals[0].sqrt(),
                "{}: {} vs {}",
                i,
                proj[i],
                scores[i]
            );
        }
    }
}
