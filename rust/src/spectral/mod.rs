//! Spectral methods on leaf coordinates (§4.3).
//!
//! The paper's point: because `P = Q Qᵀ` (symmetric case), spectral
//! methods never need the dense kernel — they run on the sparse
//! leaf-incidence matrix `Q` directly. This module provides the full
//! §4.3 pipeline, from scratch:
//!
//! * [`linalg`] — small dense kernels: modified Gram–Schmidt QR and a
//!   Jacobi symmetric eigensolver (the LAPACK-corner we need).
//! * [`subspace`] — randomized subspace iteration on an implicit
//!   symmetric PSD operator (the ARPACK-equivalent).
//! * [`pca`] — Leaf PCA on sparse `Q` and plain PCA on dense features,
//!   both via the same operator machinery, with implicit centering
//!   (never materializing the centered matrix — the trick the paper
//!   credits to sklearn's ARPACK path).
//! * [`knn`] — random-projection-tree approximate kNN graphs (the
//!   neighbor-search substrate UMAP/PHATE pipelines spend their time in).
//! * [`embed`] — graph embeddings: spectral/PCA init + attraction-
//!   repulsion SGD (UMAP-analog) and diffusion maps (PHATE-analog);
//!   DESIGN.md §Substitutions records the mapping.

pub mod embed;
pub mod knn;
pub mod linalg;
pub mod pca;
pub mod subspace;

/// Embedding-quality metric used in Fig. 4.3 / App. J: classify each
/// test point by majority vote of its k nearest *train* embedding
/// points; ties break to the smaller class id.
pub fn knn_accuracy(
    train_emb: &[f32],
    train_y: &[f32],
    test_emb: &[f32],
    test_y: &[f32],
    dim: usize,
    k: usize,
    n_classes: usize,
) -> f64 {
    let n_train = train_y.len();
    let n_test = test_y.len();
    assert_eq!(train_emb.len(), n_train * dim);
    assert_eq!(test_emb.len(), n_test * dim);
    let mut hits = 0usize;
    // Exact search is fine here: dim is 2 and this is an evaluation.
    let mut dist_idx: Vec<(f32, u32)> = Vec::with_capacity(n_train);
    for i in 0..n_test {
        let qi = &test_emb[i * dim..(i + 1) * dim];
        dist_idx.clear();
        for j in 0..n_train {
            let rj = &train_emb[j * dim..(j + 1) * dim];
            let mut d = 0f32;
            for f in 0..dim {
                let t = qi[f] - rj[f];
                d += t * t;
            }
            dist_idx.push((d, j as u32));
        }
        let kk = k.min(n_train);
        dist_idx.select_nth_unstable_by(kk - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut votes = vec![0u32; n_classes];
        for &(_, j) in &dist_idx[..kk] {
            votes[train_y[j as usize] as usize] += 1;
        }
        let pred = (0..n_classes).max_by_key(|&c| (votes[c], usize::MAX - c)).unwrap();
        if pred as f32 == test_y[i] {
            hits += 1;
        }
    }
    hits as f64 / n_test.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_accuracy_perfect_on_separated_clusters() {
        // Two clusters far apart in 2D.
        let train_emb = vec![0.0, 0.0, 0.1, 0.0, 10.0, 10.0, 10.1, 10.0];
        let train_y = vec![0.0, 0.0, 1.0, 1.0];
        let test_emb = vec![0.05, 0.01, 9.9, 10.0];
        let test_y = vec![0.0, 1.0];
        let acc = knn_accuracy(&train_emb, &train_y, &test_emb, &test_y, 2, 2, 2);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn knn_accuracy_chance_on_shuffled_labels() {
        let mut rng = crate::rng::Rng::new(1);
        let n = 400;
        let train_emb: Vec<f32> = (0..n * 2).map(|_| rng.next_f32()).collect();
        let train_y: Vec<f32> = (0..n).map(|_| rng.gen_range(2) as f32).collect();
        let test_emb: Vec<f32> = (0..100 * 2).map(|_| rng.next_f32()).collect();
        let test_y: Vec<f32> = (0..100).map(|_| rng.gen_range(2) as f32).collect();
        let acc = knn_accuracy(&train_emb, &train_y, &test_emb, &test_y, 2, 10, 2);
        assert!((acc - 0.5).abs() < 0.2, "acc={acc}");
    }
}
