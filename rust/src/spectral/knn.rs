//! Approximate k-nearest-neighbor graphs via random-projection forests.
//!
//! The UMAP/PHATE-style pipelines of §4.3 are dominated by neighbor
//! search and graph construction; this is that substrate. An RP forest
//! splits points recursively on random hyperplanes (median threshold)
//! down to small leaves; candidate neighbors are leaf cohabitants across
//! several trees, refined by exact distance. Exact brute force is kept
//! for small inputs and as the test oracle.

use crate::coordinator::sink::KernelSource;
use crate::error::Result;
use crate::rng::Rng;
use crate::{anyhow, bail};

/// A kNN graph: `neighbors[i*k + j]` is the j-th neighbor of point i
/// (sorted by ascending distance), `dists` the matching distances
/// (Euclidean).
pub struct KnnGraph {
    pub n: usize,
    pub k: usize,
    pub neighbors: Vec<u32>,
    pub dists: Vec<f32>,
}

#[inline]
fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Exact brute-force kNN (O(n²d)); test oracle and small-input path.
pub fn knn_exact(x: &[f32], n: usize, d: usize, k: usize) -> KnnGraph {
    assert!(k < n, "need k < n");
    let mut neighbors = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    let mut cand: Vec<(f32, u32)> = Vec::with_capacity(n);
    for i in 0..n {
        cand.clear();
        let xi = &x[i * d..(i + 1) * d];
        for j in 0..n {
            if j != i {
                cand.push((sqdist(xi, &x[j * d..(j + 1) * d]), j as u32));
            }
        }
        cand.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        cand.truncate(k);
        cand.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (j, &(dd, idx)) in cand.iter().enumerate() {
            neighbors[i * k + j] = idx;
            dists[i * k + j] = dd.sqrt();
        }
    }
    KnnGraph { n, k, neighbors, dists }
}

/// One random-projection tree: returns, for each point, its leaf id, plus
/// the member list per leaf.
fn rp_tree(x: &[f32], n: usize, d: usize, leaf_size: usize, rng: &mut Rng) -> (Vec<u32>, Vec<Vec<u32>>) {
    let mut leaf_of = vec![0u32; n];
    let mut leaves: Vec<Vec<u32>> = Vec::new();
    let mut idx: Vec<u32> = (0..n as u32).collect();
    // Explicit stack of index ranges.
    let mut proj = vec![0f32; n];
    let mut stack: Vec<(usize, usize)> = vec![(0, n)];
    let mut dir = vec![0f32; d];
    while let Some((lo, hi)) = stack.pop() {
        let size = hi - lo;
        if size <= leaf_size.max(2) {
            let leaf_id = leaves.len() as u32;
            for &p in &idx[lo..hi] {
                leaf_of[p as usize] = leaf_id;
            }
            leaves.push(idx[lo..hi].to_vec());
            continue;
        }
        // Random unit-ish direction.
        for v in dir.iter_mut() {
            *v = rng.next_normal() as f32;
        }
        for (slot, &p) in idx[lo..hi].iter().enumerate() {
            let xi = &x[p as usize * d..(p as usize + 1) * d];
            proj[lo + slot] = xi.iter().zip(&dir).map(|(a, b)| a * b).sum();
        }
        // Median split via select_nth on (proj, idx) pairs.
        let mut pairs: Vec<(f32, u32)> =
            idx[lo..hi].iter().enumerate().map(|(s, &p)| (proj[lo + s], p)).collect();
        let mid = size / 2;
        pairs.select_nth_unstable_by(mid, |a, b| a.0.partial_cmp(&b.0).unwrap());
        for (s, &(_, p)) in pairs.iter().enumerate() {
            idx[lo + s] = p;
        }
        // Degenerate projections (all equal) → just split in half.
        stack.push((lo, lo + mid));
        stack.push((lo + mid, hi));
    }
    (leaf_of, leaves)
}

/// Approximate kNN graph via an RP forest: `n_trees` trees with leaves
/// of ≤ `leaf_size`, exact re-ranking of leaf-cohabitant candidates.
pub fn knn_approx(
    x: &[f32],
    n: usize,
    d: usize,
    k: usize,
    n_trees: usize,
    leaf_size: usize,
    seed: u64,
) -> KnnGraph {
    assert!(k < n);
    if n <= 2048 {
        return knn_exact(x, n, d, k);
    }
    let root = Rng::new(seed);
    let trees: Vec<(Vec<u32>, Vec<Vec<u32>>)> = (0..n_trees)
        .map(|t| {
            let mut rng = root.derive(t as u64 + 1);
            rp_tree(x, n, d, leaf_size, &mut rng)
        })
        .collect();

    let mut neighbors = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    let mut cand: Vec<u32> = Vec::with_capacity(n_trees * leaf_size * 2);
    let mut scored: Vec<(f32, u32)> = Vec::with_capacity(n_trees * leaf_size * 2);
    for i in 0..n {
        cand.clear();
        for (leaf_of, leaves) in &trees {
            for &p in &leaves[leaf_of[i] as usize] {
                if p as usize != i {
                    cand.push(p);
                }
            }
        }
        cand.sort_unstable();
        cand.dedup();
        scored.clear();
        let xi = &x[i * d..(i + 1) * d];
        for &p in &cand {
            scored.push((sqdist(xi, &x[p as usize * d..(p as usize + 1) * d]), p));
        }
        let kk = k.min(scored.len());
        if kk > 0 {
            scored.select_nth_unstable_by(kk - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
            scored.truncate(kk);
            scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        }
        for j in 0..k {
            // If a leaf was starved of candidates, pad with the last
            // found neighbor (degenerate but safe).
            let (dd, p) = if j < scored.len() {
                scored[j]
            } else if !scored.is_empty() {
                scored[scored.len() - 1]
            } else {
                (f32::INFINITY, ((i + 1) % n) as u32)
            };
            neighbors[i * k + j] = p;
            dists[i * k + j] = dd.sqrt();
        }
    }
    KnnGraph { n, k, neighbors, dists }
}

/// Rank one kernel row's entries by descending proximity (ties toward
/// the smaller column id — the deterministic order every kernel-kNN
/// consumer shares), excluding column `exclude` if given, truncated to
/// the best `k`. Returns `(column, proximity)` pairs, possibly fewer
/// than `k` (no padding — see [`knn_row`] for the padded graph view).
/// This is the single ranking primitive behind [`knn_from_kernel`] and
/// the serving layer's `/neighbors` endpoint, which must agree bitwise.
pub fn rank_row(cols: &[u32], vals: &[f32], exclude: Option<usize>, k: usize) -> Vec<(u32, f32)> {
    let mut cand: Vec<(f32, u32)> = Vec::with_capacity(cols.len());
    for (&c, &v) in cols.iter().zip(vals) {
        if Some(c as usize) != exclude {
            cand.push((v, c));
        }
    }
    // Largest proximity first; deterministic tie-break on column.
    cand.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    cand.truncate(k);
    cand.into_iter().map(|(p, c)| (c, p)).collect()
}

/// The kNN-graph view of one kernel row `i` (of an `n×n` kernel):
/// exactly `k` `(neighbor, distance)` slots with self excluded,
/// distance `√(max(0, 1 − p))`, and rows with fewer than `k` nonzero
/// proximities padded with their last candidate (or `(i+1) mod n` at
/// `f32::INFINITY` when the row is empty) — [`knn_from_kernel`]'s
/// per-row contract, factored out so the online server produces
/// bit-identical answers.
pub fn knn_row(i: usize, n: usize, cols: &[u32], vals: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
    let cand = rank_row(cols, vals, Some(i), k);
    let mut neighbors = Vec::with_capacity(k);
    let mut dists = Vec::with_capacity(k);
    for j in 0..k {
        let (c, p) = if j < cand.len() {
            cand[j]
        } else if let Some(&last) = cand.last() {
            last
        } else {
            (((i + 1) % n) as u32, f32::NEG_INFINITY)
        };
        neighbors.push(c);
        dists.push(if p == f32::NEG_INFINITY { f32::INFINITY } else { (1.0 - p).max(0.0).sqrt() });
    }
    (neighbors, dists)
}

/// Build a kNN graph straight from a materialized proximity kernel
/// streamed in row order — an in-memory CSR or an out-of-core
/// [`crate::coordinator::shard::ShardReader`], through the shared
/// [`KernelSource`] interface. Per row the k largest proximities
/// (self excluded; ties toward the smaller column id) become the
/// neighbors, with distance `√(max(0, 1 − p))` so identical samples sit
/// at 0. Rows with fewer than k nonzero proximities are padded with
/// their last candidate (or `(i+1) mod n` at `f32::INFINITY` when the
/// row is empty), mirroring [`knn_approx`]'s starved-leaf behavior.
/// Per-row semantics live in [`knn_row`].
pub fn knn_from_kernel(src: &dyn KernelSource, k: usize) -> Result<KnnGraph> {
    let n = src.n_rows();
    if n != src.n_cols() {
        bail!("kernel is {}×{}, need square for a kNN graph", n, src.n_cols());
    }
    if k == 0 || k >= n.max(1) {
        return Err(anyhow!("need 0 < k < n (k={k}, n={n})"));
    }
    let mut neighbors = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    src.for_each_row(&mut |i, cols, vals| {
        let (nb, ds) = knn_row(i, n, cols, vals, k);
        neighbors[i * k..(i + 1) * k].copy_from_slice(&nb);
        dists[i * k..(i + 1) * k].copy_from_slice(&ds);
    })?;
    Ok(KnnGraph { n, k, neighbors, dists })
}

/// Cross kNN: for each query row, its k nearest rows of a *reference*
/// set (exact, used for OOS embedding attachment).
pub fn knn_cross_exact(
    queries: &[f32],
    n_q: usize,
    refs: &[f32],
    n_r: usize,
    d: usize,
    k: usize,
) -> KnnGraph {
    assert!(k <= n_r);
    let mut neighbors = vec![0u32; n_q * k];
    let mut dists = vec![0f32; n_q * k];
    let mut cand: Vec<(f32, u32)> = Vec::with_capacity(n_r);
    for i in 0..n_q {
        cand.clear();
        let qi = &queries[i * d..(i + 1) * d];
        for j in 0..n_r {
            cand.push((sqdist(qi, &refs[j * d..(j + 1) * d]), j as u32));
        }
        cand.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        cand.truncate(k);
        cand.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (j, &(dd, idx)) in cand.iter().enumerate() {
            neighbors[i * k + j] = idx;
            dists[i * k + j] = dd.sqrt();
        }
    }
    KnnGraph { n: n_q, k, neighbors, dists }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_points(side: usize) -> Vec<f32> {
        // side×side unit grid in 2D.
        let mut x = Vec::with_capacity(side * side * 2);
        for i in 0..side {
            for j in 0..side {
                x.push(i as f32);
                x.push(j as f32);
            }
        }
        x
    }

    #[test]
    fn exact_knn_on_grid_finds_adjacent_cells() {
        let side = 5;
        let x = grid_points(side);
        let g = knn_exact(&x, side * side, 2, 4);
        // Interior point (2,2) = index 12: neighbors at distance 1.
        let nb: Vec<u32> = g.neighbors[12 * 4..13 * 4].to_vec();
        let expect = [7u32, 11, 13, 17];
        let mut nb_sorted = nb.clone();
        nb_sorted.sort_unstable();
        assert_eq!(nb_sorted, expect);
        assert!(g.dists[12 * 4..13 * 4].iter().all(|&d| (d - 1.0).abs() < 1e-6));
    }

    #[test]
    fn approx_knn_high_recall_vs_exact() {
        let mut rng = Rng::new(2);
        let (n, d, k) = (3000, 8, 10);
        let x: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let exact = knn_exact(&x, n, d, k);
        let approx = knn_approx(&x, n, d, k, 6, 48, 3);
        let mut hits = 0usize;
        for i in 0..n {
            let e: std::collections::HashSet<u32> =
                exact.neighbors[i * k..(i + 1) * k].iter().copied().collect();
            for &p in &approx.neighbors[i * k..(i + 1) * k] {
                if e.contains(&p) {
                    hits += 1;
                }
            }
        }
        let recall = hits as f64 / (n * k) as f64;
        assert!(recall > 0.6, "recall={recall}");
    }

    #[test]
    fn approx_never_returns_self() {
        let mut rng = Rng::new(4);
        let (n, d, k) = (2500, 4, 5);
        let x: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let g = knn_approx(&x, n, d, k, 4, 32, 5);
        for i in 0..n {
            for &p in &g.neighbors[i * k..(i + 1) * k] {
                assert_ne!(p as usize, i);
            }
        }
    }

    #[test]
    fn cross_knn_identifies_identical_rows() {
        let refs = vec![0.0, 0.0, 5.0, 5.0, 9.0, 0.0];
        let queries = vec![5.1, 5.0, 0.0, 0.1];
        let g = knn_cross_exact(&queries, 2, &refs, 3, 2, 1);
        assert_eq!(g.neighbors[0], 1);
        assert_eq!(g.neighbors[1], 0);
    }

    #[test]
    fn knn_from_kernel_ranks_by_proximity() {
        use crate::sparse::Csr;
        let p = Csr::from_triplets(
            3,
            3,
            &[
                (0, 0, 1.0),
                (0, 1, 0.8),
                (0, 2, 0.1),
                (1, 0, 0.8),
                (1, 1, 1.0),
                (1, 2, 0.3),
                (2, 0, 0.1),
                (2, 1, 0.3),
                (2, 2, 1.0),
            ],
        );
        let g = knn_from_kernel(&p, 2).unwrap();
        assert_eq!(g.neighbors[0..2], [1, 2]); // row 0: 0.8 then 0.1
        assert_eq!(g.neighbors[2..4], [0, 2]); // row 1: 0.8 then 0.3
        assert_eq!(g.neighbors[4..6], [1, 0]); // row 2: 0.3 then 0.1
        assert!((g.dists[0] - (1.0f32 - 0.8).sqrt()).abs() < 1e-6);
        // Degenerate k rejected.
        assert!(knn_from_kernel(&p, 0).is_err());
        assert!(knn_from_kernel(&p, 3).is_err());
    }

    #[test]
    fn knn_from_kernel_pads_sparse_rows() {
        use crate::sparse::Csr;
        // Row 1 has no off-diagonal proximity at all.
        let p = Csr::from_triplets(3, 3, &[(0, 2, 0.5), (1, 1, 1.0), (2, 0, 0.5)]);
        let g = knn_from_kernel(&p, 2).unwrap();
        assert_eq!(g.neighbors[0..2], [2, 2]); // padded with last candidate
        assert_eq!(g.neighbors[2..4], [2, 2]); // empty row: (i+1) % n
        assert!(g.dists[2].is_infinite());
    }

    #[test]
    fn small_inputs_fall_back_to_exact() {
        let mut rng = Rng::new(6);
        let (n, d, k) = (100, 3, 4);
        let x: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let a = knn_approx(&x, n, d, k, 4, 16, 7);
        let e = knn_exact(&x, n, d, k);
        assert_eq!(a.neighbors, e.neighbors);
    }
}
