//! Randomized subspace iteration on an implicit symmetric PSD operator.
//!
//! The ARPACK-equivalent the paper relies on: spectral structure of
//! `P = Q Qᵀ` is recovered from `Q` directly (SVD argument after
//! Cor. 3.7), so we only ever need `v ↦ A·v` products. Block power
//! iteration with MGS re-orthonormalization and a final Rayleigh–Ritz
//! projection gives the top-k eigenpairs to the accuracy the embedding
//! pipelines need.

use super::linalg::{jacobi_eigh, matmul, mgs_orthonormalize};
use crate::rng::Rng;

/// Top-k eigenpairs of an implicit symmetric PSD operator of size `n`.
///
/// `apply(x, y)` must write `y = A·x` for block matrices in the
/// row-major-k layout (`x[i*k + j]`, both `n×k`).
///
/// Returns `(eigvals desc, eigvecs n×k row-major-k)`.
pub fn symmetric_topk(
    n: usize,
    k: usize,
    iters: usize,
    seed: u64,
    mut apply: impl FnMut(&[f32], &mut [f32]),
) -> (Vec<f32>, Vec<f32>) {
    assert!(k >= 1 && k <= n);
    let mut rng = Rng::new(seed);
    // Oversample for convergence, then truncate.
    let kk = (k + 4).min(n);
    let mut v: Vec<f32> = (0..n * kk).map(|_| rng.next_normal() as f32).collect();
    mgs_orthonormalize(&mut v, n, kk);
    let mut av = vec![0f32; n * kk];
    for _ in 0..iters.max(1) {
        apply(&v, &mut av);
        std::mem::swap(&mut v, &mut av);
        mgs_orthonormalize(&mut v, n, kk);
    }
    // Rayleigh–Ritz: B = Vᵀ A V (kk×kk), eig(B), rotate V.
    apply(&v, &mut av);
    let mut b = vec![0f32; kk * kk];
    for i in 0..n {
        let vi = &v[i * kk..(i + 1) * kk];
        let avi = &av[i * kk..(i + 1) * kk];
        for a in 0..kk {
            let va = vi[a];
            if va != 0.0 {
                for c in 0..kk {
                    b[a * kk + c] += va * avi[c];
                }
            }
        }
    }
    // Symmetrize against round-off.
    for a in 0..kk {
        for c in (a + 1)..kk {
            let m = 0.5 * (b[a * kk + c] + b[c * kk + a]);
            b[a * kk + c] = m;
            b[c * kk + a] = m;
        }
    }
    let (vals, rot) = jacobi_eigh(&b, kk);
    let rotated = matmul(&v, &rot, n, kk, kk);
    // Truncate to k.
    let mut out_vecs = vec![0f32; n * k];
    for i in 0..n {
        out_vecs[i * k..(i + 1) * k].copy_from_slice(&rotated[i * kk..i * kk + k]);
    }
    (vals[..k].to_vec(), out_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense symmetric PSD test operator A = M Mᵀ (n×n).
    fn dense_psd(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let m: Vec<f32> = (0..n * n).map(|_| rng.next_normal() as f32).collect();
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f32;
                for p in 0..n {
                    acc += m[i * n + p] * m[j * n + p];
                }
                a[i * n + j] = acc;
            }
        }
        a
    }

    fn apply_dense(a: &[f32], n: usize, k: usize) -> impl FnMut(&[f32], &mut [f32]) + '_ {
        move |x: &[f32], y: &mut [f32]| {
            y.fill(0.0);
            for i in 0..n {
                for p in 0..n {
                    let v = a[i * n + p];
                    if v != 0.0 {
                        for j in 0..k {
                            y[i * k + j] += v * x[p * k + j];
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn recovers_dense_spectrum() {
        let n = 30;
        let a = dense_psd(n, 1);
        let k = 5;
        // kk = k + 4 internally; operator must handle that block width.
        let (vals, vecs) = symmetric_topk(n, k, 30, 7, apply_dense(&a, n, k + 4));
        // Compare against Jacobi on the full matrix.
        let (full_vals, _) = jacobi_eigh(&a, n);
        for j in 0..k {
            let rel = (vals[j] - full_vals[j]).abs() / full_vals[j].max(1e-6);
            assert!(rel < 5e-3, "eig {j}: {} vs {}", vals[j], full_vals[j]);
        }
        // Residual ||A v - λ v|| small for the top pair.
        let mut av = vec![0f32; n];
        for i in 0..n {
            av[i] = (0..n).map(|p| a[i * n + p] * vecs[p * k]).sum();
        }
        let mut resid = 0f32;
        for i in 0..n {
            resid += (av[i] - vals[0] * vecs[i * k]).powi(2);
        }
        assert!(resid.sqrt() / vals[0] < 1e-2, "resid={resid}");
    }

    #[test]
    fn eigenvalues_sorted_descending_and_nonnegative() {
        let n = 20;
        let a = dense_psd(n, 2);
        let (vals, _) = symmetric_topk(n, 6, 25, 3, apply_dense(&a, n, 10));
        for w in vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
        assert!(vals.iter().all(|&v| v > -1e-3));
    }

    #[test]
    fn deterministic_in_seed() {
        let n = 15;
        let a = dense_psd(n, 3);
        let (v1, e1) = symmetric_topk(n, 3, 20, 9, apply_dense(&a, n, 7));
        let (v2, e2) = symmetric_topk(n, 3, 20, 9, apply_dense(&a, n, 7));
        assert_eq!(v1, v2);
        assert_eq!(e1, e2);
    }
}
