//! Nonlinear 2-D embeddings on kNN graphs: the UMAP-analog (fuzzy
//! attraction/repulsion SGD over a PCA init) and the PHATE-analog
//! (adaptive-bandwidth diffusion maps).
//!
//! These are deliberately compact re-implementations of the *mechanism*
//! each method contributes — neighbor-graph attraction/repulsion for
//! UMAP, diffusion-operator spectral coordinates for PHATE — since the
//! original libraries are unavailable here and Fig. 4.3's claim ("leaf
//! coordinates improve every DR pipeline") is about the pipelines'
//! inputs, not their specific force curves (DESIGN.md §Substitutions).

use super::knn::{knn_cross_exact, KnnGraph};
use super::subspace::symmetric_topk;
use crate::rng::Rng;
use crate::sparse::Csr;

/// Fuzzy edge weights from a kNN graph, UMAP-style: for each point,
/// `w_ij = exp(-(d_ij - ρ_i)/σ_i)` with `ρ_i` the distance to the
/// nearest neighbor and `σ_i` the mean excess distance. Returns a COO
/// edge list (i, j, w) with weights in (0, 1].
pub fn fuzzy_edges(graph: &KnnGraph) -> Vec<(u32, u32, f32)> {
    let (n, k) = (graph.n, graph.k);
    let mut edges = Vec::with_capacity(n * k);
    for i in 0..n {
        let dists = &graph.dists[i * k..(i + 1) * k];
        let rho = dists[0];
        let sigma = (dists.iter().map(|&d| (d - rho).max(0.0)).sum::<f32>() / k as f32).max(1e-6);
        for j in 0..k {
            let w = (-(dists[j] - rho).max(0.0) / sigma).exp();
            edges.push((i as u32, graph.neighbors[i * k + j], w));
        }
    }
    edges
}

/// Attraction/repulsion SGD refinement of a 2-D layout (UMAP-analog).
///
/// * attraction along fuzzy kNN edges with the `1/(1+d²)` kernel,
/// * repulsion against uniformly sampled negatives,
/// * linearly decaying learning rate, clipped updates.
///
/// `fixed_prefix` points are held in place (used to embed test points
/// against a frozen training layout).
pub fn sgd_refine(
    coords: &mut [f32],
    n: usize,
    edges: &[(u32, u32, f32)],
    epochs: usize,
    lr0: f32,
    neg_samples: usize,
    fixed_prefix: usize,
    seed: u64,
) {
    assert_eq!(coords.len(), n * 2);
    let mut rng = Rng::new(seed);
    let clip = 4.0f32;
    for epoch in 0..epochs {
        let lr = lr0 * (1.0 - epoch as f32 / epochs.max(1) as f32).max(0.05);
        for &(i, j, w) in edges {
            let (i, j) = (i as usize, j as usize);
            let dx = coords[i * 2] - coords[j * 2];
            let dy = coords[i * 2 + 1] - coords[j * 2 + 1];
            let d2 = dx * dx + dy * dy;
            // Attractive gradient of log(1/(1+d²)) scaled by edge weight.
            let g = (-2.0 * w / (1.0 + d2)).max(-clip);
            let (gx, gy) = ((g * dx).clamp(-clip, clip), (g * dy).clamp(-clip, clip));
            if i >= fixed_prefix {
                coords[i * 2] += lr * gx;
                coords[i * 2 + 1] += lr * gy;
            }
            if j >= fixed_prefix {
                coords[j * 2] -= lr * gx;
                coords[j * 2 + 1] -= lr * gy;
            }
            // Negative sampling: push i away from random points.
            if i >= fixed_prefix {
                for _ in 0..neg_samples {
                    let r = rng.gen_range(n);
                    if r == i {
                        continue;
                    }
                    let dx = coords[i * 2] - coords[r * 2];
                    let dy = coords[i * 2 + 1] - coords[r * 2 + 1];
                    let d2 = dx * dx + dy * dy;
                    let g = (2.0 / ((0.1 + d2) * (1.0 + d2))).min(clip);
                    coords[i * 2] += (lr * g * dx).clamp(-clip, clip);
                    coords[i * 2 + 1] += (lr * g * dy).clamp(-clip, clip);
                }
            }
        }
    }
}

/// Full UMAP-analog: fuzzy kNN edges + SGD from a (provided) 2-D init —
/// typically the top-2 PCA scores scaled to unit RMS.
pub fn umap_like(init: &[f32], n: usize, graph: &KnnGraph, epochs: usize, seed: u64) -> Vec<f32> {
    let mut coords = normalize_init(init, n);
    let edges = fuzzy_edges(graph);
    sgd_refine(&mut coords, n, &edges, epochs, 0.25, 3, 0, seed);
    coords
}

/// Embed new points against a frozen reference layout: attach each query
/// at the fuzzy-weighted mean of its k nearest reference points (in the
/// *input* space used to build the reference graph), then run a few SGD
/// epochs with the reference points fixed.
pub fn embed_oos(
    ref_inputs: &[f32],
    ref_coords: &[f32],
    n_ref: usize,
    query_inputs: &[f32],
    n_query: usize,
    dim_in: usize,
    k: usize,
    seed: u64,
) -> Vec<f32> {
    let cross = knn_cross_exact(query_inputs, n_query, ref_inputs, n_ref, dim_in, k);
    let mut out = vec![0f32; n_query * 2];
    for i in 0..n_query {
        let dists = &cross.dists[i * k..(i + 1) * k];
        let rho = dists[0];
        let sigma = (dists.iter().map(|&d| (d - rho).max(0.0)).sum::<f32>() / k as f32).max(1e-6);
        let mut wx = 0f64;
        let mut wy = 0f64;
        let mut ws = 0f64;
        for j in 0..k {
            let w = ((-(dists[j] - rho).max(0.0) / sigma).exp()) as f64;
            let p = cross.neighbors[i * k + j] as usize;
            wx += w * ref_coords[p * 2] as f64;
            wy += w * ref_coords[p * 2 + 1] as f64;
            ws += w;
        }
        out[i * 2] = (wx / ws) as f32;
        out[i * 2 + 1] = (wy / ws) as f32;
    }
    // Optional local refinement: combined layout with refs fixed.
    let mut combined = Vec::with_capacity((n_ref + n_query) * 2);
    combined.extend_from_slice(ref_coords);
    combined.extend_from_slice(&out);
    let edges: Vec<(u32, u32, f32)> = (0..n_query)
        .flat_map(|i| {
            let dists = &cross.dists[i * k..(i + 1) * k];
            let rho = dists[0];
            let sigma =
                (dists.iter().map(|&d| (d - rho).max(0.0)).sum::<f32>() / k as f32).max(1e-6);
            (0..k)
                .map(|j| {
                    let w = (-(dists[j] - rho).max(0.0) / sigma).exp();
                    ((n_ref + i) as u32, cross.neighbors[i * k + j], w)
                })
                .collect::<Vec<_>>()
        })
        .collect();
    // Attraction-only refinement: with the reference layout frozen,
    // repulsion would push a well-attached query off its cluster (its
    // nearest refs are also its strongest "negatives"), so it is
    // disabled here.
    sgd_refine(&mut combined, n_ref + n_query, &edges, 5, 0.05, 0, n_ref, seed);
    combined[n_ref * 2..].to_vec()
}

/// PHATE-analog: adaptive-bandwidth diffusion maps on the kNN graph.
///
/// Affinity `A_ij = exp(-d_ij²/(σ_i σ_j))` (symmetrized), normalized
/// `M = D^{-1/2} A D^{-1/2}`; the top non-trivial eigenpairs give
/// diffusion coordinates `ψ_j λ_j^t`. Returns the 2-D coordinates.
pub fn diffusion_map(graph: &KnnGraph, t_steps: u32, iters: usize, seed: u64) -> Vec<f32> {
    let (n, k) = (graph.n, graph.k);
    // Adaptive bandwidths: σ_i = distance to the ⌈k/2⌉-th neighbor.
    let mut sigma = vec![0f32; n];
    for i in 0..n {
        sigma[i] = graph.dists[i * k + k / 2].max(1e-6);
    }
    // Symmetric affinity matrix (union of directed kNN edges).
    let mut trip: Vec<(usize, u32, f32)> = Vec::with_capacity(2 * n * k);
    for i in 0..n {
        for j in 0..k {
            let p = graph.neighbors[i * k + j] as usize;
            let d = graph.dists[i * k + j];
            let a = (-(d * d) / (sigma[i] * sigma[p])).exp();
            trip.push((i, p as u32, a));
            trip.push((p, i as u32, a));
        }
    }
    // from_triplets sums duplicates: halve to average the two directions.
    for t in trip.iter_mut() {
        t.2 *= 0.5;
    }
    let mut a = Csr::from_triplets(n, n, &trip);
    // D^{-1/2} A D^{-1/2}.
    let deg: Vec<f32> = a.row_sums();
    let dinv: Vec<f32> = deg.iter().map(|&v| 1.0 / v.max(1e-9).sqrt()).collect();
    crate::sparse::scale_rows(&mut a, &dinv);
    crate::sparse::scale_cols(&mut a, &dinv);

    // Top 3 eigenpairs of M: the first is the trivial √deg direction.
    let mut tmp = vec![0f32; n];
    let _ = &mut tmp;
    let (vals, vecs) = symmetric_topk(n, 3, iters, seed, |x, y| {
        let kb = x.len() / n;
        a.spmm(x, kb, y);
    });
    let mut out = vec![0f32; n * 2];
    for i in 0..n {
        // ψ = D^{-1/2} v (diffusion-map convention), scaled by λ^t.
        let scale0 = vals[1].max(0.0).powi(t_steps as i32);
        let scale1 = vals[2].max(0.0).powi(t_steps as i32);
        out[i * 2] = vecs[i * 3 + 1] * dinv[i] * scale0;
        out[i * 2 + 1] = vecs[i * 3 + 2] * dinv[i] * scale1;
    }
    // Normalize to unit RMS per axis for comparability.
    normalize_init(&out, n)
}

/// Scale a 2-D layout to zero mean and unit RMS per axis.
pub fn normalize_init(init: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(init.len(), n * 2);
    let mut out = init.to_vec();
    for axis in 0..2 {
        let mean: f64 = (0..n).map(|i| out[i * 2 + axis] as f64).sum::<f64>() / n as f64;
        let mut var = 0f64;
        for i in 0..n {
            let v = out[i * 2 + axis] as f64 - mean;
            var += v * v;
        }
        let scale = 1.0 / (var / n as f64).sqrt().max(1e-12);
        for i in 0..n {
            out[i * 2 + axis] = ((out[i * 2 + axis] as f64 - mean) * scale) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::knn::knn_exact;
    use crate::rng::Rng;

    /// Two well-separated 2-D clusters, 30 points each, plus labels.
    fn two_clusters() -> (Vec<f32>, Vec<usize>) {
        let mut rng = Rng::new(1);
        let mut x = vec![];
        let mut y = vec![];
        for i in 0..60 {
            let c = i % 2;
            x.push(c as f32 * 20.0 + rng.next_normal() as f32);
            x.push(rng.next_normal() as f32);
            y.push(c);
        }
        (x, y)
    }

    fn cluster_separation(coords: &[f32], y: &[usize]) -> f32 {
        // Ratio of between-cluster centroid distance to mean within-
        // cluster spread.
        let mut cent = [[0f64; 2]; 2];
        let mut cnt = [0f64; 2];
        for (i, &c) in y.iter().enumerate() {
            cent[c][0] += coords[i * 2] as f64;
            cent[c][1] += coords[i * 2 + 1] as f64;
            cnt[c] += 1.0;
        }
        for c in 0..2 {
            cent[c][0] /= cnt[c];
            cent[c][1] /= cnt[c];
        }
        let between = ((cent[0][0] - cent[1][0]).powi(2) + (cent[0][1] - cent[1][1]).powi(2)).sqrt();
        let mut within = 0f64;
        for (i, &c) in y.iter().enumerate() {
            within += ((coords[i * 2] as f64 - cent[c][0]).powi(2)
                + (coords[i * 2 + 1] as f64 - cent[c][1]).powi(2))
            .sqrt();
        }
        within /= y.len() as f64;
        (between / within.max(1e-9)) as f32
    }

    #[test]
    fn fuzzy_edges_weights_in_unit_interval() {
        let (x, _) = two_clusters();
        let g = knn_exact(&x, 60, 2, 5);
        let edges = fuzzy_edges(&g);
        assert_eq!(edges.len(), 60 * 5);
        assert!(edges.iter().all(|&(_, _, w)| w > 0.0 && w <= 1.0));
        // Nearest neighbor always gets weight 1.
        assert!(edges.chunks(5).all(|c| (c[0].2 - 1.0).abs() < 1e-6));
    }

    #[test]
    fn umap_like_separates_clusters() {
        let (x, y) = two_clusters();
        let g = knn_exact(&x, 60, 2, 5);
        // Random init: the SGD must discover the separation from edges.
        let mut rng = Rng::new(7);
        let init: Vec<f32> = (0..120).map(|_| rng.next_normal() as f32).collect();
        let coords = umap_like(&init, 60, &g, 120, 3);
        assert!(cluster_separation(&coords, &y) > 1.5, "sep={}", cluster_separation(&coords, &y));
    }

    #[test]
    fn diffusion_map_separates_clusters() {
        let (x, y) = two_clusters();
        let g = knn_exact(&x, 60, 2, 8);
        let coords = diffusion_map(&g, 2, 40, 5);
        assert!(cluster_separation(&coords, &y) > 1.5, "sep={}", cluster_separation(&coords, &y));
    }

    #[test]
    fn normalize_init_unit_rms() {
        let mut rng = Rng::new(9);
        let init: Vec<f32> = (0..200).map(|_| 3.0 + 10.0 * rng.next_normal() as f32).collect();
        let out = normalize_init(&init, 100);
        for axis in 0..2 {
            let mean: f64 = (0..100).map(|i| out[i * 2 + axis] as f64).sum::<f64>() / 100.0;
            let rms: f64 =
                ((0..100).map(|i| (out[i * 2 + axis] as f64).powi(2)).sum::<f64>() / 100.0).sqrt();
            assert!(mean.abs() < 1e-4);
            assert!((rms - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn oos_embedding_lands_near_own_cluster() {
        let (x, y) = two_clusters();
        let g = knn_exact(&x, 60, 2, 5);
        // PCA-style init (the documented §4.3 pipeline shape): the input
        // is already 2-D, so the init is the data itself. Random init is
        // exercised by `umap_like_separates_clusters`; it can fragment
        // clusters, which is exactly why the paper's pipelines put PCA
        // in front.
        let coords = umap_like(&x, 60, &g, 120, 3);
        // Queries: one point near each cluster center in input space.
        let queries = vec![0.0, 0.0, 20.0, 0.0];
        let q_coords = embed_oos(&x, &coords, 60, &queries, 2, 2, 5, 13);
        // Each query should be nearer to its cluster's centroid.
        for (qi, cls) in [(0usize, 0usize), (1, 1)] {
            let mut best = (f32::INFINITY, usize::MAX);
            for c in 0..2 {
                let mut cent = [0f32; 2];
                let mut cnt = 0f32;
                for (i, &yy) in y.iter().enumerate() {
                    if yy == c {
                        cent[0] += coords[i * 2];
                        cent[1] += coords[i * 2 + 1];
                        cnt += 1.0;
                    }
                }
                cent[0] /= cnt;
                cent[1] /= cnt;
                let d = (q_coords[qi * 2] - cent[0]).powi(2)
                    + (q_coords[qi * 2 + 1] - cent[1]).powi(2);
                if d < best.0 {
                    best = (d, c);
                }
            }
            assert_eq!(best.1, cls, "query {qi} landed in wrong cluster");
        }
    }
}
