//! Small dense linear-algebra kernels: modified Gram–Schmidt QR and a
//! cyclic Jacobi symmetric eigensolver. Matrices are row-major; the
//! "tall" matrices of subspace iteration use the row-major-k layout
//! (`v[i*k + j]`) shared with `Csr::spmm`.

/// In-place modified Gram–Schmidt orthonormalization of the k columns of
/// a tall `n×k` row-major matrix. Columns that vanish (rank deficiency)
/// are replaced with zeros. Returns the column norms seen (diagnostics).
pub fn mgs_orthonormalize(v: &mut [f32], n: usize, k: usize) -> Vec<f32> {
    assert_eq!(v.len(), n * k);
    let mut norms = Vec::with_capacity(k);
    for j in 0..k {
        // Norm before projection: the rank-deficiency test is *relative*
        // to it (an absolute epsilon would keep normalized round-off
        // noise as a spurious basis vector).
        let mut pre = 0f64;
        for i in 0..n {
            pre += (v[i * k + j] as f64).powi(2);
        }
        let pre = pre.sqrt();
        // Two projection passes (MGS with reorthogonalization) for
        // numerical orthogonality at f32.
        for _pass in 0..2 {
            for p in 0..j {
                let mut dot = 0f64;
                for i in 0..n {
                    dot += v[i * k + j] as f64 * v[i * k + p] as f64;
                }
                let dot = dot as f32;
                if dot != 0.0 {
                    for i in 0..n {
                        v[i * k + j] -= dot * v[i * k + p];
                    }
                }
            }
        }
        let mut norm = 0f64;
        for i in 0..n {
            norm += (v[i * k + j] as f64).powi(2);
        }
        let norm = norm.sqrt();
        norms.push(norm as f32);
        if norm > 1e-6 * pre.max(1e-30) && norm > 1e-20 {
            let inv = (1.0 / norm) as f32;
            for i in 0..n {
                v[i * k + j] *= inv;
            }
        } else {
            for i in 0..n {
                v[i * k + j] = 0.0;
            }
        }
    }
    norms
}

/// Cyclic Jacobi eigendecomposition of a symmetric `k×k` matrix
/// (row-major). Returns `(eigenvalues, eigenvectors)` with eigenvectors
/// in the *columns* of the returned row-major matrix, sorted by
/// descending eigenvalue.
pub fn jacobi_eigh(a_in: &[f32], k: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(a_in.len(), k * k);
    let mut a: Vec<f64> = a_in.iter().map(|&x| x as f64).collect();
    let mut v = vec![0f64; k * k];
    for i in 0..k {
        v[i * k + i] = 1.0;
    }
    for _sweep in 0..64 {
        let mut off = 0f64;
        for p in 0..k {
            for q in (p + 1)..k {
                off += a[p * k + q] * a[p * k + q];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..k {
            for q in (p + 1)..k {
                let apq = a[p * k + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * k + p];
                let aqq = a[q * k + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of A.
                for i in 0..k {
                    let aip = a[i * k + p];
                    let aiq = a[i * k + q];
                    a[i * k + p] = c * aip - s * aiq;
                    a[i * k + q] = s * aip + c * aiq;
                }
                for i in 0..k {
                    let api = a[p * k + i];
                    let aqi = a[q * k + i];
                    a[p * k + i] = c * api - s * aqi;
                    a[q * k + i] = s * api + c * aqi;
                }
                // Accumulate rotations into V.
                for i in 0..k {
                    let vip = v[i * k + p];
                    let viq = v[i * k + q];
                    v[i * k + p] = c * vip - s * viq;
                    v[i * k + q] = s * vip + c * viq;
                }
            }
        }
    }
    // Extract eigenpairs and sort by descending eigenvalue.
    let mut pairs: Vec<(f64, usize)> = (0..k).map(|i| (a[i * k + i], i)).collect();
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let mut vals = Vec::with_capacity(k);
    let mut vecs = vec![0f32; k * k];
    for (out_col, &(val, src_col)) in pairs.iter().enumerate() {
        vals.push(val as f32);
        for i in 0..k {
            vecs[i * k + out_col] = v[i * k + src_col] as f32;
        }
    }
    (vals, vecs)
}

/// `C = A·B` for small dense row-major matrices: `(m×k)·(k×n) → m×n`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let v = a[i * k + p];
            if v != 0.0 {
                let brow = &b[p * n..(p + 1) * n];
                let crow = &mut c[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += v * brow[j];
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn mgs_produces_orthonormal_columns() {
        let mut rng = Rng::new(1);
        let (n, k) = (40, 6);
        let mut v: Vec<f32> = (0..n * k).map(|_| rng.next_normal() as f32).collect();
        mgs_orthonormalize(&mut v, n, k);
        for a in 0..k {
            for b in 0..k {
                let mut dot = 0f64;
                for i in 0..n {
                    dot += v[i * k + a] as f64 * v[i * k + b] as f64;
                }
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4, "({a},{b}): {dot}");
            }
        }
    }

    #[test]
    fn mgs_handles_rank_deficiency() {
        // Two identical columns: second must vanish.
        let mut v = vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        mgs_orthonormalize(&mut v, 3, 2);
        let col1_norm: f32 = (0..3).map(|i| v[i * 2 + 1] * v[i * 2 + 1]).sum();
        assert!(col1_norm < 1e-10);
    }

    #[test]
    fn jacobi_recovers_known_spectrum() {
        // diag(5, 2, -1) rotated by a known orthogonal matrix.
        let a = vec![
            3.0f32, 1.0, 1.0, //
            1.0, 3.0, 1.0, //
            1.0, 1.0, 3.0,
        ];
        let (vals, vecs) = jacobi_eigh(&a, 3);
        // Known eigenvalues: 5, 2, 2.
        assert!((vals[0] - 5.0).abs() < 1e-4);
        assert!((vals[1] - 2.0).abs() < 1e-4);
        assert!((vals[2] - 2.0).abs() < 1e-4);
        // A v = λ v for the top eigenvector.
        for i in 0..3 {
            let av: f32 = (0..3).map(|j| a[i * 3 + j] * vecs[j * 3]).sum();
            assert!((av - vals[0] * vecs[i * 3]).abs() < 1e-3);
        }
    }

    #[test]
    fn jacobi_eigenvectors_orthonormal() {
        let mut rng = Rng::new(3);
        let k = 5;
        let mut a = vec![0f32; k * k];
        for i in 0..k {
            for j in i..k {
                let v = rng.next_normal() as f32;
                a[i * k + j] = v;
                a[j * k + i] = v;
            }
        }
        let (_, vecs) = jacobi_eigh(&a, k);
        for c1 in 0..k {
            for c2 in 0..k {
                let dot: f32 = (0..k).map(|i| vecs[i * k + c1] * vecs[i * k + c2]).sum();
                let expect = if c1 == c2 { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn jacobi_trace_preserved() {
        let a = vec![2.0f32, 0.5, 0.5, 1.0];
        let (vals, _) = jacobi_eigh(&a, 2);
        assert!((vals.iter().sum::<f32>() - 3.0).abs() < 1e-4);
    }

    #[test]
    fn matmul_small_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
    }
}
