//! `fk-lint` — the crate's invariant linter. Walks a source tree and
//! enforces the five rule families documented in `rust/INVARIANTS.md`:
//! `no-panic-in-serve`, `safety-comment`, `determinism`,
//! `metric-hygiene`, and `zero-dep`.
//!
//! ```text
//! fk-lint [--root DIR] [--rules id,id,...] [--json]
//! ```
//!
//! Findings print as `file:line rule-id message`, one per line (or a
//! JSON array with `--json`). Exit status: 0 clean, 1 findings, 2
//! usage or I/O error. Suppress a finding in source with
//! `// fk-lint: allow(rule-id) -- reason` on the same or preceding
//! line; suppressions are counted and capped repo-wide.

use forest_kernels::analysis::{self, Config, Report, RULE_IDS};
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    root: Option<PathBuf>,
    rules: Option<String>,
    json: bool,
}

fn usage() -> &'static str {
    "usage: fk-lint [--root DIR] [--rules id,id,...] [--json]\n\
     \n\
     Default root is ./rust/src (or ./src when run from rust/).\n\
     Rules: no-panic-in-serve, safety-comment, determinism,\n\
            metric-hygiene, zero-dep (all enabled by default)."
}

fn parse_args() -> Result<Args, String> {
    let mut out = Args { root: None, rules: None, json: false };
    let mut argv = std::env::args().skip(1);
    while let Some(arg) = argv.next() {
        match arg.as_str() {
            "--root" => {
                out.root = Some(PathBuf::from(
                    argv.next().ok_or_else(|| "--root needs a directory".to_string())?,
                ))
            }
            "--rules" => {
                out.rules =
                    Some(argv.next().ok_or_else(|| "--rules needs a list".to_string())?)
            }
            "--json" => out.json = true,
            "--help" | "-h" => return Err(String::new()),
            other => return Err(format!("unknown argument {other:?}")),
        }
    }
    Ok(out)
}

fn default_root() -> PathBuf {
    let nested = PathBuf::from("rust/src");
    if nested.is_dir() {
        nested
    } else {
        PathBuf::from("src")
    }
}

fn render_json(report: &Report) -> String {
    use forest_kernels::obs::json_str;
    let mut out = String::from("{\n  \"findings\": [");
    for (i, f) in report.findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"message\": {}}}",
            json_str(&f.file),
            f.line,
            json_str(f.rule),
            json_str(&f.message)
        ));
    }
    if !report.findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str(&format!(
        "],\n  \"files_scanned\": {},\n  \"suppressions_used\": {},\n  \"suppressions_total\": {}\n}}\n",
        report.files_scanned, report.suppressions_used, report.suppressions_total
    ));
    out
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            if msg.is_empty() {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            eprintln!("fk-lint: {msg}\n{}", usage());
            return ExitCode::from(2);
        }
    };
    let cfg = match &args.rules {
        Some(list) => match Config::from_list(list) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("fk-lint: {e}");
                return ExitCode::from(2);
            }
        },
        None => Config::all(),
    };
    let root = args.root.unwrap_or_else(default_root);
    if !root.is_dir() {
        eprintln!("fk-lint: source root {} is not a directory", root.display());
        return ExitCode::from(2);
    }
    let report = match analysis::lint_dir(&root, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fk-lint: {e:#}");
            return ExitCode::from(2);
        }
    };
    if args.json {
        print!("{}", render_json(&report));
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        eprintln!(
            "fk-lint: {} file(s), {} finding(s), {} suppression(s) in use ({} total; rules: {})",
            report.files_scanned,
            report.findings.len(),
            report.suppressions_used,
            report.suppressions_total,
            RULE_IDS.join(", ")
        );
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
