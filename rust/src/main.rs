//! `repro` — the forest-kernels CLI.
//!
//! Subcommands cover the whole pipeline (train → kernel → embed →
//! predict → serve) plus one `bench-*` harness per paper figure/table
//! (see DESIGN.md's experiment index). Arguments are `--key value`
//! flags parsed by the tiny in-repo parser (the offline vendor set has
//! no clap).

use forest_kernels::bench_support::{
    doubling_sizes, peak_rss_bytes, read_bench_json, rss_bytes, time, write_bench_json,
    BenchRecord,
};
use forest_kernels::coordinator::shard::{self, ShardReader, ShardSink};
use forest_kernels::coordinator::sink::{CsrSink, SparsifyConfig, SparsifySink};
use forest_kernels::coordinator::{self, CoordinatorConfig};
use forest_kernels::error::{Context, Result};
use forest_kernels::model::{self, BundleMeta, CompanionModel, MmapMode, ModelBundle};
use forest_kernels::obs;
use forest_kernels::serve::{self, ServeConfig};
use forest_kernels::sparse::{Csr, QuantMode};
use forest_kernels::{anyhow, bail, exec};
use forest_kernels::data::registry;
use forest_kernels::experiments::{fig41, fig42, fig43, tablei1};
use forest_kernels::forest::{Forest, ForestKind, TrainConfig};
use forest_kernels::spectral::pca;
use forest_kernels::swlc::{predict, ForestKernel, ProximityKind};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Minimal `--key value` flag parser; positional args collected in order.
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = vec![];
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

const USAGE: &str = "\
repro — sparse leaf-incidence forest kernels (SWLC)

USAGE: repro <command> [--flags]

Global flags:
  --threads N      worker threads for all parallel paths (SpGEMM, forest
                   training, factor build, coordinator); default = cores,
                   also settable via FK_THREADS
  --trace FILE     write structured tracing spans/events as JSONL to FILE
                   (fit, materialize, shards run, serve, route; `shards
                   run` gives each worker FILE-partNNN.jsonl); tracing is
                   observational only — traced runs produce bitwise-
                   identical outputs to untraced ones
  --slow-ms N      (serve / route) slow-query log: requests slower than
                   N ms emit an `http.slow` JSONL event on stderr + the
                   trace sink with request id, endpoint, status, tier,
                   and duration

Model bundles (fk-bundle-v4, section-aligned; v1/v2/v3 files still load):
  fit      --dataset covertype --n 20000 --trees 50 --method gap
           [--out model.fkb] [--quantize none|int8|int4]
           [--companion depth=D,subsample=F]
           (train the forest, fit the SWLC factors, and persist the
            whole model — forest, binning thresholds, context θ, Q/W
            factors, labels — as one checksummed binary bundle;
            --quantize stores block-quantized factors instead of exact
            CSRs for a several-times-smaller artifact, and prints the
            per-section byte sizes either way; --companion also trains
            a depth-capped (D), subsampled (F·N bootstrap draws per
            tree) companion forest + factors and persists both tiers
            in the one bundle — serve answers {\"budget\": \"cheap\"}
            /predict requests from it at a fraction of the full-tier
            latency)
  every command below also accepts --model model.fkb: the bundle is
  loaded instead of retraining (bitwise-identical factors), and
  `shards run` forwards it to all P workers so the forest is fit once.
  kernel / materialize / predict / serve also accept
  --quantize none|int8|int4: int8/int4 switches the kernel products
  (stripe SpGEMM, OOS prediction, serve tiles) onto the compressed
  factors; `none` (the default for exact models) keeps the bitwise
  f32 path. A quantized --model implies its own mode; asking for a
  different one is an error.

Pipeline commands:
  datasets                                 print the Table F.1 dataset analogs
  train    --dataset covertype --n 20000 --trees 50 [--kind rf|et|gbt]
  kernel   --dataset covertype --n 20000 --trees 50 --method gap [--model model.fkb]
  predict  --dataset covertype --n 20000 --trees 50 --method gap
           [--model model.fkb --queries 1000]
  embed    --dataset pbmc --n 5000 [--pca-dims 24] [--model model.fkb --queries 1000]
  serve    --model model.fkb [--addr 127.0.0.1:7878] [--batch 32]
           [--linger-ms 2] [--shards DIR] [--embed-dims 8] [--replicas R]
           [--mmap auto|on|off] [--slow-ms N] [--trace FILE]
           (long-running HTTP/1.1 keep-alive server over real TCP:
            POST /predict, /neighbors, /embed + GET /healthz, /stats,
            /metrics (Prometheus text exposition of the process-wide
            registry: per-endpoint request counters + latency
            histograms, per-tier latency, exec busy-time, queue
            depth/wait, stripe SpGEMM totals, shard-cache hits/misses,
            reload + shed counters), and /debug/trace (the in-memory
            ring of recent trace events); every response echoes
            x-request-id (client-supplied ids are also added to JSON
            bodies); single queries are micro-batched into exec-pool
            tiles;
            answers are bitwise-identical to the in-process batch
            paths; /predict accepts {\"budget\": \"cheap\"|\"full\"|
            \"auto\"} when the bundle holds a --companion model —
            cheap runs the shallow tier, auto sheds to it under queue
            pressure instead of timing out, and /neighbors + /embed
            are always full-tier; --shards serves /neighbors row
            lookups from a
            materialized shard directory; --replicas R spawns R serve
            processes on ephemeral ports and fronts them with the
            replica router on --addr; --mmap picks the bundle load
            path: `auto` (default) maps v3 bundles zero-copy via
            mmap(2) for O(1) load, `on` requires it, `off` decodes
            onto the heap — every response carries the serving
            model_generation either way; POST /admin/reload (or
            SIGHUP) atomically swaps in a freshly loaded copy of
            --model with zero dropped queries)
  route    --backends host:port,host:port,... [--addr 127.0.0.1:7979]
           [--slow-ms N] [--trace FILE]
           (replica router over already-running serve processes: health-
            checks the backends at bind, round-robins /predict, /embed,
            and OOS /neighbors over pooled keep-alive connections, pins
            /neighbors row lookups to the row-range owner, and merges
            GET /stats across the fleet; GET /metrics scrapes every
            backend and serves the fleet-wide merged exposition
            (counters/histograms summed, gauges per-replica under a
            `backend` label); x-request-id is stamped on ingress and
            relayed to the chosen replica; routed responses are byte-
            identical to direct ones; POST /admin/reload drives a
            rolling reload across the fleet — one backend at a time,
            never retried — so the model refreshes with zero downtime)
  materialize --dataset covertype --n 20000 --method kerf
              --sink csr|shards|topk|topk-shards [--out kernel-shards]
              [--mem-budget 256M | --stripe-rows 4096]
              [--top-k 32 --epsilon 0.0] [--verify]
              (streams P through a kernel sink; shards write binary
               stripe files + manifest.json readable by ShardReader)
              worker mode: [--row-range A..B --part K --shard-dir D --procs P]
              (materialize only global rows A..B as fragment K of a shard
               directory shared with sibling workers; --procs sizes this
               process's thread pool to an even 1/P share of the cores)

Multi-process sharding (one coordinator per OS process):
  shards plan     --procs 4 [dataset/forest flags] [--shard-dir D]
                  (print cost-balanced row ranges + the worker recipe)
  shards run      --procs 4 [dataset/forest flags] [--shard-dir D]
                  [--worker-threads T] [--verify-full]
                  (spawn P materialize workers, wait, merge, validate;
                   --verify-full compares the merged directory bitwise
                   against a single-process in-memory materialization)
  shards merge    --dir D   (fuse manifest-part-*.json fragments into the
                   canonical manifest.json, checking coverage + file sizes)
  shards validate --dir D [--verify [--sample 64] + dataset/forest flags]
                  (check coverage, checksums, structure; --verify retrains
                   and cross-checks sampled rows bitwise against the
                   single-process reference product)

Paper harnesses (DESIGN.md experiment index):
  bench-fig41    [--base-n 8000 --seed 1]
  bench-fig42    --axis dataset|method|minleaf|kind|depth
                 [--min-n 4096 --max-n 65536 --trees 50 --dataset covertype]
                 [--json-out BENCH_spgemm.json]  (adds serial-vs-parallel probe)
  bench-figh1    [--min-n 4096 --max-n 32768]  (all four ablation rows)
  bench-fig43    [--dataset fashionmnist --n 12000 --test-n 2000]
  bench-tablei1  [--sizes 16384,32768,65536 --trees 50]
  bench-naive    [--n 2048] [--json-out BENCH_spgemm.json]  (factored vs naive)
  bench-materialize [--n 20000 --trees 32] [--json-out BENCH_materialize.json]
                 (in-memory CSR sink vs spill-to-disk shard sink vs shard
                  read-back scan; reports throughput + peak RSS)
  bench-shard-merge [--n 8000 --trees 20 --procs 1,2,4]
                 [--json-out BENCH_shard_merge.json]
                 (fragment write / merge / validate throughput vs. the
                  number of worker partitions, plus the bundle
                  fit-vs-load speedup a --model worker enjoys)
  bench-serve    [--n 4000 --trees 16 --queries 256] [--batches 1,4,16]
                 [--clients 1,2,4] [--transports close,keepalive]
                 [--route-replicas R] [--json-out BENCH_serve.json]
                 (spawn the HTTP server on an ephemeral port and measure
                  /predict QPS + latency percentiles vs client-side
                  batch size × client thread count; `close` opens a
                  connection per request, `keepalive` reuses one per
                  client thread; the close baseline always runs — it
                  prices the speedup the other modes record;
                  --route-replicas R adds a `routed` mode through the
                  replica router over R in-process servers)
  bench-load     [--min-n 2000 --max-n 16000 --trees 24] [--replicas 4]
                 [--json-out BENCH_load.json]
                 (fk-bundle-v3 load-path economics: parse-vs-mmap cold
                  and warm load time vs bundle size, first-query
                  latency from a cold process, and the aggregate heap
                  R replicas would pay under each mode — the mmap rows
                  should stay flat while the heap rows grow with N)
  bench-learned  [--dataset airlines --n 20000]  (§5 ablation: uniform vs
                 impurity-enriched vs learned tree-weight kernels)
  bench-quantize [--n 8192 --trees 48 --min-leaf 64 --method kerf]
                 [--sample-rows 256] [--json-out BENCH_quantize.json]
                 (exact vs int8/int4 factors: serialized bytes/row,
                  full-kernel SpGEMM throughput, and neighbor recall@10
                  / recall@100 of the quantized product vs the exact one)
  bench-tiered   [--n 6000 --trees 40 --queries 256] [--depths 3,5]
                 [--subsamples 0.1,0.25] [--json-out BENCH_tiered.json]
                 (price the accuracy-vs-p99 frontier of tiered serving:
                  for each companion depth × subsample point, serve the
                  two-tier bundle and drive /predict at both budgets —
                  per-tier p50/p95/p99 + OOS accuracy show what the
                  cheap tier buys and what it costs)

CI gate:
  bench-compare  --baseline DIR --current DIR [--max-regress 0.25]
                 (compare every BENCH_*.json present in both dirs,
                  record-by-record on wall_secs; fails on any
                  regression past --max-regress, prints a per-metric
                  markdown table — appended to $GITHUB_STEP_SUMMARY
                  when set — and exits 0 with a seed notice when the
                  baseline dir is empty or missing)

Tooling (separate binary):
  fk-lint  [--root DIR] [--rules id,id,...] [--json]
           (in-repo invariant lint over rust/src: no-panic-in-serve,
            safety-comment, determinism, metric-hygiene, zero-dep —
            see rust/INVARIANTS.md; exits 1 on findings, suppress a
            line with `// fk-lint: allow(rule-id) -- reason`)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    obs::init();
    if let Some(n) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        exec::set_threads(n);
    }
    if let Some(path) = args.get("trace") {
        if let Err(e) = obs::trace_to_file(path) {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
    let out = dispatch(&cmd, &args);
    // The JSONL sink is buffered; flush whether the command succeeded
    // or not, so a failing run still leaves its spans on disk.
    obs::flush_trace();
    if let Err(e) = out {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "datasets" => cmd_datasets(),
        "train" => cmd_train(args),
        "fit" => cmd_fit(args),
        "kernel" => cmd_kernel(args),
        "predict" => cmd_predict(args),
        "embed" => cmd_embed(args),
        "serve" => cmd_serve(args),
        "route" => cmd_route(args),
        "materialize" => cmd_materialize(args),
        "shards" => cmd_shards(args),
        "bench-materialize" => cmd_bench_materialize(args),
        "bench-shard-merge" => cmd_bench_shard_merge(args),
        "bench-serve" => cmd_bench_serve(args),
        "bench-load" => cmd_bench_load(args),
        "bench-tiered" => cmd_bench_tiered(args),
        "bench-compare" => cmd_bench_compare(args),
        "bench-fig41" => cmd_fig41(args),
        "bench-fig42" => cmd_fig42(args),
        "bench-figh1" => cmd_figh1(args),
        "bench-fig43" => cmd_fig43(args),
        "bench-tablei1" => cmd_tablei1(args),
        "bench-naive" => cmd_naive(args),
        "bench-learned" => cmd_learned(args),
        "bench-quantize" => cmd_bench_quantize(args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other}\n{USAGE}"),
    }
}

fn load_data(args: &Args) -> Result<(forest_kernels::Dataset, String)> {
    let name = args.str_or("dataset", "covertype").to_string();
    let spec = registry::by_name(&name).ok_or_else(|| anyhow!("unknown dataset {name}"))?;
    let n = args.usize_or("n", spec.default_n.min(20_000));
    let seed = args.u64_or("seed", 42);
    Ok((spec.generate(n, seed), name))
}

fn train_cfg(args: &Args) -> TrainConfig {
    let kind = match args.str_or("kind", "rf") {
        "et" => ForestKind::ExtraTrees,
        "gbt" => ForestKind::GradientBoosting,
        _ => ForestKind::RandomForest,
    };
    TrainConfig {
        kind,
        n_trees: args.usize_or("trees", 50),
        max_depth: args.get("depth").and_then(|v| v.parse().ok()),
        min_samples_leaf: args.usize_or("min-leaf", 1),
        max_samples: Some(args.usize_or("max-samples", 100_000)),
        seed: args.u64_or("seed", 42),
        criterion: if args.str_or("kind", "rf") == "gbt" {
            forest_kernels::forest::Criterion::Mse
        } else {
            forest_kernels::forest::Criterion::Gini
        },
        ..Default::default()
    }
}

fn method(args: &Args) -> Result<ProximityKind> {
    let m = args.str_or("method", "gap");
    ProximityKind::from_name(m).ok_or_else(|| anyhow!("unknown method {m}"))
}

/// Parse `--quantize`: outer `None` = flag absent (keep whatever the
/// model already has), `Some(None)` = explicit `none`, `Some(Some(m))`
/// = a requested quantized mode.
fn parse_quant(args: &Args) -> Result<Option<Option<QuantMode>>> {
    match args.get("quantize") {
        None => Ok(None),
        Some(s) => QuantMode::from_name(s)
            .map(Some)
            .ok_or_else(|| anyhow!("--quantize must be none, int8, or int4 (got {s:?})")),
    }
}

/// Apply the `--quantize` policy to a model: explicit modes must agree
/// with a quantized bundle (its exact factors are already the
/// dequantized ones — a different grid cannot be recovered), and
/// explicit `none` on a quantized bundle is equally impossible.
fn apply_quant(args: &Args, bundle: &mut ModelBundle) -> Result<()> {
    let Some(req) = parse_quant(args)? else { return Ok(()) };
    match (bundle.kernel.quantization(), req) {
        (Some(have), Some(want)) if have != want => bail!(
            "--model holds {} factors but --quantize {} was requested",
            have.name(),
            want.name()
        ),
        (Some(have), None) => bail!(
            "--model holds {} factors; --quantize none cannot restore the exact ones \
             (refit without --quantize instead)",
            have.name()
        ),
        (Some(_), Some(_)) => {} // same mode, already attached
        (None, want) => {
            bundle.kernel.set_quantization(want);
            // The tiers quantize together: a cheap-tier answer from an
            // int8 bundle should be int8 too.
            if let Some(c) = bundle.companion.as_mut() {
                c.kernel.set_quantization(want);
            }
        }
    }
    Ok(())
}

/// Parse `--companion depth=D,subsample=F`: D caps the companion
/// trees' depth, F ∈ (0, 1] scales the per-tree bootstrap draws to
/// F·N. Omitted keys take the shallow defaults depth=4,
/// subsample=0.25.
fn parse_companion(args: &Args) -> Result<Option<(usize, f32)>> {
    let Some(spec) = args.get("companion") else { return Ok(None) };
    let (mut depth, mut subsample) = (4usize, 0.25f32);
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let Some((key, val)) = part.split_once('=') else {
            bail!("--companion wants depth=D,subsample=F (got {part:?})");
        };
        match key.trim() {
            "depth" => {
                depth =
                    val.trim().parse().map_err(|_| anyhow!("bad companion depth {val:?}"))?;
            }
            "subsample" => {
                subsample = val
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad companion subsample {val:?}"))?;
            }
            other => bail!("unknown --companion key {other:?} (depth|subsample)"),
        }
    }
    if depth == 0 {
        bail!("--companion depth must be >= 1");
    }
    if !(subsample > 0.0 && subsample <= 1.0) {
        bail!("--companion subsample must be in (0, 1], got {subsample}");
    }
    Ok(Some((depth, subsample)))
}

/// Train the cheap tier next to the full forest: the same dataset and
/// proximity kind, but depth-capped at D with F·N bootstrap draws per
/// tree — the DiNo/RanBu recipe for a fraction-of-the-cost predictor.
/// Returns the companion plus its train/fit seconds, or `None` when
/// `--companion` is absent.
fn train_companion(
    args: &Args,
    data: &forest_kernels::Dataset,
    kind: ProximityKind,
    cfg: &TrainConfig,
) -> Result<Option<(CompanionModel, f64, f64)>> {
    let Some((depth, subsample)) = parse_companion(args)? else { return Ok(None) };
    let draws = ((subsample as f64 * data.n as f64).ceil() as usize).max(1);
    let ccfg =
        TrainConfig { max_depth: Some(depth), max_samples: Some(draws), ..cfg.clone() };
    let (forest, secs_train) =
        time(|| forest_kernels::experiments::train_for(data, kind, &ccfg));
    let (mut kernel, secs_fit) = time(|| ForestKernel::fit(&forest, data, kind));
    if let Some(mode) = parse_quant(args)?.flatten() {
        kernel.set_quantization(Some(mode));
    }
    Ok(Some((CompanionModel { forest, kernel, depth, subsample }, secs_train, secs_fit)))
}

/// Parse `--mmap auto|on|off` (default `auto`): how `--model` bundles
/// are bound — zero-copy mmap(2) when the file is fk-bundle-v3 and the
/// target supports it, or a full heap decode.
fn parse_mmap(args: &Args) -> Result<MmapMode> {
    match args.get("mmap") {
        None => Ok(MmapMode::Auto),
        Some(s) => {
            MmapMode::from_name(s).ok_or_else(|| anyhow!("--mmap wants auto|on|off, got {s:?}"))
        }
    }
}

/// The model every pipeline command runs on: loaded from `--model`
/// (nothing retrains — the bundle's factors are bitwise the fitted
/// ones), or trained + fitted from the dataset/forest flags. Flags
/// that would contradict a loaded bundle (`--method`, `--dataset`,
/// `--n`, `--trees`) are rejected rather than silently ignored;
/// `--seed` stays free because the query-set helpers legitimately use
/// it to draw fresh queries against a fixed model.
fn load_or_fit(args: &Args) -> Result<ModelBundle> {
    load_or_fit_with(args, MmapMode::Off).map(|(b, _)| b)
}

/// [`load_or_fit`] with an explicit bundle bind mode. Returns the
/// bundle plus how it is resident: `"mmap"` (sections borrowed from
/// the mapped file), `"heap"` (decoded + fully verified), or `"fit"`
/// (trained in-process, no file involved).
fn load_or_fit_with(args: &Args, mmap: MmapMode) -> Result<(ModelBundle, &'static str)> {
    if let Some(path) = args.get("model") {
        let (bundle, load_mode) = ModelBundle::load_with_mode(Path::new(path), mmap)
            .with_context(|| format!("loading --model {path}"))?;
        if let Some(m) = args.get("method") {
            if m != bundle.kernel.kind.name() {
                bail!(
                    "--model holds method {:?} but --method {m} was requested",
                    bundle.kernel.kind.name()
                );
            }
        }
        if let Some(ds) = args.get("dataset") {
            if ds != bundle.meta.dataset {
                bail!(
                    "--model was fitted on {:?} but --dataset {ds} was requested",
                    bundle.meta.dataset
                );
            }
        }
        if let Some(n) = args.get("n").and_then(|v| v.parse::<usize>().ok()) {
            if n != bundle.meta.n {
                bail!("--model was fitted on N={} but --n {n} was requested", bundle.meta.n);
            }
        }
        if let Some(t) = args.get("trees").and_then(|v| v.parse::<usize>().ok()) {
            if t != bundle.meta.trees {
                bail!(
                    "--model holds {} trees but --trees {t} was requested",
                    bundle.meta.trees
                );
            }
        }
        println!(
            "loaded {path} via {load_mode}: dataset={} N={} T={} method={}{} \
             ({:.1} factor MB, no retraining)",
            bundle.meta.dataset,
            bundle.kernel.ctx.n,
            bundle.kernel.ctx.t,
            bundle.kernel.kind.name(),
            match bundle.kernel.quantization() {
                Some(m) => format!(" quantize={}", m.name()),
                None => String::new(),
            },
            bundle.kernel.factor_bytes() as f64 / 1e6,
        );
        let mut bundle = bundle;
        apply_quant(args, &mut bundle)?;
        Ok((bundle, load_mode))
    } else {
        let (data, name) = load_data(args)?;
        let kind = method(args)?;
        let cfg = train_cfg(args);
        let forest = forest_kernels::experiments::train_for(&data, kind, &cfg);
        let kernel = ForestKernel::fit(&forest, &data, kind);
        let meta =
            BundleMeta { dataset: name, n: data.n, seed: cfg.seed, trees: forest.n_trees() };
        let companion = train_companion(args, &data, kind, &cfg)?.map(|(c, _, _)| c);
        let mut bundle = ModelBundle { forest, kernel, meta, companion };
        apply_quant(args, &mut bundle)?;
        Ok((bundle, "fit"))
    }
}

/// A fresh query set drawn from the bundle's dataset analog, with the
/// seed offset so queries never replay the training rows.
fn query_set(
    args: &Args,
    bundle: &ModelBundle,
    default_n: usize,
) -> Result<(forest_kernels::Dataset, String)> {
    let name = args.str_or("dataset", &bundle.meta.dataset).to_string();
    let spec = registry::by_name(&name).ok_or_else(|| anyhow!("unknown dataset {name}"))?;
    let n_q = args.usize_or("queries", default_n).max(1);
    let seed = args.u64_or("seed", bundle.meta.seed) ^ 0x51EED;
    Ok((spec.generate(n_q, seed), name))
}

fn cmd_fit(args: &Args) -> Result<()> {
    let (data, name) = load_data(args)?;
    let kind = method(args)?;
    let cfg = train_cfg(args);
    let (forest, secs_train) = {
        let _sp = obs::span_with(
            "fit.train",
            forest_kernels::kv! { dataset: name.as_str(), n: data.n, trees: cfg.n_trees },
        );
        time(|| forest_kernels::experiments::train_for(&data, kind, &cfg))
    };
    let (mut kernel, secs_fit) = {
        let _sp = obs::span("fit.factors");
        time(|| ForestKernel::fit(&forest, &data, kind))
    };
    if let Some(mode) = parse_quant(args)?.flatten() {
        kernel.set_quantization(Some(mode));
    }
    let companion = match train_companion(args, &data, kind, &cfg)? {
        Some((c, secs_ctrain, secs_cfit)) => {
            println!(
                "companion: depth<={} subsample={} -> T={} L={} | train {secs_ctrain:.2}s \
                 fit {secs_cfit:.2}s",
                c.depth,
                c.subsample,
                c.forest.n_trees(),
                c.kernel.ctx.l,
            );
            Some(c)
        }
        None => None,
    };
    let meta =
        BundleMeta { dataset: name.clone(), n: data.n, seed: cfg.seed, trees: forest.n_trees() };
    let out = PathBuf::from(args.str_or("out", "model.fkb"));
    let bundle = ModelBundle { forest, kernel, meta, companion };
    let (saved, secs_save) = time(|| {
        model::save_with_sizes(
            &out,
            &bundle.forest,
            &bundle.kernel,
            &bundle.meta,
            bundle.companion.as_ref(),
        )
    });
    let (written, sizes) = saved?;
    println!(
        "{name}: N={} T={} L={} method={}{} | train {secs_train:.2}s fit {secs_fit:.2}s | \
         wrote {:.1} MB to {} in {secs_save:.2}s (fk-bundle-v4, section-aligned, \
         FNV-1a checksummed)",
        data.n,
        bundle.forest.n_trees(),
        bundle.kernel.ctx.l,
        kind.name(),
        match bundle.kernel.quantization() {
            Some(m) => format!(" quantize={}", m.name()),
            None => String::new(),
        },
        written as f64 / 1e6,
        out.display()
    );
    println!(
        "  sections: forest {:.2} MB | context {:.2} MB | exact factors {:.2} MB | \
         quantized factors {:.2} MB | companion {:.2} MB",
        sizes.forest as f64 / 1e6,
        sizes.context as f64 / 1e6,
        sizes.factors as f64 / 1e6,
        sizes.quantized as f64 / 1e6,
        sizes.companion as f64 / 1e6,
    );
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("# Dataset analogs (cf. paper Table F.1)");
    println!("name\tpaper_N\tdefault_N\tfeatures\tclasses");
    for s in registry::registry() {
        println!("{}\t{}\t{}\t{}\t{}", s.name, s.paper_n, s.default_n, s.d, s.n_classes);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let (data, name) = load_data(args)?;
    let cfg = train_cfg(args);
    let (forest, secs) = time(|| Forest::train(&data, &cfg));
    println!(
        "{name}: N={} d={} C={} | T={} L={} h̄={:.1} | train {secs:.2}s | train-acc {:.4}",
        data.n,
        data.d,
        data.n_classes,
        forest.n_trees(),
        forest.n_leaves_total(),
        forest.mean_depth(),
        forest.accuracy(&data)
    );
    Ok(())
}

fn cmd_kernel(args: &Args) -> Result<()> {
    if args.get("model").is_some() {
        // Loaded factors: report their stats and drive the coordinator;
        // the retrain-based cost breakdown below is skipped entirely.
        let bundle = load_or_fit(args)?;
        let kernel = &bundle.kernel;
        println!(
            "{}: N={} method={} | factors {:.1} MB, λ̄={:.1}, predicted flops={} | \
             peak RSS {:.1} MB",
            bundle.meta.dataset,
            kernel.ctx.n,
            kernel.kind.name(),
            kernel.factor_bytes() as f64 / 1e6,
            kernel.ctx.mean_lambda(),
            kernel.predicted_flops(),
            peak_rss_bytes() as f64 / 1e6,
        );
        let cc = CoordinatorConfig::default();
        let (_, metrics) = coordinator::materialize_to_csr(kernel, &cc);
        let (jobs, nnz, busy) = metrics.snapshot();
        println!("coordinator: {jobs} stripe jobs, nnz={nnz}, worker-busy {busy:.3}s");
        return Ok(());
    }
    let (data, name) = load_data(args)?;
    let kind = method(args)?;
    let cfg = train_cfg(args);
    let forest = forest_kernels::experiments::train_for(&data, kind, &cfg);
    let cost = forest_kernels::experiments::measure_kernel_cost(&forest, &data, kind);
    println!(
        "{name} N={} method={} | ctx {:.3}s factors {:.3}s product {:.3}s total {:.3}s | \
         {:.1} MB, nnz={} λ̄={:.1} flops={} | peak RSS {:.1} MB",
        cost.n,
        kind.name(),
        cost.secs_context,
        cost.secs_factors,
        cost.secs_product,
        cost.secs_total(),
        cost.bytes as f64 / 1e6,
        cost.nnz,
        cost.lambda,
        cost.flops,
        peak_rss_bytes() as f64 / 1e6,
    );
    // Also exercise the coordinator path and report its metrics.
    let kernel = ForestKernel::fit(&forest, &data, kind);
    let cc = CoordinatorConfig::default();
    let (_, metrics) = coordinator::materialize_to_csr(&kernel, &cc);
    let (jobs, nnz, busy) = metrics.snapshot();
    println!("coordinator: {jobs} stripe jobs, nnz={nnz}, worker-busy {busy:.3}s");
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    if args.get("model").is_some() {
        let bundle = load_or_fit(args)?;
        let (queries, name) = query_set(args, &bundle, 1000)?;
        let (preds, secs) = time(|| {
            let qn = bundle.kernel.oos_query_map(&bundle.forest, &queries);
            predict::predict_oos(&bundle.kernel, &qn)
        });
        println!(
            "{name}: {} fresh queries in {secs:.3}s ({:.0} q/s) | forest acc {:.4} | \
             {}-weighted acc {:.4}",
            queries.n,
            queries.n as f64 / secs.max(1e-9),
            bundle.forest.accuracy(&queries),
            bundle.kernel.kind.name(),
            predict::accuracy(&preds, &queries.y)
        );
        return Ok(());
    }
    let (data, name) = load_data(args)?;
    let kind = method(args)?;
    let (train, test) = data.train_test_split(0.1, args.u64_or("seed", 42) ^ 0x5EED);
    let cfg = train_cfg(args);
    let forest = forest_kernels::experiments::train_for(&train, kind, &cfg);
    let kernel = ForestKernel::fit(&forest, &train, kind);
    let qn = kernel.oos_query_map(&forest, &test);
    let preds = predict::predict_oos(&kernel, &qn);
    println!(
        "{name}: forest test-acc {:.4} | {}-weighted test-acc {:.4}",
        forest.accuracy(&test),
        kind.name(),
        predict::accuracy(&preds, &test.y)
    );
    Ok(())
}

fn cmd_embed(args: &Args) -> Result<()> {
    if args.get("model").is_some() {
        // Spectral embedding straight from the persisted factors: fit
        // the Leaf-PCA basis on Q and project fresh queries into it —
        // the offline twin of the server's /embed endpoint.
        let bundle = load_or_fit(args)?;
        let ctx_n = bundle.kernel.ctx.n;
        let dims = args.usize_or("pca-dims", 8).clamp(1, ctx_n);
        let (queries, name) = query_set(args, &bundle, 1000)?;
        let ((scores, vals), secs_basis) =
            time(|| pca::leaf_pca(&bundle.kernel.q, dims, 30, false, 17));
        let qn = bundle.kernel.oos_query_map(&bundle.forest, &queries);
        let (proj, secs_proj) =
            time(|| pca::leaf_pca_project(&bundle.kernel.q, &scores, &vals, &qn));
        let y_train: Vec<f32> = bundle.kernel.ctx.y.iter().map(|&v| v as f32).collect();
        let acc = forest_kernels::spectral::knn_accuracy(
            &scores,
            &y_train,
            &proj,
            &queries.y,
            dims,
            5,
            bundle.kernel.ctx.n_classes,
        );
        println!(
            "{name}: Leaf-PCA basis ({dims} dims over {ctx_n} rows) in {secs_basis:.2}s | \
             projected {} queries in {secs_proj:.3}s | 5-NN label agreement {acc:.4}",
            queries.n
        );
        return Ok(());
    }
    let (data, name) = load_data(args)?;
    let (train, test) = data.train_test_split(0.15, args.u64_or("seed", 42) ^ 0xE3BED);
    let cfg = fig43::Fig43Config {
        pca_dims: args.usize_or("pca-dims", 24),
        n_trees: args.usize_or("trees", 40),
        seed: args.u64_or("seed", 42),
        ..Default::default()
    };
    let results = fig43::run(&train, &test, &cfg);
    fig43::print(&results, &format!("embed pipelines on {name}"));
    Ok(())
}

/// The long-running online server (replacing the old one-shot batch
/// demo, which lives on as `examples/oos_serving.rs`, the XLA-tile
/// counterpart of this endpoint set). `--replicas R` switches to the
/// replicated topology: R serve processes behind the router.
fn cmd_serve(args: &Args) -> Result<()> {
    let replicas = args.usize_or("replicas", 1);
    if replicas >= 2 {
        return cmd_serve_replicated(args, replicas);
    }
    let mmap = parse_mmap(args)?;
    let (bundle, load_mode) = load_or_fit_with(args, mmap)?;
    let tiered = bundle.companion.is_some();
    if let Some(c) = &bundle.companion {
        println!(
            "companion tier: depth<={} subsample={} T={} ({:.1} factor MB)",
            c.depth,
            c.subsample,
            c.forest.n_trees(),
            c.kernel.factor_bytes() as f64 / 1e6,
        );
    }
    let shards = match args.get("shards") {
        Some(dir) => Some(ShardReader::open(Path::new(dir))?),
        None => None,
    };
    let cfg = ServeConfig {
        addr: args.str_or("addr", "127.0.0.1:7878").to_string(),
        max_batch: args.usize_or("batch", 32).max(1),
        linger: Duration::from_millis(args.u64_or("linger-ms", 2)),
        embed_dims: args.usize_or("embed-dims", 8),
        slow_ms: args.get("slow-ms").and_then(|v| v.parse().ok()),
        ..ServeConfig::default()
    };
    // The reload source: only a file-backed model can be hot-swapped.
    let source = args.get("model").map(|p| (PathBuf::from(p), mmap));
    let reloadable = source.is_some();
    let server = serve::Server::bind_with_source(bundle, shards, cfg, source, load_mode)?;
    println!("serving on http://{}", server.addr());
    println!(
        "  POST /predict    {{\"x\": [f32; d] | [[f32; d], ..]\
         {}}}",
        if tiered { ", \"budget\": \"cheap\"|\"full\"|\"auto\"" } else { "" }
    );
    println!("  POST /neighbors  {{\"x\": [f32; d], \"k\": 10}} | {{\"row\": 0, \"k\": 10}}");
    println!("  POST /embed      {{\"x\": [f32; d] | [[f32; d], ..]}}");
    println!("  GET  /healthz    GET /stats    GET /metrics    GET /debug/trace");
    if reloadable {
        println!("  POST /admin/reload  (or SIGHUP) hot-swaps --model; load mode: {load_mode}");
    } else {
        println!("  model fit in-process ({load_mode}); /admin/reload needs --model");
    }
    server.run()
}

/// Spawn one serve replica on an ephemeral port and parse its bound
/// address off the first "serving on http://…" stdout line. The rest
/// of the child's stdout is drained on a background thread so its
/// prints can never fill the pipe and block it.
fn spawn_replica(
    exe: &Path,
    args: &Args,
    model_path: &Path,
) -> Result<(std::process::Child, String)> {
    use std::io::BufRead;
    let mut c = std::process::Command::new(exe);
    c.arg("serve").arg("--model").arg(model_path).arg("--addr").arg("127.0.0.1:0");
    for key in
        ["batch", "linger-ms", "embed-dims", "shards", "threads", "quantize", "mmap", "slow-ms"]
    {
        if let Some(v) = args.get(key) {
            c.arg(format!("--{key}")).arg(v);
        }
    }
    c.stdout(std::process::Stdio::piped());
    let mut child = c.spawn().context("spawning a serve replica")?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut lines = std::io::BufReader::new(stdout).lines();
    let mut addr = None;
    for line in lines.by_ref() {
        let line = line.context("reading replica stdout")?;
        if let Some(a) = line.strip_prefix("serving on http://") {
            addr = Some(a.trim().to_string());
            break;
        }
    }
    let Some(addr) = addr else {
        let _ = child.kill();
        let _ = child.wait();
        bail!("serve replica exited before announcing its address");
    };
    std::thread::spawn(move || for _ in lines {});
    Ok((child, addr))
}

/// `serve --replicas R`: persist the bundle once (the replication
/// unit), spawn R serve processes that each load it, then run the
/// replica router in this process on `--addr`.
fn cmd_serve_replicated(args: &Args, replicas: usize) -> Result<()> {
    let exe = std::env::current_exe().context("resolving the repro binary path")?;
    // A bundle written here (no --model) is ours to delete once every
    // replica has loaded it; a user-supplied --model is not.
    let mut temp_model = None;
    let model_path = match args.get("model") {
        Some(p) => PathBuf::from(p),
        None => {
            let bundle = load_or_fit(args)?;
            let p = std::env::temp_dir()
                .join(format!("fk-serve-model-{}.fkb", std::process::id()));
            let bytes = bundle.save(&p)?;
            println!(
                "wrote {} ({:.1} MB) — fit once, loaded by {replicas} replica(s)",
                p.display(),
                bytes as f64 / 1e6
            );
            temp_model = Some(p.clone());
            p
        }
    };
    let mut children: Vec<std::process::Child> = Vec::with_capacity(replicas);
    let kill_all = |children: &mut Vec<std::process::Child>| {
        for c in children.iter_mut() {
            let _ = c.kill();
            let _ = c.wait();
        }
    };
    let cleanup = |children: &mut Vec<std::process::Child>| {
        kill_all(children);
        if let Some(p) = &temp_model {
            std::fs::remove_file(p).ok();
        }
    };
    let mut backends = Vec::with_capacity(replicas);
    for i in 0..replicas {
        match spawn_replica(&exe, args, &model_path) {
            Ok((child, addr)) => {
                println!("replica {i} serving on http://{addr}");
                children.push(child);
                backends.push(addr);
            }
            Err(e) => {
                cleanup(&mut children);
                return Err(e);
            }
        }
    }
    // Every replica printed its address, which happens only after its
    // bundle finished loading — the temp file has served its purpose.
    if let Some(p) = &temp_model {
        std::fs::remove_file(p).ok();
    }
    let cfg = serve::router::RouterConfig {
        addr: args.str_or("addr", "127.0.0.1:7878").to_string(),
        backends,
    };
    let router = match serve::router::Router::bind(cfg) {
        Ok(r) => r,
        Err(e) => {
            kill_all(&mut children);
            return Err(e);
        }
    };
    if let Some(ms) = args.get("slow-ms").and_then(|v| v.parse().ok()) {
        router.set_slow_ms(ms);
    }
    println!("routing on http://{} -> {replicas} replica(s)", router.addr());
    println!("  /predict /embed + OOS /neighbors: round-robin");
    println!("  /neighbors row lookups: row-range owner");
    println!("  GET /stats + GET /metrics: merged across the fleet");
    let out = router.run();
    kill_all(&mut children);
    out
}

/// `repro route --backends a,b,c`: the replica router over serve
/// processes that are already running (started by hand, by `serve
/// --replicas`, or on other machines — the bundle file is the only
/// thing replicas share).
fn cmd_route(args: &Args) -> Result<()> {
    let backends: Vec<String> = args
        .get("backends")
        .ok_or_else(|| anyhow!("route needs --backends host:port,host:port,..."))?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let cfg = serve::router::RouterConfig {
        addr: args.str_or("addr", "127.0.0.1:7979").to_string(),
        backends,
    };
    let router = serve::router::Router::bind(cfg)?;
    if let Some(ms) = args.get("slow-ms").and_then(|v| v.parse().ok()) {
        router.set_slow_ms(ms);
    }
    let owners = router.backends();
    println!("routing on http://{} -> {} backend(s)", router.addr(), owners.len());
    for (i, b) in owners.iter().enumerate() {
        println!("  backend {i}: {b}");
    }
    router.run()
}

/// Parse a byte size with an optional K/M/G suffix (binary multiples).
fn parse_bytes(s: &str) -> Option<usize> {
    let s = s.trim();
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1usize << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1usize << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1usize << 30),
        _ => (s, 1usize),
    };
    num.trim().parse::<usize>().ok().map(|v| v.saturating_mul(mult))
}

/// Resolve the coordinator config from `--mem-budget` (stripe sizing by
/// measured factor density) and/or an explicit `--stripe-rows` override.
fn coordinator_cfg(args: &Args, kernel: &ForestKernel) -> Result<CoordinatorConfig> {
    let mut cc = if let Some(b) = args.get("mem-budget") {
        let bytes = parse_bytes(b).ok_or_else(|| anyhow!("bad --mem-budget {b}"))?;
        CoordinatorConfig::with_mem_budget(kernel, bytes)
    } else {
        CoordinatorConfig::default()
    };
    if let Some(r) = args.get("stripe-rows").and_then(|v| v.parse().ok()) {
        cc.stripe_rows = r;
    }
    Ok(cc)
}

/// Parse `A..B` (half-open, `A <= B`) for `--row-range`.
fn parse_row_range(s: &str) -> Option<std::ops::Range<usize>> {
    let (a, b) = s.split_once("..")?;
    let a: usize = a.trim().parse().ok()?;
    let b: usize = b.trim().parse().ok()?;
    (a <= b).then_some(a..b)
}

fn cmd_materialize(args: &Args) -> Result<()> {
    // Multi-process worker mode: P sibling processes share the machine,
    // so unless --threads was given explicitly, size this process's
    // pool to an even 1/P share of the cores *before* the parallel
    // forest training below.
    if args.get("threads").is_none() {
        if let Some(p) = args.get("procs").and_then(|v| v.parse::<usize>().ok()) {
            exec::set_threads(exec::threads_for_share(p));
        }
    }
    // `--model` loads the bundle (workers of a `shards run --model`
    // parent land here — the forest is fit once, not once per worker);
    // otherwise train + fit from the flags as before.
    let bundle = load_or_fit(args)?;
    let name = bundle.meta.dataset.clone();
    let kind = bundle.kernel.kind;
    let kernel = &bundle.kernel;
    let n = kernel.ctx.n;
    let cc = coordinator_cfg(args, kernel)?;
    let sparsify = SparsifyConfig {
        top_k: args.usize_or("top-k", 32),
        epsilon: args.get("epsilon").and_then(|v| v.parse().ok()).unwrap_or(0.0),
        keep_diagonal: true,
    };
    let out = PathBuf::from(args.str_or("out", "kernel-shards"));
    let sink_name = args.str_or("sink", "csr");
    let _sp = obs::span_with(
        "materialize",
        forest_kernels::kv! { n: n, sink: sink_name, stripe_rows: cc.stripe_rows },
    );
    println!(
        "{name}: N={} method={} sink={sink_name} stripe_rows={} (factors {:.1} MB)",
        n,
        kind.name(),
        cc.stripe_rows,
        kernel.factor_bytes() as f64 / 1e6,
    );
    let report = |label: &str, metrics: &coordinator::Metrics, secs: f64| {
        let (jobs, nnz, busy) = metrics.snapshot();
        println!(
            "{label}: {jobs} stripes, nnz={nnz} in {secs:.3}s \
             ({:.2} Mnnz/s, worker-busy {busy:.3}s) | peak RSS {:.1} MB",
            nnz as f64 / secs.max(1e-9) / 1e6,
            peak_rss_bytes() as f64 / 1e6,
        );
    };
    if let Some(rr) = args.get("row-range") {
        // Worker mode: materialize only global rows A..B as one
        // fragment of a shard directory shared with sibling workers.
        // Fragments are always plain shards — an explicitly requested
        // other sink would be silently ignored, so refuse it.
        if let Some(s) = args.get("sink") {
            if s != "shards" {
                bail!(
                    "--row-range workers always write shard fragments; \
                     --sink {s} is not supported"
                );
            }
        }
        let range =
            parse_row_range(rr).ok_or_else(|| anyhow!("bad --row-range {rr} (expected A..B)"))?;
        let part = args.usize_or("part", 0);
        let dir = PathBuf::from(args.str_or("shard-dir", args.str_or("out", "kernel-shards")));
        let mut sink = ShardSink::create_fragment(
            &dir,
            kernel.w.n_rows,
            kind.name(),
            part,
            range.start,
            n,
        )?;
        let (metrics, secs) =
            time(|| coordinator::materialize_range_into(kernel, &cc, range.clone(), &mut sink));
        let metrics = metrics?;
        let written = sink.bytes_written();
        let shards = sink.finish()?;
        report(&format!("part-{part:03}"), &metrics, secs);
        println!(
            "worker {part}: rows {}..{} -> {} shard(s), {:.1} MB + \
             manifest-part-{part:03}.json in {}",
            range.start,
            range.end,
            shards.len(),
            written as f64 / 1e6,
            dir.display()
        );
        return Ok(());
    }
    match sink_name {
        "csr" => {
            let ((p, metrics), secs) = time(|| coordinator::materialize_to_csr(kernel, &cc));
            report("csr", &metrics, secs);
            println!("kernel: {} x {}, {:.1} MB resident", p.n_rows, p.n_cols, p.mem_bytes() as f64 / 1e6);
        }
        "shards" => {
            let mut sink = ShardSink::create(&out, kernel.w.n_rows, kind.name())?;
            let (metrics, secs) = time(|| coordinator::materialize_into(kernel, &cc, &mut sink));
            let metrics = metrics?;
            let written = sink.bytes_written();
            let shards = sink.finish()?;
            report("shards", &metrics, secs);
            println!(
                "wrote {} shards, {:.1} MB to {} (+ manifest.json)",
                shards.len(),
                written as f64 / 1e6,
                out.display()
            );
            if args.get("verify").is_some() {
                let (reference, _) = coordinator::materialize_to_csr(kernel, &cc);
                let back = ShardReader::open(&out)?.read_csr()?;
                if back != reference {
                    bail!("shard read-back differs from in-memory kernel");
                }
                println!("verify: read-back matches the in-memory CSR exactly");
            }
        }
        "topk" => {
            let mut sink = SparsifySink::new(sparsify, CsrSink::new(kernel.w.n_rows));
            let (metrics, secs) = time(|| coordinator::materialize_into(kernel, &cc, &mut sink));
            let metrics = metrics?;
            report("topk", &metrics, secs);
            let dropped = sink.dropped;
            let p = sink.into_inner().finish();
            println!(
                "sparsified: kept nnz={} (dropped {dropped}), {:.1} MB resident",
                p.nnz(),
                p.mem_bytes() as f64 / 1e6
            );
            // Drive the streaming consumers the kNN-shaped kernel exists for.
            let pred = predict::predict_from_kernel(&p, &kernel.ctx.y, kernel.ctx.n_classes)?;
            let y_ref: Vec<f32> = kernel.ctx.y.iter().map(|&v| v as f32).collect();
            println!(
                "top-{} kernel train-acc {:.4}",
                sparsify.top_k,
                predict::accuracy(&pred, &y_ref)
            );
        }
        "topk-shards" => {
            let inner = ShardSink::create(&out, kernel.w.n_rows, kind.name())?;
            let mut sink = SparsifySink::new(sparsify, inner);
            let (metrics, secs) = time(|| coordinator::materialize_into(kernel, &cc, &mut sink));
            let metrics = metrics?;
            report("topk-shards", &metrics, secs);
            let dropped = sink.dropped;
            let shards = sink.into_inner().finish()?;
            let reader = ShardReader::open(&out)?;
            let pred = predict::predict_from_kernel(&reader, &kernel.ctx.y, kernel.ctx.n_classes)?;
            let y_ref: Vec<f32> = kernel.ctx.y.iter().map(|&v| v as f32).collect();
            println!(
                "wrote {} sparsified shards to {} (dropped {dropped} entries); \
                 streamed train-acc {:.4}",
                shards.len(),
                out.display(),
                predict::accuracy(&pred, &y_ref)
            );
        }
        other => bail!("unknown sink {other} (csr|shards|topk|topk-shards)"),
    }
    Ok(())
}

fn cmd_bench_materialize(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 20_000);
    let trees = args.usize_or("trees", 32);
    let dataset = args.str_or("dataset", "covertype");
    let spec = registry::by_name(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let seed = args.u64_or("seed", 5);
    let data = spec.generate(n, seed);
    let cfg = TrainConfig { n_trees: trees, seed, ..Default::default() };
    let forest = Forest::train(&data, &cfg);
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
    let cc = coordinator_cfg(args, &kernel)?;
    println!("# materialize sinks (dataset={dataset} N={n} T={trees} stripe_rows={})", cc.stripe_rows);

    let ((p, m_csr), secs_csr) = time(|| coordinator::materialize_to_csr(&kernel, &cc));
    let nnz = p.nnz();
    let csr_mb = p.mem_bytes() as f64 / 1e6;
    drop(p);

    let dir = std::env::temp_dir().join(format!("fk-bench-shards-{n}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut sink = ShardSink::create(&dir, kernel.w.n_rows, kernel.kind.name())?;
    let (m_shard, secs_shard) = {
        let (r, s) = time(|| coordinator::materialize_into(&kernel, &cc, &mut sink));
        (r?, s)
    };
    let shard_mb = sink.bytes_written() as f64 / 1e6;
    sink.finish()?;

    let reader = ShardReader::open(&dir)?;
    let (scanned, secs_scan) = time(|| {
        let mut acc = 0u64;
        reader
            .for_each_stripe(|s| {
                acc += s.rows.nnz() as u64;
                Ok(())
            })
            .map(|_| acc)
    });
    let scanned = scanned?;
    std::fs::remove_dir_all(&dir).ok();

    let rss_mb = peak_rss_bytes() as f64 / 1e6;
    println!("sink\tsecs\tMnnz/s\tMB");
    println!("csr\t{secs_csr:.3}\t{:.2}\t{csr_mb:.1}", nnz as f64 / secs_csr.max(1e-9) / 1e6);
    println!("shards\t{secs_shard:.3}\t{:.2}\t{shard_mb:.1}", nnz as f64 / secs_shard.max(1e-9) / 1e6);
    println!("scan\t{secs_scan:.3}\t{:.2}\t-", scanned as f64 / secs_scan.max(1e-9) / 1e6);
    println!("peak RSS {rss_mb:.1} MB | nnz={nnz} scanned={scanned}");
    let (j1, n1, _) = m_csr.snapshot();
    let (j2, n2, _) = m_shard.snapshot();
    if (j1, n1) != (j2, n2) {
        bail!("sink metrics disagree: csr ({j1}, {n1}) vs shards ({j2}, {n2})");
    }

    if let Some(path) = args.get("json-out") {
        let threads = exec::threads();
        let records = vec![
            BenchRecord {
                name: format!("materialize-csr/{dataset}"),
                n,
                wall_secs: secs_csr,
                predicted_flops: kernel.predicted_flops(),
                threads,
                speedup_vs_serial: 1.0,
            },
            BenchRecord {
                name: format!("materialize-shards/{dataset}"),
                n,
                wall_secs: secs_shard,
                predicted_flops: kernel.predicted_flops(),
                threads,
                speedup_vs_serial: 1.0,
            },
            BenchRecord {
                name: format!("materialize-scan/{dataset}"),
                n,
                wall_secs: secs_scan,
                predicted_flops: 0,
                threads,
                speedup_vs_serial: 1.0,
            },
        ];
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

/// Flags a `shards plan`/`shards run` parent forwards to its
/// `materialize --row-range` workers: everything that determines the
/// dataset, the forest, the proximity kind, and the stripe sizing —
/// the full recipe for reproducing the factors bit-for-bit in another
/// process. `model` rides along so a `--model` parent's workers load
/// the bundle instead of refitting the identical forest P times.
/// (`--threads` is deliberately excluded: workers get an even 1/P core
/// share via `--procs` unless `--worker-threads` overrides.)
const WORKER_FLAGS: [&str; 13] = [
    "model",
    "dataset",
    "n",
    "trees",
    "seed",
    "method",
    "kind",
    "depth",
    "min-leaf",
    "max-samples",
    "stripe-rows",
    "mem-budget",
    "quantize",
];

fn cmd_shards(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("plan") => cmd_shards_plan(args),
        Some("run") => cmd_shards_run(args),
        Some("merge") => cmd_shards_merge(args),
        Some("validate") => cmd_shards_validate(args),
        other => bail!("unknown shards verb {other:?} (plan|run|merge|validate)\n{USAGE}"),
    }
}

fn shard_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("dir", args.str_or("shard-dir", args.str_or("out", "kernel-shards"))))
}

/// The kernel the multi-process commands partition: loaded from
/// `--model` (no retraining), or fitted via the same train → fit path
/// the flag-driven workers themselves run. Returns `(N, name, kernel)`.
fn fit_from_flags(args: &Args) -> Result<(usize, String, ForestKernel)> {
    let bundle = load_or_fit(args)?;
    Ok((bundle.kernel.ctx.n, bundle.meta.dataset.clone(), bundle.kernel))
}

fn cmd_shards_plan(args: &Args) -> Result<()> {
    let procs = args.usize_or("procs", 2);
    let (n, name, kernel) = fit_from_flags(args)?;
    // One O(nnz(Q)) cost pass, shared by the planner and the display.
    let costs = kernel.row_flops();
    let ranges = coordinator::partition_by_cost(&costs, procs);
    let total: u128 = costs.iter().map(|&c| c as u128).sum();
    println!(
        "# {name}: N={} method={} -> {} worker(s), {} thread(s) each",
        n,
        kernel.kind.name(),
        ranges.len(),
        exec::threads_for_share(ranges.len())
    );
    println!("part\trows\t\tflops_share");
    for (k, r) in ranges.iter().enumerate() {
        let w: u128 = costs[r.clone()].iter().map(|&c| c as u128).sum();
        println!(
            "{k}\t{}..{}\t{:.1}%",
            r.start,
            r.end,
            100.0 * w as f64 / total.max(1) as f64
        );
    }
    let dir = shard_dir(args);
    let mut forwarded = String::new();
    for key in WORKER_FLAGS {
        if let Some(v) = args.get(key) {
            forwarded.push_str(&format!(" --{key} {v}"));
        }
    }
    println!(
        "\n# recipe: run each worker (any order), then merge + validate.\n\
         # (reusing a directory from a run with MORE parts? clear its\n\
         #  manifest-part-*.json / part-*.bin first — workers only clear their own part)"
    );
    for (k, r) in ranges.iter().enumerate() {
        println!(
            "repro materialize{forwarded} --row-range {}..{} --part {k} --shard-dir {} --procs {}",
            r.start,
            r.end,
            dir.display(),
            ranges.len()
        );
    }
    println!("repro shards merge --dir {}", dir.display());
    println!("repro shards validate --dir {}", dir.display());
    Ok(())
}

fn cmd_shards_run(args: &Args) -> Result<()> {
    let procs = args.usize_or("procs", 2);
    let bundle = load_or_fit(args)?;
    let (n, name) = (bundle.kernel.ctx.n, bundle.meta.dataset.clone());
    let kernel = &bundle.kernel;
    let cc = coordinator_cfg(args, kernel)?;
    let dir = shard_dir(args);
    let ranges = coordinator::partition_rows(kernel, procs);
    let exe = std::env::current_exe().context("resolving the repro binary path")?;
    let _sp = obs::span_with(
        "shards.run",
        forest_kernels::kv! { n: n, procs: ranges.len() },
    );
    println!(
        "{name}: N={} method={} -> {} worker process(es) over {}",
        n,
        kernel.kind.name(),
        ranges.len(),
        dir.display()
    );
    // Workers only clear their own part, so a previous generation with
    // more parts would otherwise survive into the merge and trip the
    // overlap check.
    shard::clear_fragments(&dir)?;
    // The parent just fitted (or loaded) the kernel — persist it so
    // every worker loads the bundle instead of refitting the identical
    // forest P more times. An explicit --model is reused as-is.
    let model_path = match args.get("model") {
        Some(p) => PathBuf::from(p),
        None => {
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating shard dir {}", dir.display()))?;
            let p = dir.join("model.fkb");
            let (bytes, secs) = time(|| bundle.save(&p));
            let bytes = bytes?;
            println!(
                "wrote {} ({:.1} MB in {secs:.2}s) — forest fit once, loaded by {} worker(s)",
                p.display(),
                bytes as f64 / 1e6,
                ranges.len()
            );
            p
        }
    };
    let t0 = std::time::Instant::now();
    let mut children = Vec::with_capacity(ranges.len());
    for (k, r) in ranges.iter().enumerate() {
        let mut c = std::process::Command::new(&exe);
        c.arg("materialize");
        for key in WORKER_FLAGS {
            // `model` is passed explicitly below (it may be the bundle
            // this parent just wrote rather than a user flag).
            if key == "model" {
                continue;
            }
            if let Some(v) = args.get(key) {
                c.arg(format!("--{key}")).arg(v);
            }
        }
        c.arg("--model").arg(&model_path);
        c.arg("--row-range").arg(format!("{}..{}", r.start, r.end));
        c.arg("--part").arg(k.to_string());
        c.arg("--shard-dir").arg(&dir);
        c.arg("--procs").arg(ranges.len().to_string());
        // Each worker traces to its own file next to the parent's —
        // one shared file would interleave JSONL lines across
        // processes.
        if let Some(base) = args.get("trace") {
            c.arg("--trace").arg(trace_part_path(base, k));
        }
        if let Some(t) = args.get("worker-threads") {
            c.arg("--threads").arg(t);
        }
        let child = c.spawn().with_context(|| format!("spawning worker {k}"))?;
        children.push((k, child));
    }
    for (k, mut child) in children {
        let status = child.wait().with_context(|| format!("waiting for worker {k}"))?;
        if !status.success() {
            bail!("worker {k} failed with {status}");
        }
    }
    let secs_workers = t0.elapsed().as_secs_f64();
    let (merged, secs_merge) = time(|| shard::merge_fragments(&dir));
    let merged = merged?;
    let (validated, secs_validate) = time(|| shard::validate_dir(&dir));
    let validated = validated?;
    println!(
        "workers {secs_workers:.3}s | merged {} fragment(s) -> {} shard(s), N={}, \
         nnz={} in {secs_merge:.3}s | validated {:.1} MB in {secs_validate:.3}s",
        merged.parts,
        merged.shards,
        merged.n_rows,
        merged.total_nnz,
        validated.bytes as f64 / 1e6
    );
    if args.get("verify-full").is_some() {
        let reference = coordinator::materialize_to_csr(kernel, &cc).0;
        let back = ShardReader::open(&dir)?.read_csr()?;
        bitwise_check(&back, &reference)?;
        println!("verify-full: merged shards are bitwise-identical to the single-process CSR");
    }
    Ok(())
}

/// `base.jsonl` -> `base-part003.jsonl`: the per-worker trace file for
/// one `shards run` child process.
fn trace_part_path(base: &str, part: usize) -> String {
    let stem = base.strip_suffix(".jsonl").unwrap_or(base);
    format!("{stem}-part{part:03}.jsonl")
}

/// Bitwise CSR equality (f32 payloads compared as raw bits).
fn bitwise_check(got: &Csr, want: &Csr) -> Result<()> {
    if got.n_rows != want.n_rows || got.n_cols != want.n_cols {
        bail!(
            "shape differs: {}x{} vs {}x{}",
            got.n_rows,
            got.n_cols,
            want.n_rows,
            want.n_cols
        );
    }
    if got.indptr != want.indptr {
        bail!("row structure differs");
    }
    if got.indices != want.indices {
        bail!("column indices differ");
    }
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    if bits(&got.data) != bits(&want.data) {
        bail!("values differ bitwise");
    }
    Ok(())
}

fn cmd_shards_merge(args: &Args) -> Result<()> {
    let dir = shard_dir(args);
    let (merged, secs) = time(|| shard::merge_fragments(&dir));
    let merged = merged?;
    println!(
        "{}: merged {} fragment(s) -> {} shard(s), N={}, nnz={} in {secs:.3}s",
        dir.display(),
        merged.parts,
        merged.shards,
        merged.n_rows,
        merged.total_nnz
    );
    Ok(())
}

fn cmd_shards_validate(args: &Args) -> Result<()> {
    let dir = shard_dir(args);
    let (report, secs) = time(|| shard::validate_dir(&dir));
    let report = report?;
    println!(
        "{}: {} shard(s), {} rows, nnz={}, {:.1} MB validated in {secs:.3}s \
         (coverage, checksums, structure)",
        dir.display(),
        report.shards,
        report.n_rows,
        report.total_nnz,
        report.bytes as f64 / 1e6
    );
    if args.get("verify").is_none() {
        return Ok(());
    }
    // Sampled bitwise cross-check: load the bundle (or retrain from
    // the same dataset/forest flags — deterministic per seed) and
    // compare shard rows against the single-process reference product.
    let (n, name, kernel) = fit_from_flags(args)?;
    let reader = ShardReader::open(&dir)?;
    if reader.kind() != kernel.kind.name() {
        bail!(
            "shard directory holds kind {:?} but flags select {:?}",
            reader.kind(),
            kernel.kind.name()
        );
    }
    if report.n_rows != n {
        bail!("shard directory covers {} rows but the kernel has {}", report.n_rows, n);
    }
    let samples = args.usize_or("sample", 64).clamp(1, n);
    let mut cached: Option<(usize, coordinator::Stripe)> = None;
    for s in 0..samples {
        // Deterministic stride sampling across [0, N).
        let row = s * n / samples;
        let si = reader
            .shard_of_row(row)
            .ok_or_else(|| anyhow!("row {row} outside the shard directory's coverage"))?;
        if cached.as_ref().map(|(i, _)| *i) != Some(si) {
            cached = Some((si, reader.read_stripe(si)?));
        }
        let (_, stripe) = cached.as_ref().unwrap();
        let (cols, vals) = stripe.rows.row(row - stripe.row_start);
        let reference = coordinator::stripe_product(&kernel, row, row + 1);
        let (rc, rv) = reference.row(0);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        if cols != rc || bits(vals) != bits(rv) {
            bail!("row {row}: shard contents differ bitwise from the reference product");
        }
    }
    println!("verify: {samples} sampled row(s) of {name} match the reference bitwise");
    Ok(())
}

fn cmd_bench_shard_merge(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 8_000);
    let trees = args.usize_or("trees", 20);
    let dataset = args.str_or("dataset", "covertype");
    let spec = registry::by_name(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let seed = args.u64_or("seed", 5);
    let data = spec.generate(n, seed);
    let cfg = TrainConfig { n_trees: trees, seed, ..Default::default() };
    let ((forest, kernel), secs_fit) = time(|| {
        let forest = Forest::train(&data, &cfg);
        let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
        (forest, kernel)
    });
    let cc = coordinator_cfg(args, &kernel)?;
    let procs: Vec<usize> =
        args.str_or("procs", "1,2,4").split(',').filter_map(|s| s.parse().ok()).collect();
    let mut records: Vec<BenchRecord> = vec![];
    // `shards run --model` loads the bundle in every worker instead of
    // repeating this train+fit — measure exactly that per-worker
    // saving and record it next to the merge numbers.
    {
        let path = std::env::temp_dir()
            .join(format!("fk-bench-bundle-{n}-{}.fkb", std::process::id()));
        let meta = BundleMeta { dataset: dataset.to_string(), n, seed, trees };
        model::save(&path, &forest, &kernel, &meta)?;
        let (loaded, secs_load) = time(|| ModelBundle::load(&path));
        let loaded = loaded?;
        std::fs::remove_file(&path).ok();
        if loaded.kernel.q != kernel.q {
            bail!("loaded bundle factors differ from the fitted ones");
        }
        println!(
            "# bundle: fit {secs_fit:.3}s vs load {secs_load:.3}s \
             ({:.1}x saved per --model worker)",
            secs_fit / secs_load.max(1e-9)
        );
        records.push(BenchRecord {
            name: "bundle-fit".into(),
            n,
            wall_secs: secs_fit,
            predicted_flops: kernel.predicted_flops(),
            threads: exec::threads(),
            speedup_vs_serial: 1.0,
        });
        records.push(BenchRecord {
            name: "bundle-load".into(),
            n,
            wall_secs: secs_load,
            predicted_flops: 0,
            threads: exec::threads(),
            speedup_vs_serial: secs_fit / secs_load.max(1e-9),
        });
    }
    println!("# shards merge/validate throughput (dataset={dataset} N={n} T={trees})");
    println!("P\tfragments_s\tmerge_s\tvalidate_s\tshards\tMB");
    for &p in &procs {
        let dir = std::env::temp_dir().join(format!(
            "fk-bench-merge-{n}-{p}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let ranges = coordinator::partition_rows(&kernel, p);
        let (written, secs_frag) = time(|| -> Result<()> {
            for (k, r) in ranges.iter().enumerate() {
                let mut sink = ShardSink::create_fragment(
                    &dir,
                    kernel.w.n_rows,
                    kernel.kind.name(),
                    k,
                    r.start,
                    n,
                )?;
                coordinator::materialize_range_into(&kernel, &cc, r.clone(), &mut sink)?;
                sink.finish()?;
            }
            Ok(())
        });
        written?;
        let (merged, secs_merge) = time(|| shard::merge_fragments(&dir));
        let merged = merged?;
        let (validated, secs_validate) = time(|| shard::validate_dir(&dir));
        let validated = validated?;
        println!(
            "{p}\t{secs_frag:.3}\t{secs_merge:.4}\t{secs_validate:.3}\t{}\t{:.1}",
            merged.shards,
            validated.bytes as f64 / 1e6
        );
        for (stage, secs) in [("merge", secs_merge), ("validate", secs_validate)] {
            records.push(BenchRecord {
                name: format!("shard-{stage}/P={p}"),
                n,
                wall_secs: secs,
                predicted_flops: 0,
                threads: exec::threads(),
                speedup_vs_serial: 1.0,
            });
        }
        std::fs::remove_dir_all(&dir).ok();
    }
    if let Some(path) = args.get("json-out") {
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

/// Drive `/predict` with `clients` real TCP client threads over a
/// shared work queue of pre-rendered bodies. `keepalive` chooses the
/// transport: one persistent connection per thread, or a fresh
/// connection per request (the close baseline). Returns the wall time
/// and the sorted per-request latencies.
fn drive_predict(
    addr: &std::net::SocketAddr,
    bodies: &[String],
    clients: usize,
    keepalive: bool,
    label: &str,
) -> Result<(f64, Vec<f64>)> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let lat: std::sync::Mutex<Vec<f64>> =
        std::sync::Mutex::new(Vec::with_capacity(bodies.len()));
    let next = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| {
                let mut client = keepalive.then(|| serve::http::HttpClient::new(*addr));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= bodies.len() {
                        break;
                    }
                    let t = std::time::Instant::now();
                    let out = match client.as_mut() {
                        Some(cl) => cl.request("POST", "/predict", &bodies[i]),
                        None => serve::http::http_request(addr, "POST", "/predict", &bodies[i]),
                    };
                    match out {
                        Ok((200, _)) => lat.lock().unwrap().push(t.elapsed().as_secs_f64()),
                        _ => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let nfail = failed.load(Ordering::Relaxed);
    if nfail > 0 {
        bail!("bench-serve: {nfail} request(s) failed ({label})");
    }
    let mut lats = lat.into_inner().unwrap();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok((wall, lats))
}

/// Persist `bundle` at `path` for the duration of `f`, removing the
/// file on **every** exit path — success or error. The replica-spawn
/// cleanup used to run only after a fully healthy fleet, so a child
/// failing its health-check *after* loading the bundle left the temp
/// file behind; routing all temp-bundle use through here closes that
/// branch too.
fn with_temp_bundle<T>(
    path: &Path,
    bundle: &ModelBundle,
    f: impl FnOnce(&Path) -> Result<T>,
) -> Result<T> {
    bundle.save(path)?;
    let out = f(path);
    std::fs::remove_file(path).ok();
    out
}

/// Spawn the HTTP server in-process on an ephemeral port and drive
/// `/predict` with real TCP clients: QPS + latency percentiles across
/// client-side batch size × client thread count × transport
/// (connection-per-request `close` vs pooled `keepalive`, plus a
/// `routed` mode through the replica router when `--route-replicas R`
/// is given), emitted as `BENCH_serve.json` next to the other bench
/// artifacts. The close-vs-keepalive pair at batch 1 is the headline:
/// it prices the per-query TCP connect/teardown the keep-alive
/// transport amortizes away.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 4_000);
    let trees = args.usize_or("trees", 16);
    let dataset = args.str_or("dataset", "covertype");
    let spec = registry::by_name(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let seed = args.u64_or("seed", 5);
    let data = spec.generate(n, seed);
    let kind = method(args)?;
    let cfg = TrainConfig { n_trees: trees, seed, ..Default::default() };
    let forest = forest_kernels::experiments::train_for(&data, kind, &cfg);
    let kernel = ForestKernel::fit(&forest, &data, kind);
    let meta = BundleMeta { dataset: dataset.to_string(), n, seed, trees: forest.n_trees() };
    let d = data.d;
    let total_queries = args.usize_or("queries", 256).max(1);
    let queries = spec.generate(total_queries, seed ^ 0x51EED);
    let batches: Vec<usize> =
        args.str_or("batches", "1,4,16").split(',').filter_map(|s| s.parse().ok()).collect();
    let clients: Vec<usize> =
        args.str_or("clients", "1,2,4").split(',').filter_map(|s| s.parse().ok()).collect();
    let transports: Vec<String> = args
        .str_or("transports", "close,keepalive")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    for t in &transports {
        if t != "close" && t != "keepalive" {
            bail!("unknown transport {t} (close|keepalive)");
        }
    }
    let route_replicas = args.usize_or("route-replicas", 0);

    let bundle = ModelBundle { forest, kernel, meta, companion: None };
    // The routed fleet loads the persisted bundle — bitwise the same
    // model, exactly the production replication path.
    let mut replica_handles = vec![];
    let mut router_handle = None;
    let mut router_addr = None;
    if route_replicas >= 2 {
        let model_path = std::env::temp_dir()
            .join(format!("fk-bench-serve-model-{}.fkb", std::process::id()));
        let backend_addrs = with_temp_bundle(&model_path, &bundle, |p| {
            let mut addrs = Vec::with_capacity(route_replicas);
            for _ in 0..route_replicas {
                let replica = serve::Server::bind(
                    ModelBundle::load(p)?,
                    None,
                    ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
                )?;
                addrs.push(replica.addr().to_string());
                replica_handles.push(replica.spawn());
            }
            Ok(addrs)
        })?;
        let router = serve::router::Router::bind(serve::router::RouterConfig {
            addr: "127.0.0.1:0".into(),
            backends: backend_addrs,
        })?;
        router_addr = Some(router.addr());
        router_handle = Some(router.spawn());
    }

    let server = serve::Server::bind(
        bundle,
        None,
        ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )?;
    let addr = server.addr();
    let handle = server.spawn();
    // Warm up the accept loop + batcher before timing anything.
    let (status, _) = serve::http::http_request(&addr, "GET", "/healthz", "")?;
    if status != 200 {
        bail!("warm-up /healthz returned {status}");
    }

    // Canonical transport order, fixed across the whole sweep. The
    // close baseline ALWAYS runs first — even when --transports omits
    // it — because every other mode's speedup-vs-close in the artifact
    // must be priced against a measured wall time, never a silent 1.0.
    let mut modes: Vec<(&str, std::net::SocketAddr, bool)> = vec![("close", addr, false)];
    if transports.iter().any(|t| t == "keepalive") {
        modes.push(("keepalive", addr, true));
    }
    if let Some(raddr) = router_addr {
        modes.push(("routed", raddr, true));
    }

    println!("# serve throughput (dataset={dataset} N={n} T={trees} queries={total_queries})");
    println!("batch\tclients\ttransport\tsecs\tq/s\tp50_ms\tp95_ms\tp99_ms");
    let mut records: Vec<BenchRecord> = vec![];
    for &b in &batches {
        let b = b.max(1);
        // Pre-render the request bodies: the query stream chunked into
        // client-side batches of b.
        let bodies: Vec<String> = (0..total_queries)
            .step_by(b)
            .map(|start| {
                let end = (start + b).min(total_queries);
                let mut body = String::from("{\"x\": [");
                for i in start..end {
                    if i > start {
                        body.push_str(", ");
                    }
                    body.push('[');
                    for f in 0..d {
                        if f > 0 {
                            body.push_str(", ");
                        }
                        body.push_str(&format!("{}", queries.x(i, f)));
                    }
                    body.push(']');
                }
                body.push_str("]}");
                body
            })
            .collect();
        for &c in &clients {
            let c = c.max(1);
            // The close baseline's wall time prices every other
            // transport at this (batch, clients) point: the QPS record
            // carries speedup-vs-close directly in the artifact.
            let mut close_wall: Option<f64> = None;
            for &(mode, target, keepalive) in &modes {
                let label = format!("batch={b}, clients={c}, transport={mode}");
                let (wall, lats) = drive_predict(&target, &bodies, c, keepalive, &label)?;
                if mode == "close" {
                    close_wall = Some(wall);
                }
                let pct = |q: f64| lats[(((lats.len() - 1) as f64) * q).round() as usize];
                let qps = total_queries as f64 / wall.max(1e-9);
                println!(
                    "{b}\t{c}\t{mode}\t{wall:.3}\t{qps:.0}\t{:.2}\t{:.2}\t{:.2}",
                    pct(0.5) * 1e3,
                    pct(0.95) * 1e3,
                    pct(0.99) * 1e3
                );
                records.push(BenchRecord {
                    name: format!("serve-predict/B={b}/clients={c}/{mode}"),
                    n: total_queries,
                    wall_secs: wall,
                    predicted_flops: 0,
                    threads: c,
                    speedup_vs_serial: close_wall.map_or(1.0, |cw| cw / wall),
                });
                for (tag, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
                    records.push(BenchRecord {
                        name: format!("serve-predict-latency/B={b}/clients={c}/{mode}/{tag}"),
                        n: b,
                        wall_secs: pct(q),
                        predicted_flops: 0,
                        threads: c,
                        speedup_vs_serial: 1.0,
                    });
                }
            }
        }
    }
    handle.stop();
    if let Some(rh) = router_handle {
        rh.stop();
    }
    for rh in replica_handles {
        rh.stop();
    }
    if let Some(path) = args.get("json-out") {
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

/// fk-bundle-v3 load-path economics, the numbers behind `--mmap`: for
/// doubling bundle sizes, time the heap decode vs the zero-copy mmap
/// bind ("cold" = first load in this process — the page cache is warm
/// from the save, so this isolates decode + allocation, which is the
/// part `--mmap` deletes; "warm" = best of 3 repeats), the full
/// cold-start-to-first-answer latency (load + bind + one `/predict`),
/// and the aggregate RSS that `--replicas R` processes would pay per
/// mode (R live bundles in this process; mapped sections are shared
/// file-backed pages, so the mmap rows should stay near-flat while the
/// heap rows grow with N). Emitted as `BENCH_load.json`.
fn cmd_bench_load(args: &Args) -> Result<()> {
    let min_n = args.usize_or("min-n", 2_000);
    let max_n = args.usize_or("max-n", 16_000);
    let trees = args.usize_or("trees", 24);
    let replicas = args.usize_or("replicas", 4).max(1);
    let dataset = args.str_or("dataset", "covertype");
    let spec = registry::by_name(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let seed = args.u64_or("seed", 7);
    let kind = method(args)?;
    let mmap_ok = model::mmap::supported();
    if !mmap_ok {
        println!("# mmap(2) unsupported on this target — heap rows only");
    }
    println!(
        "# bundle load economics (dataset={dataset} T={trees}, RSS probe = {replicas} live \
         bundles per mode)"
    );
    println!("n\tbundle_MB\tmode\tcold_ms\twarm_ms\tfirst_query_ms\trss_{replicas}x_MB");
    let mut records: Vec<BenchRecord> = vec![];
    for n in doubling_sizes(min_n, max_n) {
        let data = spec.generate(n, seed);
        let cfg = TrainConfig { n_trees: trees, seed, ..Default::default() };
        let forest = forest_kernels::experiments::train_for(&data, kind, &cfg);
        let kernel = ForestKernel::fit(&forest, &data, kind);
        let meta = BundleMeta { dataset: dataset.to_string(), n, seed, trees: forest.n_trees() };
        let d = data.d;
        let bundle = ModelBundle { forest, kernel, meta, companion: None };
        let path = std::env::temp_dir()
            .join(format!("fk-bench-load-{}-{n}.fkb", std::process::id()));
        let file_bytes = bundle.save(&path)?;
        drop(bundle);
        // One query row for the cold-start-to-first-answer probe.
        let q = spec.generate(1, seed ^ 0x51EED);
        let mut body = String::from("{\"x\": [");
        for f in 0..d {
            if f > 0 {
                body.push_str(", ");
            }
            body.push_str(&format!("{}", q.x(0, f)));
        }
        body.push_str("]}");

        let modes: &[(&str, MmapMode)] = if mmap_ok {
            &[("heap", MmapMode::Off), ("mmap", MmapMode::On)]
        } else {
            &[("heap", MmapMode::Off)]
        };
        let mut heap_cold: Option<f64> = None;
        for &(mode, mm) in modes {
            let (first, cold) = time(|| ModelBundle::load_with_mode(&path, mm));
            let (first_bundle, got_mode) = first?;
            if got_mode != mode {
                bail!("bench-load: asked for {mode} but the loader bound {got_mode}");
            }
            drop(first_bundle);
            let mut warm = f64::INFINITY;
            for _ in 0..3 {
                let (b, s) = time(|| ModelBundle::load_with_mode(&path, mm));
                drop(b?);
                warm = warm.min(s);
            }
            // Cold start to first answer: load + bind + one /predict.
            let t0 = std::time::Instant::now();
            let (b, _) = ModelBundle::load_with_mode(&path, mm)?;
            let server = serve::Server::bind(
                b,
                None,
                ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
            )?;
            let addr = server.addr();
            let handle = server.spawn();
            let (status, _) = serve::http::http_request(&addr, "POST", "/predict", &body)?;
            let first_query = t0.elapsed().as_secs_f64();
            handle.stop();
            if status != 200 {
                bail!("bench-load: first /predict returned {status}");
            }
            // Aggregate resident cost of an R-replica fleet per mode.
            let rss0 = rss_bytes();
            let mut fleet = Vec::with_capacity(replicas);
            for _ in 0..replicas {
                fleet.push(ModelBundle::load_with_mode(&path, mm)?.0);
            }
            let rss_delta = rss_bytes().saturating_sub(rss0);
            drop(fleet);

            println!(
                "{n}\t{:.2}\t{mode}\t{:.2}\t{:.2}\t{:.2}\t{:.2}",
                file_bytes as f64 / 1e6,
                cold * 1e3,
                warm * 1e3,
                first_query * 1e3,
                rss_delta as f64 / 1e6,
            );
            if mode == "heap" {
                heap_cold = Some(cold);
            }
            let speedup = if mode == "heap" {
                1.0
            } else {
                heap_cold.map_or(1.0, |h| h / cold.max(1e-9))
            };
            records.push(BenchRecord {
                name: format!("bundle-load/{mode}/cold"),
                n,
                wall_secs: cold,
                predicted_flops: file_bytes,
                threads: 1,
                speedup_vs_serial: speedup,
            });
            records.push(BenchRecord {
                name: format!("bundle-load/{mode}/warm"),
                n,
                wall_secs: warm,
                predicted_flops: file_bytes,
                threads: 1,
                speedup_vs_serial: 1.0,
            });
            records.push(BenchRecord {
                name: format!("bundle-load/{mode}/first-query"),
                n,
                wall_secs: first_query,
                predicted_flops: file_bytes,
                threads: 1,
                speedup_vs_serial: 1.0,
            });
            records.push(BenchRecord {
                name: format!("bundle-load/{mode}/rss-replicas={replicas}"),
                n,
                wall_secs: cold * replicas as f64,
                predicted_flops: rss_delta as u64,
                threads: replicas,
                speedup_vs_serial: 1.0,
            });
        }
        std::fs::remove_file(&path).ok();
    }
    if let Some(path) = args.get("json-out") {
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

/// `bench-tiered`: price the accuracy-vs-p99 frontier of two-tier
/// serving. For every companion (depth × subsample) grid point, a
/// two-tier bundle is persisted, loaded, and served in-process, then
/// `/predict` is driven at both budgets with real TCP clients — the
/// per-tier latency percentiles plus each tier's OOS accuracy are the
/// frontier records of BENCH_tiered.json. The cheap tier's records
/// carry their speedup over the full tier measured at the same grid
/// point, so the artifact shows directly what shedding to the
/// companion buys (p99) and costs (accuracy).
fn cmd_bench_tiered(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 6_000);
    let trees = args.usize_or("trees", 40);
    let dataset = args.str_or("dataset", "covertype");
    let spec = registry::by_name(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let seed = args.u64_or("seed", 9);
    let data = spec.generate(n, seed);
    let kind = method(args)?;
    let cfg = TrainConfig { n_trees: trees, seed, ..Default::default() };
    let forest = forest_kernels::experiments::train_for(&data, kind, &cfg);
    let kernel = ForestKernel::fit(&forest, &data, kind);
    let d = data.d;
    let total_queries = args.usize_or("queries", 256).max(1);
    let queries = spec.generate(total_queries, seed ^ 0x51EED);
    let clients = args.usize_or("clients", 2).max(1);
    let depths: Vec<usize> =
        args.str_or("depths", "3,5").split(',').filter_map(|s| s.parse().ok()).collect();
    let subsamples: Vec<f32> =
        args.str_or("subsamples", "0.1,0.25").split(',').filter_map(|s| s.parse().ok()).collect();
    if depths.is_empty() || subsamples.is_empty() {
        bail!("bench-tiered needs non-empty --depths and --subsamples lists");
    }

    // Full-tier OOS accuracy is a property of the full model alone —
    // measured once, shared by every grid point.
    let full_acc = {
        let qn = kernel.oos_query_map(&forest, &queries);
        predict::accuracy(&predict::predict_oos(&kernel, &qn), &queries.y)
    };
    // One single-row body per query, each pinned to a budget — the
    // latency-sensitive request shape the tiers exist for.
    let render = |budget: &str| -> Vec<String> {
        (0..total_queries)
            .map(|i| {
                let mut body = String::from("{\"x\": [");
                for f in 0..d {
                    if f > 0 {
                        body.push_str(", ");
                    }
                    body.push_str(&format!("{}", queries.x(i, f)));
                }
                body.push_str(&format!("], \"budget\": \"{budget}\"}}"));
                body
            })
            .collect()
    };
    let bodies_full = render("full");
    let bodies_cheap = render("cheap");

    println!(
        "# tiered serving frontier (dataset={dataset} N={n} T={trees} \
         queries={total_queries} clients={clients})"
    );
    println!("depth\tsub\ttier\tacc\tsecs\tq/s\tp50_ms\tp95_ms\tp99_ms");
    let mut records: Vec<BenchRecord> = vec![];
    for &depth in &depths {
        for &subsample in &subsamples {
            let draws = ((subsample as f64 * n as f64).ceil() as usize).max(1);
            let ccfg =
                TrainConfig { max_depth: Some(depth), max_samples: Some(draws), ..cfg.clone() };
            let c_forest = forest_kernels::experiments::train_for(&data, kind, &ccfg);
            let c_kernel = ForestKernel::fit(&c_forest, &data, kind);
            let cheap_acc = {
                let qn = c_kernel.oos_query_map(&c_forest, &queries);
                predict::accuracy(&predict::predict_oos(&c_kernel, &qn), &queries.y)
            };
            let companion =
                CompanionModel { forest: c_forest, kernel: c_kernel, depth, subsample };
            let meta = BundleMeta {
                dataset: dataset.to_string(),
                n,
                seed,
                trees: forest.n_trees(),
            };
            // Through the persisted v4 bundle — the production path a
            // tiered server actually takes.
            let path = std::env::temp_dir().join(format!(
                "fk-bench-tiered-{}-{depth}-{}.fkb",
                std::process::id(),
                (subsample * 1000.0) as u32
            ));
            model::save_with_sizes(&path, &forest, &kernel, &meta, Some(&companion))?;
            let loaded = ModelBundle::load(&path);
            std::fs::remove_file(&path).ok();
            let server = serve::Server::bind(
                loaded?,
                None,
                ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
            )?;
            let addr = server.addr();
            let handle = server.spawn();
            // Warm-up doubles as the tier sanity check: a cheap-budget
            // request must actually be answered by the companion.
            let (status, body) =
                serve::http::http_request(&addr, "POST", "/predict", &bodies_cheap[0])?;
            if status != 200 {
                bail!("bench-tiered warm-up returned {status}: {body}");
            }
            if !body.contains("\"tier\": \"cheap\"") {
                bail!("cheap budget was not served by the cheap tier: {body}");
            }
            let mut full_wall = None;
            let mut full_p99 = None;
            for (tier, bodies, acc) in
                [("full", &bodies_full, full_acc), ("cheap", &bodies_cheap, cheap_acc)]
            {
                let label = format!("depth={depth}, subsample={subsample}, tier={tier}");
                let (wall, lats) = drive_predict(&addr, bodies, clients, true, &label)?;
                let pct = |q: f64| lats[(((lats.len() - 1) as f64) * q).round() as usize];
                let qps = total_queries as f64 / wall.max(1e-9);
                println!(
                    "{depth}\t{subsample}\t{tier}\t{acc:.4}\t{wall:.3}\t{qps:.0}\t\
                     {:.2}\t{:.2}\t{:.2}",
                    pct(0.5) * 1e3,
                    pct(0.95) * 1e3,
                    pct(0.99) * 1e3
                );
                if tier == "full" {
                    full_wall = Some(wall);
                    full_p99 = Some(pct(0.99));
                }
                records.push(BenchRecord {
                    name: format!("tiered-predict/D={depth}/F={subsample}/{tier}"),
                    n: total_queries,
                    wall_secs: wall,
                    predicted_flops: 0,
                    threads: clients,
                    speedup_vs_serial: full_wall.map_or(1.0, |fw| fw / wall.max(1e-9)),
                });
                for (tag, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
                    records.push(BenchRecord {
                        name: format!("tiered-latency/D={depth}/F={subsample}/{tier}/{tag}"),
                        n: total_queries,
                        wall_secs: pct(q),
                        predicted_flops: 0,
                        threads: clients,
                        speedup_vs_serial: if tag == "p99" {
                            full_p99.map_or(1.0, |fp| fp / pct(q).max(1e-12))
                        } else {
                            1.0
                        },
                    });
                }
                records.push(BenchRecord {
                    name: format!("tiered-accuracy/D={depth}/F={subsample}/{tier}"),
                    n: total_queries,
                    wall_secs: acc,
                    predicted_flops: 0,
                    threads: 1,
                    speedup_vs_serial: 1.0,
                });
            }
            handle.stop();
        }
    }
    if let Some(path) = args.get("json-out") {
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

/// Append markdown to the GitHub Actions job summary when running
/// under CI (`$GITHUB_STEP_SUMMARY` set), a no-op anywhere else.
fn append_step_summary(md: &str) -> Result<()> {
    let Ok(path) = std::env::var("GITHUB_STEP_SUMMARY") else { return Ok(()) };
    if path.is_empty() {
        return Ok(());
    }
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .with_context(|| format!("opening $GITHUB_STEP_SUMMARY {path}"))?;
    f.write_all(md.as_bytes()).context("writing $GITHUB_STEP_SUMMARY")?;
    Ok(())
}

/// The per-record regression fraction the bench-compare gate tests:
/// positive = current is slower than baseline.
fn regress_fraction(baseline_secs: f64, current_secs: f64) -> f64 {
    (current_secs - baseline_secs) / baseline_secs.max(1e-12)
}

/// `bench-compare`: the CI bench-regression gate. Every BENCH_*.json
/// present in both `--baseline` and `--current` is compared record by
/// record (keyed on name + n) on wall_secs; any record slower than its
/// baseline by more than `--max-regress` fails the command. The
/// per-metric markdown table goes to stdout and is appended to
/// `$GITHUB_STEP_SUMMARY` when set. A missing or empty baseline dir
/// seeds instead of failing — the run exits 0 so `actions/cache` can
/// save the current artifacts as the next run's baseline. Records that
/// are deterministic per seed (accuracy, recall) reproduce bitwise
/// between runs and so never trip the wall-clock gate.
fn cmd_bench_compare(args: &Args) -> Result<()> {
    let baseline = PathBuf::from(
        args.get("baseline").ok_or_else(|| anyhow!("bench-compare needs --baseline DIR"))?,
    );
    let current = PathBuf::from(
        args.get("current").ok_or_else(|| anyhow!("bench-compare needs --current DIR"))?,
    );
    let max_regress: f64 =
        args.get("max-regress").and_then(|v| v.parse().ok()).unwrap_or(0.25);
    let bench_files = |dir: &Path| -> Vec<String> {
        let mut out = vec![];
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                if name.starts_with("BENCH_") && name.ends_with(".json") {
                    out.push(name);
                }
            }
        }
        out.sort();
        out
    };
    let base_files = bench_files(&baseline);
    let cur_files = bench_files(&current);
    if cur_files.is_empty() {
        bail!("--current {} holds no BENCH_*.json artifacts", current.display());
    }
    if base_files.is_empty() {
        let note = format!(
            "No baseline under `{}` — seeded from `{}` ({} artifact(s)); \
             the next run compares against these.",
            baseline.display(),
            current.display(),
            cur_files.len()
        );
        println!("bench-compare: {note}");
        append_step_summary(&format!("### Bench regression gate\n\n{note}\n"))?;
        return Ok(());
    }

    let mut table = String::from(
        "| artifact | metric | n | baseline_s | current_s | delta | status |\n\
         |---|---|---:|---:|---:|---:|---|\n",
    );
    let mut regressions: Vec<String> = vec![];
    let mut compared = 0usize;
    for f in &cur_files {
        if !base_files.contains(f) {
            table.push_str(&format!("| {f} | *new artifact, no baseline* | | | | | seeded |\n"));
            continue;
        }
        let base_recs = read_bench_json(&baseline.join(f))?;
        let cur_recs = read_bench_json(&current.join(f))?;
        let mut base_map: HashMap<(String, usize), f64> = HashMap::new();
        for r in &base_recs {
            base_map.insert((r.name.clone(), r.n), r.wall_secs);
        }
        for r in &cur_recs {
            let Some(&b) = base_map.get(&(r.name.clone(), r.n)) else { continue };
            compared += 1;
            let delta = regress_fraction(b, r.wall_secs);
            let regressed = delta > max_regress;
            let status = if regressed { "**REGRESSED**" } else { "ok" };
            table.push_str(&format!(
                "| {f} | {} | {} | {b:.4} | {:.4} | {:+.1}% | {status} |\n",
                r.name,
                r.n,
                r.wall_secs,
                delta * 100.0
            ));
            if regressed {
                regressions.push(format!(
                    "{f}:{} (n={}) {b:.4}s -> {:.4}s ({:+.1}%)",
                    r.name,
                    r.n,
                    r.wall_secs,
                    delta * 100.0
                ));
            }
        }
    }
    let verdict = if regressions.is_empty() {
        format!(
            "{compared} metric(s) compared — none slower than baseline by more than {:.0}%.",
            max_regress * 100.0
        )
    } else {
        format!(
            "{} of {compared} metric(s) regressed past {:.0}%.",
            regressions.len(),
            max_regress * 100.0
        )
    };
    println!("{table}");
    println!("bench-compare: {verdict}");
    append_step_summary(&format!("### Bench regression gate\n\n{verdict}\n\n{table}\n"))?;
    if !regressions.is_empty() {
        bail!(
            "bench-compare: throughput regressions past {:.0}%:\n  {}",
            max_regress * 100.0,
            regressions.join("\n  ")
        );
    }
    Ok(())
}

fn cmd_fig41(args: &Args) -> Result<()> {
    let base_n = args.usize_or("base-n", 8000);
    let rows = fig41::run(
        base_n,
        &[0.05, 0.1, 0.2, 0.35, 0.5],
        &[60, 80, 100, 125, 150],
        args.u64_or("seed", 1),
    );
    fig41::print(&rows);
    Ok(())
}

fn fig42_sweep(args: &Args) -> fig42::SweepConfig {
    fig42::SweepConfig {
        min_n: args.usize_or("min-n", 4096),
        max_n: args.usize_or("max-n", 65536),
        n_trees: args.usize_or("trees", 50),
        seed: args.u64_or("seed", 7),
        dataset: args.str_or("dataset", "covertype").to_string(),
    }
}

fn cmd_fig42(args: &Args) -> Result<()> {
    let cfg = fig42_sweep(args);
    let axis = match args.str_or("axis", "method") {
        "dataset" => fig42::Axis::Dataset(
            args.str_or(
                "datasets",
                "airlines,covertype,higgs,susy,pbmc,tvnews,tissuemnist,fashionmnist,signmnist",
            )
            .split(',')
            .map(String::from)
            .collect(),
        ),
        "method" => fig42::Axis::Method(vec![
            ProximityKind::Original,
            ProximityKind::Kerf,
            ProximityKind::OobSeparable,
            ProximityKind::RfGap,
        ]),
        "minleaf" => fig42::Axis::MinLeaf(vec![1, 5, 10, 25, 50]),
        "kind" => fig42::Axis::ForestKind(vec![ForestKind::RandomForest, ForestKind::ExtraTrees]),
        "depth" => fig42::Axis::Depth(vec![None, Some(20), Some(14), Some(10)]),
        other => bail!("unknown axis {other}"),
    };
    let series = fig42::run(&axis, &cfg)?;
    fig42::print(&series, &format!("Fig 4.2 axis={}", args.str_or("axis", "method")));

    // Serial-vs-parallel probe of the kernel product, hard-capped at
    // 16384 samples to stay cheap relative to the sweep (deliberately
    // allowed to fall below --min-n rather than above the cap).
    let probe_n = cfg.max_n.min(16384);
    let spec = registry::by_name(&cfg.dataset)
        .ok_or_else(|| anyhow!("unknown dataset {}", cfg.dataset))?;
    let data = spec.generate(probe_n, cfg.seed);
    let tc = TrainConfig {
        n_trees: cfg.n_trees,
        seed: cfg.seed,
        max_samples: Some(100_000),
        ..Default::default()
    };
    let forest = Forest::train(&data, &tc);
    let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Original);
    let probe = forest_kernels::experiments::spgemm_speedup_probe(&kernel, 3);
    println!(
        "\nspgemm N={} threads={}: serial {:.4}s parallel {:.4}s speedup {:.2}x",
        probe.n,
        probe.threads,
        probe.secs_serial,
        probe.secs_parallel,
        probe.speedup()
    );

    if let Some(path) = args.get("json-out") {
        let mut records: Vec<BenchRecord> = vec![];
        for s in &series {
            for p in &s.points {
                records.push(BenchRecord {
                    name: format!("fig42/{}", s.label),
                    n: p.n,
                    wall_secs: p.secs_total(),
                    predicted_flops: p.flops,
                    threads: exec::threads(),
                    speedup_vs_serial: 1.0,
                });
            }
        }
        records.push(BenchRecord {
            name: format!("spgemm/{}", cfg.dataset),
            n: probe.n,
            wall_secs: probe.secs_parallel,
            predicted_flops: probe.flops,
            threads: probe.threads,
            speedup_vs_serial: probe.speedup(),
        });
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

fn cmd_figh1(args: &Args) -> Result<()> {
    // Fig H.1: the four ablation rows on both Airlines and Covertype.
    for dataset in ["airlines", "covertype"] {
        for (axis_name, axis) in [
            (
                "method",
                fig42::Axis::Method(vec![
                    ProximityKind::Original,
                    ProximityKind::Kerf,
                    ProximityKind::OobSeparable,
                    ProximityKind::RfGap,
                ]),
            ),
            (
                "kind",
                fig42::Axis::ForestKind(vec![ForestKind::RandomForest, ForestKind::ExtraTrees]),
            ),
            ("minleaf", fig42::Axis::MinLeaf(vec![1, 10, 50])),
            ("depth", fig42::Axis::Depth(vec![None, Some(20), Some(14), Some(10)])),
        ] {
            let mut cfg = fig42_sweep(args);
            cfg.dataset = dataset.to_string();
            let series = fig42::run(&axis, &cfg)?;
            fig42::print(&series, &format!("Fig H.1 {dataset} row={axis_name}"));
            println!();
        }
    }
    Ok(())
}

fn cmd_fig43(args: &Args) -> Result<()> {
    let name = args.str_or("dataset", "fashionmnist");
    let spec = registry::by_name(name).ok_or_else(|| anyhow!("unknown dataset {name}"))?;
    let n = args.usize_or("n", 12_000);
    let test_n = args.usize_or("test-n", 2_000);
    let all = spec.generate(n + test_n, args.u64_or("seed", 11));
    let train = all.head(n);
    let test = all.subset(&(n..n + test_n).collect::<Vec<_>>());
    let cfg = fig43::Fig43Config {
        pca_dims: args.usize_or("pca-dims", 24),
        n_trees: args.usize_or("trees", 40),
        seed: args.u64_or("seed", 11),
        ..Default::default()
    };
    let results = fig43::run(&train, &test, &cfg);
    fig43::print(&results, &format!("Fig 4.3 — {name} N={n} test={test_n}"));
    Ok(())
}

fn cmd_tablei1(args: &Args) -> Result<()> {
    let sizes: Vec<usize> = args
        .str_or("sizes", "16384,32768,65536")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let rows = tablei1::run(
        &["airlines", "covertype"],
        &sizes,
        args.usize_or("trees", 50),
        args.u64_or("seed", 9),
    )?;
    tablei1::print(&rows);
    Ok(())
}

fn cmd_naive(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "covertype");
    let trees = args.usize_or("trees", 32);
    println!("# factored vs naive O(N²T) (dataset={dataset}, T={trees})");
    println!("N\tnaive_s\tfactored_s\tspeedup\tpar_speedup");
    let mut records: Vec<BenchRecord> = vec![];
    let mut n = 256usize;
    let max = args.usize_or("n", 4096);
    while n <= max {
        let naive = fig42::naive_cost(n, dataset, trees, 3)?;
        let spec = registry::by_name(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
        let data = spec.generate(n, 3);
        let cfg = TrainConfig { n_trees: trees, seed: 3, ..Default::default() };
        let forest = Forest::train(&data, &cfg);
        let cost = forest_kernels::experiments::measure_kernel_cost(
            &forest,
            &data,
            ProximityKind::Original,
        );
        let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Original);
        let probe = forest_kernels::experiments::spgemm_speedup_probe(&kernel, 3);
        println!(
            "{n}\t{naive:.4}\t{:.4}\t{:.1}x\t{:.2}x",
            cost.secs_total(),
            naive / cost.secs_total(),
            probe.speedup()
        );
        records.push(BenchRecord {
            name: format!("naive/{dataset}"),
            n,
            wall_secs: naive,
            predicted_flops: 0,
            threads: 1,
            speedup_vs_serial: 1.0,
        });
        records.push(BenchRecord {
            name: format!("spgemm/{dataset}"),
            n,
            wall_secs: probe.secs_parallel,
            predicted_flops: probe.flops,
            threads: probe.threads,
            speedup_vs_serial: probe.speedup(),
        });
        n *= 2;
    }
    if let Some(path) = args.get("json-out") {
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

/// `bench-quantize`: exact vs block-quantized factors on one fitted
/// kernel — serialized bytes/row, full-kernel SpGEMM wall time, and
/// neighbor recall@10 / recall@100 of the quantized product against the
/// exact one (sampled rows, ties broken identically on both sides).
fn cmd_bench_quantize(args: &Args) -> Result<()> {
    use forest_kernels::sparse::qcsr;
    use forest_kernels::spectral::knn::rank_row;
    let dataset = args.str_or("dataset", "covertype");
    let n = args.usize_or("n", 8192);
    let trees = args.usize_or("trees", 48);
    let sample_rows = args.usize_or("sample-rows", 256).max(1);
    let kind = {
        let m = args.str_or("method", "kerf");
        ProximityKind::from_name(m).ok_or_else(|| anyhow!("unknown method {m}"))?
    };
    let spec =
        registry::by_name(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let data = spec.generate(n, 7);
    let cfg = TrainConfig {
        n_trees: trees,
        min_samples_leaf: args.usize_or("min-leaf", 64),
        seed: 7,
        ..Default::default()
    };
    let forest = Forest::train(&data, &cfg);
    let kernel = ForestKernel::fit(&forest, &data, kind);
    let threads = exec::threads();
    let flops = kernel.predicted_flops();

    let (p_exact, secs_exact) = time(|| kernel.proximity_matrix());
    let exact_bytes =
        model::encoded_csr_bytes(&kernel.q) + model::encoded_csr_bytes(kernel.w_transpose());
    println!(
        "# quantized factors vs exact ({dataset}, N={n}, T={trees}, method={}, {threads} threads)",
        kind.name()
    );
    println!("mode\tbytes/row\tratio\tspgemm_s\trecall@10\trecall@100");
    println!(
        "exact\t{:.1}\t1.00x\t{secs_exact:.3}\t1.000\t1.000",
        exact_bytes as f64 / n as f64
    );
    let mut records = vec![
        BenchRecord {
            name: format!("quantize-spgemm/exact/{dataset}"),
            n,
            wall_secs: secs_exact,
            predicted_flops: flops,
            threads,
            speedup_vs_serial: 1.0,
        },
        BenchRecord {
            name: format!("quantize-bytes-per-row/exact/{dataset}"),
            n,
            wall_secs: exact_bytes as f64 / n as f64,
            predicted_flops: 0,
            threads: 1,
            speedup_vs_serial: 1.0,
        },
    ];

    // Mean recall@k of the quantized product's neighbor ranking vs the
    // exact one, over every `step`-th row (self excluded on both sides).
    let recall_at = |p_q: &Csr, k: usize| -> f64 {
        let step = (n / sample_rows).max(1);
        let (mut tot, mut cnt) = (0f64, 0usize);
        let mut i = 0;
        while i < n {
            let (ec, ev) = p_exact.row(i);
            let top: Vec<u32> =
                rank_row(ec, ev, Some(i), k).into_iter().map(|(c, _)| c).collect();
            if !top.is_empty() {
                let (qc, qv) = p_q.row(i);
                let got: std::collections::HashSet<u32> =
                    rank_row(qc, qv, Some(i), k).into_iter().map(|(c, _)| c).collect();
                let hit = top.iter().filter(|c| got.contains(c)).count();
                tot += hit as f64 / top.len() as f64;
                cnt += 1;
            }
            i += step;
        }
        if cnt == 0 { 1.0 } else { tot / cnt }
    };

    for mode in [QuantMode::Int8, QuantMode::Int4] {
        let qq = qcsr::quantize(&kernel.q, mode);
        let qwt = qcsr::quantize(kernel.w_transpose(), mode);
        let (p_q, secs_q) = time(|| qcsr::spgemm_q(&qq, &qwt, threads));
        let qbytes = model::encoded_qcsr_bytes(&qq) + model::encoded_qcsr_bytes(&qwt);
        let ratio = exact_bytes as f64 / qbytes as f64;
        let r10 = recall_at(&p_q, 10);
        let r100 = recall_at(&p_q, 100);
        println!(
            "{}\t{:.1}\t{ratio:.2}x\t{secs_q:.3}\t{r10:.3}\t{r100:.3}",
            mode.name(),
            qbytes as f64 / n as f64
        );
        records.push(BenchRecord {
            name: format!("quantize-spgemm/{}/{dataset}", mode.name()),
            n,
            wall_secs: secs_q,
            predicted_flops: flops,
            threads,
            speedup_vs_serial: secs_exact / secs_q,
        });
        records.push(BenchRecord {
            name: format!("quantize-bytes-per-row/{}/{dataset}", mode.name()),
            n,
            wall_secs: qbytes as f64 / n as f64,
            predicted_flops: 0,
            threads: 1,
            speedup_vs_serial: ratio,
        });
        for (k, r) in [(10usize, r10), (100usize, r100)] {
            records.push(BenchRecord {
                name: format!("quantize-recall/{}/k={k}/{dataset}", mode.name()),
                n,
                wall_secs: r,
                predicted_flops: 0,
                threads: 1,
                speedup_vs_serial: r,
            });
        }
    }
    if let Some(path) = args.get("json-out") {
        write_bench_json(std::path::Path::new(path), &records)?;
        println!("wrote {} records to {path}", records.len());
    }
    Ok(())
}

fn cmd_learned(args: &Args) -> Result<()> {
    // §5 ablation: does enriching/learning the weighting improve the
    // proximity-weighted predictor over the fixed schemes, with the
    // forest topology held fixed?
    use forest_kernels::swlc::custom;
    use forest_kernels::swlc::{kernel::incidence_matrix, weights, EnsembleContext};
    let (data, name) = load_data(args)?;
    let (train, test) = data.train_test_split(0.15, args.u64_or("seed", 42) ^ 0x1EA2);
    let cfg = train_cfg(args);
    let forest = Forest::train(&train, &cfg);
    let ctx = EnsembleContext::build(&forest, &train);

    println!("# §5 ablation on {name} (N={} T={})", train.n, ctx.t);
    println!("kernel\ttrain_acc\ttest_acc");
    // `oos_weight(tree, routed_leaf)` recomputes the symmetric scheme's
    // query weight for an unseen sample (leaf-dependent schemes need the
    // routed leaf, not a copied training row).
    let eval = |label: &str,
                spec: &forest_kernels::swlc::WeightSpec,
                oos_weight: &dyn Fn(usize, u32) -> f32| {
        let q = incidence_matrix(&ctx.leaf_of, &spec.q, ctx.n, ctx.t, ctx.l);
        let w = if spec.symmetric {
            q.clone()
        } else {
            incidence_matrix(&ctx.leaf_of, &spec.w, ctx.n, ctx.t, ctx.l)
        };
        let m = predict::leaf_class_mass(&w, &ctx.y, ctx.n_classes);
        let tr_scores = predict::class_scores(&q, &m, ctx.n_classes);
        let tr = predict::accuracy(
            &predict::argmax_scores(&tr_scores, ctx.n_classes, 0),
            &train.y,
        );
        // OOS: route test samples, reuse the same per-tree weights
        // (symmetric schemes only in this ablation).
        let leaf_new = forest.apply(&test);
        let mut qn_tab = vec![0f32; test.n * ctx.t];
        for i in 0..test.n {
            for tt in 0..ctx.t {
                qn_tab[i * ctx.t + tt] = oos_weight(tt, leaf_new[i * ctx.t + tt]);
            }
        }
        let qn = incidence_matrix(&leaf_new, &qn_tab, test.n, ctx.t, ctx.l);
        let te_scores = predict::class_scores(&qn, &m, ctx.n_classes);
        let te = predict::accuracy(
            &predict::argmax_scores(&te_scores, ctx.n_classes, 0),
            &test.y,
        );
        println!("{label}\t{tr:.4}\t{te:.4}");
        te
    };

    let sqrt_t_inv = 1.0 / (ctx.t as f32).sqrt();
    let uniform = weights::assign(ProximityKind::Original, &ctx);
    eval("original(uniform)", &uniform, &|_, _| sqrt_t_inv);
    let enriched = custom::impurity_kerf(&ctx);
    let imp = custom::leaf_impurity(&ctx);
    let tf = ctx.t as f32;
    let leaf_mass = ctx.leaf_mass.clone();
    eval("impurity-kerf", &enriched, &move |_, leaf| {
        let g = leaf as usize;
        ((1.0 - imp[g]).max(0.0) / (tf * leaf_mass[g])).sqrt()
    });
    let alpha = custom::learn_tree_weights(&ctx, args.usize_or("epochs", 15), 0.5);
    let learned = custom::learned_weight_spec(&ctx, &alpha);
    let total: f32 = alpha.iter().sum();
    let alpha_oos = alpha.clone();
    eval("learned-alpha", &learned, &move |tt, _| (alpha_oos[tt] / total).sqrt());
    let (amin, amax) = alpha.iter().fold((f32::MAX, f32::MIN), |(lo, hi), &a| (lo.min(a), hi.max(a)));
    println!("alpha range: [{amin:.3}, {amax:.3}] over {} trees", alpha.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_of(argv: &[&str]) -> Args {
        Args::parse(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn companion_spec_parses_and_validates() {
        assert_eq!(parse_companion(&args_of(&[])).unwrap(), None);
        assert_eq!(
            parse_companion(&args_of(&["--companion", "depth=3,subsample=0.5"])).unwrap(),
            Some((3, 0.5))
        );
        // Omitted keys take the shallow defaults.
        assert_eq!(
            parse_companion(&args_of(&["--companion", "depth=6"])).unwrap(),
            Some((6, 0.25))
        );
        assert_eq!(
            parse_companion(&args_of(&["--companion", "subsample=1.0"])).unwrap(),
            Some((4, 1.0))
        );
        assert!(parse_companion(&args_of(&["--companion", "depth=0"])).is_err());
        assert!(parse_companion(&args_of(&["--companion", "subsample=1.5"])).is_err());
        assert!(parse_companion(&args_of(&["--companion", "subsample=0"])).is_err());
        assert!(parse_companion(&args_of(&["--companion", "width=3"])).is_err());
        assert!(parse_companion(&args_of(&["--companion", "depth"])).is_err());
    }

    #[test]
    fn regress_fraction_signs() {
        assert!((regress_fraction(1.0, 1.5) - 0.5).abs() < 1e-12);
        assert!((regress_fraction(2.0, 1.0) + 0.5).abs() < 1e-12);
        assert!(regress_fraction(1.0, 1.0).abs() < 1e-12);
    }

    /// The PR 5 replica-spawn cleanup only ran after a fully healthy
    /// fleet: a child failing its health-check *after* loading the
    /// bundle returned early and left the temp file behind. All
    /// temp-bundle use now goes through `with_temp_bundle`, which
    /// removes the file on the error path too.
    #[test]
    fn temp_bundle_removed_even_when_replica_setup_fails() {
        let spec = registry::by_name("covertype").unwrap();
        let data = spec.generate(120, 3);
        let cfg = TrainConfig { n_trees: 3, seed: 3, ..Default::default() };
        let forest = Forest::train(&data, &cfg);
        let kernel = ForestKernel::fit(&forest, &data, ProximityKind::Kerf);
        let meta = BundleMeta { dataset: "covertype".into(), n: data.n, seed: 3, trees: 3 };
        let bundle = ModelBundle { forest, kernel, meta, companion: None };
        let path = std::env::temp_dir()
            .join(format!("fk-temp-bundle-cleanup-{}.fkb", std::process::id()));

        let out: Result<()> = with_temp_bundle(&path, &bundle, |p| {
            assert!(p.exists(), "bundle must be on disk while the fleet spawns");
            bail!("replica failed health-check after load")
        });
        assert!(out.is_err());
        assert!(!path.exists(), "temp bundle left behind on the error path");

        let out = with_temp_bundle(&path, &bundle, |p| Ok(p.exists()));
        assert!(out.unwrap());
        assert!(!path.exists(), "temp bundle left behind on the success path");
    }
}
