//! Gradient-boosted trees (least-squares and binary logistic).
//!
//! Provides the "sequential forest via gradient boosting" ensemble
//! context of App. B.6: each tree carries a nonnegative weight `w_t`
//! reflecting its contribution to the additive model, consumed by the
//! boosted SWLC proximity. We use `w_t = λ · RMS(leaf values of tree t)`
//! — an empirical per-tree contribution magnitude in the spirit of
//! Tan et al. [46] (the paper's reference for boosted proximities).

use super::binning::{BinnedData, Binner};
use super::tree::{BuildParams, Targets, TreeBuilder};
use super::{Criterion, Forest, ForestKind, SplitMode, TrainConfig};
use crate::data::Dataset;
use crate::rng::Rng;

pub fn train_gbt(data: &Dataset, binned: &BinnedData, binner: Binner, cfg: &TrainConfig) -> Forest {
    assert!(
        data.n_classes == 0 || data.n_classes == 2,
        "GBT supports regression or binary classification (got {} classes); \
         multiclass boosting is out of scope (documented in DESIGN.md)",
        data.n_classes
    );
    let n = data.n;
    let binary = data.n_classes == 2;
    let lr = cfg.learning_rate;

    // Initial score: log-odds (binary) or target mean (regression).
    let init_score = if binary {
        let pos = data.y.iter().filter(|&&v| v >= 0.5).count() as f64;
        let p = (pos / n as f64).clamp(1e-6, 1.0 - 1e-6);
        (p / (1.0 - p)).ln() as f32
    } else {
        (data.y.iter().map(|&v| v as f64).sum::<f64>() / n as f64) as f32
    };

    let params = BuildParams {
        max_depth: cfg.max_depth.unwrap_or(6), // boosting wants shallow trees
        min_samples_leaf: cfg.min_samples_leaf,
        mtry: cfg.max_features.resolve(data.d),
        criterion: Criterion::Mse,
        mode: SplitMode::Best,
        n_bins: cfg.n_bins,
    };

    let root_rng = Rng::new(cfg.seed);
    let mut builder = TreeBuilder::new();
    let mut trees = Vec::with_capacity(cfg.n_trees);
    let mut tree_weights = Vec::with_capacity(cfg.n_trees);
    let mut leaf_offsets = vec![0u32];

    let mut score = vec![init_score; n];
    let mut residual = vec![0f32; n];
    let mut samples: Vec<u32> = Vec::with_capacity(n);
    let mut leaf_of = vec![0u32; n];

    for t in 0..cfg.n_trees {
        let mut rng = root_rng.derive(t as u64 + 1);
        // Pseudo-residuals: negative gradient of the loss at current F.
        if binary {
            for i in 0..n {
                let p = sigmoid(score[i]);
                residual[i] = data.y[i] - p;
            }
        } else {
            for i in 0..n {
                residual[i] = data.y[i] - score[i];
            }
        }

        samples.clear();
        samples.extend(0..n as u32);
        let targets = Targets::Regression { values: &residual };
        let mut tree = builder.build(binned, &targets, &mut samples, &params, &mut rng);

        // Leaf values: Newton step for logistic loss (sum r / sum p(1-p));
        // least-squares leaves already hold the mean residual.
        for i in 0..n {
            leaf_of[i] = tree.apply_binned(binned.row(i));
        }
        if binary {
            let mut num = vec![0f64; tree.n_leaves];
            let mut den = vec![0f64; tree.n_leaves];
            for i in 0..n {
                let l = leaf_of[i] as usize;
                let p = sigmoid(score[i]) as f64;
                num[l] += residual[i] as f64;
                den[l] += (p * (1.0 - p)).max(1e-12);
            }
            for l in 0..tree.n_leaves {
                tree.leaf_stats[l] = (num[l] / den[l]).clamp(-4.0, 4.0) as f32;
            }
        }

        // Update scores and record the tree's additive contribution.
        let mut ss = 0f64;
        for i in 0..n {
            let v = tree.leaf_stats[leaf_of[i] as usize];
            score[i] += lr * v;
        }
        for l in 0..tree.n_leaves {
            let v = tree.leaf_stats[l] as f64;
            ss += v * v;
        }
        let w_t = (lr as f64 * (ss / tree.n_leaves.max(1) as f64).sqrt()).max(1e-12) as f32;
        tree_weights.push(w_t);

        leaf_offsets.push(leaf_offsets.last().unwrap() + tree.n_leaves as u32);
        trees.push(tree);
    }

    Forest {
        kind: ForestKind::GradientBoosting,
        trees,
        binner,
        leaf_offsets,
        inbag: vec![],
        tree_weights,
        n_classes: data.n_classes,
        init_score,
        learning_rate: lr,
        n_train: n,
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn gbt_cfg(n_trees: usize, seed: u64) -> TrainConfig {
        TrainConfig {
            kind: ForestKind::GradientBoosting,
            n_trees,
            max_depth: Some(4),
            criterion: Criterion::Mse,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn regression_loss_decreases_with_rounds() {
        let mut data = synth::gaussian_blobs(300, 4, 2, 2.0, 1);
        data.n_classes = 0; // treat labels as a regression target
        let mse = |f: &Forest| {
            let preds = f.predict(&data);
            preds
                .iter()
                .zip(&data.y)
                .map(|(p, y)| ((p - y) as f64).powi(2))
                .sum::<f64>()
                / data.n as f64
        };
        let f5 = Forest::train(&data, &gbt_cfg(5, 2));
        let f80 = Forest::train(&data, &gbt_cfg(80, 2));
        assert!(mse(&f80) < mse(&f5), "{} !< {}", mse(&f80), mse(&f5));
        assert!(mse(&f80) < 0.12, "mse={}", mse(&f80));
    }

    #[test]
    fn binary_classification_learns() {
        let data = synth::gaussian_blobs(400, 5, 2, 2.0, 3);
        let f = Forest::train(&data, &gbt_cfg(40, 4));
        assert!(f.accuracy(&data) > 0.95, "acc={}", f.accuracy(&data));
    }

    #[test]
    fn tree_weights_positive_and_shrinking_trend() {
        let data = synth::gaussian_blobs(400, 5, 2, 2.0, 5);
        let f = Forest::train(&data, &gbt_cfg(30, 6));
        assert!(f.tree_weights.iter().all(|&w| w > 0.0));
        // Later trees fit smaller residuals: average of last 5 weights
        // should be below average of first 5.
        let first: f32 = f.tree_weights[..5].iter().sum();
        let last: f32 = f.tree_weights[25..].iter().sum();
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn init_score_is_log_odds() {
        let data = synth::gaussian_blobs(200, 3, 2, 2.0, 7);
        let pos = data.y.iter().filter(|&&v| v >= 0.5).count() as f64 / 200.0;
        let f = Forest::train(&data, &gbt_cfg(2, 8));
        let expect = (pos / (1.0 - pos)).ln() as f32;
        assert!((f.init_score - expect).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "multiclass")]
    fn multiclass_rejected() {
        let data = synth::gaussian_blobs(100, 3, 3, 2.0, 9);
        Forest::train(&data, &gbt_cfg(2, 10));
    }
}
