//! From-scratch decision forests.
//!
//! The paper treats the ensemble as a given "ensemble context" `(T, θ)`
//! produced by any standard forest learner (scikit-learn in their
//! implementation). We build the learners themselves: CART trees over
//! **quantile-binned** features (256 bins, the LightGBM-style histogram
//! trick, giving `O(node_size + bins·classes)` split search), bagged
//! random forests with full in-bag/OOB bookkeeping (needed by the OOB
//! and RF-GAP weight schemes of App. B), extremely randomized trees
//! (Fig. H.1's RF-vs-ET ablation), and gradient-boosted trees with
//! per-tree weights (the boosted proximity of App. B.6).

mod bagging;
mod binning;
mod gbt;
mod tree;

pub use binning::{BinnedData, Binner};
pub use tree::{BuildParams, Node, Targets, Tree, TreeBuilder, LEAF};

/// Split search strategy: exhaustive best cut (CART) or a single random
/// cut per candidate feature (ExtraTrees).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitMode {
    Best,
    Random,
}

use crate::data::Dataset;
use crate::rng::Rng;

/// Which ensemble algorithm to train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForestKind {
    /// Breiman random forest: bootstrap + best-split CART.
    RandomForest,
    /// Extremely randomized trees: no bootstrap, random thresholds.
    ExtraTrees,
    /// Gradient-boosted trees (binary logistic or least-squares).
    GradientBoosting,
}

/// Split quality criterion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criterion {
    Gini,
    Entropy,
    /// Mean squared error (regression / boosting residuals).
    Mse,
}

/// How many features to consider per split.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MaxFeatures {
    Sqrt,
    All,
    Fraction(f32),
}

impl MaxFeatures {
    pub fn resolve(&self, d: usize) -> usize {
        match self {
            MaxFeatures::Sqrt => ((d as f64).sqrt().ceil() as usize).clamp(1, d),
            MaxFeatures::All => d,
            MaxFeatures::Fraction(f) => (((d as f32) * f).ceil() as usize).clamp(1, d),
        }
    }
}

/// Forest training hyperparameters (mirrors the knobs the paper ablates:
/// `n_trees` = T, `max_depth` = d, `min_samples_leaf` = n_min).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub kind: ForestKind,
    pub n_trees: usize,
    pub max_depth: Option<usize>,
    pub min_samples_leaf: usize,
    pub max_features: MaxFeatures,
    pub criterion: Criterion,
    /// Draws per bootstrap; `None` = N (classic bagging). Smaller values
    /// (sklearn's `max_samples`) bound per-tree training cost at large N.
    pub max_samples: Option<usize>,
    /// Histogram bins per feature (≤ 256).
    pub n_bins: usize,
    /// GBT only: shrinkage.
    pub learning_rate: f32,
    pub seed: u64,
    /// Worker threads for per-tree training; `0` = the shared
    /// [`crate::exec::threads`] knob. Any value produces the identical
    /// ensemble: each tree consumes its own pre-seeded RNG stream
    /// (`root_rng.derive(t + 1)`), so parallelism never reorders
    /// randomness. (GBT is inherently sequential and ignores this.)
    pub n_threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            kind: ForestKind::RandomForest,
            n_trees: 100,
            max_depth: None,
            min_samples_leaf: 1,
            max_features: MaxFeatures::Sqrt,
            criterion: Criterion::Gini,
            max_samples: None,
            n_bins: 256,
            learning_rate: 0.1,
            seed: 0,
            n_threads: 0,
        }
    }
}

/// A trained ensemble: trees, the global leaf indexing of §2.2, per-tree
/// in-bag multiplicities (the `c_t` of App. B.4; 0 ⇒ out-of-bag), and
/// per-tree additive weights (GBT).
pub struct Forest {
    pub kind: ForestKind,
    pub trees: Vec<Tree>,
    pub binner: Binner,
    /// `leaf_offsets[t]` = global index of leaf 0 of tree `t`;
    /// `leaf_offsets[T]` = L, the total leaf count.
    pub leaf_offsets: Vec<u32>,
    /// Per-tree in-bag multiplicities over the training set, length N
    /// each. Empty for ExtraTrees/GBT (no bootstrap ⇒ every sample
    /// in-bag once).
    pub inbag: Vec<Vec<u16>>,
    /// Per-tree weight in the additive model (GBT); 1 for bagged kinds.
    pub tree_weights: Vec<f32>,
    /// Number of classes (0 ⇒ regression).
    pub n_classes: usize,
    /// GBT binary classification: initial log-odds.
    pub init_score: f32,
    /// GBT shrinkage used at prediction time (1.0 for bagged kinds).
    pub learning_rate: f32,
    pub n_train: usize,
}

impl Forest {
    /// Train an ensemble on a dataset according to `cfg`.
    pub fn train(data: &Dataset, cfg: &TrainConfig) -> Forest {
        let binner = Binner::fit(data, cfg.n_bins, &mut Rng::new(cfg.seed ^ 0xB1AAED));
        let binned = binner.bin(data);
        match cfg.kind {
            ForestKind::RandomForest | ForestKind::ExtraTrees => {
                bagging::train_bagged(data, &binned, binner, cfg)
            }
            ForestKind::GradientBoosting => gbt::train_gbt(data, &binned, binner, cfg),
        }
    }

    /// Total number of leaves L across the ensemble.
    pub fn n_leaves_total(&self) -> usize {
        *self.leaf_offsets.last().unwrap() as usize
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Average tree height h̄ (max depth per tree, averaged).
    pub fn mean_depth(&self) -> f64 {
        self.trees.iter().map(|t| t.depth as f64).sum::<f64>() / self.trees.len().max(1) as f64
    }

    /// Route every sample of `data` through every tree: returns the
    /// sample-major `N×T` matrix of **global** leaf ids
    /// (`out[i*T + t] = ℓ_t(x_i)`), the `ℓ_t` maps of §2.2. Cost O(N·T·h̄).
    pub fn apply(&self, data: &Dataset) -> Vec<u32> {
        let binned = self.binner.bin(data);
        self.apply_binned(&binned)
    }

    /// As [`Forest::apply`] but over pre-binned rows. Samples are
    /// routed in parallel over the shared [`crate::exec`] pool; every
    /// sample writes its own disjoint `T`-slot span, so the table is
    /// identical at any thread count.
    pub fn apply_binned(&self, binned: &BinnedData) -> Vec<u32> {
        let (n, t_total) = (binned.n, self.trees.len());
        let mut out = vec![0u32; n * t_total];
        let shared = crate::exec::SharedSlice::new(&mut out);
        crate::exec::parallel_ranges(n, crate::exec::workers_for(n, 512), |_, rows| {
            for i in rows {
                let row = binned.row(i);
                for (t, tree) in self.trees.iter().enumerate() {
                    // SAFETY: sample i exclusively owns out[i*T..(i+1)*T].
                    unsafe {
                        shared.write(i * t_total + t, self.leaf_offsets[t] + tree.apply_binned(row));
                    }
                }
            }
        });
        out
    }

    /// Ensemble prediction for one binned row: classification returns the
    /// argmax class as f32; regression/GBT returns the additive score.
    pub fn predict_row(&self, row: &[u8]) -> f32 {
        match self.kind {
            ForestKind::GradientBoosting => {
                // NOTE: `tree_weights` are the *proximity* weights of
                // App. B.6; prediction uses the additive model directly,
                // i.e. shrinkage × leaf value.
                let mut f = self.init_score;
                for tree in &self.trees {
                    let leaf = tree.apply_binned(row) as usize;
                    f += self.learning_rate * tree.leaf_stats[leaf];
                }
                if self.n_classes == 2 {
                    // logistic: class = 1[σ(f) > .5] = 1[f > 0]
                    (f > 0.0) as u32 as f32
                } else {
                    f
                }
            }
            _ => {
                if self.n_classes == 0 {
                    let mut acc = 0f64;
                    for tree in &self.trees {
                        acc += tree.leaf_stats[tree.apply_binned(row) as usize] as f64;
                    }
                    (acc / self.trees.len() as f64) as f32
                } else {
                    let c = self.n_classes;
                    let mut votes = vec![0f64; c];
                    for tree in &self.trees {
                        let leaf = tree.apply_binned(row) as usize;
                        let stats = &tree.leaf_stats[leaf * c..(leaf + 1) * c];
                        let total: f32 = stats.iter().sum();
                        if total > 0.0 {
                            for (vk, &s) in votes.iter_mut().zip(stats) {
                                *vk += (s / total) as f64;
                            }
                        }
                    }
                    argmax(&votes) as f32
                }
            }
        }
    }

    /// Predictions for a whole dataset.
    pub fn predict(&self, data: &Dataset) -> Vec<f32> {
        let binned = self.binner.bin(data);
        (0..binned.n).map(|i| self.predict_row(binned.row(i))).collect()
    }

    /// Classification accuracy against the dataset labels.
    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let preds = self.predict(data);
        let hits = preds
            .iter()
            .zip(&data.y)
            .filter(|(p, y)| (**p - **y).abs() < 0.5)
            .count();
        hits as f64 / data.n.max(1) as f64
    }

    /// OOB class votes (bagged classifiers only): for each training
    /// sample, soft votes aggregated over trees where it is out-of-bag.
    /// Returns an `N × C` row-major matrix; rows that were never OOB are
    /// all-zero. RF-GAP's defining property is that proximity-weighted
    /// prediction reproduces the argmax of these votes.
    pub fn oob_votes(&self, binned: &BinnedData) -> Vec<f64> {
        assert!(self.n_classes >= 2, "oob_votes requires classification");
        assert!(!self.inbag.is_empty(), "oob_votes requires bootstrap bookkeeping");
        let c = self.n_classes;
        let mut votes = vec![0f64; binned.n * c];
        for (tree, inbag) in self.trees.iter().zip(&self.inbag) {
            for i in 0..binned.n {
                if inbag[i] == 0 {
                    let leaf = tree.apply_binned(binned.row(i)) as usize;
                    let stats = &tree.leaf_stats[leaf * c..(leaf + 1) * c];
                    let total: f32 = stats.iter().sum();
                    if total > 0.0 {
                        for k in 0..c {
                            votes[i * c + k] += (stats[k] / total) as f64;
                        }
                    }
                }
            }
        }
        votes
    }
}

pub(crate) fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn toy(n: usize, seed: u64) -> Dataset {
        synth::gaussian_blobs(n, 5, 3, 2.5, seed)
    }

    #[test]
    fn rf_fits_separable_data() {
        let data = toy(400, 1);
        let cfg = TrainConfig { n_trees: 20, seed: 3, ..Default::default() };
        let f = Forest::train(&data, &cfg);
        assert_eq!(f.n_trees(), 20);
        assert!(f.accuracy(&data) > 0.95, "acc={}", f.accuracy(&data));
    }

    #[test]
    fn extratrees_fit() {
        let data = toy(400, 2);
        let cfg = TrainConfig {
            kind: ForestKind::ExtraTrees,
            n_trees: 20,
            seed: 4,
            ..Default::default()
        };
        let f = Forest::train(&data, &cfg);
        assert!(f.inbag.is_empty());
        assert!(f.accuracy(&data) > 0.9, "acc={}", f.accuracy(&data));
    }

    #[test]
    fn gbt_binary_fit() {
        let data = synth::gaussian_blobs(400, 4, 2, 2.5, 5);
        let cfg = TrainConfig {
            kind: ForestKind::GradientBoosting,
            n_trees: 30,
            max_depth: Some(4),
            criterion: Criterion::Mse,
            seed: 6,
            ..Default::default()
        };
        let f = Forest::train(&data, &cfg);
        assert!(f.accuracy(&data) > 0.9, "acc={}", f.accuracy(&data));
        assert!(f.tree_weights.iter().all(|&w| w > 0.0));
    }

    #[test]
    fn leaf_offsets_partition_global_index_space() {
        let data = toy(200, 7);
        let f = Forest::train(&data, &TrainConfig { n_trees: 8, seed: 1, ..Default::default() });
        assert_eq!(f.leaf_offsets.len(), 9);
        for t in 0..8 {
            assert_eq!(
                f.leaf_offsets[t + 1] - f.leaf_offsets[t],
                f.trees[t].n_leaves as u32
            );
        }
    }

    #[test]
    fn apply_returns_leaves_in_tree_range() {
        let data = toy(150, 8);
        let f = Forest::train(&data, &TrainConfig { n_trees: 5, seed: 2, ..Default::default() });
        let leaves = f.apply(&data);
        assert_eq!(leaves.len(), 150 * 5);
        for i in 0..150 {
            for t in 0..5 {
                let g = leaves[i * 5 + t];
                assert!(g >= f.leaf_offsets[t] && g < f.leaf_offsets[t + 1]);
            }
        }
    }

    #[test]
    fn apply_is_deterministic() {
        let data = toy(100, 9);
        let cfg = TrainConfig { n_trees: 6, seed: 11, ..Default::default() };
        let f1 = Forest::train(&data, &cfg);
        let f2 = Forest::train(&data, &cfg);
        assert_eq!(f1.apply(&data), f2.apply(&data));
    }

    #[test]
    fn depth_cap_respected() {
        let data = toy(500, 10);
        let f = Forest::train(
            &data,
            &TrainConfig { n_trees: 5, max_depth: Some(3), seed: 1, ..Default::default() },
        );
        for t in &f.trees {
            assert!(t.depth <= 3, "depth={}", t.depth);
            assert!(t.n_leaves <= 8);
        }
    }

    #[test]
    fn min_leaf_respected() {
        let data = toy(300, 11);
        let min = 20;
        let f = Forest::train(
            &data,
            &TrainConfig { n_trees: 5, min_samples_leaf: min, seed: 1, ..Default::default() },
        );
        // Every leaf must hold >= min in-bag draws: check via routing the
        // bootstrap multiset.
        let binned = f.binner.bin(&data);
        for (t, tree) in f.trees.iter().enumerate() {
            let mut counts = vec![0usize; tree.n_leaves];
            for i in 0..data.n {
                let leaf = tree.apply_binned(binned.row(i)) as usize;
                counts[leaf] += f.inbag[t][i] as usize;
            }
            for (leaf, &c) in counts.iter().enumerate() {
                assert!(c >= min, "tree {t} leaf {leaf} has {c} < {min}");
            }
        }
    }

    #[test]
    fn inbag_counts_sum_to_draws() {
        let data = toy(256, 12);
        let f = Forest::train(&data, &TrainConfig { n_trees: 4, seed: 9, ..Default::default() });
        for inbag in &f.inbag {
            let total: usize = inbag.iter().map(|&c| c as usize).sum();
            assert_eq!(total, 256);
        }
    }

    #[test]
    fn max_features_resolution() {
        assert_eq!(MaxFeatures::Sqrt.resolve(54), 8);
        assert_eq!(MaxFeatures::All.resolve(10), 10);
        assert_eq!(MaxFeatures::Fraction(0.5).resolve(10), 5);
        assert_eq!(MaxFeatures::Fraction(0.01).resolve(10), 1);
    }
}
