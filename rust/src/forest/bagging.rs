//! Bagged ensembles: RandomForest and ExtraTrees.
//!
//! RandomForest draws a bootstrap per tree and records the in-bag
//! multiplicities `c_t(x)` — the context the OOB and RF-GAP weight
//! schemes (App. B.3/B.4) consume. ExtraTrees uses the whole training
//! set per tree (no bootstrap, sklearn default) with random-threshold
//! splits.
//!
//! Trees are independent given their RNG streams, so training fans out
//! over the shared [`crate::exec`] pool: each worker owns one
//! contiguous tree range plus its own `TreeBuilder` scratch and sample
//! buffer, and tree `t` always draws from `root_rng.derive(t + 1)` —
//! the forest is bitwise-identical at any thread count.

use super::binning::{BinnedData, Binner};
use super::tree::{BuildParams, Targets, Tree, TreeBuilder};
use super::{Forest, ForestKind, SplitMode, TrainConfig};
use crate::data::Dataset;
use crate::exec;
use crate::rng::Rng;

pub fn train_bagged(data: &Dataset, binned: &BinnedData, binner: Binner, cfg: &TrainConfig) -> Forest {
    let n = data.n;
    let y_class: Vec<u32>;
    let targets = if data.n_classes > 0 {
        y_class = data.y.iter().map(|&v| v as u32).collect();
        Targets::Classification { y: &y_class, n_classes: data.n_classes }
    } else {
        Targets::Regression { values: &data.y }
    };

    let mode = match cfg.kind {
        ForestKind::ExtraTrees => SplitMode::Random,
        _ => SplitMode::Best,
    };
    let params = BuildParams {
        max_depth: cfg.max_depth.unwrap_or(usize::MAX),
        min_samples_leaf: cfg.min_samples_leaf,
        mtry: cfg.max_features.resolve(data.d),
        criterion: cfg.criterion,
        mode,
        n_bins: cfg.n_bins,
    };
    let bootstrap = cfg.kind == ForestKind::RandomForest;
    let n_draws = cfg.max_samples.unwrap_or(n).min(n * 4);

    let root_rng = Rng::new(cfg.seed);
    let workers = if cfg.n_threads == 0 {
        exec::threads().min(cfg.n_trees).max(1)
    } else {
        cfg.n_threads.min(cfg.n_trees).max(1)
    };
    // One contiguous tree range per worker; builder scratch and the
    // bootstrap sample buffer are allocated once per worker and reused
    // across its trees.
    let blocks: Vec<Vec<(Tree, Option<Vec<u16>>)>> =
        exec::parallel_ranges(cfg.n_trees, workers, |_, range| {
            let mut builder = TreeBuilder::new();
            let mut samples: Vec<u32> = Vec::with_capacity(n_draws);
            let mut out = Vec::with_capacity(range.len());
            for t in range {
                let mut rng = root_rng.derive(t as u64 + 1);
                samples.clear();
                let bag = if bootstrap {
                    let counts = rng.bootstrap_counts(n, n_draws);
                    let mut bag = vec![0u16; n];
                    for (i, &c) in counts.iter().enumerate() {
                        debug_assert!(c < u16::MAX as u32);
                        bag[i] = c as u16;
                        for _ in 0..c {
                            samples.push(i as u32);
                        }
                    }
                    Some(bag)
                } else {
                    samples.extend(0..n as u32);
                    None
                };
                let tree = builder.build(binned, &targets, &mut samples, &params, &mut rng);
                out.push((tree, bag));
            }
            out
        });

    let mut trees = Vec::with_capacity(cfg.n_trees);
    let mut inbag: Vec<Vec<u16>> = Vec::new();
    let mut leaf_offsets = vec![0u32];
    for (tree, bag) in blocks.into_iter().flatten() {
        leaf_offsets.push(leaf_offsets.last().unwrap() + tree.n_leaves as u32);
        if let Some(bag) = bag {
            inbag.push(bag);
        }
        trees.push(tree);
    }

    let n_trees = trees.len();
    Forest {
        kind: cfg.kind,
        trees,
        binner,
        leaf_offsets,
        inbag,
        tree_weights: vec![1.0; n_trees],
        n_classes: data.n_classes,
        init_score: 0.0,
        learning_rate: 1.0,
        n_train: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::MaxFeatures;

    #[test]
    fn rf_oob_fraction_near_e_inv() {
        let data = synth::gaussian_blobs(500, 4, 2, 2.0, 1);
        let cfg = TrainConfig { n_trees: 10, seed: 2, ..Default::default() };
        let f = Forest::train(&data, &cfg);
        let mut oob_frac = 0.0;
        for bag in &f.inbag {
            oob_frac += bag.iter().filter(|&&c| c == 0).count() as f64 / 500.0;
        }
        oob_frac /= 10.0;
        // (1 - 1/N)^N -> e^-1 ≈ 0.3679
        assert!((oob_frac - 0.3679).abs() < 0.05, "oob_frac={oob_frac}");
    }

    #[test]
    fn max_samples_caps_draws() {
        let data = synth::gaussian_blobs(300, 4, 2, 2.0, 3);
        let cfg = TrainConfig { n_trees: 3, max_samples: Some(100), seed: 4, ..Default::default() };
        let f = Forest::train(&data, &cfg);
        for bag in &f.inbag {
            assert_eq!(bag.iter().map(|&c| c as usize).sum::<usize>(), 100);
        }
    }

    #[test]
    fn extratrees_no_inbag_bookkeeping() {
        let data = synth::gaussian_blobs(200, 4, 2, 2.0, 5);
        let cfg = TrainConfig {
            kind: ForestKind::ExtraTrees,
            n_trees: 4,
            max_features: MaxFeatures::All,
            seed: 6,
            ..Default::default()
        };
        let f = Forest::train(&data, &cfg);
        assert!(f.inbag.is_empty());
        assert_eq!(f.tree_weights, vec![1.0; 4]);
    }

    #[test]
    fn trees_differ_across_seeds_within_forest() {
        let data = synth::gaussian_blobs(400, 6, 3, 1.5, 7);
        let cfg = TrainConfig { n_trees: 2, seed: 8, ..Default::default() };
        let f = Forest::train(&data, &cfg);
        // Different bootstraps ⇒ the two trees route at least some
        // samples to different partitions (structure sizes may collide,
        // leaf *assignments* almost surely cannot).
        assert_ne!(f.trees[0].nodes.len(), 1);
        let binned = f.binner.bin(&data);
        let a: Vec<u32> = (0..data.n).map(|i| f.trees[0].apply_binned(binned.row(i))).collect();
        let b: Vec<u32> = (0..data.n).map(|i| f.trees[1].apply_binned(binned.row(i))).collect();
        assert_ne!(a, b);
    }
}
