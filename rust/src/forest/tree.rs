//! CART decision trees over binned features.
//!
//! One builder serves all three ensemble kinds: best-split search
//! (RandomForest, GBT) and random-threshold search (ExtraTrees) share
//! the same per-node histogram; Gini / entropy (classification) and MSE
//! (regression & boosting residuals) share the same scan loop. The
//! builder operates on a *multiset* of sample indices, so bootstrap
//! multiplicities come for free (an in-bag sample drawn twice simply
//! appears twice).

use super::binning::BinnedData;
use super::{Criterion, SplitMode};
use crate::rng::Rng;

/// Sentinel feature id marking a leaf node.
pub const LEAF: u16 = u16::MAX;

/// One tree node. Internal: `row[feature] <= threshold` goes `left`,
/// else `right` (child node indices). Leaf: `feature == LEAF` and
/// `left` holds the tree-local leaf id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Node {
    pub feature: u16,
    pub threshold: u8,
    pub left: u32,
    pub right: u32,
}

/// A trained tree plus per-leaf statistics.
///
/// `leaf_stats` layout: classification ⇒ `n_leaves × C` class counts
/// (bootstrap-weighted); regression ⇒ `n_leaves` leaf values.
#[derive(Clone, Debug, PartialEq)]
pub struct Tree {
    pub nodes: Vec<Node>,
    pub n_leaves: usize,
    pub leaf_stats: Vec<f32>,
    pub depth: usize,
}

impl Tree {
    /// Route one binned row to its tree-local leaf id — the `ℓ_t` map of
    /// §2.2 (O(h) pointer chase).
    #[inline]
    pub fn apply_binned(&self, row: &[u8]) -> u32 {
        let mut node = 0usize;
        loop {
            // SAFETY: `node` starts at the root (trees are never
            // empty) and every `left`/`right` child id was written by
            // the trainer as an index into this same `nodes` vec, so
            // the chase can never leave the arena.
            let n = unsafe { self.nodes.get_unchecked(node) };
            if n.feature == LEAF {
                return n.left;
            }
            node = if row[n.feature as usize] <= n.threshold { n.left } else { n.right } as usize;
        }
    }
}

/// Training targets.
pub enum Targets<'a> {
    /// Class labels in `0..n_classes`.
    Classification { y: &'a [u32], n_classes: usize },
    /// Real-valued targets (regression trees / boosting residuals).
    Regression { values: &'a [f32] },
}

/// Per-tree build parameters (resolved from `TrainConfig`).
pub struct BuildParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    pub mtry: usize,
    pub criterion: Criterion,
    pub mode: SplitMode,
    pub n_bins: usize,
}

struct Work {
    node: u32,
    start: usize,
    end: usize,
    depth: usize,
}

const N_BINS_MAX: usize = 256;
const EPS_GAIN: f64 = 1e-9;

/// Scratch-carrying tree builder; reusable across trees of an ensemble.
pub struct TreeBuilder {
    /// class histogram: [bin * C + k], cleared lazily via `touched`.
    hist: Vec<u32>,
    /// regression: per-bin (sum, count).
    hist_sum: Vec<f64>,
    hist_cnt: Vec<u32>,
    touched: Vec<u16>,
    feat_pool: Vec<u16>,
}

impl TreeBuilder {
    pub fn new() -> Self {
        TreeBuilder {
            hist: vec![],
            hist_sum: vec![0.0; N_BINS_MAX],
            hist_cnt: vec![0; N_BINS_MAX],
            touched: Vec::with_capacity(N_BINS_MAX),
            feat_pool: vec![],
        }
    }

    /// Build a tree on the multiset `samples` (indices into `bins`,
    /// repeats = bootstrap multiplicity). `samples` is permuted in place.
    pub fn build(
        &mut self,
        bins: &BinnedData,
        targets: &Targets,
        samples: &mut [u32],
        p: &BuildParams,
        rng: &mut Rng,
    ) -> Tree {
        let n_classes = match targets {
            Targets::Classification { n_classes, .. } => *n_classes,
            Targets::Regression { .. } => 0,
        };
        if n_classes > 0 {
            self.hist.resize(N_BINS_MAX * n_classes, 0);
        }
        if self.feat_pool.len() != bins.d {
            self.feat_pool = (0..bins.d as u16).collect();
        }

        let mut nodes: Vec<Node> = vec![Node { feature: LEAF, threshold: 0, left: 0, right: 0 }];
        let mut leaf_stats: Vec<f32> = vec![];
        let mut n_leaves = 0usize;
        let mut max_depth_seen = 0usize;

        let mut stack = vec![Work { node: 0, start: 0, end: samples.len(), depth: 0 }];
        while let Some(w) = stack.pop() {
            max_depth_seen = max_depth_seen.max(w.depth);
            let seg = &samples[w.start..w.end];
            let size = seg.len();

            let split = if w.depth >= p.max_depth || size < 2 * p.min_samples_leaf || size < 2 {
                None
            } else {
                self.find_split(bins, targets, seg, p, rng)
            };

            match split {
                Some((feature, threshold, _gain)) => {
                    let mid = partition(bins, &mut samples[w.start..w.end], feature, threshold)
                        + w.start;
                    debug_assert!(mid > w.start && mid < w.end);
                    let left_id = nodes.len() as u32;
                    nodes.push(Node { feature: LEAF, threshold: 0, left: 0, right: 0 });
                    let right_id = nodes.len() as u32;
                    nodes.push(Node { feature: LEAF, threshold: 0, left: 0, right: 0 });
                    nodes[w.node as usize] =
                        Node { feature: feature as u16, threshold, left: left_id, right: right_id };
                    stack.push(Work { node: left_id, start: w.start, end: mid, depth: w.depth + 1 });
                    stack.push(Work { node: right_id, start: mid, end: w.end, depth: w.depth + 1 });
                }
                None => {
                    // Finalize a leaf: record stats, assign local id.
                    let leaf_id = n_leaves as u32;
                    n_leaves += 1;
                    nodes[w.node as usize] =
                        Node { feature: LEAF, threshold: 0, left: leaf_id, right: 0 };
                    match targets {
                        Targets::Classification { y, n_classes } => {
                            let base = leaf_stats.len();
                            leaf_stats.resize(base + n_classes, 0.0);
                            for &s in seg {
                                leaf_stats[base + y[s as usize] as usize] += 1.0;
                            }
                        }
                        Targets::Regression { values } => {
                            let sum: f64 = seg.iter().map(|&s| values[s as usize] as f64).sum();
                            leaf_stats.push((sum / size.max(1) as f64) as f32);
                        }
                    }
                }
            }
        }
        Tree { nodes, n_leaves, leaf_stats, depth: max_depth_seen }
    }

    /// Best (feature, threshold, gain) over `mtry` sampled features, or
    /// `None` if the node is pure / no admissible split improves.
    fn find_split(
        &mut self,
        bins: &BinnedData,
        targets: &Targets,
        seg: &[u32],
        p: &BuildParams,
        rng: &mut Rng,
    ) -> Option<(usize, u8, f64)> {
        // Purity check + parent score.
        let parent_score = match targets {
            Targets::Classification { y, n_classes } => {
                let mut counts = vec![0u32; *n_classes];
                for &s in seg {
                    counts[y[s as usize] as usize] += 1;
                }
                if counts.iter().any(|&c| c as usize == seg.len()) {
                    return None; // pure
                }
                class_score(&counts, seg.len(), p.criterion)
            }
            Targets::Regression { values } => {
                let (mut sum, mut sumsq) = (0f64, 0f64);
                for &s in seg {
                    let v = values[s as usize] as f64;
                    sum += v;
                    sumsq += v * v;
                }
                if sumsq - sum * sum / (seg.len() as f64) < 1e-12 {
                    return None; // constant target
                }
                sum * sum / seg.len() as f64
            }
        };

        // Sample the feature subset (partial Fisher–Yates over the pool).
        let d = bins.d;
        let mtry = p.mtry.min(d);
        for i in 0..mtry {
            let j = i + rng.gen_range(d - i);
            self.feat_pool.swap(i, j);
        }

        let mut best: Option<(usize, u8, f64)> = None;
        for fi in 0..mtry {
            let f = self.feat_pool[fi] as usize;
            let cand = match targets {
                Targets::Classification { y, n_classes } => {
                    self.scan_feature_class(bins, y, *n_classes, seg, f, p, rng)
                }
                Targets::Regression { values } => self.scan_feature_reg(bins, values, seg, f, p, rng),
            };
            if let Some((thr, score)) = cand {
                let gain = score - parent_score;
                if gain > EPS_GAIN && best.map_or(true, |(_, _, g)| gain > g) {
                    best = Some((f, thr, gain));
                }
            }
        }
        best
    }

    /// Classification scan: returns (threshold, children score) where
    /// score = Σ_child Σ_k c_k²/n_child (Gini) or -Σ_child n_child·H_child
    /// (entropy); both are "larger is better" with the matching parent
    /// score convention in `find_split`.
    fn scan_feature_class(
        &mut self,
        bins: &BinnedData,
        y: &[u32],
        c: usize,
        seg: &[u32],
        f: usize,
        p: &BuildParams,
        rng: &mut Rng,
    ) -> Option<(u8, f64)> {
        // Build per-bin class histogram, clearing lazily.
        self.touched.clear();
        for &s in seg {
            let b = bins.bins[s as usize * bins.d + f] as usize;
            let slot = b * c;
            let occupied = self.hist[slot..slot + c].iter().any(|&v| v != 0);
            if !occupied {
                self.touched.push(b as u16);
            }
            self.hist[slot + y[s as usize] as usize] += 1;
        }
        self.touched.sort_unstable();
        let result = self.eval_class_thresholds(c, seg.len(), p, rng);
        // Clear touched bins for the next feature.
        for &b in &self.touched {
            let slot = b as usize * c;
            self.hist[slot..slot + c].fill(0);
        }
        result
    }

    fn eval_class_thresholds(
        &self,
        c: usize,
        n: usize,
        p: &BuildParams,
        rng: &mut Rng,
    ) -> Option<(u8, f64)> {
        if self.touched.len() < 2 {
            return None;
        }
        let total: Vec<u32> = (0..c)
            .map(|k| self.touched.iter().map(|&b| self.hist[b as usize * c + k]).sum())
            .collect();

        let thr_choice: Option<u8> = match p.mode {
            SplitMode::Best => None,
            SplitMode::Random => {
                // ExtraTrees: a single random cut in [lo, hi).
                let lo = *self.touched.first().unwrap();
                let hi = *self.touched.last().unwrap();
                Some((lo + rng.gen_range((hi - lo) as usize) as u16) as u8)
            }
        };

        let mut left = vec![0u32; c];
        let mut nl: usize;
        let mut best: Option<(u8, f64)> = None;
        for (i, &b) in self.touched.iter().enumerate() {
            if i == self.touched.len() - 1 {
                break;
            }
            let slot = b as usize * c;
            for k in 0..c {
                left[k] += self.hist[slot + k];
            }
            nl = left.iter().map(|&v| v as usize).sum();
            let nr = n - nl;
            if nl < p.min_samples_leaf || nr < p.min_samples_leaf {
                continue;
            }
            let thr = b as u8;
            if let Some(tc) = thr_choice {
                // Random mode: evaluate only at the drawn cut. The drawn
                // cut may fall between occupied bins; the effective split
                // is at the largest occupied bin ≤ tc, which is exactly
                // the boundary we pass through here.
                let next = self.touched[i + 1] as u8;
                if !(thr <= tc && tc < next) {
                    continue;
                }
            }
            let right: Vec<u32> = (0..c).map(|k| total[k] - left[k]).collect();
            let score =
                class_score(&left, nl, p.criterion) + class_score(&right, nr, p.criterion);
            if best.map_or(true, |(_, s)| score > s) {
                best = Some((thr, score));
            }
        }
        best
    }

    /// Regression scan (MSE): score = Σ_child sum²/n_child.
    fn scan_feature_reg(
        &mut self,
        bins: &BinnedData,
        values: &[f32],
        seg: &[u32],
        f: usize,
        p: &BuildParams,
        rng: &mut Rng,
    ) -> Option<(u8, f64)> {
        self.touched.clear();
        for &s in seg {
            let b = bins.bins[s as usize * bins.d + f] as usize;
            if self.hist_cnt[b] == 0 {
                self.touched.push(b as u16);
            }
            self.hist_cnt[b] += 1;
            self.hist_sum[b] += values[s as usize] as f64;
        }
        self.touched.sort_unstable();

        let result = (|| {
            if self.touched.len() < 2 {
                return None;
            }
            let total_sum: f64 = self.touched.iter().map(|&b| self.hist_sum[b as usize]).sum();
            let n = seg.len();

            let thr_choice: Option<u8> = match p.mode {
                SplitMode::Best => None,
                SplitMode::Random => {
                    let lo = *self.touched.first().unwrap();
                    let hi = *self.touched.last().unwrap();
                    Some((lo + rng.gen_range((hi - lo) as usize) as u16) as u8)
                }
            };

            let mut lsum = 0f64;
            let mut ln = 0usize;
            let mut best: Option<(u8, f64)> = None;
            for (i, &b) in self.touched.iter().enumerate() {
                if i == self.touched.len() - 1 {
                    break;
                }
                lsum += self.hist_sum[b as usize];
                ln += self.hist_cnt[b as usize] as usize;
                let rn = n - ln;
                if ln < p.min_samples_leaf || rn < p.min_samples_leaf {
                    continue;
                }
                let thr = b as u8;
                if let Some(tc) = thr_choice {
                    let next = self.touched[i + 1] as u8;
                    if !(thr <= tc && tc < next) {
                        continue;
                    }
                }
                let rsum = total_sum - lsum;
                let score = lsum * lsum / ln as f64 + rsum * rsum / rn as f64;
                if best.map_or(true, |(_, s)| score > s) {
                    best = Some((thr, score));
                }
            }
            best
        })();

        for &b in &self.touched {
            self.hist_cnt[b as usize] = 0;
            self.hist_sum[b as usize] = 0.0;
        }
        result
    }
}

impl Default for TreeBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// "Larger is better" class purity score: Gini ⇒ Σ c²/n,
/// entropy ⇒ Σ c·log(c/n) (= -n·H, so summing children and comparing to
/// the parent value is exactly information gain scaled by n).
fn class_score(counts: &[u32], n: usize, criterion: Criterion) -> f64 {
    if n == 0 {
        return 0.0;
    }
    match criterion {
        Criterion::Gini => {
            let s: f64 = counts.iter().map(|&c| (c as f64) * (c as f64)).sum();
            s / n as f64
        }
        Criterion::Entropy | Criterion::Mse => {
            let nf = n as f64;
            counts
                .iter()
                .filter(|&&c| c > 0)
                .map(|&c| (c as f64) * ((c as f64) / nf).ln())
                .sum()
        }
    }
}

/// In-place partition of a sample segment by `bin[f] <= thr`; returns the
/// split point (count of left samples).
fn partition(bins: &BinnedData, seg: &mut [u32], f: usize, thr: u8) -> usize {
    let d = bins.d;
    let (mut i, mut j) = (0usize, seg.len());
    while i < j {
        if bins.bins[seg[i] as usize * d + f] <= thr {
            i += 1;
        } else {
            j -= 1;
            seg.swap(i, j);
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::forest::Binner;

    fn setup(n: usize, d: usize, c: usize, seed: u64) -> (BinnedData, Vec<u32>) {
        let data = synth::gaussian_blobs(n, d, c, 2.5, seed);
        let binner = Binner::fit(&data, 256, &mut Rng::new(seed));
        let y: Vec<u32> = data.y.iter().map(|&v| v as u32).collect();
        (binner.bin(&data), y)
    }

    fn params() -> BuildParams {
        BuildParams {
            max_depth: usize::MAX,
            min_samples_leaf: 1,
            mtry: 3,
            criterion: Criterion::Gini,
            mode: SplitMode::Best,
            n_bins: 256,
        }
    }

    fn leaf_purity(tree: &Tree, bins: &BinnedData, y: &[u32], c: usize) -> f64 {
        let mut hits = 0usize;
        for i in 0..bins.n {
            let leaf = tree.apply_binned(bins.row(i)) as usize;
            let stats = &tree.leaf_stats[leaf * c..(leaf + 1) * c];
            let pred = stats
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as u32 == y[i] {
                hits += 1;
            }
        }
        hits as f64 / bins.n as f64
    }

    #[test]
    fn hand_built_tree_routes() {
        // root: f0 <= 3 -> leaf0 else (f1 <= 1 -> leaf1 else leaf2)
        let tree = Tree {
            nodes: vec![
                Node { feature: 0, threshold: 3, left: 1, right: 2 },
                Node { feature: LEAF, threshold: 0, left: 0, right: 0 },
                Node { feature: 1, threshold: 1, left: 3, right: 4 },
                Node { feature: LEAF, threshold: 0, left: 1, right: 0 },
                Node { feature: LEAF, threshold: 0, left: 2, right: 0 },
            ],
            n_leaves: 3,
            leaf_stats: vec![],
            depth: 2,
        };
        assert_eq!(tree.apply_binned(&[0, 0]), 0);
        assert_eq!(tree.apply_binned(&[3, 9]), 0);
        assert_eq!(tree.apply_binned(&[4, 0]), 1);
        assert_eq!(tree.apply_binned(&[4, 2]), 2);
    }

    #[test]
    fn fits_separable_blobs_to_purity() {
        let (bins, y) = setup(300, 4, 3, 42);
        let targets = Targets::Classification { y: &y, n_classes: 3 };
        let mut samples: Vec<u32> = (0..300).collect();
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &params(), &mut Rng::new(1));
        assert!(tree.n_leaves >= 3);
        assert!(leaf_purity(&tree, &bins, &y, 3) > 0.98);
    }

    #[test]
    fn entropy_criterion_also_fits() {
        let (bins, y) = setup(300, 4, 3, 43);
        let targets = Targets::Classification { y: &y, n_classes: 3 };
        let mut samples: Vec<u32> = (0..300).collect();
        let mut p = params();
        p.criterion = Criterion::Entropy;
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &p, &mut Rng::new(1));
        assert!(leaf_purity(&tree, &bins, &y, 3) > 0.98);
    }

    #[test]
    fn random_mode_builds_working_tree() {
        let (bins, y) = setup(400, 4, 2, 44);
        let targets = Targets::Classification { y: &y, n_classes: 2 };
        let mut samples: Vec<u32> = (0..400).collect();
        let mut p = params();
        p.mode = SplitMode::Random;
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &p, &mut Rng::new(2));
        assert!(leaf_purity(&tree, &bins, &y, 2) > 0.9);
    }

    #[test]
    fn depth_limit_enforced() {
        let (bins, y) = setup(500, 4, 3, 45);
        let targets = Targets::Classification { y: &y, n_classes: 3 };
        let mut samples: Vec<u32> = (0..500).collect();
        let mut p = params();
        p.max_depth = 2;
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &p, &mut Rng::new(3));
        assert!(tree.depth <= 2);
        assert!(tree.n_leaves <= 4);
    }

    #[test]
    fn min_leaf_enforced() {
        let (bins, y) = setup(400, 4, 2, 46);
        let targets = Targets::Classification { y: &y, n_classes: 2 };
        let mut samples: Vec<u32> = (0..400).collect();
        let mut p = params();
        p.min_samples_leaf = 30;
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &p, &mut Rng::new(4));
        let mut counts = vec![0usize; tree.n_leaves];
        for i in 0..400 {
            counts[tree.apply_binned(bins.row(i)) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c >= 30), "{counts:?}");
    }

    #[test]
    fn regression_tree_reduces_sse() {
        let n = 300;
        let data = synth::gaussian_blobs(n, 3, 2, 3.0, 47);
        let binner = Binner::fit(&data, 256, &mut Rng::new(5));
        let bins = binner.bin(&data);
        // Target = class label as a real value: perfectly learnable.
        let vals: Vec<f32> = data.y.clone();
        let targets = Targets::Regression { values: &vals };
        let mut samples: Vec<u32> = (0..n as u32).collect();
        let mut p = params();
        p.criterion = Criterion::Mse;
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &p, &mut Rng::new(6));
        let sse: f64 = (0..n)
            .map(|i| {
                let leaf = tree.apply_binned(bins.row(i)) as usize;
                let e = (tree.leaf_stats[leaf] - vals[i]) as f64;
                e * e
            })
            .sum();
        assert!(sse / (n as f64) < 0.05, "mse={}", sse / n as f64);
    }

    #[test]
    fn leaf_ids_are_dense() {
        let (bins, y) = setup(200, 3, 2, 48);
        let targets = Targets::Classification { y: &y, n_classes: 2 };
        let mut samples: Vec<u32> = (0..200).collect();
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &params(), &mut Rng::new(7));
        let mut seen = vec![false; tree.n_leaves];
        for node in &tree.nodes {
            if node.feature == LEAF {
                seen[node.left as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // Classification stats have n_leaves * C entries.
        assert_eq!(tree.leaf_stats.len(), tree.n_leaves * 2);
    }

    #[test]
    fn multiset_duplicates_weight_leaves() {
        let (bins, y) = setup(100, 3, 2, 49);
        let targets = Targets::Classification { y: &y, n_classes: 2 };
        // Sample 0 drawn 5 times.
        let mut samples: Vec<u32> = (0..100).collect();
        samples.extend([0, 0, 0, 0]);
        let mut b = TreeBuilder::new();
        let tree = b.build(&bins, &targets, &mut samples, &params(), &mut Rng::new(8));
        let total: f32 = tree.leaf_stats.iter().sum();
        assert_eq!(total, 104.0);
    }
}
