//! Quantile feature binning (the histogram trick).
//!
//! Features are discretized once into ≤256 quantile bins; trees then
//! split on bin ids, making split search `O(node + bins·classes)` per
//! feature instead of `O(node·log node)`. Bin edges are estimated from a
//! subsample and stored so that test/OOS samples bin identically.

use crate::data::Dataset;
use crate::rng::Rng;

/// Per-feature quantile bin edges. A value `v` maps to
/// `#edges ≤ v` — i.e. edges are *right-inclusive* cut points.
pub struct Binner {
    pub edges: Vec<Vec<f32>>,
    pub n_bins: usize,
}

/// A dataset with features discretized to `u8` bin ids, row-major.
pub struct BinnedData {
    pub bins: Vec<u8>,
    pub n: usize,
    pub d: usize,
}

impl BinnedData {
    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        &self.bins[i * self.d..(i + 1) * self.d]
    }
}

/// Subsample size used to estimate quantiles.
const QUANTILE_SAMPLE: usize = 50_000;

impl Binner {
    /// Estimate per-feature quantile edges from (a subsample of) `data`.
    pub fn fit(data: &Dataset, n_bins: usize, rng: &mut Rng) -> Binner {
        assert!((2..=256).contains(&n_bins));
        let take = data.n.min(QUANTILE_SAMPLE);
        let idx: Vec<usize> = if take == data.n {
            (0..data.n).collect()
        } else {
            rng.sample_indices(data.n, take)
        };
        let mut edges = Vec::with_capacity(data.d);
        let mut col = Vec::with_capacity(take);
        for f in 0..data.d {
            col.clear();
            col.extend(idx.iter().map(|&i| data.x(i, f)));
            col.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut cuts: Vec<f32> = Vec::with_capacity(n_bins - 1);
            for b in 1..n_bins {
                let pos = b * (col.len() - 1) / n_bins;
                let c = col[pos];
                if cuts.last().map_or(true, |&l| c > l) {
                    cuts.push(c);
                }
            }
            edges.push(cuts);
        }
        Binner { edges, n_bins }
    }

    /// Bin id of value `v` for feature `f`: count of edges ≤ v.
    #[inline]
    pub fn bin_value(&self, f: usize, v: f32) -> u8 {
        let e = &self.edges[f];
        // Branchless-ish binary search: first index with edge > v.
        let mut lo = 0usize;
        let mut hi = e.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if e[mid] <= v {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as u8
    }

    /// Discretize a whole dataset.
    pub fn bin(&self, data: &Dataset) -> BinnedData {
        assert_eq!(data.d, self.edges.len());
        let mut bins = vec![0u8; data.n * data.d];
        for i in 0..data.n {
            let dst = &mut bins[i * data.d..(i + 1) * data.d];
            for f in 0..data.d {
                dst[f] = self.bin_value(f, data.x(i, f));
            }
        }
        BinnedData { bins, n: data.n, d: data.d }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn bins_are_monotone_in_value() {
        let data = synth::gaussian_blobs(500, 3, 2, 2.0, 42);
        let b = Binner::fit(&data, 64, &mut Rng::new(1));
        for f in 0..3 {
            assert!(b.bin_value(f, -100.0) <= b.bin_value(f, 0.0));
            assert!(b.bin_value(f, 0.0) <= b.bin_value(f, 100.0));
        }
    }

    #[test]
    fn bin_ids_bounded() {
        let data = synth::gaussian_blobs(300, 4, 3, 2.0, 7);
        let b = Binner::fit(&data, 32, &mut Rng::new(2));
        let binned = b.bin(&data);
        assert!(binned.bins.iter().all(|&v| (v as usize) < 32));
    }

    #[test]
    fn constant_feature_single_bin() {
        let mut data = synth::gaussian_blobs(100, 2, 2, 2.0, 3);
        for i in 0..data.n {
            let j = i * data.d;
            data.x[j] = 5.0; // make feature 0 constant
        }
        let b = Binner::fit(&data, 16, &mut Rng::new(3));
        let binned = b.bin(&data);
        let first: Vec<u8> = (0..data.n).map(|i| binned.row(i)[0]).collect();
        assert!(first.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn edges_strictly_increasing() {
        let data = synth::gaussian_blobs(1000, 5, 4, 2.0, 9);
        let b = Binner::fit(&data, 256, &mut Rng::new(4));
        for e in &b.edges {
            for w in e.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn same_value_same_bin_train_and_test() {
        let train = synth::gaussian_blobs(400, 3, 2, 2.0, 11);
        let b = Binner::fit(&train, 128, &mut Rng::new(5));
        for v in [-3.0f32, -0.5, 0.0, 0.5, 3.0] {
            let a = b.bin_value(1, v);
            let c = b.bin_value(1, v);
            assert_eq!(a, c);
        }
    }
}
