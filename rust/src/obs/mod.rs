//! Process-wide observability plane: metrics registry + tracing spans.
//!
//! Zero-dependency (std only). Three metric kinds backed by atomics —
//! [`Counter`], [`Gauge`], [`Histogram`] — live in a global registry
//! keyed by `(name, labels)` and render as Prometheus text-exposition
//! format via [`render_prometheus`] (served at `GET /metrics` by both
//! the server and the router). [`parse_prometheus`] is the matching
//! strict parser — it doubles as the exposition-format lint run by CI —
//! and [`merge_prometheus`] folds several replica scrapes into one
//! fleet view (counters and histograms sum; gauges stay per-replica
//! behind a `backend` label).
//!
//! Every instrumentation point built on this module must be
//! bitwise-invisible to computed outputs: handles only read clocks and
//! bump atomics outside compute loops, never reorder work or touch
//! float accumulation order (`tests/obs.rs` asserts traced runs are
//! byte-identical to untraced ones).
//!
//! Structured tracing (spans, events, the `/debug/trace` ring and the
//! `--trace FILE` JSONL sink) lives in [`trace`]; the common entry
//! points are re-exported here as [`span`] and [`event`].

pub mod trace;

pub use trace::{
    event, event_logged, flush_trace, recent_events_json, span, span_with, trace_file_enabled,
    trace_to_file, Kv, SpanGuard,
};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

use crate::bail;
use crate::bench_support::json_escape;
use crate::error::Result;

/// Monotonically increasing integer metric. `_seconds_total` counters
/// accumulate nanoseconds via [`Counter::add`] and are scaled to
/// seconds at render time (see [`counter_secs`]).
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Accumulate an elapsed duration in nanoseconds (pair with
    /// [`counter_secs`] so the rendered value is in seconds).
    pub fn add_nanos(&self, d: std::time::Duration) {
        self.v.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins float metric (f64 bits in an `AtomicU64`).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram: non-cumulative bucket counts internally,
/// rendered cumulatively with `_sum` and `_count` per Prometheus
/// convention. `observe` is a couple of relaxed atomic ops plus one
/// CAS loop for the f64 sum — safe on request/stripe granularity.
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        let i = self.bounds.partition_point(|b| v > *b);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }
}

/// Latency bucket bounds (seconds) shared by the request and tier
/// histograms: 250µs .. 10s, roughly geometric.
pub const LATENCY_BUCKETS: &[f64] = &[
    0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
];

/// Queue-depth bucket bounds (items).
pub const DEPTH_BUCKETS: &[f64] = &[
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 4096.0,
];

enum MetricRef {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

impl MetricRef {
    fn type_name(&self) -> &'static str {
        match self {
            MetricRef::Counter(_) => "counter",
            MetricRef::Gauge(_) => "gauge",
            MetricRef::Histogram(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    help: String,
    labels: Vec<(String, String)>,
    /// Multiplier applied to counter values at render time (1e-9 turns
    /// accumulated nanoseconds into seconds; 1.0 renders the raw count).
    scale: f64,
    metric: MetricRef,
}

fn registry() -> &'static Mutex<Vec<Entry>> {
    static REG: OnceLock<Mutex<Vec<Entry>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

/// Lock the registry, recovering from poisoning *silently*: the
/// entries are append-only handle records, valid after any panic.
/// This must not go through [`lock_recover`] — that helper registers
/// a metric, which locks the registry, which would recurse right
/// back here.
fn reg_lock() -> MutexGuard<'static, Vec<Entry>> {
    registry().lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Lock a mutex, recovering the guard if a previous holder panicked.
/// For shared serve/obs state whose contents stay valid across a
/// panic (plain counters, caches, reservoir rings): the replica must
/// degrade, not die, so poisoning is recorded — the
/// `fk_lock_poisoned_total` counter plus a `lock.poisoned` trace
/// event — and the guard is handed back.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| {
        note_poisoned();
        poisoned.into_inner()
    })
}

/// [`lock_recover`] for `RwLock` read guards.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| {
        note_poisoned();
        poisoned.into_inner()
    })
}

/// [`lock_recover`] for `RwLock` write guards.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| {
        note_poisoned();
        poisoned.into_inner()
    })
}

fn note_poisoned() {
    crate::metric!(
        counter "fk_lock_poisoned_total",
        "Poisoned shared-state locks recovered instead of panicking."
    )
    .inc();
    event_logged("lock.poisoned", Vec::new());
}

/// An opaque monotonic timer. Kernel-math modules are forbidden (by
/// fk-lint's `determinism` rule) from naming `Instant::now` — timing
/// is an observability concern — so instrumentation there starts one
/// of these instead.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

/// Start a [`Stopwatch`].
pub fn stopwatch() -> Stopwatch {
    Stopwatch(Instant::now())
}

fn process_start() -> Instant {
    static T0: OnceLock<Instant> = OnceLock::new();
    *T0.get_or_init(Instant::now)
}

/// Pin the uptime origin. Called from `main` and from `Server::bind` /
/// `Router::bind` so `fk_uptime_seconds` measures from process (or at
/// worst server) start rather than from the first scrape.
pub fn init() {
    process_start();
}

/// Seconds since [`init`] (or since the first observability touch).
pub fn uptime_secs() -> f64 {
    process_start().elapsed().as_secs_f64()
}

/// Crate version baked in at compile time.
pub fn build_version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Git revision, when the build environment provides `FK_GIT_SHA`
/// (CI exports it; local builds report "unknown").
pub fn build_sha() -> &'static str {
    option_env!("FK_GIT_SHA").unwrap_or("unknown")
}

fn owned_labels(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    let mut v: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    v.sort();
    v
}

fn lookup_or_insert(
    name: &str,
    help: &str,
    labels: &[(&str, &str)],
    scale: f64,
    make: impl FnOnce() -> MetricRef,
) -> MetricRef {
    let labels = owned_labels(labels);
    let mut reg = reg_lock();
    if let Some(e) = reg.iter().find(|e| e.name == name && e.labels == labels) {
        return match e.metric {
            MetricRef::Counter(c) => MetricRef::Counter(c),
            MetricRef::Gauge(g) => MetricRef::Gauge(g),
            MetricRef::Histogram(h) => MetricRef::Histogram(h),
        };
    }
    let metric = make();
    let copy = match metric {
        MetricRef::Counter(c) => MetricRef::Counter(c),
        MetricRef::Gauge(g) => MetricRef::Gauge(g),
        MetricRef::Histogram(h) => MetricRef::Histogram(h),
    };
    reg.push(Entry {
        name: name.to_string(),
        help: help.to_string(),
        labels,
        scale,
        metric,
    });
    copy
}

/// Register (or fetch) a counter with no labels.
pub fn counter(name: &str, help: &str) -> &'static Counter {
    counter_with(name, help, &[])
}

/// Register (or fetch) a labelled counter. Re-registration with the
/// same `(name, labels)` returns the existing handle, so call sites
/// may cache the result in a `OnceLock` or call through every time.
pub fn counter_with(name: &str, help: &str, labels: &[(&str, &str)]) -> &'static Counter {
    counter_scaled(name, help, labels, 1.0)
}

/// Register a counter that accumulates nanoseconds (via
/// [`Counter::add_nanos`]) and renders as seconds.
pub fn counter_secs(name: &str, help: &str, labels: &[(&str, &str)]) -> &'static Counter {
    counter_scaled(name, help, labels, 1e-9)
}

fn counter_scaled(name: &str, help: &str, labels: &[(&str, &str)], scale: f64) -> &'static Counter {
    match lookup_or_insert(name, help, labels, scale, || {
        MetricRef::Counter(Box::leak(Box::new(Counter {
            v: AtomicU64::new(0),
        })))
    }) {
        MetricRef::Counter(c) => c,
        other => panic!("metric {name} already registered as {}", other.type_name()),
    }
}

/// Register (or fetch) a labelled gauge.
pub fn gauge_with(name: &str, help: &str, labels: &[(&str, &str)]) -> &'static Gauge {
    match lookup_or_insert(name, help, labels, 1.0, || {
        MetricRef::Gauge(Box::leak(Box::new(Gauge {
            bits: AtomicU64::new(0f64.to_bits()),
        })))
    }) {
        MetricRef::Gauge(g) => g,
        other => panic!("metric {name} already registered as {}", other.type_name()),
    }
}

/// Register (or fetch) a labelled fixed-bucket histogram.
pub fn histogram_with(
    name: &str,
    help: &str,
    labels: &[(&str, &str)],
    bounds: &[f64],
) -> &'static Histogram {
    match lookup_or_insert(name, help, labels, 1.0, || {
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        MetricRef::Histogram(Box::leak(Box::new(Histogram {
            bounds: bounds.to_vec(),
            buckets,
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        })))
    }) {
        MetricRef::Histogram(h) => h,
        other => panic!("metric {name} already registered as {}", other.type_name()),
    }
}

/// Register a metric handle once per call site: `metric!(counter NAME,
/// HELP)`, `metric!(counter_secs NAME, HELP)`, `metric!(gauge NAME,
/// HELP)` or `metric!(histogram NAME, HELP, BOUNDS)`. Expands to a
/// `OnceLock`-cached `&'static` handle so hot paths skip the registry
/// mutex after first use. Labelled variants take the label slice last.
#[macro_export]
macro_rules! metric {
    (counter $name:expr, $help:expr) => {{
        static M: std::sync::OnceLock<&'static $crate::obs::Counter> = std::sync::OnceLock::new();
        *M.get_or_init(|| $crate::obs::counter($name, $help))
    }};
    (counter $name:expr, $help:expr, $labels:expr) => {{
        static M: std::sync::OnceLock<&'static $crate::obs::Counter> = std::sync::OnceLock::new();
        *M.get_or_init(|| $crate::obs::counter_with($name, $help, $labels))
    }};
    (counter_secs $name:expr, $help:expr) => {{
        static M: std::sync::OnceLock<&'static $crate::obs::Counter> = std::sync::OnceLock::new();
        *M.get_or_init(|| $crate::obs::counter_secs($name, $help, &[]))
    }};
    (gauge $name:expr, $help:expr) => {{
        static M: std::sync::OnceLock<&'static $crate::obs::Gauge> = std::sync::OnceLock::new();
        *M.get_or_init(|| $crate::obs::gauge_with($name, $help, &[]))
    }};
    (histogram $name:expr, $help:expr, $bounds:expr) => {{
        static M: std::sync::OnceLock<&'static $crate::obs::Histogram> =
            std::sync::OnceLock::new();
        *M.get_or_init(|| $crate::obs::histogram_with($name, $help, &[], $bounds))
    }};
    (histogram $name:expr, $help:expr, $bounds:expr, $labels:expr) => {{
        static M: std::sync::OnceLock<&'static $crate::obs::Histogram> =
            std::sync::OnceLock::new();
        *M.get_or_init(|| $crate::obs::histogram_with($name, $help, $labels, $bounds))
    }};
}

fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf" } else { "-Inf" }.to_string()
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn render_entry(out: &mut String, e: &Entry) {
    match e.metric {
        MetricRef::Counter(c) => {
            let v = if e.scale == 1.0 {
                fmt_value(c.get() as f64)
            } else {
                fmt_value(c.get() as f64 * e.scale)
            };
            out.push_str(&format!("{}{} {v}\n", e.name, label_block(&e.labels, None)));
        }
        MetricRef::Gauge(g) => {
            out.push_str(&format!(
                "{}{} {}\n",
                e.name,
                label_block(&e.labels, None),
                fmt_value(g.get())
            ));
        }
        MetricRef::Histogram(h) => {
            let mut cum = 0u64;
            for (i, b) in h.bounds.iter().enumerate() {
                cum += h.buckets[i].load(Ordering::Relaxed);
                let le = fmt_value(*b);
                out.push_str(&format!(
                    "{}_bucket{} {cum}\n",
                    e.name,
                    label_block(&e.labels, Some(("le", le.as_str())))
                ));
            }
            cum += h.buckets[h.bounds.len()].load(Ordering::Relaxed);
            out.push_str(&format!(
                "{}_bucket{} {cum}\n",
                e.name,
                label_block(&e.labels, Some(("le", "+Inf")))
            ));
            out.push_str(&format!(
                "{}_sum{} {}\n",
                e.name,
                label_block(&e.labels, None),
                fmt_value(h.sum())
            ));
            out.push_str(&format!(
                "{}_count{} {}\n",
                e.name,
                label_block(&e.labels, None),
                h.count()
            ));
        }
    }
}

/// Render the whole registry as Prometheus text-exposition format.
/// Families are grouped under one `# HELP` / `# TYPE` pair in first
/// registration order; `fk_uptime_seconds` and `fk_build_info` are
/// refreshed on every render.
pub fn render_prometheus() -> String {
    gauge_with("fk_uptime_seconds", "Seconds since process start.", &[]).set(uptime_secs());
    gauge_with(
        "fk_build_info",
        "Build metadata; value is always 1.",
        &[("version", build_version()), ("git_sha", build_sha())],
    )
    .set(1.0);
    let reg = reg_lock();
    let mut out = String::new();
    let mut seen: Vec<&str> = Vec::new();
    for e in reg.iter() {
        if seen.contains(&e.name.as_str()) {
            continue;
        }
        seen.push(&e.name);
        out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
        out.push_str(&format!("# TYPE {} {}\n", e.name, e.metric.type_name()));
        for same in reg.iter().filter(|s| s.name == e.name) {
            render_entry(&mut out, same);
        }
    }
    out
}

/// One sample line of a parsed scrape.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// A parsed `/metrics` payload: samples in document order plus the
/// declared family types (`name -> counter|gauge|histogram|...`).
#[derive(Debug, Default)]
pub struct Scrape {
    pub samples: Vec<Sample>,
    pub types: Vec<(String, String)>,
    pub helps: Vec<(String, String)>,
}

impl Scrape {
    pub fn type_of(&self, family: &str) -> Option<&str> {
        self.types
            .iter()
            .find(|(n, _)| n == family)
            .map(|(_, t)| t.as_str())
    }

    /// Family name a sample belongs to: histogram series `x_bucket`,
    /// `x_sum`, `x_count` all roll up to `x`.
    pub fn family_of(&self, sample_name: &str) -> String {
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = sample_name.strip_suffix(suffix) {
                if self.type_of(base) == Some("histogram") {
                    return base.to_string();
                }
            }
        }
        sample_name.to_string()
    }

    /// Sum of all samples matching `name` and containing `labels` as a
    /// subset (test + merge helper).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        self.samples
            .iter()
            .filter(|s| {
                s.name == name
                    && labels
                        .iter()
                        .all(|(k, v)| s.labels.iter().any(|(sk, sv)| sk == k && sv == v))
            })
            .map(|s| s.value)
            .sum()
    }
}

/// The Prometheus metric-name grammar [`parse_prometheus`] enforces on
/// scrapes. Public so fk-lint's `metric-hygiene` rule checks
/// registration-site literals against the *same* predicate.
pub fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Prometheus label-name grammar; public for the same reason as
/// [`valid_metric_name`].
pub fn valid_label_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_labels(block: &str, line_no: usize) -> Result<Vec<(String, String)>> {
    let mut labels = Vec::new();
    let mut rest = block;
    loop {
        rest = rest.trim_start_matches(|c: char| c == ',' || c == ' ');
        if rest.is_empty() {
            return Ok(labels);
        }
        let eq = match rest.find('=') {
            Some(i) => i,
            None => bail!("line {line_no}: label without '='"),
        };
        let key = rest[..eq].trim();
        if !valid_label_name(key) {
            bail!("line {line_no}: bad label name {key:?}");
        }
        rest = &rest[eq + 1..];
        if !rest.starts_with('"') {
            bail!("line {line_no}: label value for {key} not quoted");
        }
        rest = &rest[1..];
        let mut val = String::new();
        let mut chars = rest.char_indices();
        let mut end = None;
        while let Some((i, c)) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some((_, 'n')) => val.push('\n'),
                    Some((_, '\\')) => val.push('\\'),
                    Some((_, '"')) => val.push('"'),
                    _ => bail!("line {line_no}: bad escape in label value"),
                },
                '"' => {
                    end = Some(i);
                    break;
                }
                c => val.push(c),
            }
        }
        let end = match end {
            Some(i) => i,
            None => bail!("line {line_no}: unterminated label value"),
        };
        labels.push((key.to_string(), val));
        rest = &rest[end + 1..];
    }
}

/// Strict parser / lint for Prometheus text-exposition format. Rejects
/// malformed comment lines, bad metric or label names, unquoted label
/// values, unparsable sample values, and samples whose family has no
/// preceding `# TYPE` declaration. CI runs this over live scrapes of
/// both the server and the router.
pub fn parse_prometheus(text: &str) -> Result<Scrape> {
    let mut scrape = Scrape::default();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            let comment = comment.trim_start();
            if let Some(rest) = comment.strip_prefix("HELP ") {
                let (name, help) = match rest.split_once(' ') {
                    Some(p) => p,
                    None => bail!("line {line_no}: HELP without text"),
                };
                if !valid_metric_name(name) {
                    bail!("line {line_no}: bad metric name {name:?} in HELP");
                }
                scrape.helps.push((name.to_string(), help.to_string()));
            } else if let Some(rest) = comment.strip_prefix("TYPE ") {
                let (name, ty) = match rest.split_once(' ') {
                    Some(p) => p,
                    None => bail!("line {line_no}: TYPE without a type"),
                };
                if !valid_metric_name(name) {
                    bail!("line {line_no}: bad metric name {name:?} in TYPE");
                }
                match ty {
                    "counter" | "gauge" | "histogram" | "summary" | "untyped" => {}
                    other => bail!("line {line_no}: unknown metric type {other:?}"),
                }
                scrape.types.push((name.to_string(), ty.to_string()));
            } else {
                bail!("line {line_no}: comment is neither HELP nor TYPE: {line:?}");
            }
            continue;
        }
        // Sample: name[{labels}] value [timestamp]
        let (name_part, rest) = match line.find(|c| c == '{' || c == ' ') {
            Some(i) => (&line[..i], &line[i..]),
            None => bail!("line {line_no}: sample without value: {line:?}"),
        };
        if !valid_metric_name(name_part) {
            bail!("line {line_no}: bad metric name {name_part:?}");
        }
        let (labels, value_part) = if let Some(body) = rest.strip_prefix('{') {
            let close = match body.find('}') {
                Some(i) => i,
                None => bail!("line {line_no}: unterminated label block"),
            };
            (parse_labels(&body[..close], line_no)?, &body[close + 1..])
        } else {
            (Vec::new(), rest)
        };
        let mut fields = value_part.split_whitespace();
        let value_str = match fields.next() {
            Some(v) => v,
            None => bail!("line {line_no}: sample without value: {line:?}"),
        };
        let value = match value_str {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            "NaN" => f64::NAN,
            v => match v.parse::<f64>() {
                Ok(x) => x,
                Err(_) => bail!("line {line_no}: bad sample value {v:?}"),
            },
        };
        if let Some(ts) = fields.next() {
            if ts.parse::<i64>().is_err() {
                bail!("line {line_no}: bad timestamp {ts:?}");
            }
        }
        if fields.next().is_some() {
            bail!("line {line_no}: trailing garbage after sample");
        }
        scrape.samples.push(Sample {
            name: name_part.to_string(),
            labels,
            value,
        });
        let family = scrape.family_of(name_part);
        if scrape.type_of(&family).is_none() {
            bail!("line {line_no}: sample {name_part} has no preceding # TYPE {family}");
        }
    }
    Ok(scrape)
}

/// Merge replica scrapes into one fleet view. Counters and histogram
/// series sum across backends by `(name, labels)`; gauges (and untyped
/// samples) are kept per-replica with an added `backend="<label>"`
/// label. Family order follows first appearance across the scrapes,
/// and the output re-parses cleanly under [`parse_prometheus`].
pub fn merge_prometheus(scrapes: &[(String, Scrape)]) -> String {
    // family -> (type, help) from the first scrape that declares it.
    let mut families: Vec<(String, String, String)> = Vec::new();
    for (_, s) in scrapes {
        for (name, ty) in &s.types {
            if !families.iter().any(|(n, _, _)| n == name) {
                let help = s
                    .helps
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, h)| h.clone())
                    .unwrap_or_else(|| "merged by router".to_string());
                families.push((name.clone(), ty.clone(), help));
            }
        }
    }
    let mut out = String::new();
    for (family, ty, help) in &families {
        out.push_str(&format!("# HELP {family} {help}\n"));
        out.push_str(&format!("# TYPE {family} {ty}\n"));
        let summed = matches!(ty.as_str(), "counter" | "histogram");
        if summed {
            // (sample name, labels) -> summed value, first-seen order.
            let mut acc: Vec<(String, Vec<(String, String)>, f64)> = Vec::new();
            for (_, s) in scrapes {
                for sample in s.samples.iter().filter(|x| s.family_of(&x.name) == *family) {
                    match acc
                        .iter_mut()
                        .find(|(n, l, _)| *n == sample.name && *l == sample.labels)
                    {
                        Some(slot) => slot.2 += sample.value,
                        None => acc.push((sample.name.clone(), sample.labels.clone(), sample.value)),
                    }
                }
            }
            for (name, labels, value) in acc {
                out.push_str(&format!(
                    "{name}{} {}\n",
                    label_block(&labels, None),
                    fmt_value(value)
                ));
            }
        } else {
            for (backend, s) in scrapes {
                for sample in s.samples.iter().filter(|x| s.family_of(&x.name) == *family) {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        sample.name,
                        label_block(&sample.labels, Some(("backend", backend))),
                        fmt_value(sample.value)
                    ));
                }
            }
        }
    }
    out
}

/// Fresh process-unique request id: `<pid hex>-<epoch-nanos hex>-<seq
/// hex>`. Stamped on ingress whenever a request arrives without an
/// `x-request-id` header.
pub fn next_request_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    static ORIGIN: OnceLock<(u32, u64)> = OnceLock::new();
    let (pid, t0) = *ORIGIN.get_or_init(|| {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        (std::process::id(), nanos)
    });
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("{pid:x}-{:x}-{seq:x}", t0 & 0xffff_ffff_ffff)
}

/// `true` when `id` is safe to echo back in a response header / JSON
/// body: printable ASCII, no separators that could split a header.
pub fn valid_request_id(id: &str) -> bool {
    !id.is_empty() && id.len() <= 128 && id.bytes().all(|b| (0x21..=0x7e).contains(&b))
}

/// Emit the slow-query log record: a structured `http.slow` event in
/// the trace ring / `--trace` sink and one JSONL line on stderr so the
/// operator sees tail latency without tracing enabled.
pub fn slow_query(request_id: &str, endpoint: &str, status: u16, tier: Option<&str>, secs: f64) {
    metric!(
        counter "fk_slow_queries_total",
        "Requests slower than --slow-ms."
    )
    .inc();
    let mut kvs = vec![
        ("request_id", Kv::from(request_id)),
        ("endpoint", Kv::from(endpoint)),
        ("status", Kv::from(status as u64)),
        ("ms", Kv::from(secs * 1e3)),
    ];
    if let Some(t) = tier {
        kvs.push(("tier", Kv::from(t)));
    }
    event_logged("http.slow", kvs);
}

/// JSON string (with quotes) — re-exported escape helper for obs call
/// sites that render ids or paths into JSONL events.
pub fn json_str(s: &str) -> String {
    json_escape(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram_render_and_reparse() {
        let c = counter_with("obs_test_requests_total", "test counter", &[("endpoint", "x")]);
        c.add(3);
        let g = gauge_with("obs_test_depth", "test gauge", &[]);
        g.set(2.5);
        let h = histogram_with("obs_test_latency_seconds", "test hist", &[], &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        let text = render_prometheus();
        let scrape = parse_prometheus(&text).expect("self-render must pass the lint");
        assert_eq!(
            scrape.value("obs_test_requests_total", &[("endpoint", "x")]),
            3.0
        );
        assert_eq!(scrape.value("obs_test_depth", &[]), 2.5);
        assert_eq!(
            scrape.value("obs_test_latency_seconds_bucket", &[("le", "0.1")]),
            1.0
        );
        assert_eq!(
            scrape.value("obs_test_latency_seconds_bucket", &[("le", "+Inf")]),
            3.0
        );
        assert_eq!(scrape.value("obs_test_latency_seconds_count", &[]), 3.0);
        assert!((scrape.value("obs_test_latency_seconds_sum", &[]) - 5.55).abs() < 1e-9);
        assert_eq!(scrape.type_of("obs_test_requests_total"), Some("counter"));
        assert_eq!(scrape.type_of("obs_test_latency_seconds"), Some("histogram"));
        assert!(scrape.value("fk_build_info", &[("version", build_version())]) == 1.0);
    }

    #[test]
    fn registration_is_idempotent() {
        let a = counter("obs_test_idem_total", "x");
        let b = counter("obs_test_idem_total", "x");
        a.inc();
        b.inc();
        assert_eq!(a.get(), b.get());
        assert_eq!(a.get(), 2);
    }

    #[test]
    fn lint_rejects_malformed_exposition() {
        assert!(parse_prometheus("# BOGUS comment\n").is_err());
        assert!(parse_prometheus("# TYPE x wibble\nx 1\n").is_err());
        assert!(parse_prometheus("no_type_declared 1\n").is_err());
        assert!(parse_prometheus("# TYPE m counter\nm{l=unquoted} 1\n").is_err());
        assert!(parse_prometheus("# TYPE m counter\nm{9bad=\"v\"} 1\n").is_err());
        assert!(parse_prometheus("# TYPE m counter\nm not_a_number\n").is_err());
        assert!(parse_prometheus("# TYPE m counter\nm 1 2 3\n").is_err());
        let ok = parse_prometheus("# HELP m help text\n# TYPE m counter\nm{a=\"b\\\"c\"} 4\n")
            .unwrap();
        assert_eq!(ok.value("m", &[("a", "b\"c")]), 4.0);
    }

    #[test]
    fn merge_sums_counters_and_labels_gauges() {
        let a = parse_prometheus(
            "# HELP r reqs\n# TYPE r counter\nr{endpoint=\"p\"} 2\n# TYPE d gauge\nd 5\n\
             # TYPE h histogram\nh_bucket{le=\"+Inf\"} 2\nh_sum 0.5\nh_count 2\n",
        )
        .unwrap();
        let b = parse_prometheus(
            "# HELP r reqs\n# TYPE r counter\nr{endpoint=\"p\"} 3\n# TYPE d gauge\nd 7\n\
             # TYPE h histogram\nh_bucket{le=\"+Inf\"} 1\nh_sum 0.25\nh_count 1\n",
        )
        .unwrap();
        let merged = merge_prometheus(&[("b0".to_string(), a), ("b1".to_string(), b)]);
        let scrape = parse_prometheus(&merged).expect("merged output must re-parse");
        assert_eq!(scrape.value("r", &[("endpoint", "p")]), 5.0);
        assert_eq!(scrape.value("d", &[("backend", "b0")]), 5.0);
        assert_eq!(scrape.value("d", &[("backend", "b1")]), 7.0);
        assert_eq!(scrape.value("h_bucket", &[("le", "+Inf")]), 3.0);
        assert_eq!(scrape.value("h_count", &[]), 3.0);
        assert!((scrape.value("h_sum", &[]) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn request_ids_are_unique_and_valid() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
        assert!(valid_request_id(&a));
        assert!(!valid_request_id(""));
        assert!(!valid_request_id("has space"));
        assert!(!valid_request_id("crlf\r\ninjection"));
        assert!(!valid_request_id(&"x".repeat(200)));
    }
}
