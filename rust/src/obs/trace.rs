//! Structured tracing: spans and point events rendered as JSONL.
//!
//! Every event lands in a bounded in-memory ring (served at
//! `GET /debug/trace`) and, when `--trace FILE` armed the file sink via
//! [`trace_to_file`], is appended to the file as one JSON object per
//! line. Span records carry the span id, the parent span id (from a
//! thread-local stack, so nested spans on one thread link up), the
//! start timestamp, duration, and free-form key-values; point events
//! carry the same minus duration.
//!
//! Emission is a clock read plus one short mutex push per event —
//! spans are placed at coarse units only (a stripe, a reload, a slow
//! request), never inside compute loops, so tracing stays
//! bitwise-invisible to computed outputs.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::bench_support::json_escape;
use crate::error::{Context, Result};

/// Number of recent events retained for `GET /debug/trace`.
const RING_CAP: usize = 256;

/// A key-value payload value: numbers render bare, strings render
/// JSON-escaped. Build lists with the [`crate::kv!`] macro.
#[derive(Debug, Clone)]
pub enum Kv {
    U(u64),
    I(i64),
    F(f64),
    S(String),
}

impl Kv {
    fn render(&self) -> String {
        match self {
            Kv::U(v) => v.to_string(),
            Kv::I(v) => v.to_string(),
            Kv::F(v) => {
                if v.is_finite() {
                    format!("{v}")
                } else {
                    json_escape(&v.to_string())
                }
            }
            Kv::S(s) => json_escape(s),
        }
    }
}

impl From<u64> for Kv {
    fn from(v: u64) -> Self {
        Kv::U(v)
    }
}

impl From<u32> for Kv {
    fn from(v: u32) -> Self {
        Kv::U(v as u64)
    }
}

impl From<usize> for Kv {
    fn from(v: usize) -> Self {
        Kv::U(v as u64)
    }
}

impl From<i64> for Kv {
    fn from(v: i64) -> Self {
        Kv::I(v)
    }
}

impl From<f64> for Kv {
    fn from(v: f64) -> Self {
        Kv::F(v)
    }
}

impl From<&str> for Kv {
    fn from(v: &str) -> Self {
        Kv::S(v.to_string())
    }
}

impl From<String> for Kv {
    fn from(v: String) -> Self {
        Kv::S(v)
    }
}

/// Build a key-value list for [`span`] / [`event`]:
/// `kv! { rows: 512, sink: "csr" }`.
#[macro_export]
macro_rules! kv {
    { $($k:ident : $v:expr),* $(,)? } => {
        vec![ $( (stringify!($k), $crate::obs::Kv::from($v)) ),* ]
    };
}

fn ring() -> &'static Mutex<VecDeque<String>> {
    static RING: OnceLock<Mutex<VecDeque<String>>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(VecDeque::with_capacity(RING_CAP)))
}

static FILE_ON: AtomicBool = AtomicBool::new(false);

fn file_sink() -> &'static Mutex<Option<BufWriter<File>>> {
    static SINK: OnceLock<Mutex<Option<BufWriter<File>>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

/// Arm the JSONL file sink (the `--trace FILE` flag). Subsequent spans
/// and events append to `path`; call [`flush_trace`] before exit.
pub fn trace_to_file(path: &str) -> Result<()> {
    let f = File::create(path).with_context(|| format!("creating trace file {path}"))?;
    *file_sink().lock().unwrap() = Some(BufWriter::new(f));
    FILE_ON.store(true, Ordering::Release);
    Ok(())
}

/// Whether the `--trace` file sink is armed.
pub fn trace_file_enabled() -> bool {
    FILE_ON.load(Ordering::Acquire)
}

/// Flush buffered trace lines to the `--trace` file, if armed.
pub fn flush_trace() {
    if FILE_ON.load(Ordering::Acquire) {
        if let Some(w) = file_sink().lock().unwrap().as_mut() {
            let _ = w.flush();
        }
    }
}

fn emit(line: String, also_stderr: bool) {
    if also_stderr {
        eprintln!("{line}");
    }
    if FILE_ON.load(Ordering::Acquire) {
        if let Some(w) = file_sink().lock().unwrap().as_mut() {
            let _ = writeln!(w, "{line}");
        }
    }
    let mut ring = ring().lock().unwrap();
    if ring.len() == RING_CAP {
        ring.pop_front();
    }
    ring.push_back(line);
}

/// Recent events as a JSON document for `GET /debug/trace`:
/// `{"count": N, "events": [...]}` (oldest first, capped at 256).
pub fn recent_events_json() -> String {
    let ring = ring().lock().unwrap();
    let mut out = String::from("{\"count\": ");
    out.push_str(&ring.len().to_string());
    out.push_str(", \"events\": [");
    for (i, e) in ring.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(e);
    }
    out.push_str("]}");
    out
}

static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

fn epoch_ms() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64() * 1e3)
        .unwrap_or(0.0)
}

fn render_kvs(out: &mut String, kvs: &[(&'static str, Kv)]) {
    for (k, v) in kvs {
        out.push_str(", ");
        out.push_str(&json_escape(k));
        out.push_str(": ");
        out.push_str(&v.render());
    }
}

/// Live span. Dropping it emits one JSONL record carrying the start
/// timestamp, duration, parent linkage, and accumulated key-values.
pub struct SpanGuard {
    id: u64,
    parent: Option<u64>,
    name: &'static str,
    t0: Instant,
    start_ms: f64,
    kvs: Vec<(&'static str, Kv)>,
}

impl SpanGuard {
    /// Attach a key-value pair (builder style).
    pub fn kv(mut self, key: &'static str, value: impl Into<Kv>) -> Self {
        self.kvs.push((key, value.into()));
        self
    }

    /// Attach a key-value pair in place (for values only known late,
    /// e.g. an nnz computed inside the span).
    pub fn add_kv(&mut self, key: &'static str, value: impl Into<Kv>) {
        self.kvs.push((key, value.into()));
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        SPAN_STACK.with(|s| {
            let mut s = s.borrow_mut();
            if s.last() == Some(&self.id) {
                s.pop();
            } else {
                s.retain(|&id| id != self.id);
            }
        });
        let mut line = format!(
            "{{\"span\": {}, \"id\": {}, \"parent\": {}, \"ts_ms\": {:.3}, \"dur_ms\": {:.6}",
            json_escape(self.name),
            self.id,
            self.parent
                .map(|p| p.to_string())
                .unwrap_or_else(|| "null".to_string()),
            self.start_ms,
            self.t0.elapsed().as_secs_f64() * 1e3,
        );
        render_kvs(&mut line, &self.kvs);
        line.push('}');
        emit(line, false);
    }
}

/// Open a span. The record is emitted when the guard drops; nest spans
/// freely — the per-thread stack links children to parents.
pub fn span(name: &'static str) -> SpanGuard {
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let parent = SPAN_STACK.with(|s| {
        let mut s = s.borrow_mut();
        let parent = s.last().copied();
        s.push(id);
        parent
    });
    SpanGuard {
        id,
        parent,
        name,
        t0: Instant::now(),
        start_ms: epoch_ms(),
        kvs: Vec::new(),
    }
}

/// Open a span with an initial key-value list (`obs::span_with("x",
/// kv!{rows: n})`).
pub fn span_with(name: &'static str, kvs: Vec<(&'static str, Kv)>) -> SpanGuard {
    let mut g = span(name);
    g.kvs = kvs;
    g
}

fn render_event(name: &str, kvs: &[(&'static str, Kv)]) -> String {
    let parent = SPAN_STACK.with(|s| s.borrow().last().copied());
    let mut line = format!(
        "{{\"event\": {}, \"parent\": {}, \"ts_ms\": {:.3}",
        json_escape(name),
        parent
            .map(|p| p.to_string())
            .unwrap_or_else(|| "null".to_string()),
        epoch_ms(),
    );
    render_kvs(&mut line, kvs);
    line.push('}');
    line
}

/// Emit a point event (no duration) to the ring and the file sink.
pub fn event(name: &str, kvs: Vec<(&'static str, Kv)>) {
    emit(render_event(name, &kvs), false);
}

/// Emit a point event that is also printed to stderr as one JSONL
/// line — the structured replacement for ad-hoc `eprintln!`
/// diagnostics (SIGHUP reload outcomes, slow queries).
pub fn event_logged(name: &str, kvs: Vec<(&'static str, Kv)>) {
    emit(render_event(name, &kvs), true);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_emit_json() {
        {
            let _outer = span("obs.test.outer").kv("rows", 42u64);
            let _inner = span("obs.test.inner").kv("label", "x\"y");
        }
        event("obs.test.point", vec![("n", Kv::from(3u64))]);
        let doc = recent_events_json();
        // The ring is process-global, so only assert our own records.
        assert!(doc.contains("\"span\": \"obs.test.inner\""));
        assert!(doc.contains("\"span\": \"obs.test.outer\""));
        assert!(doc.contains("\"label\": \"x\\\"y\""));
        assert!(doc.contains("\"event\": \"obs.test.point\""));
        // Inner span closed (and emitted) before outer: it must carry a
        // non-null parent while the outer span's parent is null.
        let inner_at = doc.find("\"span\": \"obs.test.inner\"").unwrap();
        let inner_rec = &doc[inner_at..doc[inner_at..].find('}').unwrap() + inner_at];
        assert!(!inner_rec.contains("\"parent\": null"));
    }

    #[test]
    fn kv_macro_builds_typed_pairs() {
        let kvs = crate::kv! { rows: 7usize, ratio: 0.5f64, sink: "csr" };
        assert_eq!(kvs.len(), 3);
        assert_eq!(kvs[0].0, "rows");
        assert_eq!(kvs[0].1.render(), "7");
        assert_eq!(kvs[1].1.render(), "0.5");
        assert_eq!(kvs[2].1.render(), "\"csr\"");
    }

    #[test]
    fn file_sink_writes_jsonl() {
        let dir = std::env::temp_dir().join(format!("obs-trace-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        trace_to_file(path.to_str().unwrap()).unwrap();
        event("obs.test.file", vec![("ok", Kv::from(1u64))]);
        flush_trace();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().any(|l| l.contains("obs.test.file")));
        // Every line the sink wrote must be a JSON object.
        for line in text.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
