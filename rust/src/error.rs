//! Minimal error handling for the zero-dependency build.
//!
//! The offline vendor set has no `anyhow`, so this module provides the
//! small slice of it the crate uses: a string-backed [`Error`], the
//! [`anyhow!`]/[`bail!`] macros, and a [`Context`] extension trait for
//! annotating fallible calls. Like `anyhow::Error`, [`Error`] does not
//! implement `std::error::Error` itself so that the blanket
//! `From<E: std::error::Error>` conversion (what makes `?` work on
//! `io::Error` etc.) cannot overlap the reflexive `From<Error>`.

use std::fmt;

/// A boxed-string error with an optional chain of context messages.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into(), context: Vec::new() }
    }

    fn push_context(mut self, ctx: String) -> Error {
        self.context.push(ctx);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first, root cause last (anyhow's `{:#}`
        // ordering, used unconditionally — the CLI prints `{e:#}`).
        for ctx in self.context.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::error::Error::new(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Attach context to a fallible value (the `anyhow::Context` subset the
/// crate uses).
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::new(e.to_string()).push_context(ctx.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::new(e.to_string()).push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::new(ctx.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_chains_context_outermost_first() {
        let base: Result<()> = Err(Error::new("root cause"));
        let e = base.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: root cause");
        assert_eq!(format!("{e:#}"), "reading manifest: root cause");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope: {}", "reason")
        }
        assert_eq!(f().unwrap_err().to_string(), "nope: reason");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
