//! `fk-lint`: in-repo static analysis for the crate's real invariants.
//!
//! The compiler cannot see the contracts this crate actually rests
//! on: bitwise parallel == serial determinism in the kernels, a serve
//! plane that degrades (never dies) on untrusted input, `unsafe`
//! confined to an allowlist and justified, Prometheus-clean metric
//! names, and a zero-dependency manifest. This module is the analyzer
//! behind the `fk-lint` binary and the `tests/lint_clean.rs` gate that
//! keep those contracts machine-checked on every push.
//!
//! * [`scan`] — the token-level source scanner (comment/string/char
//!   stripping, `#[cfg(test)]` region tracking, suppression parsing).
//! * [`rules`] — the five rule families, suppression accounting, and
//!   the [`Report`] type.
//! * [`lint_dir`] / [`lint_sources`] — entry points for the binary,
//!   the integration test, and the fixture self-tests below.
//!
//! The analyzer is std-only and parses nothing: every invariant it
//! checks is visible at the lexical layer, which keeps it fast (one
//! pass per file) and keeps the crate zero-dep — rule 5 applies to
//! the linter too.

pub mod rules;
pub mod scan;

pub use rules::{Config, Finding, Report, MAX_SUPPRESSIONS, RULE_IDS, UNSAFE_ALLOWLIST};
pub use scan::{scan_source, SourceFile};

use crate::error::{Context, Result};
use std::path::Path;

/// Scan every `.rs` file under `src_root` (sorted, recursive) into
/// stripped [`SourceFile`]s with root-relative `/`-separated paths.
pub fn scan_dir(src_root: &Path) -> Result<Vec<SourceFile>> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut out = Vec::with_capacity(files.len());
    for path in files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        out.push(scan_source(&rel, &text));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("walking {}", dir.display()))?;
    for entry in entries {
        let entry = entry.with_context(|| format!("walking {}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint a source tree on disk. The `zero-dep` rule reads
/// `<src_root>/../Cargo.toml` when present (absent manifests pass —
/// fixture trees don't carry one).
pub fn lint_dir(src_root: &Path, cfg: &Config) -> Result<Report> {
    let sources = scan_dir(src_root)?;
    let manifest = src_root.parent().map(|p| p.join("Cargo.toml"));
    let toml = match manifest {
        Some(p) if p.is_file() => {
            Some(std::fs::read_to_string(&p).with_context(|| format!("reading {}", p.display()))?)
        }
        _ => None,
    };
    Ok(rules::run(&sources, toml.as_deref(), cfg))
}

/// Lint in-memory sources — the fixture-test entry point. Each item
/// is `(relative_path, source_text)`.
pub fn lint_sources(files: &[(&str, &str)], cargo_toml: Option<&str>, cfg: &Config) -> Report {
    let sources: Vec<SourceFile> =
        files.iter().map(|(rel, text)| scan_source(rel, text)).collect();
    rules::run(&sources, cargo_toml, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(rel: &str, text: &str) -> Report {
        lint_sources(&[(rel, text)], None, &Config::all())
    }

    fn rules_of(report: &Report) -> Vec<&'static str> {
        report.findings.iter().map(|f| f.rule).collect()
    }

    // ---- scanner ----

    #[test]
    fn scanner_strips_comments_strings_and_chars() {
        let src = "let x = \"unwrap() inside\"; // .unwrap() in comment\nlet c = '{'; /* panic! */\n";
        let f = scan_source("serve/x.rs", src);
        assert!(scan::find_token(&f.lines[0].code, ".unwrap()", 0).is_none());
        assert!(f.lines[0].comment.contains(".unwrap()"));
        assert_eq!(f.lines[0].strings, vec!["unwrap() inside".to_string()]);
        // The '{' char literal must not corrupt brace depth or code.
        assert!(!f.lines[1].code.contains('{'));
    }

    #[test]
    fn scanner_counts_lines_through_string_continuations() {
        // A `\`-newline continuation must still advance the line
        // counter (a historic off-by-N source in serve/mod.rs).
        let src = "let s = \"a \\\n   b\";\nlet t = s.unwrap();\n";
        let f = scan_source("serve/x.rs", src);
        assert_eq!(f.lines[0].strings, vec!["a b".to_string()]);
        let r = lint_one("serve/x.rs", src);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn scanner_handles_raw_strings_and_escaped_quotes() {
        let src = "let a = r#\"x.unwrap() \"quoted\"\"#;\nlet b = \"\\\"y.unwrap()\\\"\";\nlet c = '\\'';\n";
        let f = scan_source("serve/x.rs", src);
        for line in &f.lines {
            assert!(scan::find_token(&line.code, ".unwrap()", 0).is_none());
        }
        assert!(f.lines[0].strings[0].contains("x.unwrap()"));
    }

    #[test]
    fn scanner_tracks_test_regions_by_brace_depth() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn live2() { z.unwrap(); }\n";
        let f = scan_source("serve/x.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test);
        let r = lint_one("serve/x.rs", src);
        assert_eq!(r.findings.iter().map(|f| f.line).collect::<Vec<_>>(), vec![1, 6]);
    }

    // ---- rule 1: no-panic-in-serve ----

    #[test]
    fn no_panic_fires_on_each_forbidden_call() {
        for snippet in [
            "fn f() { x.unwrap(); }",
            "fn f() { x.expect(\"boom\"); }",
            "fn f() { panic!(\"boom\"); }",
            "fn f() { unreachable!() }",
            "fn f() { todo!() }",
        ] {
            let r = lint_one("serve/x.rs", snippet);
            assert_eq!(rules_of(&r), vec!["no-panic-in-serve"], "snippet: {snippet}");
        }
    }

    #[test]
    fn no_panic_fires_on_literal_indexing_only() {
        let r = lint_one("model/x.rs", "fn f(b: &[u8]) { let x = b[12]; }");
        assert_eq!(rules_of(&r), vec!["no-panic-in-serve"]);
        let r = lint_one("model/x.rs", "fn f(b: &[u8]) { let x = &b[20..28]; }");
        assert_eq!(rules_of(&r), vec!["no-panic-in-serve"]);
        // Computed subscripts, attributes, and array types are not
        // flagged.
        for ok in [
            "fn f(b: &[u8], i: usize) { let x = b[i]; }",
            "fn f(b: &[u8], at: usize) { let x = &b[at..at + 8]; }",
            "#[derive(Clone)]\nstruct S;",
            "fn f() -> [u8; 4] { [0u8; 4] }",
            "fn f(d: &[usize]) { if let [a, b] = d[..] {} }",
        ] {
            let r = lint_one("model/x.rs", ok);
            assert!(r.clean(), "should not fire on: {ok}\n{:?}", rules_of(&r));
        }
    }

    #[test]
    fn no_panic_scope_is_serve_model_runtime_only() {
        assert!(lint_one("sparse/x.rs", "fn f() { x.unwrap(); }").clean());
        assert!(lint_one("main.rs", "fn f() { x.unwrap(); }").clean());
        assert!(!lint_one("runtime/x.rs", "fn f() { x.unwrap(); }").clean());
        // unwrap_or_else is not unwrap.
        assert!(lint_one("serve/x.rs", "fn f() { x.unwrap_or_else(|| 0); }").clean());
    }

    // ---- rule 2: safety-comment ----

    #[test]
    fn safety_comment_requires_justification_within_lookback() {
        let bad = "fn f() { unsafe { work() } }";
        let r = lint_one("sparse/buf.rs", bad);
        assert_eq!(rules_of(&r), vec!["safety-comment"]);
        let good = "// SAFETY: the caller upholds the contract.\nfn f() { unsafe { work() } }";
        assert!(lint_one("sparse/buf.rs", good).clean());
        // `# Safety` doc sections on unsafe fns also count.
        let doc = "/// # Safety\n/// Caller keeps `i < len`.\npub unsafe fn g() {}";
        assert!(lint_one("sparse/buf.rs", doc).clean());
    }

    #[test]
    fn safety_comment_confines_unsafe_to_the_allowlist() {
        let src = "// SAFETY: justified but misplaced.\nfn f() { unsafe { work() } }";
        let r = lint_one("swlc/mod.rs", src);
        assert_eq!(rules_of(&r), vec!["safety-comment"]);
        assert!(r.findings[0].message.contains("allowlist"));
    }

    #[test]
    fn safety_comment_ignores_the_deny_attribute() {
        // `unsafe_op_in_unsafe_fn` is an ident containing "unsafe",
        // not the keyword.
        assert!(lint_one("lib.rs", "#![deny(unsafe_op_in_unsafe_fn)]\n").clean());
    }

    // ---- rule 3: determinism ----

    #[test]
    fn determinism_fires_in_kernel_modules_only() {
        for tok in ["HashMap", "HashSet", "Instant::now", "SystemTime::now", "ThreadId"] {
            let src = format!("fn f() {{ let x = std::it::{tok}(); }}");
            let r = lint_one("sparse/x.rs", &src);
            assert_eq!(rules_of(&r), vec!["determinism"], "token: {tok}");
            assert!(lint_one("obs/x.rs", &src).clean(), "obs may use {tok}");
        }
        // Tests inside kernel modules may use hash collections.
        let test_src = "#[cfg(test)]\nmod tests {\n    fn t() { let s: std::collections::HashSet<u32> = x; }\n}\n";
        assert!(lint_one("spectral/knn.rs", test_src).clean());
    }

    // ---- rule 4: metric-hygiene ----

    #[test]
    fn metric_hygiene_checks_grammar_prefix_and_suffix() {
        let bad_grammar = "fn f() { crate::metric!(counter \"fk bad name_total\", \"h\").inc(); }";
        let r = lint_one("serve/x.rs", bad_grammar);
        assert_eq!(rules_of(&r), vec!["metric-hygiene"]);
        let bad_prefix = "fn f() { crate::metric!(counter \"queue_total\", \"h\").inc(); }";
        assert_eq!(rules_of(&lint_one("serve/x.rs", bad_prefix)), vec!["metric-hygiene"]);
        let counter_no_total = "fn f() { crate::metric!(counter \"fk_queue\", \"h\").inc(); }";
        assert_eq!(rules_of(&lint_one("serve/x.rs", counter_no_total)), vec!["metric-hygiene"]);
        let gauge_with_total = "fn f() { crate::metric!(gauge \"fk_depth_total\", \"h\").set(1.0); }";
        assert_eq!(rules_of(&lint_one("serve/x.rs", gauge_with_total)), vec!["metric-hygiene"]);
        let good = "fn f() { crate::metric!(counter \"fk_jobs_total\", \"h\").inc(); }";
        assert!(lint_one("serve/x.rs", good).clean());
    }

    #[test]
    fn metric_hygiene_handles_multiline_calls_and_direct_fns() {
        let multiline = "fn f() {\n    crate::metric!(\n        counter \"fk_hits_total\",\n        \"Cache hits.\"\n    )\n    .inc();\n}";
        assert!(lint_one("serve/x.rs", multiline).clean());
        let direct = "fn f() { obs::histogram_with(\"fk_lat_seconds\", \"h\", &[], B).observe(1.0); }";
        assert!(lint_one("serve/x.rs", direct).clean());
        let direct_bad = "fn f() { obs::gauge_with(\"fk_lat_total\", \"h\", &[]).set(1.0); }";
        assert_eq!(rules_of(&lint_one("serve/x.rs", direct_bad)), vec!["metric-hygiene"]);
    }

    #[test]
    fn metric_hygiene_enforces_one_type_and_help_per_name() {
        let two_kinds = "fn f() { crate::metric!(counter \"fk_x_total\", \"h\").inc(); }\nfn g() { crate::metric!(gauge \"fk_x_total\", \"h\").set(1.0); }";
        let r = lint_one("serve/x.rs", two_kinds);
        assert!(rules_of(&r).contains(&"metric-hygiene"));
        // Same name + kind + help across sites is the per-label-set
        // registration pattern and stays legal.
        let dup_ok = "fn f() { crate::metric!(counter \"fk_x_total\", \"Same.\").inc(); }\nfn g() { crate::metric!(counter \"fk_x_total\", \"Same.\").inc(); }";
        assert!(lint_one("serve/x.rs", dup_ok).clean());
        let dup_help = "fn f() { crate::metric!(counter \"fk_x_total\", \"One.\").inc(); }\nfn g() { crate::metric!(counter \"fk_x_total\", \"Two.\").inc(); }";
        assert!(rules_of(&lint_one("serve/x.rs", dup_help)).contains(&"metric-hygiene"));
    }

    #[test]
    fn metric_hygiene_rejects_non_literal_names_outside_obs() {
        let src = "fn f(name: &str) { obs::counter_with(name, \"h\", &[]).inc(); }";
        assert_eq!(rules_of(&lint_one("serve/x.rs", src)), vec!["metric-hygiene"]);
        assert!(lint_one("obs/mod.rs", src).clean());
    }

    #[test]
    fn metric_hygiene_skips_tests_and_histogram_collisions_fire() {
        let test_src = "#[cfg(test)]\nmod tests {\n    fn t() { crate::metric!(counter \"obs_test_x\", \"h\").inc(); }\n}\n";
        assert!(lint_one("obs/mod.rs", test_src).clean());
        let clash = "fn f() { crate::metric!(histogram \"fk_lat_seconds\", \"h\", B).observe(1.0); }\nfn g() { crate::metric!(gauge \"fk_lat_seconds_count\", \"h\").set(1.0); }";
        assert!(rules_of(&lint_one("serve/x.rs", clash)).contains(&"metric-hygiene"));
    }

    // ---- rule 5: zero-dep ----

    #[test]
    fn zero_dep_allows_only_feature_gated_xla() {
        let clean = "[package]\nname = \"forest_kernels\"\n\n[features]\nxla = []\n";
        assert!(lint_sources(&[], Some(clean), &Config::all()).clean());
        let with_dep = "[package]\nname = \"x\"\n\n[dependencies]\nserde = \"1\"\n";
        let r = lint_sources(&[], Some(with_dep), &Config::all());
        assert_eq!(rules_of(&r), vec!["zero-dep"]);
        assert_eq!(r.findings[0].file, "Cargo.toml");
        let xla_ok = "[dependencies]\nxla = { path = \"vendor/xla\", optional = true }\n";
        assert!(lint_sources(&[], Some(xla_ok), &Config::all()).clean());
        let dotted = "[dependencies.rand]\nversion = \"0.8\"\n";
        assert_eq!(rules_of(&lint_sources(&[], Some(dotted), &Config::all())), vec!["zero-dep"]);
        let dev = "[dev-dependencies]\nproptest = \"1\"\n";
        assert_eq!(rules_of(&lint_sources(&[], Some(dev), &Config::all())), vec!["zero-dep"]);
    }

    // ---- suppressions ----

    #[test]
    fn suppression_covers_same_line_and_next_line() {
        let trailing = "fn f() { x.unwrap(); } // fk-lint: allow(no-panic-in-serve) -- test fixture reason\n";
        assert!(lint_one("serve/x.rs", trailing).clean());
        let standalone = "// fk-lint: allow(no-panic-in-serve) -- test fixture reason\nfn f() { x.unwrap(); }\n";
        assert!(lint_one("serve/x.rs", standalone).clean());
        // Two lines down is out of range.
        let far = "// fk-lint: allow(no-panic-in-serve) -- reason\nfn f() {\n    x.unwrap();\n}\n";
        let r = lint_one("serve/x.rs", far);
        assert!(rules_of(&r).contains(&"no-panic-in-serve"));
    }

    #[test]
    fn suppression_requires_reason_and_known_rule() {
        let no_reason = "fn f() { x.unwrap(); } // fk-lint: allow(no-panic-in-serve)\n";
        let r = lint_one("serve/x.rs", no_reason);
        assert!(rules_of(&r).contains(&"suppression"));
        let unknown = "// fk-lint: allow(no-such-rule) -- because\nfn f() {}\n";
        let r = lint_one("serve/x.rs", unknown);
        assert!(rules_of(&r).contains(&"suppression"));
    }

    #[test]
    fn unused_suppressions_are_findings() {
        let src = "// fk-lint: allow(no-panic-in-serve) -- nothing here needs it\nfn f() {}\n";
        let r = lint_one("serve/x.rs", src);
        assert_eq!(rules_of(&r), vec!["suppression"]);
        assert!(r.findings[0].message.contains("unused"));
    }

    #[test]
    fn suppression_budget_is_capped() {
        let mut src = String::new();
        for _ in 0..(MAX_SUPPRESSIONS + 1) {
            src.push_str("fn f() { x.unwrap(); } // fk-lint: allow(no-panic-in-serve) -- r\n");
        }
        let r = lint_one("serve/x.rs", &src);
        assert!(r.findings.iter().any(|f| f.message.contains("budget exceeded")));
        assert_eq!(r.suppressions_total, MAX_SUPPRESSIONS + 1);
    }

    #[test]
    fn rule_selection_via_config() {
        let cfg = Config::from_list("determinism, zero-dep").unwrap();
        let src = "fn f() { x.unwrap(); }";
        let r = lint_sources(&[("serve/x.rs", src)], None, &cfg);
        assert!(r.clean(), "no-panic rule was not enabled");
        assert!(Config::from_list("no-such-rule").is_err());
        assert!(Config::from_list("").is_err());
    }
}
