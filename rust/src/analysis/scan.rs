//! Token-level Rust source scanner for the `fk-lint` rules.
//!
//! This is deliberately *not* a parser: the invariants the rules check
//! (forbidden call tokens, `unsafe` confinement, metric name literals)
//! are all visible at the lexical layer, so a line-oriented scanner
//! with exact comment/string/char-literal stripping is enough — and it
//! keeps the analyzer zero-dependency and fast (one pass per file).
//!
//! [`scan_source`] splits a file into [`Line`]s, each carrying:
//!
//! * `code` — the source with comments removed, string literals
//!   replaced by [`STR_MARK`] sentinels, and char literals replaced by
//!   [`CHAR_MARK`] (so rules never match tokens inside literals, and
//!   braces inside `'{'` or `"{}"` never corrupt depth tracking);
//! * `comment` — the concatenated comment text of the line (where
//!   `// SAFETY:` justifications and `// fk-lint: allow(...)`
//!   suppressions live);
//! * `strings` — the contents of string literals *started* on the
//!   line, in order, so a rule that hits a `STR_MARK` can recover the
//!   literal (the metric-hygiene rule resolves names this way);
//! * `in_test` — whether the line sits inside a `#[cfg(test)]` item,
//!   tracked by brace depth (test code is exempt from the panic and
//!   determinism rules — panicking asserts are what tests are for).
//!
//! Raw strings (`r"…"`, `r#"…"#`), byte strings, byte chars, nested
//! block comments, escaped quotes, and `\`-newline string
//! continuations are all handled; lifetimes (`'a`) are distinguished
//! from char literals by lookahead.

/// Sentinel standing in for a string literal in [`Line::code`].
pub const STR_MARK: char = '\u{1}';
/// Sentinel standing in for a char / byte literal in [`Line::code`].
pub const CHAR_MARK: char = '\u{2}';

/// How many preceding lines a `// SAFETY:` comment may sit above the
/// `unsafe` it justifies (multi-line justifications are common).
pub const SAFETY_LOOKBACK: usize = 8;

/// One scanned source line. See the module docs for field semantics.
#[derive(Default)]
pub struct Line {
    pub code: String,
    pub comment: String,
    pub strings: Vec<String>,
    pub in_test: bool,
}

/// A `// fk-lint: allow(rule-a, rule-b) -- reason` annotation. It
/// covers findings on its own line (trailing form) and on the next
/// line (standalone form).
pub struct Suppression {
    /// 1-based line the annotation sits on.
    pub line: usize,
    pub rules: Vec<String>,
    pub reason: String,
    /// Set when the annotation could not be parsed (no `allow(...)`
    /// list, or no `-- reason`); the rules engine reports these.
    pub malformed: Option<String>,
}

/// One scanned file: stripped lines plus its suppression annotations.
pub struct SourceFile {
    /// Path relative to the scanned source root, `/`-separated.
    pub rel: String,
    pub lines: Vec<Line>,
    pub suppressions: Vec<Suppression>,
}

impl SourceFile {
    /// Whether any comment within [`SAFETY_LOOKBACK`] lines above (or
    /// on) 0-based line `idx` carries a safety justification.
    pub fn has_safety_comment(&self, idx: usize) -> bool {
        let lo = idx.saturating_sub(SAFETY_LOOKBACK);
        self.lines
            .get(lo..=idx)
            .unwrap_or(&[])
            .iter()
            .any(|l| l.comment.contains("SAFETY:") || l.comment.contains("# Safety"))
    }
}

/// Is `c` part of a Rust identifier (for word-boundary checks)?
pub fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Find `needle` in `code` as a whole word. A word-boundary check
/// applies on each side only where the needle's own edge character is
/// an identifier character — so `unsafe` won't match inside
/// `unsafe_op_in_unsafe_fn`, while `metric!(` and `.expect(` match
/// regardless of what follows the paren. Returns the byte offset of
/// the first such match at or after `from`.
pub fn find_token(code: &str, needle: &str, from: usize) -> Option<usize> {
    let needs_before = needle.chars().next().is_some_and(is_ident_char);
    let needs_after = needle.chars().next_back().is_some_and(is_ident_char);
    let mut at = from;
    while let Some(rel) = code.get(at..).and_then(|s| s.find(needle)) {
        let start = at + rel;
        let end = start + needle.len();
        let before_ok = !needs_before
            || code[..start].chars().next_back().is_none_or(|c| !is_ident_char(c));
        let after_ok =
            !needs_after || code[end..].chars().next().is_none_or(|c| !is_ident_char(c));
        if before_ok && after_ok {
            return Some(start);
        }
        at = start + needle.len().max(1);
    }
    None
}

fn parse_suppression(comment: &str, line: usize) -> Option<Suppression> {
    // The annotation must START the comment (`// fk-lint: ...`) —
    // prose that merely *mentions* the syntax (doc comments, the
    // linter's own sources) is not an annotation.
    let rest = comment.trim_start().strip_prefix("fk-lint:")?.trim_start();
    let malformed = |why: &str| Suppression {
        line,
        rules: Vec::new(),
        reason: String::new(),
        malformed: Some(why.to_string()),
    };
    let Some(body) = rest.strip_prefix("allow(") else {
        return Some(malformed("expected `allow(rule, ...)` after `fk-lint:`"));
    };
    let Some(close) = body.find(')') else {
        return Some(malformed("unterminated `allow(` list"));
    };
    let rules: Vec<String> = body[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        return Some(malformed("empty `allow()` list"));
    }
    let Some(reason) = body[close + 1..].split("--").nth(1).map(str::trim) else {
        return Some(malformed("missing `-- reason` justification"));
    };
    if reason.is_empty() {
        return Some(malformed("empty `-- reason` justification"));
    }
    Some(Suppression { line, rules, reason: reason.to_string(), malformed: None })
}

/// Lexing mode of the scanner's single pass.
enum Mode {
    Code,
    LineComment,
    /// Nesting depth of `/* */`.
    BlockComment(u32),
    /// `in_continuation`: skipping indentation after a `\`-newline.
    Str { strip_ws: bool },
    RawStr { hashes: u32 },
}

/// Scan one file into stripped lines. `rel` is kept verbatim as the
/// reporting path.
pub fn scan_source(rel: &str, text: &str) -> SourceFile {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut lines: Vec<Line> = vec![Line::default()];
    let mut suppressions: Vec<Suppression> = Vec::new();
    let mut mode = Mode::Code;
    let mut depth: i64 = 0;
    // #[cfg(test)] tracking: `pending` is set when the attribute has
    // been seen and its item's `{` has not; `close_depth` is the depth
    // the region's closing `}` returns below.
    let mut pending_test = false;
    let mut test_close_depth: Option<i64> = None;
    // Index (into `lines`) of the line the current string started on.
    let mut str_start_line = 0usize;
    let mut cur_str = String::new();
    let mut i = 0usize;

    macro_rules! cur {
        () => {
            lines.last_mut().expect("lines is never empty")
        };
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Code;
            }
            // Strings and block comments continue across the newline;
            // the raw-string / string content keeps its newline so
            // literals round-trip — except inside a `\`-newline
            // continuation, where Rust drops the newline itself.
            match mode {
                Mode::Str { strip_ws: false } | Mode::RawStr { .. } => cur_str.push('\n'),
                _ => {}
            }
            let in_test = pending_test || test_close_depth.is_some();
            let line_no = lines.len();
            let line = cur!();
            line.in_test = line.in_test || in_test;
            if let Some(s) = parse_suppression(&line.comment, line_no) {
                suppressions.push(s);
            }
            lines.push(Line::default());
            i += 1;
            continue;
        }
        match mode {
            Mode::LineComment => {
                cur!().comment.push(c);
                i += 1;
            }
            Mode::BlockComment(nest) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    mode = if nest == 1 { Mode::Code } else { Mode::BlockComment(nest - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::BlockComment(nest + 1);
                    i += 2;
                } else {
                    cur!().comment.push(c);
                    i += 1;
                }
            }
            Mode::Str { strip_ws } => {
                if strip_ws && (c == ' ' || c == '\t') {
                    i += 1;
                } else if c == '\\' {
                    match chars.get(i + 1) {
                        // `\`-newline continuation: the newline is left
                        // for the top-of-loop handler (so line counting
                        // stays in one place); leading whitespace of
                        // the next line is skipped per Rust semantics.
                        Some('\n') => {
                            mode = Mode::Str { strip_ws: true };
                            i += 1;
                        }
                        Some('n') => {
                            cur_str.push('\n');
                            mode = Mode::Str { strip_ws: false };
                            i += 2;
                        }
                        Some(&e) => {
                            // Other escapes keep their raw spelling —
                            // the rules only substring-match contents.
                            cur_str.push(e);
                            mode = Mode::Str { strip_ws: false };
                            i += 2;
                        }
                        None => i += 1,
                    }
                } else if c == '"' {
                    let done = std::mem::take(&mut cur_str);
                    lines
                        .get_mut(str_start_line)
                        .expect("string start line exists")
                        .strings
                        .push(done);
                    mode = Mode::Code;
                    i += 1;
                } else {
                    cur_str.push(c);
                    mode = Mode::Str { strip_ws: false };
                    i += 1;
                }
            }
            Mode::RawStr { hashes } => {
                let closes = c == '"'
                    && (1..=hashes as usize)
                        .all(|k| chars.get(i + k) == Some(&'#'));
                if closes {
                    let done = std::mem::take(&mut cur_str);
                    lines
                        .get_mut(str_start_line)
                        .expect("string start line exists")
                        .strings
                        .push(done);
                    mode = Mode::Code;
                    i += 1 + hashes as usize;
                } else {
                    cur_str.push(c);
                    i += 1;
                }
            }
            Mode::Code => {
                let prev_ident =
                    cur!().code.chars().next_back().is_some_and(is_ident_char);
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    mode = Mode::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                    continue;
                }
                // Raw / byte string prefixes (checked before the ident
                // char lands in `code`): r"…", r#"…"#, b"…", br"…".
                if (c == 'r' || c == 'b') && !prev_ident {
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let raw = chars.get(j) == Some(&'#') || (c != 'b' && chars.get(j) == Some(&'"'));
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        if raw || hashes > 0 {
                            mode = Mode::RawStr { hashes };
                        } else {
                            // b"…" — ordinary escapes apply.
                            mode = Mode::Str { strip_ws: false };
                        }
                        str_start_line = lines.len() - 1;
                        cur_str.clear();
                        cur!().code.push(STR_MARK);
                        i = j + 1;
                        continue;
                    }
                    if c == 'b' && chars.get(i + 1) == Some(&'\'') {
                        // Byte char literal: consume like a char literal.
                        i = consume_char_literal(&chars, i + 1);
                        cur!().code.push(CHAR_MARK);
                        continue;
                    }
                    // Plain identifier starting with r/b.
                }
                if c == '"' {
                    mode = Mode::Str { strip_ws: false };
                    str_start_line = lines.len() - 1;
                    cur_str.clear();
                    cur!().code.push(STR_MARK);
                    i += 1;
                    continue;
                }
                if c == '\'' && !prev_ident {
                    // Char literal vs lifetime: a literal is `'\…'` or
                    // `'X'` (any single char then a quote); everything
                    // else (`'a`, `'static`, `'_ `) is a lifetime.
                    let is_literal = chars.get(i + 1) == Some(&'\\')
                        || (chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\''));
                    if is_literal {
                        i = consume_char_literal(&chars, i);
                        cur!().code.push(CHAR_MARK);
                        continue;
                    }
                }
                if c == '{' {
                    depth += 1;
                    if test_close_depth.is_none()
                        && (pending_test || cur!().code.contains("#[cfg(test)]"))
                    {
                        test_close_depth = Some(depth);
                        pending_test = false;
                        cur!().in_test = true;
                    }
                } else if c == '}' {
                    depth -= 1;
                    if let Some(td) = test_close_depth {
                        if depth < td {
                            // The closing line is still test code.
                            cur!().in_test = true;
                            test_close_depth = None;
                        }
                    }
                } else if c == ';' && pending_test && test_close_depth.is_none() {
                    // `#[cfg(test)] use …;` — a braceless test item
                    // ends at the semicolon.
                    cur!().in_test = true;
                    pending_test = false;
                }
                cur!().code.push(c);
                if test_close_depth.is_none() && cur!().code.ends_with("#[cfg(test)]") {
                    pending_test = true;
                    cur!().in_test = true;
                }
                i += 1;
            }
        }
    }
    // Finalize the last (unterminated) line.
    let line_no = lines.len();
    let line = cur!();
    line.in_test = line.in_test || pending_test || test_close_depth.is_some();
    if let Some(s) = parse_suppression(&line.comment, line_no) {
        suppressions.push(s);
    }
    SourceFile { rel: rel.to_string(), lines, suppressions }
}

/// Consume a char literal starting at the opening `'` at `at`; returns
/// the index just past the closing quote. Escapes (`'\''`, `'\u{1}'`)
/// are skipped pairwise, so an escaped quote never terminates early.
fn consume_char_literal(chars: &[char], at: usize) -> usize {
    let mut j = at + 1;
    while j < chars.len() {
        match chars[j] {
            '\\' => j += 2,
            '\'' => return j + 1,
            '\n' => return j, // malformed; don't eat the newline
            _ => j += 1,
        }
    }
    j
}

/// Byte spans inside `code` that index a slice/array with a *literal*
/// subscript — `buf[12]`, `head[20..28]`, `&payload[1..]`, `x[..8]` —
/// the fixed-offset decode pattern that panics on short input. A
/// subscript mentioning any identifier (`buf[at + 8..]`, `v[i]`) is
/// skipped: computed indices are usually range-checked by construction
/// and flagging them all would drown the signal.
pub fn literal_index_spans(code: &str) -> Vec<(usize, String)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut k = 0usize;
    while k < bytes.len() {
        if bytes[k] != b'[' {
            k += 1;
            continue;
        }
        // Indexing needs an expression before the bracket: an ident
        // char, a close-paren/bracket, or a `?` (`take(1)?[0]`).
        // `#[attr]`, `vec![…]`, and `[T; N]` literals all fail this.
        let before = code[..k].chars().next_back();
        let indexes =
            matches!(before, Some(c) if is_ident_char(c) || c == ')' || c == ']' || c == '?');
        // Find the matching `]` on this line (nested brackets bail).
        let mut close = None;
        let mut depth_b = 0i32;
        for (off, &b) in bytes.iter().enumerate().skip(k + 1) {
            match b {
                b'[' => depth_b += 1,
                b']' if depth_b > 0 => depth_b -= 1,
                b']' => {
                    close = Some(off);
                    break;
                }
                _ => {}
            }
        }
        let Some(close) = close else {
            k += 1;
            continue;
        };
        let inner = code[k + 1..close].trim();
        if indexes && is_literal_subscript(inner) {
            out.push((k, inner.to_string()));
        }
        k = close + 1;
    }
    out
}

/// `12`, `0x10`, `1_000`, `20..28`, `1..`, `..8`, `4..=7` — integer
/// literals and ranges of them, with at least one digit present.
fn is_literal_subscript(s: &str) -> bool {
    fn int_or_empty(p: &str) -> bool {
        p.trim().chars().all(|c| c.is_ascii_hexdigit() || c == '_' || c == 'x')
    }
    if s.is_empty() || !s.chars().any(|c| c.is_ascii_digit()) {
        return false;
    }
    match s.split_once("..") {
        Some((lo, hi)) => int_or_empty(lo) && int_or_empty(hi.strip_prefix('=').unwrap_or(hi)),
        None => int_or_empty(s),
    }
}
