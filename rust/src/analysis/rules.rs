//! The five `fk-lint` rule families, the suppression mechanism, and
//! the [`Report`] the binary and the self-tests consume.
//!
//! Every rule works on the stripped [`SourceFile`] representation from
//! [`crate::analysis::scan`]; none of them parse Rust. See
//! `rust/INVARIANTS.md` for the rationale behind each rule and the
//! suppression policy.

use super::scan::{find_token, literal_index_spans, SourceFile, STR_MARK};
use crate::error::Result;
use crate::{anyhow, bail};

/// All rule ids, in reporting order. `--rules` accepts any subset.
pub const RULE_IDS: &[&str] =
    &["no-panic-in-serve", "safety-comment", "determinism", "metric-hygiene", "zero-dep"];

/// Repo-wide ceiling on `fk-lint: allow(...)` annotations. Suppression
/// is an escape hatch, not a lifestyle; when the repo accumulates this
/// many, the lint fails until some are removed (or the invariant is
/// renegotiated in INVARIANTS.md and this cap raised there + here).
pub const MAX_SUPPRESSIONS: usize = 16;

/// Files allowed to contain the token `unsafe` at all. Everything
/// else fails `safety-comment` even with a SAFETY justification — the
/// point is confinement, not paperwork.
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "exec/mod.rs",
    "forest/mod.rs",
    "forest/tree.rs",
    "model/mmap.rs",
    "model/mod.rs",
    "sparse/buf.rs",
    "sparse/csr.rs",
    "sparse/spgemm.rs",
    "serve/mod.rs",
];

/// Request/decode paths where a panic kills a serving replica.
pub const NO_PANIC_SCOPE: &[&str] = &["serve/", "model/", "runtime/"];

/// Kernel-math modules where any nondeterminism (hash iteration
/// order, wall-clock reads, thread identity) can silently break the
/// parallel == serial bitwise contract.
pub const DETERMINISM_SCOPE: &[&str] = &["sparse/", "swlc/", "spectral/", "forest/"];

/// One violation: `file:line rule-id message`.
#[derive(Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// The outcome of a lint run.
pub struct Report {
    /// Surviving findings (suppressed ones removed), sorted by
    /// file/line/rule.
    pub findings: Vec<Finding>,
    /// Suppressions that actually hid at least one finding.
    pub suppressions_used: usize,
    /// Total `fk-lint: allow` annotations seen (used or not).
    pub suppressions_total: usize,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Which rules run. Built from `--rules a,b,c` or [`Config::all`].
pub struct Config {
    enabled: Vec<&'static str>,
}

impl Config {
    pub fn all() -> Self {
        Config { enabled: RULE_IDS.to_vec() }
    }

    /// Parse a `--rules` list; unknown ids are an error so a typo
    /// can't silently disable enforcement.
    pub fn from_list(list: &str) -> Result<Self> {
        let mut enabled = Vec::new();
        for part in list.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let id = RULE_IDS
                .iter()
                .find(|r| **r == part)
                .ok_or_else(|| anyhow!("unknown rule id {:?} (known: {})", part, RULE_IDS.join(", ")))?;
            enabled.push(*id);
        }
        if enabled.is_empty() {
            bail!("--rules list selected no rules");
        }
        Ok(Config { enabled })
    }

    pub fn enabled(&self, id: &str) -> bool {
        self.enabled.iter().any(|r| *r == id)
    }
}

fn in_scope(rel: &str, scope: &[&str]) -> bool {
    scope.iter().any(|p| rel.starts_with(p))
}

/// Run the enabled rules over pre-scanned sources. `cargo_toml` is the
/// manifest text for the `zero-dep` rule (`None` skips that rule, for
/// fixture runs that only exercise source rules).
pub fn run(sources: &[SourceFile], cargo_toml: Option<&str>, cfg: &Config) -> Report {
    let mut raw: Vec<Finding> = Vec::new();
    for src in sources {
        if cfg.enabled("no-panic-in-serve") {
            no_panic_rule(src, &mut raw);
        }
        if cfg.enabled("safety-comment") {
            safety_comment_rule(src, &mut raw);
        }
        if cfg.enabled("determinism") {
            determinism_rule(src, &mut raw);
        }
    }
    if cfg.enabled("metric-hygiene") {
        metric_hygiene_rule(sources, &mut raw);
    }
    if cfg.enabled("zero-dep") {
        if let Some(toml) = cargo_toml {
            zero_dep_rule(toml, &mut raw);
        }
    }

    // Apply suppressions: an annotation covers findings on its own
    // line (trailing form) and on the next line (standalone form).
    let mut findings: Vec<Finding> = Vec::new();
    let mut used_total = 0usize;
    let mut total = 0usize;
    // Findings that belong to no scanned source (the `zero-dep` rule
    // reports against Cargo.toml) have nowhere to hang a suppression;
    // pass them straight through.
    for f in &raw {
        if !sources.iter().any(|s| s.rel == f.file) {
            findings.push(f.clone());
        }
    }
    for src in sources {
        total += src.suppressions.len();
        let mut used = vec![false; src.suppressions.len()];
        for f in raw.iter().filter(|f| f.file == src.rel) {
            let covering = src.suppressions.iter().position(|s| {
                s.malformed.is_none()
                    && (s.line == f.line || s.line + 1 == f.line)
                    && s.rules.iter().any(|r| r == f.rule)
            });
            match covering {
                Some(i) => used[i] = true,
                None => findings.push(f.clone()),
            }
        }
        for (s, was_used) in src.suppressions.iter().zip(&used) {
            if let Some(why) = &s.malformed {
                findings.push(Finding {
                    file: src.rel.clone(),
                    line: s.line,
                    rule: "suppression",
                    message: format!("malformed fk-lint annotation: {why}"),
                });
                continue;
            }
            if let Some(bad) = s.rules.iter().find(|r| !RULE_IDS.contains(&r.as_str())) {
                findings.push(Finding {
                    file: src.rel.clone(),
                    line: s.line,
                    rule: "suppression",
                    message: format!("allow() names unknown rule {bad:?}"),
                });
                continue;
            }
            if *was_used {
                used_total += 1;
            } else if s.rules.iter().all(|r| cfg.enabled(r)) {
                // Only call an annotation dead when every rule it
                // names actually ran — a partial `--rules` run can't
                // tell whether the others would have fired.
                findings.push(Finding {
                    file: src.rel.clone(),
                    line: s.line,
                    rule: "suppression",
                    message: format!("unused allow({}) — remove it", s.rules.join(", ")),
                });
            }
        }
    }
    if total > MAX_SUPPRESSIONS {
        findings.push(Finding {
            file: sources.first().map(|s| s.rel.clone()).unwrap_or_default(),
            line: 1,
            rule: "suppression",
            message: format!(
                "suppression budget exceeded: {total} fk-lint annotations repo-wide, cap is {MAX_SUPPRESSIONS}"
            ),
        });
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Report {
        findings,
        suppressions_used: used_total,
        suppressions_total: total,
        files_scanned: sources.len(),
    }
}

/// Rule 1: `no-panic-in-serve`. A replica must degrade on bad input,
/// never die; the request/decode paths may not contain panicking
/// calls or fixed-offset slice indexing.
fn no_panic_rule(src: &SourceFile, out: &mut Vec<Finding>) {
    if !in_scope(&src.rel, NO_PANIC_SCOPE) || src.rel.starts_with("bench_support") {
        return;
    }
    const CALLS: &[(&str, &str)] = &[
        (".unwrap()", "`.unwrap()` can panic a serving replica; use `?`/`ok_or_else` or a recovering helper"),
        (".expect(", "`.expect(...)` can panic a serving replica; return a structured error instead"),
        ("panic!", "`panic!` in a request/decode path kills the replica; bail with an error"),
        ("unreachable!", "`unreachable!` in a request/decode path kills the replica on the day it is reached"),
        ("todo!", "`todo!` must not ship in a request/decode path"),
        ("unimplemented!", "`unimplemented!` must not ship in a request/decode path"),
    ];
    for (idx, line) in src.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for (tok, msg) in CALLS {
            if find_token(&line.code, tok, 0).is_some() {
                out.push(Finding {
                    file: src.rel.clone(),
                    line: idx + 1,
                    rule: "no-panic-in-serve",
                    message: (*msg).to_string(),
                });
            }
        }
        for (_, subscript) in literal_index_spans(&line.code) {
            out.push(Finding {
                file: src.rel.clone(),
                line: idx + 1,
                rule: "no-panic-in-serve",
                message: format!(
                    "literal index `[{subscript}]` panics on short input; use `get(..)`/a checked helper"
                ),
            });
        }
    }
}

/// Rule 2: `safety-comment`. `unsafe` is confined to the allowlist
/// and every occurrence carries a `// SAFETY:` justification on the
/// same line or within the lookback window above it. Test code is NOT
/// exempt — unsafe in a test needs the same contract.
fn safety_comment_rule(src: &SourceFile, out: &mut Vec<Finding>) {
    let allowed = UNSAFE_ALLOWLIST.contains(&src.rel.as_str());
    for (idx, line) in src.lines.iter().enumerate() {
        if find_token(&line.code, "unsafe", 0).is_none() {
            continue;
        }
        if !allowed {
            out.push(Finding {
                file: src.rel.clone(),
                line: idx + 1,
                rule: "safety-comment",
                message: "`unsafe` outside the module allowlist (see analysis::rules::UNSAFE_ALLOWLIST)"
                    .to_string(),
            });
        } else if !src.has_safety_comment(idx) {
            out.push(Finding {
                file: src.rel.clone(),
                line: idx + 1,
                rule: "safety-comment",
                message: "`unsafe` without a `// SAFETY:` justification within 8 lines".to_string(),
            });
        }
    }
}

/// Rule 3: `determinism`. Kernel math may not observe hash iteration
/// order, wall clocks, or thread identity — any of them can break the
/// bitwise parallel == serial contract. Timing belongs to `obs::`
/// (see `obs::stopwatch`), keyed collections to `BTreeMap`/sorted vecs.
fn determinism_rule(src: &SourceFile, out: &mut Vec<Finding>) {
    if !in_scope(&src.rel, DETERMINISM_SCOPE) {
        return;
    }
    const TOKENS: &[(&str, &str)] = &[
        ("HashMap", "HashMap iteration order is nondeterministic; use BTreeMap or a sorted Vec"),
        ("HashSet", "HashSet iteration order is nondeterministic; use BTreeSet or a sorted Vec"),
        ("Instant::now", "wall-clock reads belong to obs/bench layers; use `obs::stopwatch()`"),
        ("SystemTime::now", "wall-clock reads belong to obs/bench layers; use `obs::stopwatch()`"),
        ("thread::current", "thread identity must not influence kernel math"),
        ("ThreadId", "thread identity must not influence kernel math"),
    ];
    for (idx, line) in src.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for (tok, msg) in TOKENS {
            if find_token(&line.code, tok, 0).is_some() {
                out.push(Finding {
                    file: src.rel.clone(),
                    line: idx + 1,
                    rule: "determinism",
                    message: (*msg).to_string(),
                });
            }
        }
    }
}

/// One metric registration discovered in source.
struct MetricSite {
    file: String,
    line: usize,
    /// counter | gauge | histogram.
    kind: &'static str,
    name: String,
    help: Option<String>,
}

/// Rule 4: `metric-hygiene`. Every registration site uses a literal
/// name matching the Prometheus grammar `obs::parse_prometheus`
/// enforces on scrapes, carries the `fk_` prefix, agrees with the
/// suffix convention (counters end `_total`, nothing else does), and
/// each name has exactly one TYPE and one help string repo-wide.
/// Duplicate call sites for the same name are fine when kind + help
/// agree (per-label-set registration does this on purpose).
fn metric_hygiene_rule(sources: &[SourceFile], out: &mut Vec<Finding>) {
    // (token, kind-or-None-for-macro)
    const SITES: &[(&str, Option<&str>)] = &[
        ("metric!(", None),
        ("counter_with(", Some("counter")),
        ("counter_secs(", Some("counter")),
        ("counter(", Some("counter")),
        ("gauge_with(", Some("gauge")),
        ("gauge(", Some("gauge")),
        ("histogram_with(", Some("histogram")),
        ("histogram(", Some("histogram")),
    ];
    let mut found: Vec<MetricSite> = Vec::new();
    for src in sources {
        for (idx, line) in src.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for (tok, kind) in SITES {
                let mut at = 0usize;
                while let Some(pos) = find_token(&line.code, tok, at) {
                    at = pos + tok.len();
                    // Skip the definitions themselves (`pub fn counter_with(`).
                    if line.code[..pos].trim_end().ends_with("fn") {
                        continue;
                    }
                    collect_site(src, idx, pos + tok.len(), *kind, &mut found, out);
                }
            }
        }
    }
    // Cross-site checks.
    for site in &found {
        if !crate::obs::valid_metric_name(&site.name) {
            push_metric_finding(out, site, format!(
                "metric name {:?} fails the Prometheus grammar obs::parse_prometheus enforces",
                site.name
            ));
        } else if !site.name.starts_with("fk_") {
            push_metric_finding(out, site, format!(
                "metric name {:?} must carry the crate's `fk_` prefix", site.name
            ));
        }
        let is_total = site.name.ends_with("_total");
        if site.kind == "counter" && !is_total {
            push_metric_finding(out, site, format!(
                "counter {:?} must end in `_total` (Prometheus counter convention)", site.name
            ));
        } else if site.kind != "counter" && is_total {
            push_metric_finding(out, site, format!(
                "{} {:?} must not end in `_total` — that suffix marks counters",
                site.kind, site.name
            ));
        }
    }
    for (i, site) in found.iter().enumerate() {
        for other in &found[..i] {
            if other.name != site.name {
                continue;
            }
            if other.kind != site.kind {
                push_metric_finding(out, site, format!(
                    "metric {:?} registered as {} here but as {} at {}:{} — one TYPE per name",
                    site.name, site.kind, other.kind, other.file, other.line
                ));
            } else if site.help.is_some() && other.help.is_some() && site.help != other.help {
                push_metric_finding(out, site, format!(
                    "metric {:?} help text differs from the site at {}:{} — keep one help per name",
                    site.name, other.file, other.line
                ));
            }
        }
        // A histogram exports `<name>_bucket/_sum/_count` series; no
        // other metric may squat on those derived names.
        if site.kind == "histogram" {
            for suffix in ["_bucket", "_sum", "_count"] {
                let derived = format!("{}{}", site.name, suffix);
                if let Some(clash) = found.iter().find(|o| o.name == derived) {
                    push_metric_finding(out, site, format!(
                        "histogram {:?} derives series {:?}, which collides with the metric at {}:{}",
                        site.name, derived, clash.file, clash.line
                    ));
                }
            }
        }
    }
}

fn push_metric_finding(out: &mut Vec<Finding>, site: &MetricSite, message: String) {
    out.push(Finding {
        file: site.file.clone(),
        line: site.line,
        rule: "metric-hygiene",
        message,
    });
}

/// How many lines after a registration token the name/help literals
/// may sit (rustfmt splits the long calls across lines).
const METRIC_LOOKAHEAD: usize = 6;

/// Parse one registration site starting just past the opening paren of
/// the token found on `lines[idx]` at byte offset `after`.
fn collect_site(
    src: &SourceFile,
    idx: usize,
    after: usize,
    fn_kind: Option<&'static str>,
    found: &mut Vec<MetricSite>,
    out: &mut Vec<Finding>,
) {
    // Flatten the call's argument text across the lookahead window,
    // remembering which line each string sentinel resolves into.
    let mut text = String::new();
    let mut strings: Vec<(usize, String)> = Vec::new();
    for (j, line) in src.lines.iter().enumerate().skip(idx).take(METRIC_LOOKAHEAD) {
        let code = if j == idx { line.code.get(after..).unwrap_or("") } else { &line.code };
        // Sentinels before `after` on the first line belong to earlier
        // calls; skip that many of the line's strings.
        let skip = if j == idx {
            line.code.get(..after).unwrap_or("").matches(STR_MARK).count()
        } else {
            0
        };
        for s in line.strings.iter().skip(skip) {
            strings.push((j + 1, s.clone()));
        }
        text.push_str(code);
        text.push('\n');
    }
    let mut rest = text.trim_start();
    let kind: &'static str = match fn_kind {
        Some(k) => k,
        None => {
            // metric!(KIND "name", "help", ...): the kind is the first
            // word of the argument text.
            let word: String = rest.chars().take_while(|c| is_kind_char(*c)).collect();
            rest = rest[word.len()..].trim_start();
            match word.as_str() {
                "counter" | "counter_secs" => "counter",
                "gauge" => "gauge",
                "histogram" => "histogram",
                other => {
                    out.push(Finding {
                        file: src.rel.clone(),
                        line: idx + 1,
                        rule: "metric-hygiene",
                        message: format!("metric! with unknown kind {other:?}"),
                    });
                    return;
                }
            }
        }
    };
    // The name must be the first argument AND a string literal (its
    // sentinel leads the remaining argument text). The registry
    // plumbing in obs/ forwards `$name`/`name` parameters; anywhere
    // else a non-literal name blinds the lint, which is itself a
    // violation.
    if !rest.starts_with(STR_MARK) {
        if src.rel != "obs/mod.rs" {
            out.push(Finding {
                file: src.rel.clone(),
                line: idx + 1,
                rule: "metric-hygiene",
                message: "metric registered with a non-literal name; the lint cannot check it"
                    .to_string(),
            });
        }
        return;
    }
    let Some((name_line, name)) = strings.first().cloned() else {
        return;
    };
    let help = strings.get(1).map(|(_, h)| h.clone());
    found.push(MetricSite { file: src.rel.clone(), line: name_line, kind, name, help });
}

fn is_kind_char(c: char) -> bool {
    c.is_ascii_lowercase() || c == '_'
}

/// Rule 5: `zero-dep`. The manifest's dependency tables stay empty
/// except the feature-gated `xla` backend. Absent tables pass.
fn zero_dep_rule(cargo_toml: &str, out: &mut Vec<Finding>) {
    let mut in_dep_table = false;
    for (idx, raw_line) in cargo_toml.lines().enumerate() {
        let line = raw_line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            let section = line.trim_matches(|c| c == '[' || c == ']');
            in_dep_table = is_dep_section(section);
            // `[dependencies.foo]` declares foo directly.
            if let Some(dep) = section.strip_prefix("dependencies.") {
                if dep != "xla" {
                    out.push(zero_dep_finding(idx + 1, dep));
                }
            }
            continue;
        }
        if in_dep_table {
            let key = line.split('=').next().unwrap_or("").trim().trim_matches('"');
            if !key.is_empty() && key != "xla" {
                out.push(zero_dep_finding(idx + 1, key));
            }
        }
    }
}

fn is_dep_section(section: &str) -> bool {
    section == "dependencies"
        || section == "dev-dependencies"
        || section == "build-dependencies"
        || (section.starts_with("target.") && section.ends_with("dependencies"))
}

fn zero_dep_finding(line: usize, dep: &str) -> Finding {
    Finding {
        file: "Cargo.toml".to_string(),
        line,
        rule: "zero-dep",
        message: format!(
            "dependency {dep:?} violates the zero-dep contract (only the feature-gated `xla` is allowed)"
        ),
    }
}
